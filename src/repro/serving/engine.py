"""Batched integer serving engine over a paged KV cache.

The serving counterpart of the ASIC's control unit (§III-J): a
continuous-batching scheduler that admits requests into fixed batch
*lanes*, runs the INT8 prefill/decode datapath (int8 KV caches = the
paper's quantization applied to the cache), and retires finished
sequences — all in the fixed-shape XLA world.

Prefill (``prefill_chunk``):

  * **chunked** (default on paged, full-causal, attention+ffn archs) —
    prompts advance ``prefill_chunk`` tokens at a time through ONE
    batched launch of the fused prefill attention kernel, writing K/V
    straight into physical pages through the page table
    (``models.inttransformer.int_prefill_chunk_step`` →
    ``ops.int_paged_prefill``).  A prefill queue interleaves with decode
    steps: ``prefill_budget`` caps the prompt tokens advanced per engine
    step, so decoding sessions keep emitting a token every step while
    long prompts stream in.  Bit-exact against token streaming.
  * **streaming** — the PR 4 path: prompt tokens one at a time through
    the decode step (sliding-window / SSM / MoE / cross archs, and the
    contiguous layout).

Prefix sharing (``prefix_cache``): prompts hash into a per-engine
:class:`~repro.serving.kvcache.PrefixIndex` keyed by token prefixes —
a session whose prompt starts with a previously prefilled prefix maps
the *same physical pages* (allocator refcounts) and skips recomputing
them; the first write into a shared page copy-on-writes it, so sharers
can never corrupt each other and shared-prefix sessions produce token
streams identical to unshared ones.  Under pool pressure the allocator
reclaims cached prefix pages LRU-first.

Cache layouts (``cache_mode``):

  * ``"paged"`` (default) — K/V live in a physical page pool addressed
    through a per-lane page table (``repro.serving.kvcache``).  A
    *session* owns its page list; lanes are just decode positions, so
    cache memory is O(live tokens), pages recycle through a ref-counted
    allocator without zeroing (``valid_len`` masking makes stale
    contents unobservable), and a session can be **preempted** (pages
    kept, lane freed — mid-prefill included) and later resumed
    bit-exactly.  The page table rides into the decode and prefill
    kernels as a scalar-prefetch operand next to ``valid_len``; backends
    without the ``paged_decode`` / ``paged_prefill`` capabilities get
    exact gather/scatter lowerings (repro.ops dispatch).
  * ``"contiguous"`` — the PR 3 layout: one ``cache_len`` slab per lane.

Every decode step dispatches through the configured backend's
``int_decode_attention`` — on ``pallas_fused`` one valid_len-masked
kernel launch that skips dead cache blocks — and, with ``fold_wo``
(default), folds each attention sublayer's output-projection per-channel
requant into that launch's epilogue (``decode_wo_fold``; the chunked
prefill launch folds it too via ``prefill_wo_fold``) — bit-exact vs the
unfolded path.

Tensor parallelism (``tp``): the engine shards its attention datapath
head-wise over a 1-D device mesh (``distributed.tp_serving``) — each
device owns ``Hkv/tp`` KV heads of every physical page and the matching
query-head slice of wq/wk/wv, wo combines int32 partial o-projections
with an exact :func:`~repro.distributed.collectives.psum_int32` *before*
the requant epilogue (so it rounds once), and everything host-side
(allocator, page table, prefix index, scheduler) stays replicated
because page ids are device-agnostic.  Sharding engages only when every
backend advertises the ``tp_serving`` capability and the process has
``tp`` devices; otherwise the engine serves ``tp > 1`` through an exact
single-device gather lowering (same API, same tokens).  Token streams
are bit-exact across tp degrees: the datapath is all-integer, so the
psum is order-independent and the replicated non-attention sublayers
see identical inputs on every device.

Speculative decoding (``spec_k``): each decode step drafts up to
``spec_k`` tokens per live lane from a self-speculative proposer
(``serving.speculate`` — prompt-lookup over the lane's own prompt +
output, no draft model) and verifies all ``spec_k + 1`` positions in ONE
``int_decode_attention`` launch with the Sq = K+1 stepped mask (fused on
``pallas_fused``, exact oracle elsewhere).  Greedy acceptance commits
the longest draft prefix matching the model's own argmax stream plus
one bonus token; rejected tokens roll back as a position decrement plus
:meth:`~repro.serving.kvcache.PagedKVCache.truncate` (now-empty pages
return to the allocator; stale K/V is hidden by ``valid_len`` and
overwritten by the next step).  Token streams are bit-exact with
``spec_k = 0`` — speculation changes *when* tokens are computed, never
*which* — so it composes with every cache layout, prefill mode and tp
degree.  Greedy only: ``temperature > 0`` requests are rejected with a
typed :class:`~repro.serving.speculate.SpeculationUnsupported`.

Shapes (batch lanes, page pool, logical cache length, prefill chunk) are
fixed at engine construction, so lanes and pages recycle without
recompiling.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis import contracts
from repro.distributed import tp_serving
from repro.models import intlayers as il
from repro.models import inttransformer as it
from repro.models.common import ArchConfig
from repro.models.transformer import layer_group_spec
from repro.ops import OP_NAMES, resolve_ops
from repro.quant import plans as qplans
from repro.serving import speculate
from repro.serving.kvcache import (NULL_PAGE, CacheLayout,
                                   PagePoolExhausted, PagedKVCache,
                                   PrefixIndex, Session)


class StepInFlight(RuntimeError):
    """A lifecycle operation (``evict`` / ``preempt`` / another
    ``dispatch_step``) was attempted between :meth:`ServingEngine.
    dispatch_step` and :meth:`ServingEngine.commit_step`.  The dispatched
    launch captured snapshots of ``pos`` and the page table, but the
    *scheduler* state (slots, sessions, allocator) it will be committed
    against must not move underneath it — commit the pending step first
    (the async front end's run loop applies cancellations only between
    commit and the next dispatch for exactly this reason)."""


class EngineStalled(RuntimeError):
    """``run_until_done`` exhausted its step budget with sessions still
    queued or on lanes — a stall (pool livelock, starved prefill, a
    budget too small for the workload), not completion.  Carries the
    scheduler state a caller needs to diagnose it: ``max_steps``,
    ``queue_depth``, and per-lane ``slots`` dicts (uid / state / pos /
    prefill_pos)."""

    def __init__(self, max_steps: int, slots, queue_depth: int):
        self.max_steps = max_steps
        self.slots = slots
        self.queue_depth = queue_depth
        lanes = ", ".join(
            "lane %d: uid=%s %s pos=%s prefill_pos=%s" % (
                i, s["uid"], s["state"], s["pos"], s["prefill_pos"])
            for i, s in enumerate(slots) if s is not None) or "all idle"
        super().__init__(
            f"engine stalled: {max_steps} steps exhausted with "
            f"{queue_depth} queued session(s) and unfinished lanes "
            f"({lanes}); raise max_steps, relieve pool pressure, or "
            "evict a session")

# Process-level cache of compiled engine steps (decode and chunked
# prefill), keyed by everything the traced closure captures (cfg, plans,
# shapes, cache geometry, chunk size, the resolved backend per op).  Two
# engines with the same key share ONE executable, so (a) engine
# construction stops paying an XLA recompile and (b) identical request
# streams produce identical tokens across engine instances — separately
# compiled executables of the same program are not guaranteed to agree
# to the last integer on every input (XLA CPU compile variance), which
# shows up as cross-engine token divergence in parity tests.  Bounded
# LRU (insertion order): a process sweeping many distinct (shape, plan)
# combinations evicts the oldest executable instead of pinning one per
# combination forever.
_STEP_CACHE: Dict[tuple, Callable] = {}
_STEP_CACHE_MAX = 16


def _cached_step(key, build: Callable[[], Callable]) -> Callable:
    try:
        hash(key)
    except TypeError:
        return build()              # private: key can't be shared
    fn = _STEP_CACHE.pop(key, None)
    if fn is None:
        fn = build()
    _STEP_CACHE[key] = fn           # (re-)insert most recent
    while len(_STEP_CACHE) > _STEP_CACHE_MAX:
        _STEP_CACHE.pop(next(iter(_STEP_CACHE)))
    return fn


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class PendingStep:
    """An in-flight engine step: scheduling (admit / prefill / draft) ran
    and the decode or verify launch was **dispatched** — its ``logits``
    are an unmaterialized device array — but nothing has been sampled or
    committed.  Produced by :meth:`ServingEngine.dispatch_step`, consumed
    exactly once by :meth:`ServingEngine.commit_step`; the window between
    the two is where an async driver overlaps host work (detokenizing /
    distributing the *previous* step's tokens) with the device
    computation.  The launch itself read snapshots (``_snap_pos`` /
    ``_snap_pages``), so host bookkeeping in that window is safe as long
    as the scheduler state commit will walk — ``slots`` and the captured
    ``sessions`` — is left alone (:class:`StepInFlight` guards the
    mutating lifecycle ops)."""

    occupied: int
    kind: str                       # "idle" | "decode" | "verify"
    live: List[int] = dataclasses.field(default_factory=list)
    sessions: List[Session] = dataclasses.field(default_factory=list)
    logits: object = None           # device array, (B, V) or (B, S, V)
    n_new: Optional[np.ndarray] = None
    drafts: Optional[Dict[int, List[int]]] = None


class ServingEngine:
    def __init__(self, qparams, plans: qplans.LayerPlans, cfg: ArchConfig,
                 batch_size: int = 8, cache_len: int = 512,
                 ops=None, seed: int = 0, backend=None,
                 cache_mode: str = "paged", page_size: int = 16,
                 num_pages: Optional[int] = None, kv_dtype: str = "int8",
                 fold_wo: bool = True,
                 prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 prefix_cache: bool = True, tp: int = 1,
                 spec_k: int = 0, spec_mode: str = "ngram"):
        if backend is not None:
            warnings.warn("ServingEngine(backend=...) is deprecated; pass "
                          "ops= (an OpSet or backend name)",
                          DeprecationWarning, stacklevel=2)
            ops = backend if ops is None else ops
        if cache_mode not in ("paged", "contiguous"):
            raise ValueError("cache_mode must be 'paged' or 'contiguous',"
                             f" got {cache_mode!r}")
        if kv_dtype != "int8" and cache_mode != "paged":
            raise ValueError("kv_dtype='int4' needs cache_mode='paged' "
                             "(the packed tier stores per-page requant "
                             "shifts next to the page pools)")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 token/step, "
                             f"got {prefill_budget}")
        self.cfg = cfg
        self.plans = plans
        self.qparams = qparams
        self.batch = batch_size
        self.cache_len = cache_len
        self.fold_wo = fold_wo
        self.ops = resolve_ops(ops, cfg)
        # tensor parallelism: typed validation always (tp must divide
        # Hkv, arch must be head-shardable), then capability/device
        # negotiation picks the lowering — shard_map over a ("tp",) mesh
        # when every backend advertises ``tp_serving`` and the process
        # has the devices, else the exact single-device gather lowering
        # (tokens identical either way, so tp > 1 is never an error on a
        # 1-device box)
        tp_serving.validate_tp(cfg, tp)
        # speculative decoding: typed validation at the boundary (k in
        # budget, arch verify-able, proposer registered) — the Sq=K+1
        # launch contract is checked below, once the cache geometry is
        # known
        speculate.validate_spec(cfg, spec_k, spec_mode)
        self.spec_k = spec_k
        self.spec_mode = spec_mode if spec_k else "off"
        self.proposer = speculate.get_proposer(spec_mode) if spec_k \
            else None
        self._spec_drafted = 0
        self._spec_accepted = 0
        self.tp = tp
        self.tp_sharded = (tp > 1
                           and tp_serving.backends_support_tp(self.ops)
                           and jax.device_count() >= tp)
        self.mesh = tp_serving.make_tp_mesh(tp) if self.tp_sharded \
            else None
        if self.tp_sharded and self.fold_wo:
            # the folded epilogue requants inside the kernel — before
            # the cross-device psum — which would round per-shard; the
            # sharded step always runs unfolded (requant-rounds-once)
            self.fold_wo = False
        # whether prefill/cross attention runs as one fused kernel launch
        # (pallas / pallas_fused) or the two-pass oracle path (ref)
        self.attn_fused = \
            self.ops.backend_for("int_attention").fused_attention
        # whether the per-step decode attention over the ragged KV cache
        # runs as the backend's single-launch valid_len-masked kernel
        # (the ``fused_decode`` capability flag; pallas_fused only) or
        # the full-matrix oracle; either way the step dispatches through
        # the backend — there is no hardcoded oracle call on the decode
        # path (models.intlayers.int_attn_decode)
        decode_be = self.ops.backend_for("int_decode_attention")
        self.decode_fused = getattr(decode_be, "fused_decode", False)
        self.decode_paged_native = getattr(decode_be, "paged_decode", False)
        self.prefill_paged_native = getattr(
            self.ops.backend_for("int_paged_prefill"), "paged_prefill",
            False)
        self.rng = np.random.default_rng(seed)
        self.rope_tab = il.build_rope_table(cache_len + 1, cfg.hd,
                                            cfg.rope_theta) \
            if cfg.pos == "rope" else None
        # logical per-session cache length (the attention window bounds
        # it, mirroring init_decode_cache)
        self.L = min(cache_len, cfg.window) if cfg.window > 0 else cache_len
        gl, ng, kinds = layer_group_spec(cfg)
        self._has_ssm = any(k[0] == "ssm" for k in kinds)
        self.paged = cache_mode == "paged"
        if self.paged:
            self.layout = CacheLayout.fit(batch_size, self.L, page_size,
                                          num_pages, kv_dtype=kv_dtype)
            self.kv = PagedKVCache(self.layout)
            self.caches = it.init_decode_cache(cfg, batch_size, cache_len,
                                               layout=self.layout)
        else:
            self.layout = None
            self.kv = None
            self.caches = it.init_decode_cache(cfg, batch_size, cache_len)
        self.prefill_chunk = self._resolve_prefill_chunk(prefill_chunk)
        self._use_chunked = self.prefill_chunk > 0
        self.prefill_budget = prefill_budget
        self._chunkable = self.paged and it.chunked_prefill_supported(cfg)
        if self._chunkable and prefix_cache:
            self.prefix: Optional[PrefixIndex] = PrefixIndex(
                self.kv.allocator, self.layout.page_size)
            # pool pressure reclaims cached-but-unreferenced prefix
            # pages before any allocation fails
            self.kv.allocator.reclaim = self._reclaim_prefix
        else:
            self.prefix = None
        self._cow_copies = 0
        if self.spec_k:
            # construction-time twin of the verify launch's own
            # require_launch: the Sq = spec_k + 1 stepped-mask decode
            # must satisfy the kernel contract on this cache geometry
            # (policy declines are fine — the backend falls back
            # exactly; contract violations raise here, typed)
            contracts.require_launch(contracts.check_launch(
                "int_decode_attention", b=self.batch,
                sq=self.spec_k + 1, h=cfg.n_heads, hkv=cfg.n_kv_heads,
                d=cfg.hd, **self._decode_geom()))
        if self.tp_sharded:
            # static per-shard launch contracts first (shape errors name
            # the tp clause, not a kernel assert three layers down),
            # then lay the params and pools out over the mesh
            self._check_tp_launches()
            self._qspecs = tp_serving.qparam_pspecs(qparams)
            self._cspecs = tp_serving.cache_pspecs(self.caches)
            self.qparams = tp_serving.shard_put(self.qparams,
                                                self._qspecs, self.mesh)
            self.caches = tp_serving.shard_put(self.caches,
                                               self._cspecs, self.mesh)
        self.pos = np.zeros(batch_size, np.int32)
        self.slots: List[Optional[Session]] = [None] * batch_size
        self.queue: List[Session] = []
        self._finished: List[Request] = []
        self._uid = 0
        self._inflight: Optional[PendingStep] = None
        self._decode = self._shared_decode_step()
        self._prefill_step = self._shared_prefill_step() \
            if self._use_chunked else None
        self._verify = self._shared_verify_step() if self.spec_k \
            else None

    def _resolve_prefill_chunk(self, prefill_chunk: Optional[int]) -> int:
        """Validate/auto-size the prefill chunk.  0 disables chunked
        prefill (token streaming); None auto-sizes it for eligible
        engines.  Typed errors here, not kernel-shape failures later."""
        chunkable = self.paged and it.chunked_prefill_supported(self.cfg)
        if prefill_chunk is None:
            if not chunkable:
                return 0
            ps = self.layout.page_size
            # ~32-token chunks, page-compatible by construction
            return min(ps * max(1, 32 // ps), self.layout.logical_len)
        if prefill_chunk == 0:
            return 0
        if prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0, got "
                             f"{prefill_chunk}")
        if not self.paged:
            raise ValueError("prefill_chunk needs cache_mode='paged' "
                             "(chunked prefill writes K/V through the "
                             "page table)")
        if not chunkable:
            raise ValueError(
                "chunked prefill is unsupported for arch "
                f"{self.cfg.name!r}: it needs window == 0 and "
                "attention+ffn sublayers only (sliding-window, SSM, MoE "
                "and cross-attention archs keep token-streaming "
                "prefill); pass prefill_chunk=0")
        ps = self.layout.page_size
        if prefill_chunk % ps and ps % prefill_chunk:
            raise ValueError(
                f"prefill_chunk={prefill_chunk} must divide or be a "
                f"multiple of page_size={ps} so chunk writes tile "
                "physical pages")
        return min(prefill_chunk, self.layout.logical_len)

    def _decode_geom(self) -> dict:
        """The decode launch's cache-geometry params for
        :func:`~repro.analysis.contracts.check_launch`."""
        if self.paged:
            geom = dict(max_pages=self.layout.max_pages,
                        page_size=self.layout.page_size)
            if self.layout.kv_dtype == "int4":
                geom.update(kv_pack=True,
                            num_pages=self.layout.num_pages)
            return geom
        return dict(L=self.L)

    def _check_tp_launches(self):
        """Per-shard launch contracts for the sharded step: under
        shard_map every device launches the attention kernels with
        ``H/tp`` / ``Hkv/tp`` heads, and :func:`~repro.analysis.
        contracts.check_tp_launch` is the offline twin of the
        ``require_launch`` each wrapper will run on those local
        shapes.  Policy declines are fine (the backend falls back
        exactly, per shard); contract violations raise here, at
        construction."""
        cfg, tp = self.cfg, self.tp
        geom = self._decode_geom()
        # one check per decode-launch Sq the engine will issue: 1 for
        # the plain step, spec_k + 1 for the speculative verify (Sq is
        # replicated under the mesh — only the head counts shard)
        sqs = (1,) if not self.spec_k else (1, self.spec_k + 1)
        for sq in sqs:
            contracts.require_launch(contracts.check_tp_launch(
                "int_decode_attention", tp=tp, b=self.batch, sq=sq,
                h=cfg.n_heads, hkv=cfg.n_kv_heads, d=cfg.hd, **geom))
        if self._use_chunked:
            pf = dict(max_pages=self.layout.max_pages,
                      page_size=self.layout.page_size)
            if self.layout.kv_dtype == "int4":
                pf.update(kv_pack=True, num_pages=self.layout.num_pages)
            contracts.require_launch(contracts.check_tp_launch(
                "int_paged_prefill", tp=tp, b=self.batch,
                c=self.prefill_chunk, h=cfg.n_heads, hkv=cfg.n_kv_heads,
                d=cfg.hd, **pf))

    # ------------------------------------------------------ compiled step --

    def _step_key(self, tag: str, *extra) -> tuple:
        geometry = ("paged", self.layout.page_size, self.layout.num_pages,
                    self.layout.max_pages, self.L,
                    self.layout.kv_dtype) if self.paged \
            else ("contiguous",)
        # mesh geometry: sharded engines key on (tp, device ids) — a
        # differently-sized or differently-placed mesh must not share
        # an executable; every unsharded engine (tp=1 AND the tp>1
        # gather fallback, which traces the identical single-device
        # program) collapses onto one ("mesh", 1) entry
        mesh = ("mesh", self.tp,
                tuple(int(d.id) for d in self.mesh.devices.flat)) \
            if self.tp_sharded else ("mesh", 1)
        return (tag, self.cfg, self.plans, self.batch, self.cache_len,
                geometry, self.fold_wo, mesh, *extra,
                tuple(id(self.ops.backend_for(op)) for op in OP_NAMES))

    def _shared_decode_step(self) -> Callable:
        """The jitted decode step, shared across same-shaped engines via
        ``_STEP_CACHE`` (falls back to a private jit when the key is
        unhashable, e.g. exotic plan objects).

        The callable closes over (plans, cfg, rope_tab, ops, cache
        geometry) only — never ``self`` — so a retired engine's weights,
        caches and sessions are not pinned by the process-global cache.
        The key carries the page-pool shape and mesh geometry: engines
        over differently-provisioned pools or meshes must not share an
        executable."""
        plans, cfg, rope_tab, ops = (self.plans, self.cfg,
                                     self.rope_tab, self.ops)
        page_size = self.layout.page_size if self.paged else 0
        max_len = self.L if self.paged else 0
        fold_wo = self.fold_wo
        tp_axis = None
        if self.tp_sharded:
            cfg = tp_serving.local_cfg(cfg, self.tp)
            tp_axis = tp_serving.TP_AXIS

        def step(qparams, caches, tokens, pos, pages=None):
            return it.int_decode_step(
                qparams, caches, tokens, pos, plans, cfg, rope_tab,
                ops=ops, pages=pages, page_size=page_size,
                max_len=max_len, fold_wo=fold_wo, tp_axis=tp_axis)

        if self.tp_sharded:
            step = self._tp_wrap(step, n_host_args=3 if self.paged else 2)
        return _cached_step(self._step_key("decode"),
                            lambda: jax.jit(step))

    def _shared_prefill_step(self) -> Callable:
        """The jitted chunked-prefill step (tokens (B, C), base_pos (B,),
        prefill-view page table) -> new caches; cached exactly like the
        decode step, with the chunk size in the key."""
        plans, cfg, rope_tab, ops = (self.plans, self.cfg,
                                     self.rope_tab, self.ops)
        page_size = self.layout.page_size
        fold_wo = self.fold_wo
        tp_axis = None
        if self.tp_sharded:
            cfg = tp_serving.local_cfg(cfg, self.tp)
            tp_axis = tp_serving.TP_AXIS

        def step(qparams, caches, tokens, base_pos, pages):
            return it.int_prefill_chunk_step(qparams, caches, tokens,
                                             base_pos, plans, cfg,
                                             rope_tab, ops=ops,
                                             pages=pages,
                                             page_size=page_size,
                                             fold_wo=fold_wo,
                                             tp_axis=tp_axis)

        if self.tp_sharded:
            step = self._tp_wrap(step, n_host_args=3, caches_only=True)
        return _cached_step(self._step_key("prefill", self.prefill_chunk),
                            lambda: jax.jit(step))

    def _shared_verify_step(self) -> Callable:
        """The jitted speculative verify step (tokens (B, S = spec_k+1)
        right-aligned, pos (B,), n_new (B,), page table) -> (logits
        (B, S, V), new caches); cached exactly like the decode step,
        with a ("spec", S) element in the key — a spec engine and a
        plain engine (or two different spec_k) must not share an
        executable."""
        plans, cfg, rope_tab, ops = (self.plans, self.cfg,
                                     self.rope_tab, self.ops)
        page_size = self.layout.page_size if self.paged else 0
        max_len = self.L if self.paged else 0
        fold_wo = self.fold_wo
        tp_axis = None
        if self.tp_sharded:
            cfg = tp_serving.local_cfg(cfg, self.tp)
            tp_axis = tp_serving.TP_AXIS

        def step(qparams, caches, tokens, pos, n_new, pages=None):
            return it.int_verify_step(
                qparams, caches, tokens, pos, n_new, plans, cfg,
                rope_tab, ops=ops, pages=pages, page_size=page_size,
                max_len=max_len, fold_wo=fold_wo, tp_axis=tp_axis)

        if self.tp_sharded:
            step = self._tp_wrap(step, n_host_args=4 if self.paged else 3)
        return _cached_step(self._step_key("spec", self.spec_k + 1),
                            lambda: jax.jit(step))

    def _tp_wrap(self, step: Callable, n_host_args: int,
                 caches_only: bool = False) -> Callable:
        """shard_map a local step over the engine's ``("tp",)`` mesh:
        qparams and caches flow in under their head-sharding specs,
        the ``n_host_args`` scheduler operands (tokens, positions, page
        table) replicate, and the returned caches keep their sharding so
        the next step consumes them in place.  Logits come back
        replicated — every device computed the identical full-width
        value after the exact wo psum (``check_rep=False``: the
        replication invariant is by integer-exactness construction, and
        rep-checking doesn't trace through the pallas launches)."""
        host = tuple(P() for _ in range(n_host_args))
        in_specs = (self._qspecs, self._cspecs) + host
        out_specs = self._cspecs if caches_only else (P(), self._cspecs)
        smap = tp_serving.shard_map_fn()
        return smap(step, mesh=self.mesh, in_specs=in_specs,
                    out_specs=out_specs, check_rep=False)

    # ------------------------------------------------------ scheduling ---

    def submit(self, req: Request) -> Session:
        """Queue a request; returns the Session that owns its cache
        pages for the rest of its life (evict/preempt take Sessions).

        Impossible requests fail HERE, typed, not deep inside a step:
        :func:`~repro.analysis.contracts.require_request` rejects a
        prompt longer than the logical cache (prefill would write past
        the page table and silently corrupt live positions) and — for
        full-causal archs — a ``prompt + max_new_tokens`` stream that
        overruns ``cache_len`` (the engine retires lanes at ``pos >=
        cache_len``, so such a request is guaranteed to come back short;
        the exact bound is ``len(prompt) - 1 + max_new_tokens <=
        cache_len``).  Transient *pool* pressure is not checked — that
        is an admission-time concern (``PagePoolExhausted`` when the
        prompt can never fit the pool; requeue-and-retry otherwise)."""
        if self.spec_k and req.temperature > 0:
            raise speculate.SpeculationUnsupported(
                f"spec_k={self.spec_k} serves greedy requests only: "
                "acceptance keeps the longest draft prefix matching the "
                "argmax stream, so a temperature="
                f"{req.temperature} sampled stream would silently "
                "diverge from the non-speculative engine; sample with "
                "spec_k=0")
        contracts.require_request(len(req.prompt), req.max_new_tokens,
                                  self.cache_len, window=self.cfg.window)
        sess = Session(uid=self._uid, request=req)
        self._uid += 1
        self.queue.append(sess)
        return sess

    def _admit(self):
        for slot in range(self.batch):
            if self.slots[slot] is None and self.queue:
                sess = self.queue[0]
                if sess.state == "preempted":
                    self.queue.pop(0)
                    self._rebind(sess, slot)
                    continue
                if not self._try_bind_new(sess, slot):
                    break           # pool pressure: retry next step

    @staticmethod
    def _n_pre(sess: Session) -> int:
        return len(sess.request.prompt) - 1

    def _try_bind_new(self, sess: Session, slot: int) -> bool:
        """Admit a queued session: longest-prefix lookup, all-or-nothing
        page reservation for the rest of the prompt, lane binding.
        Returns False under transient pool pressure (session stays
        queued); raises :class:`PagePoolExhausted` when the prompt can
        never fit."""
        n_pre = self._n_pre(sess)
        shared: List[int] = []
        if self.prefix is not None and n_pre > 0:
            hit = self.prefix.lookup(sess.request.prompt, n_pre)
            if hit is not None:
                shared = list(hit.pages)    # retained for this session
                sess.prefill_pos = hit.count
        if self.paged:
            try:
                reserved = self._reserve_prefill(sess, n_pre, shared)
            except PagePoolExhausted:
                # the never-fits raise must not leak the refcounts the
                # prefix lookup retained (the caller may keep stepping)
                for page in shared:
                    self.kv.allocator.release(page)
                sess.prefill_pos = 0
                raise
            if not reserved:
                for page in shared:
                    self.kv.allocator.release(page)
                sess.prefill_pos = 0
                return False
        self.queue.pop(0)
        self.slots[slot] = sess
        self.pos[slot] = sess.prefill_pos
        sess.pos = sess.prefill_pos
        if self.paged:
            self.kv.bind(sess, slot)
        else:
            sess.slot = slot
        sess.state = "prefilling"
        self._reset_slot_cache(slot)
        if sess.prefill_pos >= n_pre:
            # nothing to prefill (single-token prompt or a full prefix
            # hit): straight to decode
            self._finish_prefill(slot, sess)
        return True

    def _reserve_prefill(self, sess: Session, n_pre: int,
                         shared: List[int]) -> bool:
        """Reserve the pages the prompt prefill will write, so admission
        is all-or-nothing (no half-prefilled session stuck on a lane);
        ``shared`` prefix pages already cover ``sess.prefill_pos``
        tokens.  Chunk padding past the prompt needs no pages — the
        scatter routes writes through unmapped table entries to the
        null page.  Returns False under transient pool pressure; raises
        :class:`PagePoolExhausted` when the prompt can never fit."""
        span = min(n_pre, self.L)
        blocks = -(-span // self.layout.page_size) if span > 0 else 0
        need = blocks - len(shared)
        # never-fits is judged on TOTAL blocks, shared pages included —
        # they are pool pages too, so a prompt whose block count exceeds
        # the pool can never fit no matter how much of it is cached
        if blocks > self.layout.num_pages - 1:
            raise PagePoolExhausted(
                f"prompt needs {blocks} pages, pool only has "
                f"{self.layout.num_pages - 1}")
        acquired: List[int] = []
        try:
            while len(acquired) < need:
                acquired.append(self.kv.allocator.alloc())
        except PagePoolExhausted:
            for page in acquired:
                self.kv.allocator.release(page)
            return False
        sess.pages = shared + acquired
        return True

    def _rebind(self, sess: Session, slot: int):
        """Resume a preempted session: reattach its page-table row and
        position — its K/V pages were never touched, so decode (or the
        remaining prefill, for mid-prefill preemption) continues
        bit-exactly where it stopped."""
        self.slots[slot] = sess
        self.pos[slot] = sess.pos
        self.kv.bind(sess, slot)
        if sess.last_token is None:
            sess.state = "prefilling"   # preempted mid-prefill

    def _finish_prefill(self, slot: int, sess: Session):
        n_pre = self._n_pre(sess)
        sess.prefill_pos = n_pre
        sess.state = "active"
        self.pos[slot] = n_pre
        sess.pos = n_pre
        sess.last_token = sess.request.prompt[-1]
        if self.prefix is not None and n_pre > 0:
            self.prefix.register(sess.request.prompt, n_pre, sess.pages)

    # --------------------------------------------------------- prefill ---

    def _advance_prefill(self):
        """Advance prefilling lanes, at most ``prefill_budget`` prompt
        tokens per engine step (None = finish them all, the
        pre-scheduler semantics; the cap is chunk-granular — one chunk
        minimum per step so the scheduler always progresses).  Chunked
        engines batch the included lanes into one fused-kernel launch
        per round; streaming engines feed tokens through the decode
        step."""
        budget = math.inf if self.prefill_budget is None \
            else self.prefill_budget
        while budget > 0:
            lanes = [i for i, s in enumerate(self.slots)
                     if s is not None and s.state == "prefilling"]
            if not lanes:
                return
            if self._use_chunked:
                budget -= self._prefill_chunk_round(lanes, budget)
            else:
                budget -= self._prefill_stream_round(lanes, budget)

    def _prefill_stream_round(self, lanes: List[int], budget) -> int:
        """Token-streaming prefill through the decode step (slot-local;
        keeps every shape static)."""
        spent = 0
        for i in lanes:
            sess = self.slots[i]
            prompt = sess.request.prompt
            n_pre = self._n_pre(sess)
            while sess.prefill_pos < n_pre and spent < budget:
                self._step_one(i, prompt[sess.prefill_pos])
                sess.prefill_pos += 1
                spent += 1
            if sess.prefill_pos >= n_pre:
                self._finish_prefill(i, sess)
        return max(spent, 1)

    def _prefill_chunk_round(self, lanes: List[int], budget) -> int:
        """One batched chunk round through a single fused-prefill
        launch.  Lanes are included while the remaining ``budget``
        allows (chunk granularity, one lane minimum so the scheduler
        always progresses); the rest wait for the next engine step.
        Returns the real prompt tokens advanced (pad tokens are free —
        their K/V writes land on positions decode overwrites before
        ``valid_len`` marks them live, or on the null page)."""
        C = self.prefill_chunk
        ps = self.layout.page_size
        logical = self.layout.logical_len
        toks = np.zeros((self.batch, C), np.int32)
        base = np.zeros(self.batch, np.int32)
        spent = 0
        included: List[int] = []
        for i in lanes:
            if included and spent >= budget:
                break               # chunk-granularity budget cap
            sess = self.slots[i]
            prompt = sess.request.prompt
            b0 = sess.prefill_pos
            base[i] = b0
            real = min(C, self._n_pre(sess) - b0)
            toks[i, :real] = prompt[b0:b0 + real]
            spent += real
            included.append(i)
            # copy-on-write any shared (prefix-index / multi-session)
            # page this chunk will write into — only the partially
            # filled page at an unaligned prefix boundary can be shared
            blk_hi = (min(b0 + C, logical) - 1) // ps
            for blk in range(b0 // ps, min(blk_hi + 1, len(sess.pages))):
                if self.kv.allocator.refcount[sess.pages[blk]] > 1:
                    self._cow(sess, blk)
        lanes = included
        # the prefill *view* of the page table: rows of lanes not in
        # this round (idle, decoding, or budgeted out) are nulled, so
        # their (discarded) chunk writes land on the null page instead
        # of live pages
        view = self.kv.page_table.snapshot()
        for slot in range(self.batch):
            if slot not in lanes:
                view[slot] = NULL_PAGE
        self.caches = self._prefill_step(self.qparams, self.caches,
                                         jnp.asarray(toks),
                                         jnp.asarray(base),
                                         jnp.asarray(view))
        for i in lanes:
            sess = self.slots[i]
            n_pre = self._n_pre(sess)
            sess.prefill_pos = min(sess.prefill_pos + C, n_pre)
            self.pos[i] = sess.prefill_pos
            sess.pos = sess.prefill_pos
            if sess.prefill_pos >= n_pre:
                self._finish_prefill(i, sess)
        return max(spent, 1)

    def _reset_slot_cache(self, slot: int):
        """Zero a recycled lane's lane-indexed cache state (Mamba SSD
        state, conv tails, cross memory).  Paged attention pools are
        *not* lane-indexed and are never zeroed — ``valid_len`` masking
        makes stale page contents unobservable (the bit-exact-reuse
        invariant of repro.serving.kvcache)."""
        new_caches = []
        for c in self.caches:
            nc = dict(c)
            for key, leaf in c.items():
                # page-pool state is never lane-indexed: the pools stay
                # (valid_len masking) and the per-page requant shifts
                # must survive too — their (ng, num_pages) shape could
                # coincidentally match the batch test below
                if self.paged and key in ("k8", "v8",
                                          "k_shift", "v_shift"):
                    continue
                if leaf.ndim >= 2 and leaf.shape[1] == self.batch:
                    nc[key] = leaf.at[:, slot].set(0)
            new_caches.append(nc)
        self.caches = new_caches

    # --------------------------------------------------- paged bookkeeping

    def _reclaim_prefix(self):
        """Allocator pressure hook: evict prefix-index entries LRU-first
        until a page frees (or the index drains) — cached prefixes cost
        only otherwise-idle pages."""
        while self.kv.allocator.free_pages == 0 and self.prefix is not None \
                and self.prefix.evict_lru():
            pass

    def _cow(self, sess: Session, blk: int):
        """Copy-on-write: give ``sess`` a private copy of a shared page
        before a write lands on it.  Shared pages arise from the prefix
        index (and sessions sharing a prefix through it); copying before
        the first divergent write keeps every sharer's — and the cached
        prefix's — K/V bit-exact."""
        old = sess.pages[blk]
        try:
            new = self.kv.allocator.alloc()
        except PagePoolExhausted:
            # the allocator's pressure reclaim may have just evicted the
            # prefix entries that shared this page — if the session is
            # now its only holder, write in place instead of copying
            if self.kv.allocator.refcount[old] == 1:
                return
            raise
        new_caches = []
        for c in self.caches:
            nc = dict(c)
            # the per-page requant shifts are page-indexed on the same
            # axis, so a CoW copies the source page's shift along with
            # its bytes (today every page shares the static KV_SHIFT;
            # the copy keeps the invariant if shifts ever diverge)
            for key in ("k8", "v8", "k_shift", "v_shift"):
                if key in c:
                    nc[key] = c[key].at[:, new].set(c[key][:, old])
            new_caches.append(nc)
        self.caches = new_caches
        self.kv.allocator.release(old)
        sess.pages[blk] = new
        if sess.slot is not None:
            self.kv.page_table.table[sess.slot, blk] = new
        self._cow_copies += 1

    def _ensure_write_pages(self, n_new=None):
        """Before a decode step, make the page under every live lane's
        write position resident (append-only allocation; raises
        :class:`PagePoolExhausted` when the pool is out) and exclusively
        owned (copy-on-write for pages shared through the prefix
        index).  ``n_new`` (B,) widens the per-lane write span to
        ``[pos, pos + n_new)`` for the speculative verify launch —
        every block the span touches is made resident and CoW'd, so a
        draft write can never land on a page the prefix index (or a
        prefix-sharing sibling) still reads."""
        if not self.paged:
            return
        for slot, sess in enumerate(self.slots):
            if sess is None:
                continue
            p = int(self.pos[slot])
            span = 1 if n_new is None else int(n_new[slot])
            for j in range(span):
                q = p + j
                wslot = q % self.cfg.window if self.cfg.window > 0 else q
                wslot = min(wslot, self.L - 1)
                self.kv.ensure(sess, wslot)
                blk = wslot // self.layout.page_size
                if self.kv.allocator.refcount[sess.pages[blk]] > 1:
                    self._cow(sess, blk)

    def _require_committed(self, op: str):
        if self._inflight is not None:
            raise StepInFlight(
                f"{op} while a dispatched step is uncommitted: call "
                "commit_step(pending) first — the pending launch will "
                "be committed against the sessions it captured")

    def evict(self, sess: Session):
        """Cancel a session: free its lane and release every page it
        owns (they return to the allocator at refcount zero — pages the
        prefix index also holds stay cached for future prompts)."""
        self._require_committed("evict")
        if sess in self.queue:
            self.queue.remove(sess)
        if sess.slot is not None:
            self.pos[sess.slot] = 0
            self.slots[sess.slot] = None
        if self.paged:
            self.kv.release(sess)
        else:
            sess.slot = None
            sess.state = "done"

    def preempt(self, sess: Session):
        """Take a live session off its lane but keep its pages: it goes
        back to the queue head and resumes bit-exactly (same physical
        K/V) when a lane frees up — decoding sessions resume decode,
        mid-prefill sessions resume the prompt at ``prefill_pos``.
        Paged mode only — the contiguous layout ties cache contents to
        the lane."""
        self._require_committed("preempt")
        if not self.paged:
            raise ValueError("preempt needs cache_mode='paged' (the "
                             "contiguous layout ties K/V to the lane)")
        if self._has_ssm:
            raise ValueError("preempt is unsupported for SSM/hybrid "
                             "archs: Mamba state is lane-indexed")
        if sess.state not in ("active", "prefilling") or sess.slot is None:
            raise ValueError("cannot preempt session in state "
                             f"{sess.state!r}")
        slot = sess.slot
        sess.pos = int(self.pos[slot])
        self.kv.unbind(sess)
        self.slots[slot] = None
        self.pos[slot] = 0
        self.queue.insert(0, sess)

    def _retire(self, slot: int):
        sess = self.slots[slot]
        sess.request.done = True
        self.slots[slot] = None
        self.pos[slot] = 0
        if self.paged:
            self.kv.release(sess)
        else:
            sess.slot = None
            sess.state = "done"
        self._finished.append(sess.request)

    # ---------------------------------------------------------- decode ---

    def _snap_pos(self):
        """Snapshot ``self.pos`` for a decode call.

        ``jnp.asarray`` on a numpy array may alias its buffer (zero-copy)
        while dispatch is asynchronous; the engine then mutates
        ``self.pos`` in place (``+= 1``), racing the executing step and
        intermittently decoding at the wrong position.  An explicit copy
        makes the hand-off a snapshot.  (This was a real, observed ~1/10
        token-stream flake on CPU.)  The page table gets the same
        treatment in ``_snap_pages``.
        """
        return jnp.asarray(self.pos.copy())

    def _snap_pages(self):
        return jnp.asarray(self.kv.page_table.snapshot())

    def _run_decode(self, toks):
        if self.paged:
            return self._decode(self.qparams, self.caches,
                                jnp.asarray(toks), self._snap_pos(),
                                self._snap_pages())
        return self._decode(self.qparams, self.caches, jnp.asarray(toks),
                            self._snap_pos())

    def _run_verify(self, toks, n_new):
        n_new = jnp.asarray(n_new.copy())      # same snapshot rule as pos
        if self.paged:
            return self._verify(self.qparams, self.caches,
                                jnp.asarray(toks), self._snap_pos(),
                                n_new, self._snap_pages())
        return self._verify(self.qparams, self.caches, jnp.asarray(toks),
                            self._snap_pos(), n_new)

    def _step_one(self, slot: int, token: int):
        toks = np.zeros(self.batch, np.int32)
        toks[slot] = token
        self._ensure_write_pages()
        logits, self.caches = self._run_decode(toks)
        self.pos[slot] += 1
        self.slots[slot].pos = int(self.pos[slot])
        return np.asarray(logits[slot])

    def _at_cache_end(self, slot: int) -> bool:
        """Whether the lane's NEXT token has nowhere to go: emitting it
        would need a K/V write at logical slot ``pos`` (``pos ≤ L - 1``
        for full-causal caches) and a RoPE rotation at ``pos`` (the
        table spans ``cache_len + 1`` positions).  Retiring at
        ``pos >= cache_len`` makes the final cache slot usable — the
        old ``>= cache_len - 1`` boundary retired one token early,
        wasting it."""
        return self.pos[slot] >= self.cache_len

    def step(self) -> int:
        """One engine step: admit, advance prefill (budgeted), and one
        batched decode for lanes whose prefill is complete (with
        ``spec_k > 0``, one batched draft-verify launch committing up to
        ``spec_k + 1`` tokens per lane).  Returns the number of occupied
        lanes.

        ``step()`` is exactly ``commit_step(dispatch_step())`` — the
        split exists so an async driver can overlap host work with the
        device computation; the synchronous composition is bit-exact
        with the pre-split engine by construction."""
        return self.commit_step(self.dispatch_step())

    def dispatch_step(self) -> PendingStep:
        """The scheduling + dispatch half of :meth:`step`: admit queued
        sessions, advance prefill (budgeted), draft (``spec_k > 0``) and
        dispatch the batched decode / verify launch WITHOUT materializing
        its logits.  Returns the :class:`PendingStep` the caller must
        pass to :meth:`commit_step` — between the two the device is
        computing while the host is free (the launch consumed snapshots
        of ``pos`` and the page table, so host-side reads are safe), but
        ``evict`` / ``preempt`` / another dispatch raise
        :class:`StepInFlight` until the commit lands."""
        self._require_committed("dispatch_step")
        self._admit()
        self._advance_prefill()
        occupied = sum(s is not None for s in self.slots)
        live = [i for i, s in enumerate(self.slots)
                if s is not None and s.state == "active"]
        if not live:
            return PendingStep(occupied, "idle")
        sessions = list(self.slots)
        if self.spec_k:
            toks, n_new, drafts = self._build_spec_batch(live)
            self._ensure_write_pages(n_new)
            logits, self.caches = self._run_verify(toks, n_new)
            pending = PendingStep(occupied, "verify", live, sessions,
                                  logits, n_new, drafts)
        else:
            toks = np.zeros(self.batch, np.int32)
            for i in live:
                toks[i] = self.slots[i].last_token
            self._ensure_write_pages()
            logits, self.caches = self._run_decode(toks)
            pending = PendingStep(occupied, "decode", live, sessions,
                                  logits)
        self._inflight = pending
        return pending

    def commit_step(self, pending: PendingStep) -> int:
        """The sampling + bookkeeping half of :meth:`step`: materialize
        the dispatched logits (this is where the host blocks on the
        device), sample / greedily accept, advance positions, retire
        finished lanes.  Returns the occupied-lane count, mirroring
        ``step()``."""
        if pending.kind == "idle":
            return pending.occupied
        if self._inflight is not pending:
            raise StepInFlight(
                "commit_step got a PendingStep that is not the one in "
                "flight: each dispatch_step() result is committed "
                "exactly once, in order")
        self._inflight = None
        if pending.kind == "verify":
            self._commit_spec(pending)
        else:
            self._commit_decode(pending)
        return pending.occupied

    def _commit_decode(self, pending: PendingStep):
        logits = np.asarray(pending.logits)
        for i in pending.live:
            sess = self.slots[i]
            req = sess.request
            self.pos[i] += 1
            sess.pos = int(self.pos[i])
            row = logits[i][:self.cfg.vocab]
            nxt = self._sample(req, row)
            req.out_tokens.append(nxt)
            sess.last_token = nxt
            if len(req.out_tokens) >= req.max_new_tokens \
                    or self._at_cache_end(i):
                self._retire(i)

    def _sample(self, req: Request, row: np.ndarray) -> int:
        """Next token from one lane's logits row.

        ``temperature <= 0``: greedy argmax.  Otherwise a softmax
        sample: the row is the head's *dequantized* float logits (int32
        accumulator × per-channel ``head_scale`` × ``s_act8`` —
        ``models.inttransformer.logits_int``), so ``temperature`` acts
        on that documented scale, pinned to float64 so the distribution
        is platform-reproducible.  Randomness comes from the engine's
        own seeded ``np.random.default_rng(seed)`` Generator — the
        sampled stream is a pure function of (seed, schedule), and two
        engines stepping identical schedules reproduce each other
        token for token."""
        if req.temperature <= 0:
            return int(np.argmax(row))
        z = row.astype(np.float64)
        p = np.exp((z - z.max()) / req.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _build_spec_batch(self, live: List[int]):
        """The draft half of a speculative decode round.

        Per live lane: the proposer drafts ``k_b = min(spec_k,
        remaining - 1, L - pos - 1)`` tokens (never past the request's
        budget or the cache), and the lane's ``[last_token, *draft]``
        rows go right-aligned into one (B, spec_k + 1) verify launch
        (idle/prefilling lanes ride along as the same discarded
        token-0 row the plain step gives them)."""
        S = self.spec_k + 1
        toks = np.zeros((self.batch, S), np.int32)
        n_new = np.ones(self.batch, np.int32)
        drafts: Dict[int, List[int]] = {}
        for i in live:
            sess = self.slots[i]
            req = sess.request
            remaining = req.max_new_tokens - len(req.out_tokens)
            room = self.L - int(self.pos[i]) - 1
            k_b = max(0, min(self.spec_k, remaining - 1, room))
            draft = self.proposer.propose(
                req.prompt + req.out_tokens, k_b) if k_b else []
            drafts[i] = draft
            n = 1 + len(draft)
            n_new[i] = n
            toks[i, S - n:] = [sess.last_token] + draft
        return toks, n_new, drafts

    def _commit_spec(self, pending: PendingStep):
        """The acceptance half: greedy acceptance commits the longest
        draft prefix matching the model's argmax rows plus the bonus
        token — bit-exact against ``a + 1`` plain steps — then rollback
        truncates the page list to the committed positions, releasing
        pages only rejected drafts touched."""
        S = self.spec_k + 1
        live, n_new, drafts = pending.live, pending.n_new, pending.drafts
        logits = np.asarray(pending.logits)
        for i in live:
            sess = self.slots[i]
            req = sess.request
            draft = drafts[i]
            n = int(n_new[i])
            rows = logits[i, S - n:, :self.cfg.vocab]
            preds = np.argmax(rows, axis=-1)
            a = 0
            while a < len(draft) and int(preds[a]) == draft[a]:
                a += 1
            commit = [int(t) for t in preds[:a + 1]]
            self._spec_drafted += len(draft)
            self._spec_accepted += a
            req.out_tokens.extend(commit)
            sess.last_token = commit[-1]
            self.pos[i] += len(commit)
            sess.pos = int(self.pos[i])
            if self.paged and len(commit) < n:
                # rejected drafts wrote past the committed positions:
                # release any page only they touched (valid_len hides
                # the stale K/V in the kept tail page)
                self.kv.truncate(sess, int(self.pos[i]))
            if len(req.out_tokens) >= req.max_new_tokens \
                    or self._at_cache_end(i):
                self._retire(i)

    # ------------------------------------------------------ introspection --

    def describe(self) -> dict:
        """Structured engine signature: backend ids, decode/prefill
        modes, cache geometry, live page-pool and prefix-cache stats.
        ``describe_str()`` derives the one-line log form from this
        dict."""
        if self.paged:
            cache = dict(mode="paged", kv_pack=self.layout.kv_dtype,
                         **self.kv.stats())
            cache["live_tokens"] = int(sum(
                s.live_tokens for s in self.slots if s is not None)
                + sum(s.live_tokens for s in self.queue))
            cache["shared_pages"] = int(
                (self.kv.allocator.refcount[1:] > 1).sum())
            cache["cow_copies"] = self._cow_copies
            cache["prefix"] = self.prefix.stats() \
                if self.prefix is not None else None
        else:
            cache = {"mode": "contiguous", "kv_pack": "int8"}
        # derived from the stored element width: packed pools carry half
        # the elements per token, so this halves under kv_dtype="int4"
        cache["kv_bytes"] = int(sum(
            c[key].size * c[key].dtype.itemsize
            for c in self.caches for key in ("k8", "v8") if key in c))
        tp = {
            "tp": self.tp,
            # "sharded": shard_map over the mesh; "gathered": tp > 1 but
            # a backend lacks tp_serving (or the process lacks devices)
            # — the exact single-device lowering; "off": tp == 1
            "mode": ("sharded" if self.tp_sharded
                     else "gathered" if self.tp > 1 else "off"),
            "mesh": None if self.mesh is None else {
                "axis": tp_serving.TP_AXIS,
                "shape": [self.tp],
                "devices": [int(d.id) for d in self.mesh.devices.flat],
            },
            # each device holds Hkv/tp of every page, so its pool slice
            # is exactly 1/tp of the global KV bytes
            "per_device_kv_bytes": cache["kv_bytes"] // self.tp
            if self.tp_sharded else cache["kv_bytes"],
        }
        drafted, accepted = self._spec_drafted, self._spec_accepted
        spec = {
            "k": self.spec_k,
            "mode": self.spec_mode,
            "drafted": drafted,
            "accepted": accepted,
            "accept_rate": round(accepted / drafted, 4) if drafted
            else None,
            "wasted": drafted - accepted,
        }
        return {
            "ops": self.ops.name,
            "backends": {op: self.ops.backend_for(op).name
                         for op in OP_NAMES},
            "attn": "fused" if self.attn_fused else "two-pass",
            "decode": "fused" if self.decode_fused else "oracle",
            "spec": spec,
            "prefill": {
                "mode": "chunked" if self._use_chunked else "streaming",
                "chunk": self.prefill_chunk,
                "budget": self.prefill_budget,
                "paged_native": self.prefill_paged_native,
            },
            "fold_wo": self.fold_wo,
            "tp": tp,
            "batch": self.batch,
            "cache_len": self.cache_len,
            "cache": cache,
        }

    def describe_str(self) -> str:
        """One-line engine signature for drivers/logs, derived from
        :meth:`describe`."""
        d = self.describe()
        c = d["cache"]
        if c["mode"] == "paged":
            pack = "" if c.get("kv_pack", "int8") == "int8" \
                else f", {c['kv_pack']}"
            cache = (f"paged[{c['page_size']}tok x {c['num_pages']}pg"
                     f"{pack}, "
                     f"{c['pages_used']}/{c['num_pages'] - 1} used]")
        else:
            cache = "contiguous"
        pf = d["prefill"]
        prefill = f"chunked:{pf['chunk']}" if pf["mode"] == "chunked" \
            else "streaming"
        if c.get("prefix") is not None:
            prefill += f"+prefix[{c['prefix']['entries']}]"
        tp = "" if d["tp"]["tp"] == 1 \
            else f" tp={d['tp']['tp']}:{d['tp']['mode']}"
        sp = d["spec"]
        spec = "" if not sp["k"] else (
            f" spec={sp['mode']}:k{sp['k']}"
            + (f"@{sp['accept_rate']:.2f}"
               if sp["accept_rate"] is not None else ""))
        return (f"ops={d['ops']} attn={d['attn']} decode={d['decode']} "
                f"prefill={prefill} fold_wo={str(d['fold_wo']).lower()}"
                f"{tp}{spec} cache={cache} batch={d['batch']} "
                f"cache_len={d['cache_len']}")

    def run_until_done(self, max_steps: int = 10000) -> List[Request]:
        """Step until queue and lanes drain; returns the requests that
        retired since the last call (completion order).

        Raises :class:`EngineStalled` if ``max_steps`` elapse with
        sessions still queued or resident — a silent partial return
        here let callers mistake a stalled schedule (admission
        deadlock, runaway generation) for completion.
        """
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        else:
            if self.queue or any(s is not None for s in self.slots):
                slots = [
                    None if s is None else {
                        "uid": s.request.uid,
                        "state": s.state,
                        "pos": int(self.pos[i]),
                        "prefill_pos": s.prefill_pos,
                    }
                    for i, s in enumerate(self.slots)
                ]
                raise EngineStalled(max_steps, slots, len(self.queue))
        finished, self._finished = self._finished, []
        return finished
