"""Speculative decoding through the scheduler: drafts, batched verify,
bit-exact acceptance, rollback — plus the serving-loop bugfix sweep.

The contract under test (docs/ARCHITECTURE.md "Speculative decoding"):

  * greedy token streams are **bit-exact** with speculation on vs off,
    across backend (ref / pallas_fused) x cache mode (paged-chunked /
    paged-streaming / contiguous) — speculation changes *when* tokens
    are computed, never *which*;
  * the verify launch packs per-lane variable-length drafts
    right-aligned into one ``Sq = spec_k + 1`` ``int_decode_attention``
    call; rejected drafts roll back as a page-table truncation with
    exact refcount accounting (CoW / prefix sharing included);
  * the prompt-lookup proposer accepts > 0 drafts on repeated-structure
    traffic;
  * bugfixes: sessions retire at ``pos >= cache_len`` (the final cache
    slot is usable), ``run_until_done`` raises the typed
    :class:`EngineStalled` instead of silently returning, and
    ``temperature > 0`` requests get a typed rejection under spec mode.
"""
import jax
import pytest

from repro.analysis.budgets import MAX_SQ
from repro.analysis.contracts import check_launch
from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.models.inttransformer import speculative_decode_supported
from repro.quant import convert
from repro.serving import (EngineStalled, NgramProposer, Request,
                           ServingEngine, SpeculationError,
                           SpeculationUnsupported, get_proposer,
                           validate_spec)

# ---------------------------------------------------------- proposer ----


def test_ngram_proposer_continues_most_recent_occurrence():
    p = NgramProposer(max_n=3)
    # trailing 3-gram [7, 8, 9] re-occurs; propose its continuation
    assert p.propose([7, 8, 9, 1, 2, 7, 8, 9], 2) == [1, 2]
    # cycle: the latest occurrence whose continuation spans a full k
    # tokens wins; only when every match truncates at the context end
    # does the latest partial continuation get used (no wrap-around)
    assert p.propose([5, 6, 5, 6, 5, 6], 3) == [5, 6]
    assert p.propose([5, 6, 5, 6, 5, 6, 5], 3) == [6, 5, 6]
    # no earlier occurrence of any suffix -> empty draft
    assert p.propose([1, 2, 3, 4], 2) == []
    # k caps the draft
    assert p.propose([9, 9, 9, 9, 9], 1) == [9]
    assert p.propose([1, 2, 3], 0) == []
    assert p.propose([], 4) == []


def test_ngram_proposer_prefers_longer_suffix_match():
    p = NgramProposer(max_n=3)
    # 1-gram [2] occurs at index 0 (-> 7) and via the 2-gram [1, 2] at
    # index 3 (-> 8): the longer suffix wins over the shorter
    assert p.propose([2, 7, 3, 1, 2, 8, 1, 2], 1) == [8]


def test_proposer_registry_typed_errors():
    assert get_proposer("ngram").name == "ngram"
    with pytest.raises(SpeculationError, match="unknown spec_mode"):
        get_proposer("draft-model")
    with pytest.raises(SpeculationError, match="min_n"):
        NgramProposer(max_n=2, min_n=3)


# ---------------------------------------------------------- validation ----


def test_validate_spec_budget_and_arch_gating():
    ok = M.reduce_config(get_config("llama3-8b"), dtype="float32")
    validate_spec(ok, 0, "ngram")
    validate_spec(ok, MAX_SQ - 1, "ngram")
    with pytest.raises(SpeculationError, match="spec_k must be >= 0"):
        validate_spec(ok, -1, "ngram")
    with pytest.raises(SpeculationError, match="MAX_SQ"):
        validate_spec(ok, MAX_SQ, "ngram")
    with pytest.raises(SpeculationError, match="unknown spec_mode"):
        validate_spec(ok, 2, "medusa")
    # spec_k = 0 never probes the proposer registry
    validate_spec(ok, 0, "medusa")
    # arch gating: sliding-window and SSM/hybrid archs are rejected
    # with the typed subclass (their rolling / lane-indexed state can't
    # roll a rejected draft back)
    for arch in ("h2o-danube-3-4b", "mamba2-130m", "jamba-v0.1-52b",
                 "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        assert not speculative_decode_supported(cfg)
        with pytest.raises(SpeculationUnsupported):
            validate_spec(cfg, 2, "ngram")
    assert speculative_decode_supported(get_config("qwen2-moe-a2.7b"))


def test_verify_launch_passes_decode_contract():
    # the engine asserts this at construction; pin it independently so
    # a budget change shows up here, not as an engine crash
    for sq in (2, MAX_SQ):
        r = check_launch("int_decode_attention", b=2, sq=sq, h=4, hkv=4,
                         d=64, L=64)
        assert r.ok, r.reason
    r = check_launch("int_decode_attention", b=2, sq=MAX_SQ + 1, h=4,
                     hkv=4, d=64, L=64)
    assert not r.ok


# ------------------------------------------------------------ engines ----


@pytest.fixture(scope="module")
def setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1, n_heads=4,
                          n_kv_heads=4)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


# a prompt whose continuation the model pushes into short cycles, and
# whose own structure repeats — both feed the n-gram proposer
REP = [3, 5, 7, 3, 5, 7, 3, 5]
PROMPTS = [REP, [11, 2, 11, 2, 11], [40, 41, 42]]


def _drive(setup, spec_k, prompts=PROMPTS, max_new=12, batch=2,
           cache_len=64, **kw):
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=batch,
                        cache_len=cache_len, spec_k=spec_k, **kw)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [list(r.out_tokens) for r in reqs]


MATRIX = [
    ("ref", dict(cache_mode="paged")),                       # chunked
    ("ref", dict(cache_mode="paged", prefill_chunk=0)),      # streaming
    ("ref", dict(cache_mode="contiguous")),
    ("pallas_fused", dict(cache_mode="paged")),
    ("pallas_fused", dict(cache_mode="paged", prefill_chunk=0)),
    ("pallas_fused", dict(cache_mode="contiguous")),
]


def test_spec_streams_bit_exact_across_backend_and_cache_mode(setup):
    """The acceptance matrix: spec_k in {0, 2, MAX_SQ-1} must produce
    bit-identical greedy streams in every backend x cache-mode combo,
    and every combo must agree with every other."""
    base = None
    for ops, kw in MATRIX:
        eng0, out0 = _drive(setup, 0, ops=ops, **kw)
        assert eng0.describe()["spec"]["k"] == 0
        if base is None:
            base = out0
        assert out0 == base, (ops, kw)
        for k in (2, MAX_SQ - 1):
            eng, out = _drive(setup, k, ops=ops, **kw)
            assert out == base, (ops, kw, k)
            spec = eng.describe()["spec"]
            assert spec["drafted"] >= spec["accepted"] >= 0
            assert spec["wasted"] == spec["drafted"] - spec["accepted"]


def test_spec_accepts_drafts_on_repeated_structure(setup):
    """Prompt-lookup must actually land drafts on repetitive traffic —
    accept-rate > 0, and accepted drafts shorten the step count."""
    eng, out = _drive(setup, 3, prompts=[REP], max_new=24)
    spec = eng.describe()["spec"]
    assert spec["drafted"] > 0
    assert spec["accepted"] > 0
    assert spec["accept_rate"] > 0
    assert f"spec=ngram:k3" in eng.describe_str()
    _, out0 = _drive(setup, 0, prompts=[REP], max_new=24)
    assert out == out0


def test_spec_stats_zero_before_any_draft(setup):
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", spec_k=2)
    spec = eng.describe()["spec"]
    assert spec == {"k": 2, "mode": "ngram", "drafted": 0,
                    "accepted": 0, "accept_rate": None, "wasted": 0}


def test_spec_rollback_keeps_exact_refcounts(setup):
    """Rejected drafts truncate the session's page list; after every
    run the allocator's refcounts must equal the live holders exactly
    (prefix entries included) and pool accounting must balance."""
    import collections
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", spec_k=3, page_size=8)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=16)
            for i, p in enumerate(PROMPTS)]
    sessions = [eng.submit(r) for r in reqs]
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        eng.kv.allocator.check()
        held = collections.Counter()
        for sess in sessions:
            held.update(sess.pages)
        if eng.prefix is not None:
            for entry in eng.prefix.entries.values():
                held.update(entry.pages)
        for page in range(1, eng.layout.num_pages):
            assert eng.kv.allocator.refcount[page] == held.get(page, 0)
    assert eng.describe()["spec"]["drafted"] > 0


def test_paged_truncate_releases_trailing_pages(setup):
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=1, cache_len=64,
                        ops="ref", page_size=8, prefix_cache=False)
    sess = eng.submit(Request(uid=0, prompt=[1] * 20, max_new_tokens=4))
    eng.run_until_done()
    # re-grow a dedicated session by hand: 3 pages -> keep 9 tokens
    sess2 = eng.submit(Request(uid=1, prompt=[2] * 20,
                               max_new_tokens=2))
    eng.step()                               # prefill allocates pages
    n_pages = len(sess2.pages)
    assert n_pages >= 3
    freed = eng.kv.truncate(sess2, 9)        # ceil(9/8) = 2 pages kept
    assert freed == n_pages - 2
    assert len(sess2.pages) == 2
    eng.kv.allocator.check()
    with pytest.raises(ValueError):
        eng.kv.truncate(sess2, -1)
    assert eng.kv.truncate(sess2, 16) == 0   # no-op: already short


# ------------------------------------------------- bugfix regressions ----


def test_final_cache_slot_usable_exact_full_cache(setup):
    """Regression (PR 8): sessions used to retire at ``pos >=
    cache_len - 1``, wasting the last slot — a prompt + continuation
    that exactly fills the cache must emit every token, spec on & off.
    """
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8]]
    outs = {}
    for mode in ("contiguous", "paged"):
        for k in (0, 3):
            eng, out = _drive(setup, k, prompts=prompts, max_new=8,
                              batch=1, cache_len=16, ops="ref",
                              cache_mode=mode)
            assert len(out[0]) == 8, (mode, k, out)
            outs[(mode, k)] = out
    assert len(set(map(tuple, (o[0] for o in outs.values())))) == 1


def test_spec_never_overruns_cache_or_token_budget(setup):
    """Near the cache end the per-lane draft clamp must shrink k so a
    multi-token commit can't write past the last slot or past
    max_new_tokens."""
    eng, out = _drive(setup, MAX_SQ - 1, prompts=[REP, REP[:5]],
                      max_new=7, batch=2, cache_len=16, ops="ref")
    assert all(len(o) == 7 for o in out)
    _, out0 = _drive(setup, 0, prompts=[REP, REP[:5]], max_new=7,
                     batch=2, cache_len=16, ops="ref")
    assert out == out0


def test_run_until_done_raises_typed_stall(setup):
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    eng.submit(Request(uid=7, prompt=[1, 2, 3], max_new_tokens=50))
    with pytest.raises(EngineStalled) as ei:
        eng.run_until_done(max_steps=3)
    e = ei.value
    assert e.max_steps == 3 and e.queue_depth == 0
    assert any(s and s["uid"] == 7 for s in e.slots)
    assert "uid=7" in str(e) and "prefill_pos" in str(e)
    # draining normally afterwards still works and returns the request
    done = eng.run_until_done()
    assert [r.uid for r in done] == [7]


def test_run_until_done_zero_work_never_stalls(setup):
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    assert eng.run_until_done(max_steps=0) == []


def test_temperature_requests_rejected_under_spec(setup):
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", spec_k=2)
    with pytest.raises(SpeculationUnsupported, match="greedy"):
        eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=4,
                           temperature=0.7))
    # greedy requests still admitted; temperature on a spec-free engine
    # still works (and is reproducible for a fixed engine seed)
    eng.submit(Request(uid=1, prompt=[1, 2], max_new_tokens=4))
    eng.run_until_done()


def test_temperature_sampling_reproducible_across_engines(setup):
    cfg, qp, plans = setup
    streams = []
    for _ in range(2):
        eng = ServingEngine(qp, plans, cfg, batch_size=1, cache_len=64,
                            ops="ref", seed=11)
        r = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=8,
                    temperature=0.8)
        eng.submit(r)
        eng.run_until_done()
        streams.append(list(r.out_tokens))
    assert streams[0] == streams[1]
    assert all(0 <= t < cfg.vocab for t in streams[0])


def test_spec_constructor_rejects_unsupported(setup):
    cfg, qp, plans = setup
    with pytest.raises(SpeculationError, match="MAX_SQ"):
        ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                      ops="ref", spec_k=MAX_SQ)
    with pytest.raises(SpeculationError, match="unknown spec_mode"):
        ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                      ops="ref", spec_k=2, spec_mode="medusa")


def test_spec_composes_with_preempt_and_evict(setup):
    """Mid-stream preemption/resume under spec must keep the committed
    stream identical to the uninterrupted spec-off stream."""
    cfg, qp, plans = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=1, cache_len=64,
                        ops="ref", spec_k=3)
    r0 = Request(uid=0, prompt=list(REP), max_new_tokens=16)
    r1 = Request(uid=1, prompt=[11, 2, 11, 2, 11], max_new_tokens=8)
    s0 = eng.submit(r0)
    eng.submit(r1)
    for _ in range(4):
        eng.step()
    eng.preempt(s0)                    # r1 takes the lane
    eng.run_until_done()
    assert r0.done and r1.done
    _, want = _drive(setup, 0, prompts=[list(REP),
                                        [11, 2, 11, 2, 11]],
                     max_new=16, batch=2, ops="ref")
    assert list(r0.out_tokens) == want[0]
    assert list(r1.out_tokens) == want[1][:8]
