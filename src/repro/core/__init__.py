"""SwiftTron core: integer-only quantized transformer numerics.

The paper's primary contribution (§III) as a composable JAX library:
dyadic requantization, i-exp/i-erf/i-sqrt primitives, integer softmax /
GELU / LayerNorm / RMSNorm / SiLU / softplus, and integer attention.
"""
from repro.core import activations, attention, dyadic, intmath, norms, quant
from repro.core import softmax
from repro.core.dyadic import (Dyadic, apply_dyadic, clip_to_bits,
                               fit_dyadic, requantize, rshift_round)
from repro.core.quant import (dequantize, fake_quant, quantize,
                              scale_from_absmax)

__all__ = [
    "activations", "attention", "dyadic", "intmath", "norms", "quant",
    "softmax", "Dyadic", "apply_dyadic", "clip_to_bits", "fit_dyadic",
    "requantize", "rshift_round", "dequantize", "fake_quant", "quantize",
    "scale_from_absmax",
]
