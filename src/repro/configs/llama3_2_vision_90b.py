"""llama-3.2-vision-90b [vlm]: 100 layers = 80 self-attn + 20 cross-attn
(every 5th) [hf:meta-llama/Llama-3.2-11B-Vision, scaled].  The vision
tower is a STUB: input_specs() supplies precomputed patch embeddings."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm", num_layers=100,
    d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256,
    head_dim=128, cross_every=5, n_img_tokens=1600, activation="swiglu",
    norm="rmsnorm", rope_theta=500000.0,
)
