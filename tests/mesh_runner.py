"""Shared subprocess runner for multi-device CPU tests.

``--xla_force_host_platform_device_count`` only takes effect if it is in
``XLA_FLAGS`` *before* jax initializes its backends, and ``conftest.py``
deliberately never sets it (smoke tests and benches must see exactly one
device).  So every multi-device test hands its body to
:func:`run_with_devices`, which runs it in a fresh subprocess whose
script sets the flag first, imports jax second, and asserts the device
count it actually obtained — silently testing 1 device is the failure
mode this runner exists to prevent.
"""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                   "src"))

_PRELUDE = """\
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=%(n)d")
import sys
sys.path.insert(0, %(src)r)
import jax
assert jax.device_count() == %(n)d, (
    "forced host-device count not honored: asked for %(n)d, got "
    + str(jax.device_count())
    + " (jax initialized before XLA_FLAGS was set?)")
"""


def run_with_devices(body: str, n: int, tmp_path, timeout: int = 900):
    """Run ``body`` (python source; jax + repro already importable, the
    device count already asserted) in a subprocess forced to ``n`` host
    devices.  The parent's own ``XLA_FLAGS`` is dropped from the child
    environment so the script's pre-import assignment is authoritative.
    Asserts a clean exit and the runner's own completion marker (so a
    child that dies before the end fails loudly, with its stderr)."""
    code = (_PRELUDE % {"n": n, "src": SRC}
            + body + '\nprint("MESH-OK")\n')
    f = tmp_path / "mesh_run.py"
    f.write_text(code)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(f)], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, (
        f"mesh child (n={n}) failed:\n--- stdout ---\n"
        f"{out.stdout[-2000:]}\n--- stderr ---\n{out.stderr[-4000:]}")
    assert "MESH-OK" in out.stdout, out.stdout[-2000:]
    return out
