"""End-to-end reproduction of the paper's central claim (Table II, in
miniature): a model trained in float and converted to the SwiftTron
integer-only datapath loses almost no task accuracy.

Train a small decoder on the synthetic bigram language, quantize, and
compare next-token accuracy of the integer path vs the float path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models import inttransformer as it
from repro.models import model as M
from repro.models import transformer as tf
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWConfig
from repro.quant import convert, qat


@pytest.fixture(scope="module")
def trained():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=256, num_layers=2)
    data = SyntheticLMDataset(cfg.vocab, 32, 16, seed=3)
    params = tf.init_params(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.01)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(qat.loss_fn, has_aux=True)(
            params, batch, cfg, qat=True)
        params, opt, _ = adamw_update(g, opt, params, opt_cfg)
        return params, opt, loss

    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    return cfg, params, data, losses


def test_training_learns(trained):
    cfg, params, data, losses = trained
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def _accuracy(logits, labels):
    pred = np.argmax(logits, axis=-1)
    return float((pred == labels).mean())


def test_integer_path_preserves_accuracy(trained):
    """The paper's Table II: quantized accuracy within ~1pt of float."""
    cfg, params, data, _ = trained
    qp, plans = convert.quantize_params(params, cfg)
    batch = next(data)
    toks = jnp.asarray(batch["tokens"])
    logits_f, _ = tf.forward_float(params, {"tokens": toks,
                                            "labels": toks}, cfg)
    # per-position integer logits via repeated prefill on prefixes is slow;
    # evaluate last-position accuracy over many examples instead
    acc_f, acc_i, n = 0.0, 0.0, 0
    for i in range(8):
        b = next(data)
        toks = jnp.asarray(b["tokens"])
        lf, _ = tf.forward_float(params, {"tokens": toks, "labels": toks},
                                 cfg)
        li = it.int_prefill(qp, {"tokens": toks}, plans, cfg)
        labels = b["labels"][:, -1]
        acc_f += _accuracy(np.asarray(lf[:, -1, :cfg.vocab]), labels)
        acc_i += _accuracy(np.asarray(li[:, :cfg.vocab]), labels)
        n += 1
    acc_f, acc_i = acc_f / n, acc_i / n
    assert acc_f > 0.25, f"float model failed to learn ({acc_f})"
    assert acc_i > acc_f - 0.05, \
        f"integer path lost accuracy: float {acc_f:.3f} int {acc_i:.3f}"
