"""Property-based parity for the fused decode attention kernel.

Hypothesis drives the whole decode-contract space — head dims, GQA
groupings, speculative query counts, cache tilings and *ragged* per-slot
``valid_len`` (empty, single-token, block-boundary, full) plus all three
RequantSpec epilogue forms and int8-extreme operands — and asserts the
single-launch kernel is bit-exact against the full-matrix oracle on
every draw.  Deterministic edge-case coverage (and the negative paths)
lives in ``test_decode_attention.py``; this module needs the optional
``hypothesis`` dev dependency.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import attention as iattn
from repro.core.dyadic import fit_dyadic
from repro.kernels.int_decode_attention import int_decode_attention_fused
from repro.ops import RequantSpec, get_backend

REF = get_backend("ref")

# (L, bkv) pairs exercise exact tiling, boundary blocks and bkv == L
CACHES = [(32, 8), (48, 16), (64, 64)]


@st.composite
def decode_cases(draw):
    seed = draw(st.integers(0, 2 ** 31 - 1))
    h = draw(st.sampled_from([1, 2, 4]))
    hkv = draw(st.sampled_from([g for g in (1, 2, 4) if h % g == 0]))
    d = draw(st.sampled_from([8, 16, 32]))
    sq = draw(st.integers(1, 8))
    L, bkv = draw(st.sampled_from(CACHES))
    b = draw(st.integers(1, 3))
    # ragged occupancy per slot, biased onto the edges the mask must get
    # right: empty, one token, the block boundary, the full cache
    edges = [0, 1, bkv - 1, bkv, bkv + 1, L - 1, L]
    vl = [draw(st.one_of(st.sampled_from(edges), st.integers(0, L)))
          for _ in range(b)]
    form = draw(st.sampled_from(["per_tensor", "per_channel", "raw"]))
    extreme = draw(st.booleans())
    return seed, b, sq, L, bkv, h, hkv, d, tuple(vl), form, extreme


@given(decode_cases())
@settings(max_examples=12, deadline=None)
def test_decode_kernel_matches_oracle_on_random_cases(case):
    seed, b, sq, L, bkv, h, hkv, d, vl, form, extreme = case
    rng = np.random.default_rng(seed)
    plan = iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)
    if extreme:
        # rail-to-rail operands: saturation arithmetic must still agree
        q = rng.choice(np.asarray([-128, -127, 127], np.int8),
                       (b, sq, h, d))
        k = rng.choice(np.asarray([-128, 127], np.int8), (b, L, hkv, d))
        v = rng.choice(np.asarray([-128, 127], np.int8), (b, L, hkv, d))
    else:
        q = np.clip(rng.normal(0, 40, (b, sq, h, d)), -128, 127)
        k = np.clip(rng.normal(0, 40, (b, L, hkv, d)), -128, 127)
        v = np.clip(rng.normal(0, 40, (b, L, hkv, d)), -128, 127)
    q8, k8, v8 = (jnp.asarray(a, jnp.int8) for a in (q, k, v))
    valid = jnp.asarray(vl, jnp.int32)
    b_vec = None
    if form == "per_tensor":
        spec = RequantSpec.per_tensor(
            fit_dyadic(plan.dn_out.value * 1.7, 127 * (1 << 8)))
    elif form == "per_channel":
        spec = RequantSpec.per_channel(c=28, pre=7)
        b_vec = jnp.asarray(rng.integers(1000, 30000, (h * d,)), jnp.int32)
    else:
        spec = RequantSpec.raw()
    got = np.asarray(int_decode_attention_fused(
        q8, k8, v8, plan, valid, requant=spec, b_vec=b_vec, bkv=bkv))
    want = np.asarray(REF.int_decode_attention(
        q8, k8, v8, plan, valid, requant=spec, b_vec=b_vec))
    assert np.array_equal(got, want), \
        f"decode parity broke: {case!r}"
