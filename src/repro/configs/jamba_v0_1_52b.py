"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE 16 experts
top-2 every other layer [arXiv:2403.19887].  The Mamba-1 blocks are
realised with the SSD form (state 16) — DESIGN.md §6 records the
substitution.  long_500k RUNS: only 4/32 layers carry KV caches."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536, head_dim=128,
    attn_every=8, attn_offset=4, n_experts=16, top_k=2, moe_every=2,
    moe_offset=1, moe_d_ff=14336, ssm_state=16, ssm_expand=2,
    ssm_head_dim=64, ssm_conv=4, ssm_groups=1, activation="swiglu",
    norm="rmsnorm", pos="none",
)
