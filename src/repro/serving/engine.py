"""Batched integer serving engine over a paged KV cache.

The serving counterpart of the ASIC's control unit (§III-J): admits
requests into fixed batch *lanes*, runs the INT8 prefill/decode datapath
(int8 KV caches = the paper's quantization applied to the cache), and
retires finished sequences — a continuous-batching-lite scheduler
suitable for the fixed-shape XLA world.

Cache layouts (``cache_mode``):

  * ``"paged"`` (default) — K/V live in a physical page pool addressed
    through a per-lane page table (``repro.serving.kvcache``).  A
    *session* owns its page list; lanes are just decode positions, so
    cache memory is O(live tokens), pages recycle through a ref-counted
    allocator without zeroing (``valid_len`` masking makes stale
    contents unobservable), and a session can be **preempted** (pages
    kept, lane freed) and later resumed bit-exactly.  The page table
    rides into the decode kernel as a scalar-prefetch operand next to
    ``valid_len``; backends without the ``paged_decode`` capability get
    an exact gather-into-contiguous lowering (repro.ops dispatch).
  * ``"contiguous"`` — the PR 3 layout: one ``cache_len`` slab per lane.

Every decode step dispatches through the configured backend's
``int_decode_attention`` — on ``pallas_fused`` one valid_len-masked
kernel launch that skips dead cache blocks — and, with ``fold_wo``
(default), folds each attention sublayer's output-projection per-channel
requant into that launch's epilogue (bit-exact vs the unfolded path).

Shapes (batch lanes, page pool, logical cache length) are fixed at
engine construction, so lanes and pages recycle without recompiling.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import intlayers as il
from repro.models import inttransformer as it
from repro.models.common import ArchConfig
from repro.models.transformer import layer_group_spec
from repro.ops import OP_NAMES, resolve_ops
from repro.quant import plans as qplans
from repro.serving.kvcache import (CacheLayout, PagePoolExhausted,
                                   PagedKVCache, Session)

# Process-level cache of compiled decode steps, keyed by everything the
# traced closure captures (cfg, plans, shapes, cache geometry, the
# resolved backend per op).  Two engines with the same key share ONE
# executable, so (a) engine construction stops paying an XLA recompile
# and (b) identical request streams produce identical tokens across
# engine instances — separately compiled executables of the same program
# are not guaranteed to agree to the last integer on every input (XLA
# CPU compile variance), which shows up as cross-engine token divergence
# in parity tests.  Bounded LRU (insertion order): a process sweeping
# many distinct (shape, plan) combinations evicts the oldest executable
# instead of pinning one per combination forever.
_DECODE_STEP_CACHE: Dict[tuple, Callable] = {}
_DECODE_STEP_CACHE_MAX = 8


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, qparams, plans: qplans.LayerPlans, cfg: ArchConfig,
                 batch_size: int = 8, cache_len: int = 512,
                 ops=None, seed: int = 0, backend=None,
                 cache_mode: str = "paged", page_size: int = 16,
                 num_pages: Optional[int] = None, fold_wo: bool = True):
        if backend is not None:
            warnings.warn("ServingEngine(backend=...) is deprecated; pass "
                          "ops= (an OpSet or backend name)",
                          DeprecationWarning, stacklevel=2)
            ops = backend if ops is None else ops
        if cache_mode not in ("paged", "contiguous"):
            raise ValueError(f"cache_mode must be 'paged' or 'contiguous',"
                             f" got {cache_mode!r}")
        self.cfg = cfg
        self.plans = plans
        self.qparams = qparams
        self.batch = batch_size
        self.cache_len = cache_len
        self.fold_wo = fold_wo
        self.ops = resolve_ops(ops, cfg)
        # whether prefill/cross attention runs as one fused kernel launch
        # (pallas / pallas_fused) or the two-pass oracle path (ref)
        self.attn_fused = \
            self.ops.backend_for("int_attention").fused_attention
        # whether the per-step decode attention over the ragged KV cache
        # runs as the backend's single-launch valid_len-masked kernel
        # (the ``fused_decode`` capability flag; pallas_fused only) or
        # the full-matrix oracle; either way the step dispatches through
        # the backend — there is no hardcoded oracle call on the decode
        # path (models.intlayers.int_attn_decode)
        decode_be = self.ops.backend_for("int_decode_attention")
        self.decode_fused = getattr(decode_be, "fused_decode", False)
        self.decode_paged_native = getattr(decode_be, "paged_decode", False)
        self.rng = np.random.default_rng(seed)
        self.rope_tab = il.build_rope_table(cache_len + 1, cfg.hd,
                                            cfg.rope_theta) \
            if cfg.pos == "rope" else None
        # logical per-session cache length (the attention window bounds
        # it, mirroring init_decode_cache)
        self.L = min(cache_len, cfg.window) if cfg.window > 0 else cache_len
        gl, ng, kinds = layer_group_spec(cfg)
        self._has_ssm = any(k[0] == "ssm" for k in kinds)
        self.paged = cache_mode == "paged"
        if self.paged:
            self.layout = CacheLayout.fit(batch_size, self.L, page_size,
                                          num_pages)
            self.kv = PagedKVCache(self.layout)
            self.caches = it.init_decode_cache(cfg, batch_size, cache_len,
                                               layout=self.layout)
        else:
            self.layout = None
            self.kv = None
            self.caches = it.init_decode_cache(cfg, batch_size, cache_len)
        self.pos = np.zeros(batch_size, np.int32)
        self.slots: List[Optional[Session]] = [None] * batch_size
        self.queue: List[Session] = []
        self._finished: List[Request] = []
        self._uid = 0
        self._decode = self._shared_decode_step()

    # ------------------------------------------------------ compiled step --

    def _shared_decode_step(self) -> Callable:
        """The jitted decode step, shared across same-shaped engines via
        ``_DECODE_STEP_CACHE`` (falls back to a private jit when the key
        is unhashable, e.g. exotic plan objects).

        The callable closes over (plans, cfg, rope_tab, ops, cache
        geometry) only — never ``self`` — so a retired engine's weights,
        caches and sessions are not pinned by the process-global cache.
        The key carries the page-pool shape: engines over
        differently-provisioned pools must not share an executable."""
        plans, cfg, rope_tab, ops = (self.plans, self.cfg,
                                     self.rope_tab, self.ops)
        page_size = self.layout.page_size if self.paged else 0
        max_len = self.L if self.paged else 0
        fold_wo = self.fold_wo

        def step(qparams, caches, tokens, pos, pages=None):
            return it.int_decode_step(qparams, caches, tokens, pos,
                                      plans, cfg, rope_tab, ops=ops,
                                      pages=pages, page_size=page_size,
                                      max_len=max_len, fold_wo=fold_wo)

        geometry = ("paged", self.layout.page_size, self.layout.num_pages,
                    self.layout.max_pages, self.L) if self.paged \
            else ("contiguous",)
        try:
            key = (self.cfg, self.plans, self.batch, self.cache_len,
                   geometry, self.fold_wo,
                   tuple(id(self.ops.backend_for(op)) for op in OP_NAMES))
            hash(key)
        except TypeError:
            return jax.jit(step)            # private: key can't be shared
        fn = _DECODE_STEP_CACHE.pop(key, None)
        if fn is None:
            fn = jax.jit(step)
        _DECODE_STEP_CACHE[key] = fn            # (re-)insert most recent
        while len(_DECODE_STEP_CACHE) > _DECODE_STEP_CACHE_MAX:
            _DECODE_STEP_CACHE.pop(next(iter(_DECODE_STEP_CACHE)))
        return fn

    # ------------------------------------------------------ scheduling ---

    def submit(self, req: Request) -> Session:
        """Queue a request; returns the Session that owns its cache
        pages for the rest of its life (evict/preempt take Sessions)."""
        if not req.prompt:
            raise ValueError("empty prompt: a request needs at least one "
                             "token")
        if self.cfg.window == 0 and len(req.prompt) > self.L:
            # without a sliding window there is nowhere for positions
            # >= L to go: prefill would write past the cache (paged:
            # past the page table) and silently corrupt live positions
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds the "
                f"cache_len={self.L} logical cache; raise cache_len or "
                "use a sliding-window arch")
        sess = Session(uid=self._uid, request=req)
        self._uid += 1
        self.queue.append(sess)
        return sess

    def _admit(self):
        for slot in range(self.batch):
            if self.slots[slot] is None and self.queue:
                sess = self.queue[0]
                if sess.state == "preempted":
                    self.queue.pop(0)
                    self._rebind(sess, slot)
                    continue
                if self.paged and not self._reserve_prefill(sess):
                    break           # pool pressure: retry next step
                self.queue.pop(0)
                self._bind_new(sess, slot)

    def _reserve_prefill(self, sess: Session) -> bool:
        """Reserve the pages the prompt prefill will write, so admission
        is all-or-nothing (no half-prefetched session stuck on a lane).
        Returns False under transient pool pressure; raises
        :class:`PagePoolExhausted` when the prompt can never fit."""
        n_pre = min(len(sess.request.prompt) - 1, self.L)
        blocks = -(-n_pre // self.layout.page_size) if n_pre > 0 else 0
        if blocks > self.layout.num_pages - 1:
            raise PagePoolExhausted(
                f"prompt needs {blocks} pages, pool only has "
                f"{self.layout.num_pages - 1}")
        acquired = []
        try:
            while len(sess.pages) < blocks:
                page = self.kv.allocator.alloc()
                sess.pages.append(page)
                acquired.append(page)
        except PagePoolExhausted:
            for page in acquired:
                self.kv.allocator.release(page)
                sess.pages.remove(page)
            return False
        return True

    def _bind_new(self, sess: Session, slot: int):
        self.slots[slot] = sess
        self.pos[slot] = 0
        sess.pos = 0
        if self.paged:
            self.kv.bind(sess, slot)
        else:
            sess.slot = slot
            sess.state = "active"
        self._reset_slot_cache(slot)
        self._prefill(slot, sess)

    def _rebind(self, sess: Session, slot: int):
        """Resume a preempted session: reattach its page-table row and
        position — its K/V pages were never touched, so decode continues
        bit-exactly where it stopped."""
        self.slots[slot] = sess
        self.pos[slot] = sess.pos
        self.kv.bind(sess, slot)

    def _prefill(self, slot: int, sess: Session):
        """Prefill by streaming prompt tokens through decode (slot-local);
        keeps every shape static."""
        for t in sess.request.prompt[:-1]:
            self._step_one(slot, t)
        sess.last_token = sess.request.prompt[-1]

    def _reset_slot_cache(self, slot: int):
        """Zero a recycled lane's lane-indexed cache state (Mamba SSD
        state, conv tails, cross memory).  Paged attention pools are
        *not* lane-indexed and are never zeroed — ``valid_len`` masking
        makes stale page contents unobservable (the bit-exact-reuse
        invariant of repro.serving.kvcache)."""
        new_caches = []
        for c in self.caches:
            nc = dict(c)
            for key, leaf in c.items():
                if self.paged and key in ("k8", "v8"):
                    continue
                if leaf.ndim >= 2 and leaf.shape[1] == self.batch:
                    nc[key] = leaf.at[:, slot].set(0)
            new_caches.append(nc)
        self.caches = new_caches

    # --------------------------------------------------- paged bookkeeping

    def _ensure_write_pages(self):
        """Before a decode step, make the page under every live lane's
        write position resident (append-only allocation; raises
        :class:`PagePoolExhausted` when the pool is out)."""
        if not self.paged:
            return
        for slot, sess in enumerate(self.slots):
            if sess is None:
                continue
            p = int(self.pos[slot])
            wslot = p % self.cfg.window if self.cfg.window > 0 else p
            self.kv.ensure(sess, min(wslot, self.L - 1))

    def evict(self, sess: Session):
        """Cancel a session: free its lane and release every page it
        owns (they return to the allocator at refcount zero)."""
        if sess in self.queue:
            self.queue.remove(sess)
        if sess.slot is not None:
            self.pos[sess.slot] = 0
            self.slots[sess.slot] = None
        if self.paged:
            self.kv.release(sess)
        else:
            sess.slot = None
            sess.state = "done"

    def preempt(self, sess: Session):
        """Take a live session off its lane but keep its pages: it goes
        back to the queue head and resumes bit-exactly (same physical
        K/V) when a lane frees up.  Paged mode only — the contiguous
        layout ties cache contents to the lane."""
        if not self.paged:
            raise ValueError("preempt needs cache_mode='paged' (the "
                             "contiguous layout ties K/V to the lane)")
        if self._has_ssm:
            raise ValueError("preempt is unsupported for SSM/hybrid "
                             "archs: Mamba state is lane-indexed")
        if sess.state != "active" or sess.slot is None:
            raise ValueError(f"cannot preempt session in state "
                             f"{sess.state!r}")
        slot = sess.slot
        sess.pos = int(self.pos[slot])
        self.kv.unbind(sess)
        self.slots[slot] = None
        self.pos[slot] = 0
        self.queue.insert(0, sess)

    def _retire(self, slot: int):
        sess = self.slots[slot]
        sess.request.done = True
        self.slots[slot] = None
        self.pos[slot] = 0
        if self.paged:
            self.kv.release(sess)
        else:
            sess.slot = None
            sess.state = "done"
        self._finished.append(sess.request)

    # ---------------------------------------------------------- decode ---

    def _snap_pos(self):
        """Snapshot ``self.pos`` for a decode call.

        ``jnp.asarray`` on a numpy array may alias its buffer (zero-copy)
        while dispatch is asynchronous; the engine then mutates
        ``self.pos`` in place (``+= 1``), racing the executing step and
        intermittently decoding at the wrong position.  An explicit copy
        makes the hand-off a snapshot.  (This was a real, observed ~1/10
        token-stream flake on CPU.)  The page table gets the same
        treatment in ``_snap_pages``.
        """
        return jnp.asarray(self.pos.copy())

    def _snap_pages(self):
        return jnp.asarray(self.kv.page_table.snapshot())

    def _run_decode(self, toks):
        if self.paged:
            return self._decode(self.qparams, self.caches,
                                jnp.asarray(toks), self._snap_pos(),
                                self._snap_pages())
        return self._decode(self.qparams, self.caches, jnp.asarray(toks),
                            self._snap_pos())

    def _step_one(self, slot: int, token: int):
        toks = np.zeros(self.batch, np.int32)
        toks[slot] = token
        self._ensure_write_pages()
        logits, self.caches = self._run_decode(toks)
        self.pos[slot] += 1
        self.slots[slot].pos = int(self.pos[slot])
        return np.asarray(logits[slot])

    def step(self) -> int:
        """One engine step: admit + one batched decode for live lanes.
        Returns the number of live sessions."""
        self._admit()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        toks = np.zeros(self.batch, np.int32)
        for i in live:
            toks[i] = self.slots[i].last_token
        self._ensure_write_pages()
        logits, self.caches = self._run_decode(toks)
        logits = np.asarray(logits)
        for i in live:
            sess = self.slots[i]
            req = sess.request
            self.pos[i] += 1
            sess.pos = int(self.pos[i])
            row = logits[i][:self.cfg.vocab]
            if req.temperature <= 0:
                nxt = int(np.argmax(row))
            else:
                p = np.exp((row - row.max()) / req.temperature)
                p /= p.sum()
                nxt = int(self.rng.choice(len(p), p=p))
            req.out_tokens.append(nxt)
            sess.last_token = nxt
            if len(req.out_tokens) >= req.max_new_tokens \
                    or self.pos[i] >= self.cache_len - 1:
                self._retire(i)
        return len(live)

    # ------------------------------------------------------ introspection --

    def describe(self) -> dict:
        """Structured engine signature: backend ids, decode mode, cache
        geometry and live page-pool stats.  ``describe_str()`` derives
        the one-line log form from this dict."""
        if self.paged:
            cache = dict(mode="paged", **self.kv.stats())
            cache["live_tokens"] = int(sum(
                s.live_tokens for s in self.slots if s is not None)
                + sum(s.live_tokens for s in self.queue))
        else:
            cache = {"mode": "contiguous"}
        cache["kv_bytes"] = int(sum(
            c[key].size * c[key].dtype.itemsize
            for c in self.caches for key in ("k8", "v8") if key in c))
        return {
            "ops": self.ops.name,
            "backends": {op: self.ops.backend_for(op).name
                         for op in OP_NAMES},
            "attn": "fused" if self.attn_fused else "two-pass",
            "decode": "fused" if self.decode_fused else "oracle",
            "fold_wo": self.fold_wo,
            "batch": self.batch,
            "cache_len": self.cache_len,
            "cache": cache,
        }

    def describe_str(self) -> str:
        """One-line engine signature for drivers/logs, derived from
        :meth:`describe`."""
        d = self.describe()
        c = d["cache"]
        if c["mode"] == "paged":
            cache = (f"paged[{c['page_size']}tok x {c['num_pages']}pg, "
                     f"{c['pages_used']}/{c['num_pages'] - 1} used]")
        else:
            cache = "contiguous"
        return (f"ops={d['ops']} attn={d['attn']} decode={d['decode']} "
                f"fold_wo={str(d['fold_wo']).lower()} cache={cache} "
                f"batch={d['batch']} cache_len={d['cache_len']}")

    def run_until_done(self, max_steps: int = 10000) -> List[Request]:
        """Step until queue and lanes drain; returns the requests that
        retired since the last call (completion order)."""
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        finished, self._finished = self._finished, []
        return finished
