"""Production mesh construction (multi-pod dry-run step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types only exists on jax >= 0.5 (explicit-sharding work)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def set_mesh(mesh):
    """Context manager scoping ``mesh``: jax.set_mesh on new jax, the
    Mesh object itself (which is a context manager) on older releases."""
    fn = getattr(jax, "set_mesh", None)
    return fn(mesh) if fn is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary test meshes (e.g. (2,2) on 4 fake devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get("model", 1)
