"""Pallas TPU kernels for the SwiftTron integer datapath.

One module per op (``int8_matmul``, ``int_softmax``, ``int_gelu``,
``int_layernorm``, ``int_attention`` — online softmax,
``int_attention_fused`` — bit-exact attention+requant) plus the pure-jnp
oracles (``ref``) they are tested against.  Models never import these
directly: dispatch goes through the ``repro.ops`` backend registry (see
docs/KERNELS.md for the contract, docs/OPS_API.md for the API).
``ops.py`` here is the deprecated string-dispatch shim kept for one
release of migration.
"""
