"""Pallas TPU kernels for the SwiftTron integer datapath.

One module per op (``int8_matmul``, ``int_softmax``, ``int_gelu``,
``int_layernorm``, ``int_attention`` — online softmax,
``int_attention_fused`` — bit-exact attention+requant,
``int_decode_attention`` — fused ragged-cache decode with valid_len
scalar-prefetch masking) plus the pure-jnp oracles (``ref``) they are
tested against.  Models never import these directly: dispatch goes
through the ``repro.ops`` backend registry (see docs/KERNELS.md for the
contract, docs/OPS_API.md for the API).  The old ``ops.py``
string-dispatch shims are removed; importing them raises with a pointer
to ``repro.ops``.
"""
