"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].  60 experts padded to 64 for 16-way EP; the
router masks padding experts to -inf (DESIGN.md §6)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=151936, head_dim=128,
    attn_bias=True, n_experts=60, top_k=4, n_shared_experts=4,
    moe_d_ff=1408, moe_every=1, activation="swiglu", norm="rmsnorm",
)
