"""Data pipeline: deterministic, shardable, resumable.

Two sources:
  * ``SyntheticLMDataset`` — a seeded Zipfian n-gram language (structured
    enough that models measurably learn it; used by examples/tests and the
    Table-II accuracy benchmark),
  * ``TokenFileDataset`` — memory-mapped uint16/uint32 token files (the
    production path: shard by host, sequential reads).

Both yield packed (tokens, labels) with next-token labels and support
``state_dict``/``load_state_dict`` so the fault-tolerant loop can resume
mid-epoch.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.common import ArchConfig


class SyntheticLMDataset:
    """Zipfian bigram-chain language with a few long-range copy rules."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.step = 0
        rng = np.random.default_rng(seed)
        v = vocab
        # sparse bigram table: each token has ~8 plausible successors
        self._succ = rng.integers(0, v, size=(v, 8))
        self._zipf_p = 1.0 / np.arange(1, 9)
        self._zipf_p /= self._zipf_p.sum()

    def _gen(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n + 1, np.int64)
        out[0] = rng.integers(0, self.vocab)
        choices = rng.choice(8, size=n, p=self._zipf_p)
        noise = rng.random(n)
        for i in range(n):
            if noise[i] < 0.05:       # 5% random restarts
                out[i + 1] = rng.integers(0, self.vocab)
            else:
                out[i + 1] = self._succ[out[i], choices[i]]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        # independent stream per (host, step) -> deterministic resume
        rng = np.random.default_rng(
            (self.seed, self.host_id, self.step))
        toks = np.stack([self._gen(rng, self.seq_len)
                         for _ in range(self.batch)])
        self.step += 1
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, st: Dict):
        self.step = st["step"]
        assert st["seed"] == self.seed, "dataset seed changed across restart"


class TokenFileDataset:
    """Memory-mapped token file, host-sharded, sequential windows."""

    def __init__(self, path: str, seq_len: int, batch: int,
                 dtype=np.uint16, host_id: int = 0, n_hosts: int = 1):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.seq_len = seq_len
        self.batch = batch
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.cursor = host_id * seq_len * batch

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        need = self.batch * (self.seq_len + 1)
        if self.cursor + need >= len(self.data):
            self.cursor = self.host_id * self.seq_len * self.batch
        flat = np.asarray(self.data[self.cursor:self.cursor + need])
        self.cursor += need * self.n_hosts
        toks = flat.reshape(self.batch, self.seq_len + 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state_dict(self):
        return {"cursor": self.cursor}

    def load_state_dict(self, st):
        self.cursor = st["cursor"]


def make_train_iterator(cfg: ArchConfig, seq_len: int, batch: int,
                        seed: int = 0, path: Optional[str] = None,
                        host_id: int = 0, n_hosts: int = 1):
    if path:
        return TokenFileDataset(path, seq_len, batch, host_id=host_id,
                                n_hosts=n_hosts)
    return SyntheticLMDataset(min(cfg.vocab, cfg.padded_vocab()), seq_len,
                              batch, seed, host_id, n_hosts)
