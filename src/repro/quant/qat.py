"""Quantization-aware training: the producer of SwiftTron checkpoints.

``loss_fn`` runs the float model with straight-through fake quantization on
every tensor the accelerator sees in INT8/INT10 (weights per-channel,
activations per-tensor on the design grids), so the trained weights land on
the integer grid that ``quant.convert`` freezes.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig
from repro.models.transformer import forward_float


def cross_entropy(logits, labels, vocab: int, z_loss: float = 1e-4):
    """Token CE with padding mask (label < 0 ignored) and z-loss.

    Sharding-aware: the gold logit is a one-hot contraction (not
    take_along_axis) so a vocab-sharded logits tensor reduces with a psum
    instead of an all-gather of the full (B,S,V) array."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=lf.dtype)
    gold = jnp.sum(lf * onehot, axis=-1)
    nll = lse - gold
    mask = (labels >= 0).astype(jnp.float32)
    nll = nll * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return (jnp.sum(nll) + jnp.sum(zl)) / denom


def chunked_ce(x, w, labels, cfg: ArchConfig, chunk: int = 512,
               z_loss: float = 1e-4):
    """Sequence-chunked CE: logits are (re)computed per chunk under remat,
    so the full (B,S,V) tensor never materialises — per-chunk peak is
    B * chunk * V / vocab-shards."""
    from repro.distributed.sharding import shard
    b, s, d = x.shape
    ck = min(chunk, s)
    while s % ck:
        ck -= 1
    n = s // ck

    def piece(args):
        xc, lc = args
        logits = jnp.einsum("bsd,dv->bsv", xc, w)
        logits = shard(logits, "batch", "seq", "vocab")
        lf = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(lf, -1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), -1)) + m[..., 0]
        onehot = jax.nn.one_hot(jnp.maximum(lc, 0), logits.shape[-1],
                                dtype=lf.dtype)
        gold = jnp.sum(lf * onehot, -1)
        mask = (lc >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        zl = z_loss * jnp.square(lse) * mask
        return jnp.sum(nll) + jnp.sum(zl), jnp.sum(mask)

    piece = jax.remat(piece)
    if n == 1:
        tot, cnt = piece((x, labels))
    else:
        xs = x.reshape(b, n, ck, d).transpose(1, 0, 2, 3)
        ls = labels.reshape(b, n, ck).transpose(1, 0, 2)
        def step(c, a):
            t, k = piece(a)
            return (c[0] + t, c[1] + k), None

        (tot, cnt), _ = jax.lax.scan(
            step, (jnp.zeros(()), jnp.zeros(())), (xs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig, qat: bool = True,
            aux_weight: float = 0.01):
    from repro.models import layers as fl
    x, aux = forward_float(params, batch, cfg, qat=qat,
                           return_hidden=True)
    x = fl.norm_fwd(params["final_norm"], x, cfg)
    x = fl.maybe_fq(x, cfg.s_act8, enabled=qat)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    w = fl.fq_weight(w, 1, qat)
    loss = chunked_ce(x, w, batch["labels"], cfg)
    return loss + aux_weight * aux, (loss, aux)
