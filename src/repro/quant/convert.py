"""Float checkpoint -> SwiftTron integer parameters (design-time flow,
paper Fig. 17: HuggingFace/PyTorch models + I-BERT quantization -> the
accelerator's constants).

Every weight becomes int8 with per-out-channel scales folded into int32
dyadic multiplier vectors; biases become int32 at the accumulator scale;
norm gammas/betas become the integer constants of the i-LayerNorm unit.
The result is (qparams, plans): the pytree of integer arrays and the
frozen static plan set.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import norms
from repro.models.common import ArchConfig
from repro.models.transformer import layer_group_spec
from repro.ops import QuantLinearParams
from repro.quant import plans as qplans

Pytree = Any


def _pc_scales(w: np.ndarray, out_axis: int) -> np.ndarray:
    axes = tuple(i for i in range(w.ndim) if i != out_axis % w.ndim)
    return np.maximum(np.abs(w).max(axis=axes), 1e-8) / 127.0


def _q_linear(w, plan: qplans.LinearPlan, bias=None, stacked: bool = False):
    """w: (K, N) or stacked (..., K, N) -> QuantLinearParams.

    Per-channel scales along the last axis; leading axes (layer-stack /
    expert) keep their own scale vectors.
    """
    w = np.asarray(jax.device_get(w), np.float64)
    s = np.maximum(np.abs(w).max(axis=-2), 1e-8) / 127.0       # (..., N)
    w8 = np.clip(np.round(w / s[..., None, :]), -127, 127).astype(np.int8)
    b_mult = bias32 = None
    if plan.s_out != 0.0:
        ratios = plan.s_in * s / plan.s_out
        b = np.round(ratios * (1 << plan.c))
        assert (np.abs(b) < 2 ** 31).all(), "per-channel multiplier overflow"
        b_mult = jnp.asarray(b.astype(np.int32))
    if bias is not None:
        bias = np.asarray(jax.device_get(bias), np.float64)
        bias32 = jnp.asarray(
            np.round(bias / (plan.s_in * s)).astype(np.int32))
    return QuantLinearParams(jnp.asarray(w8), b_mult, bias32), s


def _q_attn_w(w, plan):
    """(D,H,hd) or stacked (G,D,H,hd) -> flatten head dims."""
    w = np.asarray(jax.device_get(w), np.float64)
    flat = w.reshape(*w.shape[:-2], -1)
    q, _ = _q_linear(flat, plan)
    return q


def _q_norm(p, plan: norms.INormPlan):
    g, b = norms.quantize_norm_weights(
        jnp.asarray(np.asarray(jax.device_get(p["gamma"]), np.float32)),
        jnp.asarray(np.asarray(jax.device_get(p["beta"]), np.float32))
        if "beta" in p else None, plan)
    out = {"gamma_q": g}
    if b is not None:
        out["beta_q"] = b
    return out


def _q_attn(p, plans: qplans.AttnPlan):
    out = {
        "wq": _q_attn_w(p["wq"], plans.qkv),
        "wk": _q_attn_w(p["wk"], plans.qkv),
        "wv": _q_attn_w(p["wv"], plans.qkv),
    }
    wo = np.asarray(jax.device_get(p["wo"]), np.float64)
    wo = wo.reshape(*wo.shape[:-3], -1, wo.shape[-1])
    out["wo"], _ = _q_linear(wo, plans.out)
    for name in ("bq", "bk", "bv"):
        if name in p:
            w_key = "w" + name[1]
            bias = np.asarray(jax.device_get(p[name]), np.float64)
            bias = bias.reshape(*bias.shape[:-2], -1)
            w = np.asarray(jax.device_get(p[w_key]), np.float64)
            w = w.reshape(*w.shape[:-2], -1)
            s = np.maximum(np.abs(w).max(axis=-2), 1e-8) / 127.0
            out[w_key] = out[w_key]._replace(bias32=jnp.asarray(
                np.round(bias / (plans.qkv.s_in * s)).astype(np.int32)))
    return out


def _q_ffn(p, plans: qplans.FfnPlan):
    out = {}
    out["w1"], s1 = _q_linear(p["w1"], plans.up,
                              bias=p.get("b1"))
    if "w3" in p:
        out["w3"], _ = _q_linear(p["w3"], plans.up)
    out["w2"], _ = _q_linear(p["w2"], plans.down, bias=p.get("b2"))
    return out


def _q_moe(p, plans: qplans.MoePlan):
    out = {}
    w = np.asarray(jax.device_get(p["router"]), np.float64)
    s_router = np.abs(w).max() / 127.0
    out["router"] = QuantLinearParams(jnp.asarray(
        np.clip(np.round(w / s_router), -127, 127).astype(np.int8)))
    out["w1"], _ = _q_linear(p["w1"], plans.expert.up)
    if "w3" in p:
        out["w3"], _ = _q_linear(p["w3"], plans.expert.up)
    out["w2"], _ = _q_linear(p["w2"], plans.expert.down)
    if "shared" in p:
        out["shared"] = _q_ffn(p["shared"], plans.shared)
    return out, s_router


def _q_mamba(p, mp: qplans.MambaPlan, cfg: ArchConfig):
    w = np.asarray(jax.device_get(p["in_proj"]), np.float64)
    n_zxbc = w.shape[-1] - cfg.ssm_heads
    out = {}
    out["in_proj"], _ = _q_linear(w[..., :n_zxbc], mp.in_proj)
    wdt = w[..., n_zxbc:]
    s_dtw = float(np.abs(wdt).max()) / 127.0
    out["dt_proj"] = QuantLinearParams(jnp.asarray(
        np.clip(np.round(wdt / s_dtw), -127, 127).astype(np.int8)))
    cw = np.asarray(jax.device_get(p["conv_w"]), np.float64)
    s_conv = float(np.abs(cw).max()) / 127.0
    out["conv_w8"] = jnp.asarray(
        np.clip(np.round(cw / s_conv), -127, 127).astype(np.int8))
    a = np.exp(np.asarray(jax.device_get(p["A_log"]), np.float64))
    out["A_q"] = jnp.asarray(np.round(a / mp.s_A).astype(np.int32))
    # D on the 2^-16 state grid (D*x enters y in h units)
    out["D_q"] = jnp.asarray(np.round(
        np.asarray(jax.device_get(p["D"]), np.float64) / mp.s_h)
        .astype(np.int32))
    out["dt_bias_q"] = jnp.asarray(np.round(
        np.asarray(jax.device_get(p["dt_bias"]), np.float64)
        / (mp.in_proj.s_in * s_dtw)).astype(np.int32))
    g, _ = norms.quantize_norm_weights(
        jnp.asarray(np.asarray(jax.device_get(p["norm_gamma"]),
                               np.float32)), None, mp.norm)
    out["norm_gamma_q"] = g
    out["out_proj"], _ = _q_linear(p["out_proj"], mp.out_proj)
    return out, s_dtw, s_conv


def _q_sublayer(p, plans: qplans.LayerPlans, cfg: ArchConfig, kind,
                calib_sink: dict):
    mix, ff, has_cross = kind
    out = {"norm1": _q_norm(p["norm1"], plans.norm)}
    if mix in ("attn", "cross"):
        out["attn"] = _q_attn(p["attn"],
                              plans.attn if mix == "attn" else plans.cross)
    else:
        out["ssm"], s_dtw, s_conv = _q_mamba(p["ssm"], plans.mamba, cfg)
        calib_sink["s_dtw"] = s_dtw
        calib_sink["s_conv"] = s_conv
    if has_cross:
        out["cross"] = _q_attn(p["cross"], plans.cross)
        out["norm_cross"] = _q_norm(p["norm_cross"], plans.norm)
    if ff == "moe":
        out["moe"], s_router = _q_moe(p["moe"], plans.moe)
        calib_sink["s_router"] = s_router
    elif ff == "ffn":
        out["norm2"] = _q_norm(p["norm2"], plans.norm)
        out["ffn"] = _q_ffn(p["ffn"], plans.ffn)
    if ff == "moe":
        out["norm2"] = _q_norm(p["norm2"], plans.norm)
    return out


def quantize_params(params: Pytree, cfg: ArchConfig
                    ) -> Tuple[Pytree, qplans.LayerPlans]:
    """Float params -> (qparams, plans).  Two passes: measure the per-tensor
    calibration scales, freeze the plans, then quantize everything."""
    emb = np.asarray(jax.device_get(params["embed"]), np.float64)
    calib = {"s_emb": float(np.abs(emb).max()) / 127.0}
    # first pass purely to collect s_router / s_dtw / s_conv
    probe_plans = qplans.build_layer_plans(cfg, calib)
    gl, ng, kinds = layer_group_spec(cfg)
    sink: Dict[str, float] = {}
    for j, kind in enumerate(kinds):
        _q_sublayer(jax.tree.map(lambda t: t[:1], params["layers"][j]),
                    probe_plans, cfg, kind, sink)
    calib.update(sink)
    plans = qplans.build_layer_plans(cfg, calib)

    qparams: Dict[str, Pytree] = {}
    qparams["embed_w8"] = jnp.asarray(np.clip(
        np.round(emb / plans.embed.s_emb), -127, 127).astype(np.int8))
    qparams["final_norm"] = _q_norm(params["final_norm"], plans.final_norm)
    head_w = emb.T if cfg.tie_embeddings else np.asarray(
        jax.device_get(params["lm_head"]), np.float64)
    s_head = _pc_scales(head_w, 1)
    qparams["head"] = QuantLinearParams(jnp.asarray(np.clip(
        np.round(head_w / s_head[None, :]), -127, 127).astype(np.int8)))
    qparams["head_scale"] = jnp.asarray(s_head.astype(np.float32))
    qparams["layers"] = [
        _q_sublayer(params["layers"][j], plans, cfg, kinds[j], {})
        for j in range(gl)
    ]
    if cfg.family == "encdec":
        qparams["enc_layers"] = [
            _q_sublayer(params["enc_layers"][0], plans, cfg,
                        ("attn", "ffn", False), {})]
        qparams["enc_final_norm"] = _q_norm(params["enc_final_norm"],
                                            plans.norm)
    return qparams, plans
