from repro.serving.engine import EngineStalled, Request, ServingEngine
from repro.serving.kvcache import (BlockAllocator, CacheLayout, NULL_PAGE,
                                   PagedKVCache, PagePoolExhausted,
                                   PageTable, PrefixEntry, PrefixIndex,
                                   Session)
from repro.serving.speculate import (NgramProposer, Proposer,
                                     SpeculationError,
                                     SpeculationUnsupported, get_proposer,
                                     validate_spec)

__all__ = ["ServingEngine", "Request", "EngineStalled", "BlockAllocator",
           "CacheLayout", "NULL_PAGE", "PagedKVCache", "PagePoolExhausted",
           "PageTable", "PrefixEntry", "PrefixIndex", "Session",
           "NgramProposer", "Proposer", "SpeculationError",
           "SpeculationUnsupported", "get_proposer", "validate_spec"]
