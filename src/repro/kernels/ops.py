"""jit'd dispatch wrappers over the Pallas kernels and their jnp oracles.

``backend`` selects the implementation:
  * ``"ref"``       — pure-jnp (repro.core); what the multi-pod dry-run
                      compiles (XLA-visible FLOPs/bytes for the roofline);
  * ``"pallas"``    — pl.pallas_call with interpret=True on CPU (tests) and
                      interpret=False on real TPU.

Models call these entry points; the flag lives in the arch config
(``ArchConfig.kernel_backend``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.int_attention import int_attention_pallas
from repro.kernels.int_gelu import int_gelu_pallas
from repro.kernels.int_layernorm import int_layernorm_pallas
from repro.kernels.int_softmax import int_softmax_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def int8_matmul(x8, w8, bias32=None, dn=None, b_vec=None, c=0, pre=0,
                out_bits=8, backend="ref", **blocks):
    if backend == "pallas":
        out_dtype = jnp.int8 if out_bits <= 8 else jnp.int32
        return int8_matmul_pallas(x8, w8, bias32, dn=dn, b_vec=b_vec, c=c,
                                  pre=pre, out_bits=out_bits,
                                  out_dtype=out_dtype,
                                  interpret=_interpret(), **blocks)
    if dn is not None:
        return _ref.ref_int8_matmul(x8, w8, bias32, dn, out_bits)
    return _ref.ref_int8_matmul_perchannel(x8, w8, bias32, b_vec, c, pre,
                                           out_bits)


def int_softmax(scores, plan, backend="ref", **kw):
    if backend == "pallas":
        return int_softmax_pallas(scores, plan, interpret=_interpret(), **kw)
    return _ref.ref_int_softmax(scores, plan)


def int_gelu(q, plan, dn_out, out_bits=8, backend="ref", **kw):
    if backend == "pallas":
        return int_gelu_pallas(q, plan, dn_out, out_bits,
                               interpret=_interpret(), **kw)
    return _ref.ref_int_gelu(q, plan, dn_out, out_bits)


def int_layernorm(q, q_gamma, q_beta, plan, out_bits=8, backend="ref", **kw):
    if backend == "pallas":
        return int_layernorm_pallas(q, q_gamma, q_beta, plan, out_bits,
                                    interpret=_interpret(), **kw)
    return _ref.ref_int_layernorm(q, q_gamma, q_beta, plan, out_bits)


def int_attention(q8, k8, v8, plan, causal=True, window=0, out_bits=8,
                  backend="ref", **kw):
    if backend == "pallas":
        return int_attention_pallas(q8, k8, v8, plan, causal=causal,
                                    window=window, out_bits=out_bits,
                                    interpret=_interpret(), **kw)
    return _ref.ref_int_attention(q8, k8, v8, plan, causal, window, out_bits)
