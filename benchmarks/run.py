"""Benchmark harness: one function per paper table/figure.

Prints ``name,value,derived`` CSV rows.  Tables:
  Fig 2    -> bench_operators   (INT8 vs FP32 operator cost)
  Table I  -> bench_asic_model  (area/power/cycle model of the ASIC)
  Fig 18   -> bench_asic_model  (block-level area/power breakdown)
  Table II -> bench_table2      (accuracy: float vs integer path)
             + bench_asic_model latency rows (cycle model)
  §III     -> bench_approx_error (per-unit approximation error)
  kernels  -> bench_kernels     (per-kernel microbench)
  fusion   -> bench_fused_attention (fused vs two-pass attention)
  decode   -> bench_decode_attention (fused vs oracle ragged decode)
  serving  -> bench_serving     (paged vs contiguous engine; also writes
             the machine-readable benchmarks/BENCH_serving.json that the
             bench-smoke CI job uploads as an artifact)

``--quick`` runs a smoke subset (each module's cheapest shapes, the
slow accuracy sweep skipped) — the CI job runs exactly this, so the
benchmark scripts cannot rot.
"""
import inspect
import sys
import traceback


def main(argv=None) -> None:
    import os
    argv = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in argv
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import (bench_approx_error, bench_asic_model,
                            bench_decode_attention, bench_fused_attention,
                            bench_kernels, bench_operators, bench_serving,
                            bench_table2)
    mods = [bench_operators, bench_asic_model, bench_approx_error,
            bench_kernels, bench_fused_attention, bench_decode_attention,
            bench_serving, bench_table2]
    if quick:
        # the Table-II accuracy sweep dominates runtime; smoke the rest
        mods.remove(bench_table2)
    print("name,value,derived")
    ok = True
    for mod in mods:
        try:
            kw = {}
            if quick and "quick" in inspect.signature(mod.run).parameters:
                kw["quick"] = True
            for row in mod.run(**kw):
                print(",".join(str(x) for x in row))
        except Exception as e:
            ok = False
            print(f"{mod.__name__},ERROR,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
