"""Chunked batched prefill + cross-session prefix sharing: exact parity.

The contract under test (docs/KERNELS.md "paged prefill" section and
docs/ARCHITECTURE.md scheduler):

  * the ``int_paged_prefill`` op — scatter a prompt chunk's K/V through
    the page table, attend causally over history + chunk — is bit-exact
    against the ``ref_int_paged_prefill`` oracle for every backend:
    natively on ``pallas_fused`` (``paged_prefill`` capability, the
    fused kernel reading K/V through the scalar-prefetched table), via
    the dispatch layer's scatter/gather lowering everywhere else;
  * the folded o-projection (``prefill_wo_fold``) is bit-exact against
    the unfolded composition for all three RequantSpec forms;
  * the engine's chunked prefill pipeline produces token streams
    bit-identical to token streaming across cache_mode × backend ×
    chunk/budget, interleaves with decode under ``prefill_budget``, and
    survives mid-prefill preemption;
  * sessions sharing a prompt prefix map the same physical pages
    (allocator refcounts), produce identical streams, diverge safely
    through copy-on-write, and hit again after evict → re-admit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import attention as iattn
from repro.kernels import ref as kref
from repro.kernels.int_attention_fused import int_paged_prefill_fused
from repro.models import model as M
from repro.models import transformer as tf
from repro.ops import (QuantLinearParams, RequantSpec, get_backend,
                       resolve_ops)
from repro.ops.paged import scatter_chunk
from repro.quant import convert
from repro.serving import Request, ServingEngine

FUSED = get_backend("pallas_fused")


def _plan(d):
    return iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)


def _pool(rng, num_pages, ps, hkv, d):
    k = jnp.asarray(rng.integers(-127, 128, (num_pages, ps, hkv, d)),
                    jnp.int8)
    v = jnp.asarray(rng.integers(-127, 128, (num_pages, ps, hkv, d)),
                    jnp.int8)
    return k, v


def _chunk(rng, b, c, h, d):
    return jnp.asarray(rng.integers(-127, 128, (b, c, h, d)), jnp.int8)


# ------------------------------------------------- kernel-level parity ----

def test_paged_prefill_kernel_matches_oracle_ragged(rng):
    """Permuted, partially-mapped tables + ragged (page-unaligned) chunk
    bases: the kernel's block->page translation and stepped
    causal-over-history mask must match the scatter+gather+decode-oracle
    definition bit-for-bit, sub-page tiling included."""
    b, h, hkv, d, ps, num_pages, c = 3, 4, 2, 32, 16, 11, 32
    plan = _plan(d)
    q8 = _chunk(rng, b, c, h, d)
    kn, vn = _chunk(rng, b, c, hkv, d), _chunk(rng, b, c, hkv, d)
    kp, vp = _pool(rng, num_pages, ps, hkv, d)
    pages = jnp.asarray([[3, 7, 1, 0],      # fresh session: no history
                         [2, 4, 5, 6],      # one page of history
                         [8, 9, 10, 1]], jnp.int32)
    base = jnp.asarray([0, 16, 23], jnp.int32)     # 23: unaligned base
    want, kpr, vpr = kref.ref_int_paged_prefill(
        q8, kn, vn, kp, vp, plan, base, pages, ps)
    kps = scatter_chunk(kp, kn, base, pages, ps)
    vps = scatter_chunk(vp, vn, base, pages, ps)
    assert np.array_equal(np.asarray(kps), np.asarray(kpr))
    assert np.array_equal(np.asarray(vps), np.asarray(vpr))
    got = int_paged_prefill_fused(q8, kps, vps, plan, base + c, pages, ps,
                                  bkv=16)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # sub-page tiling: bkv < page_size walks sub-blocks through the
    # table; smaller query blocks exercise the q grid dimension
    got8 = int_paged_prefill_fused(q8, kps, vps, plan, base + c, pages,
                                   ps, bkv=8, bq=16)
    assert np.array_equal(np.asarray(got8), np.asarray(want))


def test_paged_prefill_scatter_routes_overflow_to_null_page(rng):
    """Chunk positions past the table span (padded tails) and positions
    on unmapped rows land on the reserved null page — a chunk write can
    never touch a live page it does not own."""
    ps, num_pages = 8, 5
    kp, _ = _pool(rng, num_pages, ps, 1, 4)
    chunk = _chunk(rng, 2, 8, 1, 4)
    pages = jnp.asarray([[1, 2], [0, 0]], jnp.int32)   # row 1 unmapped
    base = jnp.asarray([12, 0], jnp.int32)   # row 0 pads past 16
    out = scatter_chunk(kp, chunk, base, pages, ps)
    # row 0: positions 12..15 hit page 2 offsets 4..7; 16..19 -> null
    assert np.array_equal(np.asarray(out[2, 4:]),
                          np.asarray(chunk[0, :4]))
    # pages 1..4 untouched by row 1 (all writes absorbed by null page 0)
    assert np.array_equal(np.asarray(out[1]), np.asarray(kp[1]))
    assert np.array_equal(np.asarray(out[3:]), np.asarray(kp[3:]))


def test_paged_prefill_dispatch_parity_all_backends(rng):
    """OpSet capability negotiation: pallas_fused consumes the table
    natively, ref/pallas get the exact scatter/gather lowering — all
    three return identical attention outputs AND identical pool bytes."""
    b, h, hkv, d, ps, num_pages, c = 2, 2, 1, 16, 16, 7, 16
    plan = _plan(d)
    q8 = _chunk(rng, b, c, h, d)
    kn, vn = _chunk(rng, b, c, hkv, d), _chunk(rng, b, c, hkv, d)
    kp, vp = _pool(rng, num_pages, ps, hkv, d)
    pages = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    base = jnp.asarray([5, 32], jnp.int32)
    want, kpr, vpr = kref.ref_int_paged_prefill(
        q8, kn, vn, kp, vp, plan, base, pages, ps)
    for name in ("ref", "pallas", "pallas_fused"):
        o, kk, vv = resolve_ops(name).int_paged_prefill(
            q8, kn, vn, kp, vp, plan, base, pages, ps)
        assert np.array_equal(np.asarray(o), np.asarray(want)), name
        assert np.array_equal(np.asarray(kk), np.asarray(kpr)), name
        assert np.array_equal(np.asarray(vv), np.asarray(vpr)), name


def test_paged_prefill_untileable_falls_back_exactly(rng):
    """Pages below the kernel's min block (and odd chunk sizes) must
    gather + oracle with identical numerics rather than enter the
    kernel."""
    b, h, d, ps, num_pages, c = 2, 2, 16, 8, 9, 24
    plan = _plan(d)
    q8 = _chunk(rng, b, c, h, d)
    kn, vn = _chunk(rng, b, c, h, d), _chunk(rng, b, c, h, d)
    kp, vp = _pool(rng, num_pages, ps, h, d)
    pages = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    base = jnp.asarray([0, 8], jnp.int32)
    want, kpr, vpr = kref.ref_int_paged_prefill(
        q8, kn, vn, kp, vp, plan, base, pages, ps)
    o, kk, vv = FUSED.int_paged_prefill(q8, kn, vn, kp, vp, plan, base,
                                        pages, ps)
    assert np.array_equal(np.asarray(o), np.asarray(want))
    assert np.array_equal(np.asarray(kk), np.asarray(kpr))


# ----------------------------------------------------- wo-fold parity -----

@pytest.mark.parametrize("form", ["per_channel", "per_tensor", "raw"])
def test_prefill_wo_fold_matches_unfolded_composition(rng, form):
    """The folded o-projection epilogue of the prefill launch —
    in-kernel on pallas_fused (``prefill_wo_fold``), dispatch-composed
    on ref — is bit-exact against attention followed by the int8
    matmul, for every wo RequantSpec form."""
    from repro.core.dyadic import fit_dyadic
    b, h, hkv, d, ps, num_pages, c = 2, 4, 2, 16, 16, 9, 16
    n_out = h * d
    plan = _plan(d)
    q8 = _chunk(rng, b, c, h, d)
    kn, vn = _chunk(rng, b, c, hkv, d), _chunk(rng, b, c, hkv, d)
    kp, vp = _pool(rng, num_pages, ps, hkv, d)
    pages = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    base = jnp.asarray([0, 21], jnp.int32)
    wo_w8 = jnp.asarray(rng.integers(-127, 128, (h * d, n_out)), jnp.int8)
    bias32 = jnp.asarray(rng.integers(-500, 500, (n_out,)), jnp.int32)
    if form == "per_channel":
        spec = RequantSpec.per_channel(c=28, pre=7, out_bits=14)
        wo = QuantLinearParams(wo_w8, jnp.asarray(
            rng.integers(1000, 30000, (n_out,)), jnp.int32), bias32)
    elif form == "per_tensor":
        spec = RequantSpec.per_tensor(fit_dyadic(1 / 64.0, 1 << 24),
                                      out_bits=14)
        wo = QuantLinearParams(wo_w8, None, bias32)
    else:
        spec = RequantSpec.raw()
        wo = QuantLinearParams(wo_w8, None, bias32)
    o_attn, _, _ = kref.ref_int_paged_prefill(q8, kn, vn, kp, vp, plan,
                                              base, pages, ps)
    want = np.asarray(kref.ref_apply_wo(o_attn, wo.w8, wo.bias32,
                                        wo.b_mult, spec))
    for name in ("ref", "pallas_fused"):
        got, _, _ = resolve_ops(name).int_paged_prefill(
            q8, kn, vn, kp, vp, plan, base, pages, ps, wo=wo,
            wo_spec=spec)
        assert np.array_equal(np.asarray(got), want), (name, form)
    assert want.shape == (b, c, n_out)


def test_prefill_wo_fold_rejects_non_int8_attention_epilogue(rng):
    plan = _plan(16)
    q8 = _chunk(rng, 1, 16, 2, 16)
    kn = _chunk(rng, 1, 16, 2, 16)
    kp, vp = _pool(rng, 3, 16, 2, 16)
    pages = jnp.asarray([[1, 2]], jnp.int32)
    base = jnp.asarray([0], jnp.int32)
    wo = QuantLinearParams(
        jnp.asarray(rng.integers(-127, 128, (32, 32)), jnp.int8))
    for ops in (resolve_ops("ref"), FUSED):
        call = ops.int_paged_prefill
        with pytest.raises(ValueError, match="int8 attention epilogue"):
            call(q8, kn, kn, kp, vp, plan, base, pages, 16,
                 requant=RequantSpec.raw(), wo=wo,
                 wo_spec=RequantSpec.raw())
        with pytest.raises(ValueError, match="wo_spec"):
            call(q8, kn, kn, kp, vp, plan, base, pages, 16, wo=wo)


# ------------------------------------------------------- engine parity ----

@pytest.fixture(scope="module")
def engine_setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          capacity_factor=8.0)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


RNG = np.random.default_rng(7)
PROMPTS = [list(map(int, RNG.integers(1, 64, n))) for n in
           (40, 3, 25, 1, 33)]


def _drive(engine_setup, prompts=PROMPTS, max_new=4, **kw):
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64, **kw)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [r.out_tokens for r in reqs]


def test_engine_chunked_prefill_token_parity(engine_setup):
    """The acceptance matrix: chunked prefill must be bit-exact vs the
    token-streaming path across cache_mode × backend, for chunk sizes
    above/at/below the page size and with a budget that forces
    prefill/decode interleaving."""
    _, base = _drive(engine_setup, ops="ref", cache_mode="contiguous")
    combos = [
        dict(ops="ref"),                                  # chunked @32
        dict(ops="pallas_fused"),
        dict(ops="ref", prefill_chunk=16),                # == page size
        dict(ops="ref", prefill_chunk=8),                 # sub-page
        dict(ops="pallas_fused", prefill_chunk=64),
        dict(ops="ref", prefill_chunk=0),                 # streaming paged
        dict(ops="ref", prefill_budget=8),                # interleaved
        dict(ops="pallas_fused", prefill_chunk=16, prefill_budget=4),
        dict(ops="ref", fold_wo=False),
        dict(ops="ref", prefix_cache=False),
    ]
    for kw in combos:
        eng, toks = _drive(engine_setup, **kw)
        assert toks == base, kw
    # the fused engine runs the paged prefill kernel natively
    eng, _ = _drive(engine_setup, ops="pallas_fused")
    assert eng.prefill_paged_native
    assert eng.describe()["prefill"]["mode"] == "chunked"


def test_engine_prefix_sharing_maps_same_pages(engine_setup):
    """Two staggered same-prompt sessions: the second must hit the
    prefix table, physically share the first session's pages (allocator
    refcounts > 1 while both hold them), and emit an identical stream."""
    cfg, qp, plans = engine_setup
    _, solo = _drive(engine_setup, prompts=[PROMPTS[0]], max_new=4,
                     ops="ref", prefix_cache=False)
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    a = Request(uid=0, prompt=list(PROMPTS[0]), max_new_tokens=4)
    sa = eng.submit(a)
    eng.step()                              # a prefilled + first token
    b = Request(uid=1, prompt=list(PROMPTS[0]), max_new_tokens=4)
    sb = eng.submit(b)
    eng.step()                              # b admitted via prefix hit
    px = eng.describe()["cache"]["prefix"]
    assert px["hits"] == 1 and px["tokens_reused"] == len(PROMPTS[0]) - 1
    # physical sharing, observable in the allocator refcounts
    shared = set(sa.pages) & set(sb.pages)
    assert shared, "same-prompt sessions must map the same pages"
    assert all(eng.kv.allocator.refcount[p] > 1 for p in shared)
    assert eng.describe()["cache"]["shared_pages"] >= len(shared)
    eng.run_until_done()
    assert a.out_tokens == b.out_tokens == solo[0]
    eng.kv.allocator.check()


def test_engine_prefix_share_evict_readmit_bit_exact(engine_setup):
    """Prefix-share → evict → re-admit: the index outlives the session,
    so a re-admitted prompt hits the cached pages and reproduces the
    stream bit-exactly; clearing the index returns every page."""
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    a = Request(uid=0, prompt=list(PROMPTS[0]), max_new_tokens=4)
    sa = eng.submit(a)
    eng.step()
    eng.evict(sa)                           # mid-generation cancel
    partial = list(a.out_tokens)
    hits0 = eng.prefix.hits
    b = Request(uid=1, prompt=list(PROMPTS[0]), max_new_tokens=4)
    eng.submit(b)
    eng.run_until_done()
    assert eng.prefix.hits > hits0          # re-admit hit the cache
    assert b.out_tokens[:len(partial)] == partial
    _, solo = _drive(engine_setup, prompts=[PROMPTS[0]], max_new=4,
                     ops="ref", prefix_cache=False)
    assert b.out_tokens == solo[0]
    eng.prefix.clear()
    assert eng.kv.allocator.used_pages == 0
    eng.kv.allocator.check()


def test_engine_copy_on_write_divergence(engine_setup):
    """Sessions sharing a prefix then diverging: the first write into a
    shared page copies it (cow_copies > 0), streams match the unshared
    engine for BOTH prompts, and the cached prefix stays intact."""
    cfg, qp, plans = engine_setup
    p1 = list(PROMPTS[0])
    p2 = p1[:-1] + [int(p1[-1]) % 60 + 1]   # same prefix, last differs
    _, base = _drive(engine_setup, prompts=[p1, p2], max_new=4,
                     ops="ref", prefix_cache=False)
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    a = Request(uid=0, prompt=p1, max_new_tokens=4)
    eng.submit(a)
    eng.step()
    b = Request(uid=1, prompt=p2, max_new_tokens=4)
    eng.submit(b)
    eng.run_until_done()
    d = eng.describe()["cache"]
    assert d["prefix"]["hits"] >= 1         # p2 reused p1's prefix pages
    assert d["cow_copies"] > 0              # ... and diverged via CoW
    assert a.out_tokens == base[0]
    assert b.out_tokens == base[1]
    eng.kv.allocator.check()


def test_engine_preempt_mid_prefill_resumes_bit_exact(engine_setup):
    """A session preempted while its prompt is still prefilling keeps
    prefill_pos + pages and resumes the remaining chunks bit-exactly."""
    cfg, qp, plans = engine_setup
    _, solo = _drive(engine_setup, prompts=[PROMPTS[0]], max_new=4,
                     ops="ref")
    eng = ServingEngine(qp, plans, cfg, batch_size=1, cache_len=64,
                        ops="ref", prefill_chunk=16, prefill_budget=16)
    a = Request(uid=0, prompt=list(PROMPTS[0]), max_new_tokens=4)
    sa = eng.submit(a)
    eng.step()                              # one 16-token chunk only
    assert sa.state == "prefilling" and 0 < sa.prefill_pos < 39
    eng.preempt(sa)
    assert sa.state == "preempted" and sa.pages
    eng.submit(Request(uid=1, prompt=[7, 8], max_new_tokens=2))
    eng.run_until_done()
    assert a.out_tokens == solo[0]


def test_engine_prefill_budget_interleaves_decode(engine_setup):
    """With a budget, an already-decoding session keeps emitting a token
    every engine step while a long prompt prefills in the background."""
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", prefill_chunk=8, prefill_budget=8)
    a = Request(uid=0, prompt=[3, 1], max_new_tokens=30)
    eng.submit(a)
    eng.step()
    eng.submit(Request(uid=1, prompt=list(PROMPTS[0]), max_new_tokens=2))
    before = len(a.out_tokens)
    for _ in range(4):                      # prompt needs ~5 chunk rounds
        eng.step()
        assert len(a.out_tokens) == before + 1  # one token per step
        before += 1
    eng.run_until_done()


def test_engine_never_fits_with_prefix_hit_raises_without_leaking(
        engine_setup):
    """A prompt whose TOTAL block count exceeds the pool can never fit,
    prefix hit or not (shared pages are pool pages too): admission must
    raise the typed error immediately AND must not leak the refcounts
    the prefix lookup retained, even when the caller keeps stepping."""
    from repro.serving import PagePoolExhausted
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=1, cache_len=64,
                        ops="ref", page_size=16, num_pages=3)
    short = Request(uid=0, prompt=list(PROMPTS[0][:17]), max_new_tokens=1)
    eng.submit(short)                       # caches a 16-token prefix
    eng.run_until_done()
    long = Request(uid=1, prompt=list(PROMPTS[0][:17]) + [1] * 40,
                   max_new_tokens=1)
    eng.submit(long)
    before = eng.kv.allocator.refcount.copy()
    for _ in range(3):                      # every retry must be clean
        with pytest.raises(PagePoolExhausted):
            eng.step()
        assert np.array_equal(eng.kv.allocator.refcount, before)
    eng.prefix.clear()
    assert eng.kv.allocator.used_pages == 0
    eng.kv.allocator.check()


def test_engine_prefill_budget_caps_lanes_per_round(engine_setup):
    """The budget caps prompt tokens per engine step at chunk
    granularity: with budget == chunk, two co-admitted long prompts
    advance ONE lane per step, not both — and still finish bit-exactly."""
    cfg, qp, plans = engine_setup
    _, base = _drive(engine_setup, prompts=[PROMPTS[0], PROMPTS[2]],
                     max_new=4, ops="ref")
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", prefill_chunk=8, prefill_budget=8)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=4)
            for i, p in enumerate([PROMPTS[0], PROMPTS[2]])]
    sess = [eng.submit(r) for r in reqs]
    eng.step()
    advanced = sum(s.prefill_pos for s in sess)
    assert advanced <= 8                    # one chunk, one lane
    eng.run_until_done()
    assert [r.out_tokens for r in reqs] == base


def test_engine_typed_prefill_chunk_errors(engine_setup):
    cfg, qp, plans = engine_setup
    with pytest.raises(ValueError, match="divide or be a multiple"):
        ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                      ops="ref", prefill_chunk=24)
    with pytest.raises(ValueError, match="cache_mode='paged'"):
        ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                      ops="ref", cache_mode="contiguous", prefill_chunk=16)
    with pytest.raises(ValueError, match=">= 0"):
        ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                      ops="ref", prefill_chunk=-8)
    with pytest.raises(ValueError, match="prefill_budget"):
        ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                      ops="ref", prefill_budget=0)


def test_engine_sliding_window_arch_streams_and_rejects_chunked():
    """Sliding-window archs keep token-streaming prefill (a batched
    chunk write would clobber rolling-buffer positions earlier rows
    still need): the default silently streams, an explicit chunk is a
    typed error."""
    cfg = M.reduce_config(get_config("h2o-danube-3-4b"), dtype="float32",
                          vocab=128, num_layers=1)
    assert cfg.window > 0
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=80,
                        ops="ref")
    assert eng.describe()["prefill"]["mode"] == "streaming"
    assert eng.prefix is None               # prefix needs window == 0
    with pytest.raises(ValueError, match="unsupported for arch"):
        ServingEngine(qp, plans, cfg, batch_size=2, cache_len=80,
                      ops="ref", prefill_chunk=16)


# ------------------------------------------------------- bench schema -----

def test_bench_json_schema_checker(tmp_path):
    """The CI schema gate: a valid BENCH_serving.json document passes;
    a field drop or type change is caught.  (The artifact itself is
    generated, not checked in — when a local bench run left one behind,
    validate it too.)"""
    import json
    import os
    from benchmarks.check_bench_json import check_file
    data = {
        "configs": {"paged_chunked": {
            "tokens": 8, "tokens_per_s": 1.5, "kv_bytes": 1024,
            "kv_pack": "int8", "weight_bytes": 4096,
            "pages": {"page_size": 16, "num_pages": 7}, "mode": "paged",
            "prefill": {"mode": "chunked", "chunk": 32,
                        "ttft_s": 0.01, "tokens_per_s": 100.0},
            "prefix_hit_rate": None,
        }},
        "parity": True, "arch": "llama3-8b", "quick": True,
        "tp": {
            "devices": 4, "parity": True,
            "tp1": {"tokens_per_s": 10.0, "mode": "off",
                    "kv_bytes": 1024, "per_device_kv_bytes": 1024},
            "tp4": {"tokens_per_s": 9.0, "mode": "sharded",
                    "kv_bytes": 1024, "per_device_kv_bytes": 256},
        },
        "spec": {
            "k0": {"tokens_per_s": 10.0, "accept_rate": None,
                   "drafted": 0, "accepted": 0},
            "k2": {"tokens_per_s": 15.0, "accept_rate": 0.9,
                   "drafted": 100, "accepted": 90},
            "k4": {"tokens_per_s": 14.0, "accept_rate": 0.8,
                   "drafted": 200, "accepted": 160},
            "parity": True, "speedup": 1.5,
        },
        "latency": {
            "arrival_rate_per_s": 20.0, "submitted": 8,
            "terminal": {"completed": 7, "cancelled": 0, "timeout": 0,
                         "rejected": 1},
            "ttft_s": {"n": 7, "mean": 0.01, "p50": 0.008, "p99": 0.02},
            "inter_token_s": {"n": 21, "mean": 0.002, "p50": 0.001,
                              "p99": 0.007},
            "queue_wait_s": {"n": 7, "mean": 0.005, "p50": 0.004,
                             "p99": 0.01},
            "occupancy": {"mean": 1.5, "max": 2},
            "queue_depth": {"mean": 0.5, "max": 2},
        },
    }
    good = tmp_path / "BENCH_serving.json"
    good.write_text(json.dumps(data))
    assert check_file(str(good)) == []
    real = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_serving.json")
    if os.path.exists(real):                # generated by bench runs
        assert check_file(real) == []
    del data["parity"]
    del data["tp"]["tp4"]["per_device_kv_bytes"]
    for cfg in data["configs"].values():
        cfg["tokens_per_s"] = "fast"
    # semantic violations the structural pass can't see: inverted
    # percentiles, terminal counts not reconciling with submitted
    data["latency"]["ttft_s"]["p50"] = 0.5          # > p99 = 0.02
    data["latency"]["terminal"]["completed"] = 3    # sums to 4 != 8
    # the int4 KV tier gate: a paged_kv4 config that neither halves the
    # bytes nor tags itself int4 must be flagged
    data["configs"]["paged_kv4"] = dict(
        data["configs"]["paged_chunked"], kv_bytes=1000)
    bad = tmp_path / "BENCH_bad" / "BENCH_serving.json"
    bad.parent.mkdir()
    bad.write_text(json.dumps(data))
    errors = check_file(str(bad))
    assert any("parity" in e for e in errors)
    assert any("tokens_per_s" in e for e in errors)
    assert any("per_device_kv_bytes" in e for e in errors)
    assert any("p50" in e and "p99" in e for e in errors)
    assert any("submitted" in e for e in errors)
    assert any("1.8x gate" in e for e in errors)
    assert any("kv_pack" in e for e in errors)
    assert check_file(str(tmp_path / "BENCH_missing.json"))
