"""Paper Fig. 2: INT8 vs FP32 operator cost.

The paper synthesizes single adders/multipliers in 65 nm and reports ~10x
latency/power/area overheads for FP32.  Without a synthesis flow we
reproduce the claim two ways:
  1. an analytical gate-count model of ripple-carry INT8 vs IEEE-754 FP32
     units (standard VLSI counts), reproducing the order-of-magnitude gap;
  2. a measured JAX microbenchmark: int8->int32 matmul-accumulate vs fp32,
     showing the arithmetic-throughput direction on this host.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

# --- 1. analytical gate model (65nm-style unit counts) --------------------
# full adder ~ 5 gate-equivalents (GE); array multiplier n^2 FAs; FP32 has
# 24-bit mantissa datapath + alignment/normalisation shifters + exponent.
GE_FA = 5


def int_adder_ge(bits):
    return bits * GE_FA


def int_mult_ge(bits):
    return bits * bits * GE_FA


def fp32_adder_ge():
    # align shifter (~24*5 GE) + 24b add + norm shifter + exp logic
    return 24 * GE_FA + int_adder_ge(24) + 24 * GE_FA + 8 * GE_FA * 3


def fp32_mult_ge():
    return int_mult_ge(24) + int_adder_ge(8) * 2 + 24 * GE_FA


def run():
    rows = []
    add_ratio = fp32_adder_ge() / int_adder_ge(8)
    mul_ratio = fp32_mult_ge() / int_mult_ge(8)
    rows.append(("fig2_analytical_adder_overhead", 0.0, f"{add_ratio:.1f}x"))
    rows.append(("fig2_analytical_mult_overhead", 0.0, f"{mul_ratio:.1f}x"))

    # --- 2. measured matmul-accumulate throughput -------------------------
    n = 1024
    a8 = jnp.asarray(np.random.randint(-127, 128, (n, n)), jnp.int8)
    b8 = jnp.asarray(np.random.randint(-127, 128, (n, n)), jnp.int8)
    af, bf = a8.astype(jnp.float32), b8.astype(jnp.float32)

    f_int = jax.jit(lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32))
    f_fp = jax.jit(lambda a, b: a @ b)

    def bench(f, a, b, iters=10):
        f(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f(a, b).block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6

    t_int = bench(f_int, a8, b8)
    t_fp = bench(f_fp, af, bf)
    rows.append(("fig2_matmul_int8_us", t_int, ""))
    rows.append(("fig2_matmul_fp32_us", t_fp, ""))
    rows.append(("fig2_measured_ratio", 0.0, f"{t_fp / t_int:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
