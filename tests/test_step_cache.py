"""The process-level compiled-step cache (PR 3), asserted directly.

Until now the cache was only exercised implicitly (engines happened to
share executables in the serving suites).  Locked in here:

  * same-geometry engines share ONE jitted decode step (identity, not
    just equal keys) — the cross-engine bit-determinism story depends
    on it;
  * differing pool geometry / cache mode / chunk size / fold_wo miss;
  * the new mesh element: every unsharded engine keys ``("mesh", 1)``
    — including a ``tp > 1`` engine in gathered-fallback mode, which
    traces the identical single-device program and so must share the
    tp=1 executable (sharded mesh-keyed entries are asserted on the
    forced-4-device mesh in ``test_serving_sharded``).
"""
import jax
import pytest

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


def _engine(setup, **kw):
    cfg, qp, plans = setup
    kw.setdefault("batch_size", 2)
    kw.setdefault("cache_len", 64)
    kw.setdefault("ops", "ref")
    return ServingEngine(qp, plans, cfg, **kw)


def test_same_geometry_engines_share_one_step(setup):
    e1 = _engine(setup)
    e2 = _engine(setup)
    assert e1._decode is e2._decode
    assert e1._prefill_step is e2._prefill_step


def test_differing_geometry_misses(setup):
    base = _engine(setup)
    assert _engine(setup, num_pages=base.layout.num_pages + 3) \
        ._decode is not base._decode
    assert _engine(setup, page_size=8)._decode is not base._decode
    assert _engine(setup, cache_mode="contiguous")._decode \
        is not base._decode
    assert _engine(setup, fold_wo=False)._decode is not base._decode


def test_prefill_chunk_keyed_separately(setup):
    e1 = _engine(setup, prefill_chunk=16)
    e2 = _engine(setup, prefill_chunk=32)
    # the decode step doesn't depend on the chunk size — shared ...
    assert e1._decode is e2._decode
    # ... the prefill step does — distinct executables
    assert e1._prefill_step is not e2._prefill_step


def test_step_key_carries_mesh_element(setup):
    eng = _engine(setup)
    assert ("mesh", 1) in eng._step_key("decode")


def test_gathered_tp_fallback_shares_tp1_executable(setup):
    """A tp=2 engine in gathered-fallback mode traces the identical
    single-device program, so it must hit the tp=1 entry (its key
    carries the same ("mesh", 1) element).  Pinned to the pallas
    backend — it never advertises ``tp_serving``, so the engine gathers
    regardless of how many devices this process happens to have (the
    multi-device CI job runs this file under a forced 4-device
    count)."""
    e1 = _engine(setup, ops="pallas")
    e2 = _engine(setup, ops="pallas", tp=2)
    assert e2.describe()["tp"]["mode"] == "gathered"
    assert ("mesh", 1) in e2._step_key("decode")
    assert e1._decode is e2._decode
    assert e1._prefill_step is e2._prefill_step
