from repro.quant import calibrate, convert, plans, qat  # noqa: F401
