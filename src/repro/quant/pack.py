"""Design-time weight packing: int8 plans → the sub-8-bit storage tier.

Two schemes, both stored as two's-complement nibble pairs along the
contraction axis (byte ``i`` = values ``2i`` low / ``2i + 1`` high):

  * ``"int4"`` — plain nibbles; only valid when every weight already
    fits ``[-7, 7]`` (lossless there, refused otherwise);
  * ``"msr4"`` — the Low-Cost-AI-Accelerator observation that ~99% of
    int8 weights carry their information in a 4-bit most-significant
    run: store ``clip(w, -7, 7)`` as nibbles plus, per ``group``-sized
    K-slice and out-channel, a *static* number of outlier-compensation
    lanes ``(out_idx, out_val)`` with ``out_val = w - clip(w, -7, 7)``
    (∈ [-121, 120], an int8).  Reconstruction is exact for **every**
    int8 value, including -128.

Packing happens once, offline, in numpy — like ``quant.convert`` this
module is design-time code.  The runtime inverse lives in
``repro.ops.packed`` (the declared dequant reference) and the fused
in-kernel unpack in ``repro.kernels``.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.ops.spec import PackMeta, QuantLinearParams

__all__ = ["pack_int4", "pack_msr4", "pack_linear", "pack_tree"]


def _nibble_pack_np(a: np.ndarray, axis: int = -2) -> np.ndarray:
    a = np.asarray(a).astype(np.int32)
    ax = axis % a.ndim
    lo_sl = [slice(None)] * a.ndim
    hi_sl = [slice(None)] * a.ndim
    lo_sl[ax] = slice(0, None, 2)
    hi_sl[ax] = slice(1, None, 2)
    lo, hi = a[tuple(lo_sl)], a[tuple(hi_sl)]
    byte = (lo & 15) | ((hi & 15) << 4)
    return (((byte & 255) ^ 128) - 128).astype(np.int8)


def pack_int4(w8) -> np.ndarray:
    """Pack int4-range int8 weights ``(..., K, N)`` → ``(..., K//2, N)``.

    Raises if any ``|w| > 7`` — plain int4 has no outlier lanes, use
    :func:`pack_msr4` for general int8 weights.
    """
    w = np.asarray(w8)
    if w.shape[-2] % 2:
        raise ValueError(f"K must be even to nibble-pack, got {w.shape}")
    if w.size and int(np.abs(w.astype(np.int32)).max()) > 7:
        raise ValueError("int4 packing needs all |w| <= 7; use msr4 for "
                         "full int8 weights")
    return _nibble_pack_np(w, axis=-2)


def pack_msr4(w8, group: int = 256):
    """MSR-4 pack: nibbles + static-count outlier lanes. Lossless.

    Returns ``(packed, meta, out_idx, out_val)`` numpy arrays where
    ``packed`` is ``(..., K//2, N)`` int8 nibbles of ``clip(w, -7, 7)``,
    and for each K-group of size ``group`` and out-channel the
    ``n_outliers`` lanes hold within-group row indices (int16 — groups
    are far below 32768 rows) and deltas (int8) such that
    scatter-adding them reproduces ``w8`` exactly.  ``n_outliers`` is the *max* outlier count over all
    (group, channel) columns — filler lanes carry delta 0 — so the lane
    arrays are static-shaped and jit/scan friendly.
    """
    w = np.asarray(w8).astype(np.int32)
    *lead, k, n = w.shape
    if k % 2:
        raise ValueError(f"K must be even to nibble-pack, got {w.shape}")
    g = group if (group > 0 and k % group == 0) else k
    if g > 32767:
        raise ValueError(f"group {g} overflows the int16 outlier index")
    nib = np.clip(w, -7, 7)
    delta = w - nib                                   # in [-121, 120]
    ngrp = k // g
    d_g = delta.reshape(*lead, ngrp, g, n)
    m_g = d_g != 0
    n_out = int(m_g.sum(axis=-2).max(initial=0))
    # stable argsort of the inverted mask lists outlier rows first, so
    # the first n_out lanes per column are a permutation prefix: indices
    # are distinct and filler lanes land on delta-0 rows
    order = np.argsort(~m_g, axis=-2, kind="stable")
    out_idx = order[..., :n_out, :].astype(np.int16)
    out_val = np.take_along_axis(d_g, out_idx, axis=-2).astype(np.int8)
    packed = _nibble_pack_np(nib, axis=-2)
    meta = PackMeta(scheme="msr4", group=g, n_outliers=n_out, k=k)
    return packed, meta, out_idx, out_val


def pack_linear(qw, scheme: str = "msr4", group: int = 256
                ) -> QuantLinearParams:
    """Pack one dense ``QuantLinearParams`` into packed storage.

    ``b_mult`` / ``bias32`` ride along unchanged — the packed matmul's
    epilogue is the same typed ``RequantSpec`` path, applied to the
    bit-identical reconstructed accumulator.
    """
    qw = QuantLinearParams.of(qw)
    if qw.is_packed:
        return qw
    if qw.w8 is None:
        raise ValueError("cannot pack a QuantLinearParams without w8")
    w = np.asarray(qw.w8)
    if scheme == "int4":
        packed = pack_int4(w)
        meta = PackMeta(scheme="int4", group=0, n_outliers=0,
                        k=w.shape[-2])
        out_idx = out_val = None
    elif scheme == "msr4":
        packed, meta, out_idx, out_val = pack_msr4(w, group=group)
        out_idx = jnp.asarray(out_idx)
        out_val = jnp.asarray(out_val)
    else:
        raise ValueError(f"unknown pack scheme {scheme!r}")
    return QuantLinearParams(
        w8=None, b_mult=qw.b_mult, bias32=qw.bias32,
        w_packed=jnp.asarray(packed), pack_meta=meta,
        out_idx=out_idx, out_val=out_val)


def _packable(qw: QuantLinearParams) -> bool:
    if qw.is_packed or qw.w8 is None:
        return False
    w = qw.w8
    # 2-D plain weights or (ng, K, N) layer-group stacks; stacked expert
    # tensors (4-D) stay dense — expert matmuls don't dispatch through
    # int8_matmul_packed
    if w.ndim not in (2, 3):
        return False
    return w.shape[-2] % 2 == 0


def pack_tree(qparams, scheme: str = "msr4", group: int = 256):
    """Pack every packable ``QuantLinearParams`` in a parameter pytree.

    Leaves that are not linear params (embeddings, norm tables, conv
    filters) and shapes the runtime packed paths don't cover (odd K,
    4-D expert stacks) pass through unchanged.
    """
    def _maybe_pack(leaf):
        if isinstance(leaf, QuantLinearParams) and _packable(leaf):
            return pack_linear(leaf, scheme=scheme, group=group)
        return leaf

    return jax.tree_util.tree_map(
        _maybe_pack, qparams,
        is_leaf=lambda x: isinstance(x, QuantLinearParams))
