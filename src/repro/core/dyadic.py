"""Dyadic-number requantization (SwiftTron §III-C, Eq. 2; HAWQ-V3 [28]).

A scale ratio ``r = S_in / S_out`` is frozen at design time into a dyadic
number ``b / 2**c`` so the integer datapath never sees a float:

    q_out = (q_in * b) >> c

The ASIC multiplies INT32 by INT32 into a wide product register. TPUs (and
XLA without x64) give us int32*int32 with wrap-around, so we use the
**two-stage** formulation that is exactly representable in int32:

    q_out = rshift_round(rshift_round(q_in, pre) * b, c - pre)

with ``b`` constrained to ``mult_bits`` (default 15) bits and ``pre`` chosen
statically from the worst-case input magnitude so the product always fits in
int32.  ``pre`` discards input LSBs *below* the rounding point of the output;
with 15-bit multipliers the relative requant error is < 2**-14, far below
int8 output resolution.  All three constants are design-time Python ints —
they appear in the lowered graph as scalar constants, mirroring the paper's
"provided as constant values to the SwiftTron architecture".
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

INT32_MAX = 2**31 - 1


def bits_for(v: int) -> int:
    """Number of bits needed for magnitude ``v`` (ceil(log2(v+1)))."""
    v = int(v)
    if v <= 0:
        return 0
    return v.bit_length()


def rshift_round(x, s: int):
    """Arithmetic right shift by static ``s`` with round-half-up.

    s == 0 is the identity.  Works on int32 jnp arrays; the rounding addend
    is a design-time constant.
    """
    if s == 0:
        return x
    if s < 0:  # static left shift (exact)
        return x << (-s)
    half = 1 << (s - 1)
    return (x + half) >> s


def rshift_floor(x, s: int):
    if s <= 0:
        return x if s == 0 else x << (-s)
    return x >> s


@dataclasses.dataclass(frozen=True)
class Dyadic:
    """Frozen requant constants: value ≈ b / 2**(c) applied after ``pre``."""

    b: int          # multiplier, fits in ``mult_bits`` bits
    c: int          # total right shift (including ``pre``)
    pre: int        # input pre-shift so (q >> pre) * b fits int32
    qmax_in: int    # design-time bound on |q_in| this dyadic was sized for

    @property
    def value(self) -> float:
        return self.b / (1 << self.c) if self.c >= 0 else self.b * (1 << -self.c)

    def __call__(self, q):
        return apply_dyadic(q, self)


def fit_dyadic(ratio: float, qmax_in: int, mult_bits: int = 15) -> Dyadic:
    """Design-time fit of ``ratio`` (> 0) to a dyadic pair.

    ``qmax_in`` is the worst-case |q_in|; we size the pre-shift so the
    int32 product never overflows and statically verify it.
    """
    if not ratio > 0.0 or not math.isfinite(ratio):
        raise ValueError(f"dyadic ratio must be positive finite, got {ratio}")
    mb = mult_bits
    m, e = math.frexp(ratio)          # ratio = m * 2**e, m in [0.5, 1)
    b = int(round(m * (1 << mb)))
    c = mb - e
    if b == (1 << mb):                # rounding spilled over
        b >>= 1
        c -= 1
    while b and b % 2 == 0 and c > 0:  # exact power-of-two folding
        b >>= 1
        c -= 1

    def prod_max(pre_):
        half = 1 << max(0, c - pre_ - 1)
        return ((qmax_in >> pre_) + 1) * b + half   # +1: pre-shift rounding

    pre = 0
    while pre < c and prod_max(pre) > INT32_MAX:
        pre += 1
    if prod_max(pre) > INT32_MAX:
        raise ValueError(
            f"dyadic overflow: ratio={ratio} qmax_in={qmax_in} "
            f"(b={b}, c={c}, pre={pre})")
    return Dyadic(b=b, c=c, pre=pre, qmax_in=int(qmax_in))


def apply_dyadic(q, dn: Dyadic):
    """q_out = round(q * b / 2**c), staged in int32.  q: int32 array."""
    y = rshift_round(q, dn.pre)
    y = y * jnp.int32(dn.b)
    return rshift_round(y, dn.c - dn.pre)


def apply_dyadic_exact_np(q: np.ndarray, dn: Dyadic) -> np.ndarray:
    """int64 numpy oracle of the ideal (single-stage) dyadic requant."""
    q = q.astype(np.int64)
    half = 1 << (dn.c - 1) if dn.c > 0 else 0
    return (q * dn.b + half) >> dn.c


def requantize(q, ratio: float, qmax_in: int, out_bits: int = 8,
               mult_bits: int = 15):
    """One-shot: fit + apply + clip to the signed ``out_bits`` range.

    Returns int32 values clipped to the int``out_bits`` range (cast at the
    consumer: matmul inputs cast to int8).
    """
    dn = fit_dyadic(ratio, qmax_in, mult_bits)
    lo, hi = -(1 << (out_bits - 1)), (1 << (out_bits - 1)) - 1
    return jnp.clip(apply_dyadic(q, dn), lo, hi)


def clip_to_bits(q, out_bits: int):
    lo, hi = -(1 << (out_bits - 1)), (1 << (out_bits - 1)) - 1
    return jnp.clip(q, lo, hi)


def apply_dyadic_perchannel(q, b_vec, c: int, pre: int, axis: int = -1):
    """Per-channel dyadic requant: ``b_vec`` int32 array broadcast on ``axis``.

    The shift ``c``/``pre`` are shared statics (per-tensor), only the
    multiplier varies per channel — this matches per-channel weight scales
    folded into the output requant of a matmul.
    """
    shape = [1] * q.ndim
    shape[axis] = -1
    b = jnp.reshape(b_vec.astype(jnp.int32), shape)
    y = rshift_round(q, pre)
    y = y * b
    return rshift_round(y, c - pre)
