"""Float<->integer boundary: symmetric quantization, calibration statistics,
and straight-through fake-quant for QAT (SwiftTron §III-A).

The integer datapath itself never touches a float — this module is the
*design-time* side: it turns calibrated float ranges into frozen scales, and
provides the fake-quant operator the QAT training step uses so the trained
weights land on the same grid the accelerator executes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def qrange(bits: int):
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def scale_from_absmax(absmax: float, bits: int = 8) -> float:
    """Symmetric scale so that +-absmax maps onto the int range."""
    _, hi = qrange(bits)
    absmax = max(float(absmax), 1e-8)
    return absmax / hi


def quantize(x, scale: float, bits: int = 8):
    """Float -> int32 values on the int``bits`` grid (design-time helper)."""
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int32)


def dequantize(q, scale: float):
    return q.astype(jnp.float32) * scale


def fake_quant(x, scale, bits: int = 8):
    """Straight-through-estimator fake quantization for QAT.

    Forward: dequantize(quantize(x)); backward: identity inside the clip
    range (gradients flow through unchanged).  ``scale`` may be a traced
    array (per-channel QAT) or a Python float.
    """
    lo, hi = qrange(bits)
    xc = jnp.clip(x / scale, lo, hi)
    q = jnp.round(xc)
    return (x + jax.lax.stop_gradient((q - xc) * scale
                                      + (xc * scale - x))).astype(x.dtype)


def per_channel_absmax(x, axis: int):
    """Max-abs along all axes except ``axis`` (weight out-channel scales)."""
    axes = tuple(i for i in range(x.ndim) if i != (axis % x.ndim))
    return jnp.max(jnp.abs(x), axis=axes)


@dataclasses.dataclass
class CalibStats:
    """Running activation-range statistics collected by calibration."""
    absmax: float = 0.0
    n: int = 0

    def update(self, x) -> "CalibStats":
        m = float(jnp.max(jnp.abs(x)))
        return CalibStats(absmax=max(self.absmax, m), n=self.n + 1)

    def scale(self, bits: int = 8, headroom: float = 1.0) -> float:
        return scale_from_absmax(self.absmax * headroom, bits)


def ema_absmax(prev: float, x, decay: float = 0.95) -> float:
    """EMA max-abs update (per-tensor activation calibration)."""
    m = float(jnp.max(jnp.abs(x)))
    return decay * prev + (1.0 - decay) * m if prev > 0 else m
