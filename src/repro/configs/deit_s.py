"""DeiT-S — the paper's vision model (Table II): 12-layer pre-LN ViT,
196+1 patch tokens at 224x224 (patch embeddings stubbed)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deit-s", family="encoder", num_layers=12, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=1000, head_dim=64,
    activation="gelu", norm="layernorm", post_norm=False, pos="learned",
    n_img_tokens=197,
)
