"""The static bit-budget certifier and repo-rule linter.

Covers, deterministically: the budgets leaf (typed ``BitBudgetError``),
the ``IntRange`` domain and its dyadic transfer functions, the
kernel-contract checker (``check_launch`` / ``require_launch``) against
the kernels' real preconditions, the deliberately-unsafe-spec regression
(a bad constant must be *rejected with a typed, location-bearing
error*), the AST repo-rule linter (RR001-RR004), and a registry-config
certification smoke + the ``CERTIFY.json`` schema gate.  Randomised
soundness properties live in ``test_analysis_props.py``.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (BitBudgetError, INT32_MAX, IntRange,
                            KernelContractError, MAX_ROWSUM_LEN, MAX_SQ,
                            check_launch, require_launch, static_check)
from repro.analysis import contracts, interpret, lint, ranges
from repro.core.dyadic import Dyadic, fit_dyadic
from repro.ops.spec import RequantSpec

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------- budgets --

def test_static_check_passes_through_value():
    assert static_check(123, "x") == 123
    assert static_check(INT32_MAX, "x") == INT32_MAX


def test_bit_budget_error_is_typed_and_located():
    with pytest.raises(BitBudgetError) as ei:
        static_check(INT32_MAX + 1, "ffn accumulator", op="int8_matmul",
                     layer="ffn.down")
    e = ei.value
    assert isinstance(e, ValueError)          # legacy contract
    assert (e.what, e.value) == ("ffn accumulator", INT32_MAX + 1)
    assert e.budget == INT32_MAX
    assert (e.op, e.layer) == ("int8_matmul", "ffn.down")
    assert "int32 overflow in ffn accumulator" in str(e)
    assert "[op=int8_matmul]" in str(e) and "[layer=ffn.down]" in str(e)


def test_non_int32_budget_message():
    with pytest.raises(BitBudgetError, match="budget exceeded"):
        static_check(MAX_ROWSUM_LEN + 1, "softmax row length",
                     budget=MAX_ROWSUM_LEN)


# --------------------------------------------------------------- IntRange --

def test_intrange_properties():
    r = IntRange.symmetric(127)
    assert (r.lo, r.hi, r.qmax, r.bits) == (-127, 127, 127, 8)
    assert r.headroom_bits == 24
    assert IntRange.const(5).qmax == 5
    with pytest.raises(ValueError):
        IntRange(3, 2)


def test_clip_design_grid_vs_container():
    wide = IntRange.symmetric(1 << 20)
    assert ranges.t_clip(wide, 8) == IntRange(-127, 127)
    assert ranges.t_clip(wide, 8, design_grid=False) == IntRange(-128, 127)


def test_rshift_round_int_matches_jax_twin():
    import jax.numpy as jnp
    from repro.core.dyadic import rshift_round
    vals = [-(1 << 30), -12345, -1, 0, 1, 7, 12345, 1 << 30]
    for s in (0, 1, 3, 15):
        got = [ranges.rshift_round_int(v, s) for v in vals]
        ref = rshift_round(jnp.asarray(vals, jnp.int32), s).tolist()
        assert got == ref, (s, got, ref)


def test_t_dyadic_endpoints_are_exact():
    dn = fit_dyadic(0.003, 10_000)
    r = ranges.t_dyadic(IntRange.symmetric(10_000), dn)
    f = lambda v: ranges.rshift_round_int(
        ranges.rshift_round_int(v, dn.pre) * dn.b, dn.c - dn.pre)
    assert (r.lo, r.hi) == (f(-10_000), f(10_000))


# ----------------------------------------------- unsafe-spec regression --

def test_overflowing_requant_spec_rejected_with_location():
    """An intentionally-unsafe constant: a raw per-tensor multiplier with
    no pre-shift against a wide accumulator overflows the int32 staging
    product — certification must refuse it, naming op and layer."""
    bad = Dyadic(b=(1 << 15) - 1, c=20, pre=0, qmax_in=1 << 30)
    spec = RequantSpec.per_tensor(bad, out_bits=8)
    with pytest.raises(BitBudgetError) as ei:
        interpret.check_requant_spec(spec, IntRange.symmetric(1 << 30),
                                     op="int8_matmul", layer="attn.qkv")
    e = ei.value
    assert e.op == "int8_matmul" and e.layer == "attn.qkv"
    assert e.value > INT32_MAX
    assert "[layer=attn.qkv]" in str(e)


def test_safe_requant_spec_accepted():
    dn = fit_dyadic(1e-4, 1 << 22)
    spec = RequantSpec.per_tensor(dn, out_bits=8)
    out = interpret.check_requant_spec(spec, IntRange.symmetric(1 << 22),
                                       op="int8_matmul", layer="x")
    assert -128 <= out.lo <= out.hi <= 127


def test_overflowing_perchannel_spec_rejected():
    spec = RequantSpec.per_channel(c=16, pre=0, out_bits=8)
    with pytest.raises(BitBudgetError, match=r"\[op=int8_matmul\]"):
        interpret.check_requant_spec(spec, IntRange.symmetric(1 << 20),
                                     op="int8_matmul", layer="ffn.up")


# ---------------------------------------------------------- check_launch --

def test_check_launch_ok_and_grid():
    rep = check_launch("int8_matmul", m=256, n=256, k=1024)
    assert rep.ok and rep.fused
    assert rep.grid == (2, 2, 2)
    assert rep.blocks == {"bm": 128, "bn": 128, "bk": 512}
    assert rep.vmem_bytes > 0
    assert require_launch(rep) is rep


def test_check_launch_divisibility_violation():
    rep = check_launch("int8_matmul", m=100, n=30, k=64, bm=128, bn=28)
    assert not rep.ok
    with pytest.raises(KernelContractError) as ei:
        require_launch(rep)
    assert isinstance(ei.value, AssertionError)   # legacy assert contract
    assert ei.value.op == "int8_matmul"
    assert any("divide" in r for r in ei.value.reasons)


def test_check_launch_attention_budget():
    rep = check_launch("int_attention", b=1, sq=128, skv=MAX_ROWSUM_LEN + 1,
                       h=4, hkv=4, d=64)
    assert not rep.ok
    assert any("row-sum int32 budget" in r for r in rep.reasons)
    # the online kernel has a bigger budget: same shape passes
    rep = check_launch("int_attention", b=1, sq=128, skv=1 << 16,
                       h=4, hkv=4, d=64, online=True)
    assert rep.ok


def test_check_launch_policy_decline_is_not_an_error():
    """Tiny decode shapes: the kernel would accept, the backend falls
    back to the oracle — ok=True, fused=False."""
    rep = check_launch("int_attention", b=1, sq=8, skv=8, h=2, hkv=2, d=64)
    assert rep.ok and not rep.fused
    require_launch(rep)                           # must not raise


def test_check_launch_decode_paged_prefetch():
    rep = check_launch("int_decode_attention", b=3, sq=1, h=4, hkv=2,
                       d=64, max_pages=8, page_size=64)
    assert rep.ok and rep.fused
    assert rep.scalar_prefetch == (("valid_len", (3,)), ("pages", (3, 8)))
    rep = check_launch("int_decode_attention", b=1, sq=MAX_SQ + 1, h=4,
                       hkv=4, d=64, L=512)
    assert not rep.ok and any("Sq <=" in r for r in rep.reasons)


def test_check_launch_unknown_op():
    with pytest.raises(KeyError, match="unknown kernel op"):
        check_launch("int_conv", x=1)


def test_backend_policy_delegates_to_contracts():
    from repro.ops import get_backend
    be = get_backend("pallas_fused")
    cases = [(128, 128, 128, 128), (8, 8, 8, 8),
             (128, MAX_ROWSUM_LEN + 128, 128, 128)]
    for sq, skv, bq, bkv in cases:
        assert be._can_tile(sq, skv, bq, bkv) == \
            contracts.can_tile(sq, skv, bq, bkv, be.min_block)
    assert be._can_tile_decode(1, 256, 64, 128) == \
        contracts.can_tile_decode(1, 256, 64, 128, be.min_block)
    assert be._can_tile_prefill(512, 64, 128, 64) == \
        contracts.can_tile_prefill(512, 64, 128, 64, be.min_block)


def test_kernel_wrapper_raises_contract_error():
    import jax.numpy as jnp
    from repro.kernels.int8_matmul import int8_matmul_pallas
    with pytest.raises(AssertionError, match="launch contract violated"):
        int8_matmul_pallas(jnp.zeros((100, 64), jnp.int8),
                           jnp.zeros((64, 30), jnp.int8),
                           dn=fit_dyadic(0.01, 64 * 127 * 127),
                           bm=128, bn=28)


# ------------------------------------------------------------------ lint --

def test_lint_rr001_kernel_import_scoping():
    src = "from repro.kernels.int8_matmul import int8_matmul_pallas\n"
    bad = lint.lint_source(src, "src/repro/models/model.py")
    assert [f.code for f in bad] == ["RR001"]
    assert "backend registry" in bad[0].message
    # allowed scopes: kernels themselves and the backends
    assert lint.lint_source(src, "src/repro/ops/backends/pallas.py") == []
    assert lint.lint_source(src, "src/repro/kernels/ref.py") == []
    # tests/ and benchmarks/ are out of scope entirely
    assert lint.lint_source(src, "tests/test_kernels.py") == []


def test_lint_rr002_asarray_on_engine_state():
    bad = lint.lint_source("x = jnp.asarray(self.pos)\n",
                           "src/repro/serving/engine.py")
    assert [f.code for f in bad] == ["RR002"]
    assert "snapshot" in bad[0].message
    # snapshotted forms pass (the call result is not an ast.Attribute)
    ok = "a = jnp.asarray(self.pos.copy())\nb = jnp.asarray(t.snapshot())\n"
    assert lint.lint_source(ok, "src/repro/serving/engine.py") == []
    # outside serving/ the rule is silent
    assert lint.lint_source("x = jnp.asarray(self.pos)\n",
                            "src/repro/models/model.py") == []


def test_lint_rr003_float_dtype_in_core():
    bad = lint.lint_source("y = q.astype(jnp.float32)\n",
                           "src/repro/core/norms.py")
    assert [f.code for f in bad] == ["RR003"]
    # the dequant boundary is sanctioned
    assert lint.lint_source("y = q.astype(jnp.float32)\n",
                            "src/repro/core/quant.py") == []


def test_lint_rr004_unpack_above_backend_boundary():
    src = ("from repro.ops import packed\n"
           "w = packed.unpack_weights(qw)\n"
           "p = unpack_kv_pool(pool, shifts)\n")
    bad = lint.lint_source(src, "src/repro/models/intlayers.py")
    assert [f.code for f in bad] == ["RR004", "RR004"]
    bad = lint.lint_source(src, "src/repro/serving/engine.py")
    assert [f.code for f in bad] == ["RR004", "RR004"]
    # the kernel / backend tiers are the sanctioned unpack sites
    assert lint.lint_source(src, "src/repro/kernels/int8_matmul.py") == []
    assert lint.lint_source(
        src, "src/repro/ops/backends/pallas_fused.py") == []
    # packing on write is legal everywhere — the rule is unpack-prefixed
    assert lint.lint_source("k = pack_kv(v8)\n",
                            "src/repro/models/intlayers.py") == []


def test_lint_finding_format_is_location_bearing():
    f = lint.lint_source("import repro.kernels.ref\n",
                         "src/repro/serving/engine.py")[0]
    assert str(f).startswith("src/repro/serving/engine.py:1:0 RR001")


def test_repo_tree_lints_clean():
    assert lint.lint_paths([os.path.join(ROOT, "src", "repro")]) == []


# --------------------------------------------------------------- certify --

def test_certify_config_smoke():
    from repro.configs.registry import ARCHS
    name = sorted(ARCHS)[0]
    rep = interpret.certify_config(ARCHS[name], seq_len=256, cache_len=512)
    assert rep.name == name and rep.ops
    assert 0 < rep.worst_bits <= 32
    assert rep.min_headroom_bits >= 0
    assert rep.n_dyadics > 0
    assert any("qmax_res" in a for a in rep.assumptions)
    layers = {o.layer for o in rep.ops}
    assert "norm" in layers and "head" in layers


def test_certify_all_registry_configs():
    from repro.analysis.certify import certify_all
    report, n_failed = certify_all(seq_len=1024, cache_len=4096)
    assert n_failed == 0, [c.get("error") for c in
                           report["configs"].values() if not c["ok"]]
    assert report["schema"] == "repro/certify-v1"
    assert report["n_configs"] == len(report["configs"]) > 0
    assert report["budgets"]["MAX_ROWSUM_LEN"] == MAX_ROWSUM_LEN


def test_certify_cli_single_arch(tmp_path):
    from repro.analysis.certify import main
    from repro.configs.registry import ARCHS
    out = tmp_path / "CERTIFY.json"
    rc = main(["--arch", sorted(ARCHS)[0], "--seq-len", "256",
               "--cache-len", "512", "--json", str(out)])
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["n_failed"] == 0 and len(data["configs"]) == 1


def test_certify_json_artifact_schema():
    """The committed benchmarks/CERTIFY.json must satisfy the same schema
    gate CI applies via benchmarks/check_bench_json.py."""
    path = os.path.join(ROOT, "benchmarks", "CERTIFY.json")
    assert os.path.exists(path), "run python -m repro.analysis.certify"
    from benchmarks.check_bench_json import check_file
    assert check_file(path) == []


def test_lint_cli_exit_status(tmp_path):
    bad = tmp_path / "src" / "repro" / "core" / "z.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import jnp\ny = jnp.float32\n")
    rc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad)],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert rc.returncode == 1
    assert "RR003" in rc.stdout
