"""Schema sanity check for the machine-readable JSON artifacts
(``BENCH_*.json`` and the static-certification ``CERTIFY.json``).

CI's bench-smoke job runs this right after ``run.py --quick`` (and the
static-analysis job right after ``repro.analysis.certify``): the JSON
artifacts are consumed by tooling tracking the perf/certification
trajectory per commit, so a refactor that silently changes or drops a
field should fail the build, not the downstream dashboards.

The validator is a ~30-line structural checker (no external jsonschema
dependency): a schema is a dict mapping field name -> type | nested
schema | tuple of allowed types; ``...`` as a dict key validates every
value of an open-ended mapping against one sub-schema.  Unknown extra
fields are allowed (benches may grow columns), missing or mistyped
required fields are errors.
"""
from __future__ import annotations

import glob
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))

NUM = (int, float)

# one tp degree of the tensor-parallel measurement (4-device child)
TP_CONFIG = {
    "tokens_per_s": NUM,
    "mode": str,                    # "sharded" / "gathered" / "off"
    "kv_bytes": int,
    "per_device_kv_bytes": int,
}

SERVING_CONFIG = {
    "tokens": int,
    "tokens_per_s": NUM,
    "kv_bytes": int,
    "kv_pack": str,                 # stored KV element dtype: int8 / int4
    "weight_bytes": int,            # quantized-parameter bytes as stored
    "pages": dict,
    "mode": str,
    "prefill": {
        "mode": str,
        "chunk": int,
        "ttft_s": NUM,
        "tokens_per_s": NUM,
    },
    "prefix_hit_rate": (int, float, type(None)),
}

# one spec_k point of the speculative-decoding measurement
SPEC_CONFIG = {
    "tokens_per_s": NUM,
    "accept_rate": (int, float, type(None)),   # None at spec_k = 0
    "drafted": int,
    "accepted": int,
}

# one percentile summary of the latency section (front-end _pct shape)
PCT = {
    "n": int,
    "mean": NUM,
    "p50": NUM,
    "p99": NUM,
}

# request-latency distribution under open-loop load (async front end)
LATENCY = {
    "arrival_rate_per_s": NUM,
    "submitted": int,
    "terminal": {
        "completed": int,
        "cancelled": int,
        "timeout": int,
        "rejected": int,
    },
    "ttft_s": PCT,
    "inter_token_s": PCT,
    "queue_wait_s": PCT,
    "occupancy": {"mean": NUM, "max": int},
    "queue_depth": {"mean": NUM, "max": int},
}

# per-config entry of CERTIFY.json: only "ok" is shared between the
# certified shape (worst_bits/ops/assumptions) and the failed shape
# (error {what, value, budget, op, layer, message}) — the checker has
# no conditionals, so require the common field and let extras pass
CERTIFY_CONFIG = {
    "ok": bool,
}

SCHEMAS = {
    "BENCH_serving.json": {
        "configs": {...: SERVING_CONFIG},
        "parity": bool,
        "tp": {
            "devices": int,
            "parity": bool,
            "tp1": TP_CONFIG,
            "tp4": TP_CONFIG,
        },
        "spec": {
            "k0": SPEC_CONFIG,
            "k2": SPEC_CONFIG,
            "k4": SPEC_CONFIG,
            "parity": bool,
            "speedup": NUM,
        },
        "latency": LATENCY,
        "arch": str,
        "quick": bool,
    },
    "CERTIFY.json": {
        "schema": str,
        "seq_len": int,
        "cache_len": int,
        "budgets": {
            "INT32_MAX": int,
            "MAX_ROWSUM_LEN": int,
            "MAX_SQ": int,
        },
        "n_configs": int,
        "n_failed": int,
        "configs": {...: CERTIFY_CONFIG},
    },
}


def _check(value, schema, path: str, errors: list):
    if isinstance(schema, dict):
        if not isinstance(value, dict):
            errors.append(f"{path}: expected object, got "
                          f"{type(value).__name__}")
            return
        if ... in schema:
            for key, sub in value.items():
                _check(sub, schema[...], f"{path}.{key}", errors)
            return
        for key, sub in schema.items():
            if key not in value:
                errors.append(f"{path}.{key}: missing")
            else:
                _check(value[key], sub, f"{path}.{key}", errors)
        return
    if isinstance(schema, tuple):
        if not isinstance(value, schema) or isinstance(value, bool) \
                and bool not in schema:
            errors.append(f"{path}: expected one of "
                          f"{[t.__name__ for t in schema]}, got "
                          f"{type(value).__name__}")
        return
    if schema is bool:
        if not isinstance(value, bool):
            errors.append(f"{path}: expected bool, got "
                          f"{type(value).__name__}")
        return
    if not isinstance(value, schema) or isinstance(value, bool):
        errors.append(f"{path}: expected {schema.__name__}, got "
                      f"{type(value).__name__}")


def _semantic_serving(data: dict, errors: list):
    """Invariants the structural check can't express: percentile order,
    terminal-state accounting of the latency section, and the int4 KV
    tier's byte-reduction gate."""
    lat = data.get("latency")
    if not isinstance(lat, dict):
        return                      # structural check already flagged it
    for metric in ("ttft_s", "inter_token_s", "queue_wait_s"):
        p = lat.get(metric)
        if isinstance(p, dict) and isinstance(p.get("p50"), NUM) \
                and isinstance(p.get("p99"), NUM) and p["p50"] > p["p99"]:
            errors.append(f"latency.{metric}: p50 {p['p50']} > p99 "
                          f"{p['p99']}")
    term = lat.get("terminal")
    sub = lat.get("submitted")
    if isinstance(term, dict) and isinstance(sub, int):
        counts = [v for v in term.values() if isinstance(v, int)]
        if sum(counts) != sub:
            errors.append(f"latency.terminal: counts {term} sum to "
                          f"{sum(counts)}, expected submitted={sub}")
    # the sub-8-bit KV tier: on the equal-page-count schedule the int4
    # pool must actually halve the bytes (the bench's 1.8x gate), and
    # its kv_pack tag must say so
    cfgs = data.get("configs")
    if isinstance(cfgs, dict):
        base, kv4 = cfgs.get("paged_chunked"), cfgs.get("paged_kv4")
        if isinstance(base, dict) and isinstance(kv4, dict) \
                and isinstance(base.get("kv_bytes"), int) \
                and isinstance(kv4.get("kv_bytes"), int) \
                and kv4["kv_bytes"] > 0:
            ratio = base["kv_bytes"] / kv4["kv_bytes"]
            if ratio < 1.8:
                errors.append(f"configs.paged_kv4: kv_bytes reduction "
                              f"{ratio:.2f}x below the 1.8x gate")
            if kv4.get("kv_pack") != "int4":
                errors.append("configs.paged_kv4: kv_pack is "
                              f"{kv4.get('kv_pack')!r}, expected 'int4'")


SEMANTIC = {
    "BENCH_serving.json": _semantic_serving,
}


def check_file(path: str) -> list:
    """Validate one BENCH_*.json; returns a list of error strings."""
    name = os.path.basename(path)
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{name}: unreadable ({e})"]
    errors: list = []
    if not isinstance(data, dict):
        return [f"{name}: top level must be an object"]
    schema = SCHEMAS.get(name)
    if schema is not None:
        _check(data, schema, name, errors)
    semantic = SEMANTIC.get(name)
    if semantic is not None:
        semantic(data, errors)
    return errors


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        paths = sorted(glob.glob(os.path.join(HERE, "BENCH_*.json")))
        certify = os.path.join(HERE, "CERTIFY.json")
        if os.path.exists(certify):
            paths.append(certify)
    if not paths:
        print("check_bench_json: no BENCH_*.json files found",
              file=sys.stderr)
        return 1
    failed = False
    for path in paths:
        errors = check_file(path)
        status = "FAIL" if errors else "ok"
        print(f"{os.path.basename(path)}: {status}")
        for err in errors:
            failed = True
            print(f"  {err}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
