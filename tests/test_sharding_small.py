"""Scaled-down production-mesh integration: lower+compile AND execute the
sharded train/serve steps on a tiny (2,2) mesh with 4 real host devices.

This is the runnable counterpart of the 512-chip dry-run: same sharding
rules, same step functions, real numerics.  Runs through
``mesh_runner.run_with_devices`` — subprocess isolation keeps
``conftest.py``'s 1-device rule for smoke tests, and the runner's
prelude asserts the forced device count was actually obtained (the old
in-module ``os.environ`` mutation silently tested 1 device whenever jax
was already initialized).
"""
import pytest

from mesh_runner import run_with_devices

BODY = r"""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_config
from repro.launch import shardings as shd
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh, set_mesh
from repro.models import model as M, transformer as tf
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig
from repro.quant import convert

cfg = M.reduce_config(get_config("ARCH"), dtype="float32")
mesh = make_mesh((2, 2), ("data", "model"))
params = tf.init_params(jax.random.key(0), cfg)
b, s = 4, 32
batch = {"tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                      cfg.vocab),
         "labels": jax.random.randint(jax.random.key(2), (b, s), 0,
                                      cfg.vocab)}
if cfg.family == "vlm":
    batch["img_embeds"] = jax.random.normal(
        jax.random.key(3), (b, cfg.n_img_tokens, cfg.d_model))
if cfg.family == "encdec":
    batch["src_embeds"] = jax.random.normal(
        jax.random.key(3), (b, s, cfg.d_model))
with set_mesh(mesh):
    opt_cfg = AdamWConfig(lr=1e-3)
    p_sh = shd.param_pspecs(params, mesh)
    step = steps_mod.make_train_step(cfg, opt_cfg, param_specs=p_sh)
    opt = adamw_init(params, opt_cfg)
    b_sh = shd.batch_pspecs(batch, mesh)
    fn = jax.jit(step, in_shardings=(shd.as_shardings(p_sh, mesh), None,
                                     shd.as_shardings(b_sh, mesh)))
    params2, opt2, metrics = fn(params, opt, batch)
    loss1 = float(metrics["loss"])
    _, _, metrics2 = fn(params2, opt2, batch)
    loss2 = float(metrics2["loss"])
assert loss2 < loss1 + 0.5, (loss1, loss2)
# sharded == unsharded reference loss.  Dense archs are smooth in the
# reduction order, so float-eps differences stay well under 0.05.  MoE
# archs are NOT: top-k routing + capacity eviction are discontinuous in
# the router logits, and the sharded einsums' different reduction order
# perturbs logits at float-eps scale, which can flip near-tie
# token->expert assignments.  Each flipped token moves the mean loss by
# at most ~ln(vocab)/(b*s) = ln(512)/128 ~ 0.049, so we allow up to 3
# flips (0.16) for expert-routed models -- the observed miss (0.054)
# is exactly a one-token flip, not a numerics bug in either path.
from repro.quant import qat
ref_loss, _ = qat.loss_fn(params, batch, cfg, qat=True)
tol = 0.16 if cfg.n_experts else 0.05
assert abs(float(ref_loss) - loss1) < tol, (float(ref_loss), loss1, tol)
print("OK", loss1, loss2)
"""


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen2-moe-a2.7b",
                                  "jamba-v0.1-52b"])
def test_sharded_train_step_matches_reference(arch, tmp_path):
    out = run_with_devices(BODY.replace("ARCH", arch), 4, tmp_path)
    assert "OK" in out.stdout
