import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Re-run the (unrolled, reduced-batch) layer probes for existing
single-pod dry-run records and patch the JSONs in place."""
import json
import sys
import time
import traceback


from repro.configs.registry import ASSIGNED, get_config
from repro.launch.cells import cell_supported
from repro.launch.dryrun import _probe_layers
from repro.launch.mesh import make_production_mesh
from repro.models.common import SHAPES

OUT = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
mesh = make_production_mesh()
for arch in ASSIGNED:
    for shape_name in SHAPES:
        if cell_supported(arch, shape_name):
            continue
        p = os.path.join(OUT, f"{arch}_{shape_name}_16x16.json")
        if not os.path.exists(p):
            continue
        rec = json.load(open(p))
        if "error" in rec:
            continue
        t0 = time.time()
        try:
            rec["probe"] = _probe_layers(get_config(arch),
                                         SHAPES[shape_name], mesh)
            print(f"[probe] {arch} {shape_name}: "
                  f"ng1={rec['probe']['ng1']['flops']:.3e} "
                  f"ng2={rec['probe']['ng2']['flops']:.3e} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:
            print(f"[probe-fail] {arch} {shape_name}: {e}", flush=True)
            rec["probe_error"] = str(e)[:500]
            traceback.print_exc()
        json.dump(rec, open(p, "w"), indent=1)
