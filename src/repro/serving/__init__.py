from repro.serving.engine import ServingEngine, Request
from repro.serving.kvcache import (BlockAllocator, CacheLayout, NULL_PAGE,
                                   PagedKVCache, PagePoolExhausted,
                                   PageTable, PrefixEntry, PrefixIndex,
                                   Session)

__all__ = ["ServingEngine", "Request", "BlockAllocator", "CacheLayout",
           "NULL_PAGE", "PagedKVCache", "PagePoolExhausted", "PageTable",
           "PrefixEntry", "PrefixIndex", "Session"]
