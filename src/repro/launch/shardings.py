"""Parameter / input / cache sharding rules (DESIGN.md §7).

Rules are path-pattern based over the param pytree: TP on the ``model``
axis for heads / d_ff / vocab / experts, replication for norms and small
tensors, with divisibility guards (e.g. GQA kv heads replicate when
kv < model-axis size; mamba2-130m's fused in_proj width 3352 replicates
while jamba's 16544 shards).
"""
from __future__ import annotations

import fnmatch
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.treepath import path_parts

Pytree = Any


def as_shardings(tree, mesh):
    """PartitionSpec trees -> jit-compatible shardings.

    jax >= 0.5 accepts raw PartitionSpecs in in_shardings/out_shardings;
    older releases need them wrapped in NamedSharding."""
    if hasattr(jax, "set_mesh"):
        return tree
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree, is_leaf=lambda x: isinstance(x, P))


def _path_str(path) -> str:
    return "/".join(path_parts(path))


# (pattern, spec-template) — template entries: "model" | None | "div:<dim>"
# means: shard dim on model only when divisible.  Matched against the
# flattened path; first match wins.  Shapes are handled by _fit().
PARAM_RULES = [
    # ---- quantized params ----
    ("embed_w8", ("model", None)),
    ("head/w8", (None, "model")),
    ("head_scale", ("model",)),
    ("*attn/wq/w8", (..., None, "model")),
    ("*attn/wq/b_mult", (..., "model")),
    ("*attn/wq/bias32", (..., "model")),
    ("*attn/wk/*", (..., None, "model")),
    ("*attn/wv/*", (..., None, "model")),
    ("*cross/wq/w8", (..., None, "model")),
    ("*cross/wq/b_mult", (..., "model")),
    ("*cross/wk/*", (..., None, "model")),
    ("*cross/wv/*", (..., None, "model")),
    ("*attn/wo/w8", (..., "model", None)),
    ("*cross/wo/w8", (..., "model", None)),
    ("*attn/wo/b_mult", (..., None)),
    ("*moe/router/w8", (..., None, "model")),
    ("*moe/w1/w8", (..., "model", None, "data")),
    ("*moe/w1/b_mult", (..., "model", "data")),
    ("*moe/w3/w8", (..., "model", None, "data")),
    ("*moe/w3/b_mult", (..., "model", "data")),
    ("*moe/w2/w8", (..., "model", "data", None)),
    ("*moe/w2/b_mult", (..., "model", None)),
    ("*moe/shared/w1/*", (..., None, "model")),
    ("*moe/shared/w3/*", (..., None, "model")),
    ("*moe/shared/w2/w8", (..., "model", None)),
    ("*moe/shared/w2/b_mult", (..., None)),
    ("*ffn/w1/*", (..., None, "model")),
    ("*ffn/w3/*", (..., None, "model")),
    ("*ffn/w2/w8", (..., "model", None)),
    ("*ffn/w2/b_mult", (..., None)),
    ("*ssm/in_proj/w8", (..., None, "model")),
    ("*ssm/in_proj/b_mult", (..., "model")),
    ("*ssm/out_proj/w8", (..., "model", None)),
    ("*ssm/out_proj/b_mult", (..., None)),
    ("*ssm/norm_gamma_q", (..., "model")),
    # ---- float params (same geometry, head dims unflattened) ----
    ("embed", ("model", None)),
    ("lm_head", (None, "model")),
    ("pos_embed", (None, None)),
    ("*attn/wq", (..., None, "model", None)),
    ("*attn/wk", (..., None, "model", None)),
    ("*attn/wv", (..., None, "model", None)),
    ("*attn/wo", (..., "model", None, None)),
    ("*attn/bq", (..., "model", None)),
    ("*attn/bk", (..., "model", None)),
    ("*attn/bv", (..., "model", None)),
    ("*cross/wq", (..., None, "model", None)),
    ("*cross/wk", (..., None, "model", None)),
    ("*cross/wv", (..., None, "model", None)),
    ("*cross/wo", (..., "model", None, None)),
    ("*moe/router", (..., None, "model")),
    ("*moe/w1", (..., "model", None, "data")),
    ("*moe/w2", (..., "model", "data", None)),
    ("*moe/w3", (..., "model", None, "data")),
    ("*moe/shared/w1", (..., None, "model")),
    ("*moe/shared/w3", (..., None, "model")),
    ("*moe/shared/w2", (..., "model", None)),
    ("*ffn/w1", (..., None, "model")),
    ("*ffn/w3", (..., None, "model")),
    ("*ffn/w2", (..., "model", None)),
    ("*ffn/b1", (..., "model")),
    ("*ssm/in_proj", (..., None, "model")),
    ("*ssm/out_proj", (..., "model", None)),
    ("*ssm/norm_gamma", (..., "model")),
]


def _fit(template, shape, sizes: dict) -> P:
    """Expand a template against a concrete shape with divisibility guards."""
    tpl = list(template)
    if tpl and tpl[0] is Ellipsis:
        tpl = [None] * (len(shape) - (len(tpl) - 1)) + tpl[1:]
    if len(tpl) != len(shape):        # rank mismatch -> replicate
        return P(*([None] * len(shape)))
    out = []
    for dim, t in zip(shape, tpl):
        sz = sizes.get(t, 1) if isinstance(t, str) else 1
        if isinstance(t, str) and sz > 1 and dim % sz == 0 and dim >= sz:
            out.append(t)
        else:
            out.append(None)
    return P(*out)


def param_pspecs(tree: Pytree, mesh, fsdp: bool = False) -> Pytree:
    """PartitionSpec pytree for a (float or quantized) param tree.

    ``fsdp``: additionally spread every large weight over the ``data``
    axis (first unsharded divisible dim) — per-layer all-gather in
    exchange for /DP-degree parameter memory (used for >20B models)."""
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dsize = sizes.get("data", 1)

    def spec_for(path, leaf):
        ps = _path_str(path)
        spec = P(*([None] * len(leaf.shape)))
        for pat, tpl in PARAM_RULES:
            if fnmatch.fnmatch(ps, pat) or fnmatch.fnmatch(ps, "*" + pat):
                spec = _fit(tpl, leaf.shape, sizes)
                break
        if fsdp and leaf.size >= (1 << 24) and dsize > 1:
            flat = [a for s in spec if s for a in
                    (s if isinstance(s, tuple) else (s,))]
            if "data" not in flat:
                out = list(spec)
                best, best_dim = None, 0
                for i, (s, dim) in enumerate(zip(out, leaf.shape)):
                    if s is None and dim % dsize == 0 and dim > best_dim:
                        best, best_dim = i, dim
                if best is not None:
                    out[best] = "data"
                    spec = P(*out)
        return spec

    return jax.tree_util.tree_map_with_path(spec_for, tree)


def batch_pspecs(batch: Pytree, mesh) -> Pytree:
    """Inputs: batch dim over (pod, data); everything else replicated.
    Batch-1 (long-context) inputs replicate."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    for a in daxes:
        dsize *= sizes[a]

    def spec_for(path, leaf):
        if not leaf.shape:
            return P()
        b = leaf.shape[0]
        first = daxes if (b % dsize == 0 and b >= dsize) else None
        if isinstance(first, tuple) and len(first) == 1:
            first = first[0]
        return P(first, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_pspecs(cache: Pytree, mesh, cfg) -> Pytree:
    """Decode caches: (ng, B, L, Hkv, hd) — batch over data axes when
    divisible, kv-heads / mamba-heads / conv channels over model when
    divisible."""
    msize = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("model", 1)
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dsize = 1
    for a in daxes:
        dsize *= sizes[a]
    dax = daxes[0] if len(daxes) == 1 else daxes

    def spec_for(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % dsize == 0 and shape[1] >= dsize:
            spec[1] = dax
        # shard the "heads"-like dim on model when divisible
        name = ps.rsplit("/", 1)[-1]
        head_dim_idx = {"k8": 3, "v8": 3, "ck8": 3, "cv8": 3, "h": 2,
                        "conv": 3}.get(name)
        if head_dim_idx is not None and head_dim_idx < len(shape):
            if shape[head_dim_idx] % msize == 0 \
                    and shape[head_dim_idx] >= msize and msize > 1:
                spec[head_dim_idx] = "model"
            elif name in ("k8", "v8") and len(shape) >= 3 \
                    and shape[2] % msize == 0 and msize > 1:
                # GQA kv heads too few to shard -> shard the sequence dim
                # of the cache instead (long-context decode)
                spec[2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def named(tree: Pytree, mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
