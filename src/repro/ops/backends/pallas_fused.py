"""`pallas_fused` backend: bit-exact fused attention+requant kernel.

Everything except attention reuses the :class:`PallasBackend` kernels;
``int_attention`` routes to ``kernels.int_attention_fused`` — one kernel
launch for Q·Kᵀ → Shiftmax → P·V → requant, streaming over KV blocks —
and is **bit-exact** against the two-pass reference
(``kernels.ref.ref_int_attention``), unlike the ``pallas`` backend's
one-pass online kernel (±LSB).  ``int_decode_attention`` routes to
``kernels.int_decode_attention`` — the same fused datapath for the
serving hot path (Sq ≤ 8 queries over a ragged KV cache, per-slot
``valid_len`` as a scalar-prefetch operand, dead blocks skipped) —
bit-exact against ``kernels.ref.ref_int_decode_attention``.  The
backend additionally advertises the two optional decode capabilities
(docs/KERNELS.md): ``paged_decode`` (the page table rides as a second
scalar-prefetch operand and KV blocks translate through it in the index
map) and ``decode_wo_fold`` (the o-projection + its per-channel requant
run as the launch's epilogue).

Shapes the kernel can't tile fall back to the existing two-pass path
with identical numerics:

  * ``Skv > 2^15`` — the exact row sum would leave the int32 budget; the
    chunked two-pass streaming formulation takes over (per-tensor
    epilogues only, which is all the model datapath uses at such
    lengths);
  * awkward sequence lengths (no block divisor ≥ ``min_block`` — e.g. a
    prime Sq) and tiny problems, where a grid of degenerate blocks would
    be slower than the full-matrix oracle.

See docs/KERNELS.md for the kernel contract this backend satisfies.
"""
from __future__ import annotations

from repro.analysis import contracts as _contracts
from repro.analysis.budgets import MAX_ROWSUM_LEN as MAX_SKV
from repro.kernels import ref as _ref
from repro.ops import spec as _spec
from repro.ops.backends.pallas import PallasBackend, _fit_block
from repro.ops.paged import gather_pages as _gather

# NOTE: the fused kernel modules (kernels.int_attention_fused /
# kernels.int_decode_attention) are imported lazily inside the methods:
# this module runs during ``repro.ops`` package init, and both kernel
# modules themselves import ``repro.ops.spec`` — a top-level import here
# would re-enter a half-initialised kernel module whenever a caller
# imports a kernel before the ops package.


class PallasFusedBackend(PallasBackend):
    fused_attention = True
    fused_decode = True       # single-launch valid_len-masked decode kernel
    paged_decode = True       # consumes page-table KV pools directly
    decode_wo_fold = True     # folds the o-projection into the launch
    paged_prefill = True      # chunked prefill straight over the page table
    prefill_wo_fold = True    # ... with the o-projection folded in too
    packed_matmul = True      # int4/msr4 weights unpacked inside the launch
    packed_kv = True          # int4 KV pages dequantized inside the launch
    tp_serving = True         # kernels launch per-shard under shard_map
    #   (the wrapper's require_launch then validates the LOCAL h/tp,
    #   hkv/tp shapes; analysis.contracts.check_tp_launch is its
    #   offline twin)

    def __init__(self, name: str = "pallas_fused", interpret=None,
                 blocks=None, min_block: int = 16):
        super().__init__(name, interpret=interpret, blocks=blocks)
        self.min_block = min_block

    # --------------------------------------------------- packed matmul --

    def int8_matmul_packed(self, x8, qw, spec, **opts):
        """Matmul over int4/msr4 packed weights, nibbles expanded
        in-register — the dense int8 weight matrix never exists in HBM.

        * plain **int4** (and msr4 with zero outliers): one fused launch
          of the packed matmul kernel carrying the full typed epilogue;
        * **msr4** with outlier lanes: a *raw* packed launch accumulates
          the nibble contraction, the outlier lanes apply as an exact
          sparse correction (`x @ scatter(out_val)`), and integer
          distributivity makes ``acc_nib + corr == x @ w8`` exactly —
          the identical int32 accumulator then takes the identical
          dyadic epilogue, so the result is bit-exact vs the unpacked
          reference for every RequantSpec form.
        """
        from repro.kernels.int8_matmul import int8_matmul_pallas
        from repro.ops.packed import msr4_correction
        from repro.core.dyadic import (apply_dyadic,
                                       apply_dyadic_perchannel,
                                       clip_to_bits)
        import jax.numpy as jnp
        opts = self._opts("int8_matmul", opts)
        qw = _spec.QuantLinearParams.of(qw)
        meta = qw.pack_meta
        m, k = x8.shape
        n = qw.n_dim
        bm = _fit_block(opts.pop("bm", 128), m)
        bn = _fit_block(opts.pop("bn", 128), n)
        # nibble pairing needs an even K-block: fit on K/2 pairs, double
        bk = 2 * _fit_block(max(opts.pop("bk", 512) // 2, 1), k // 2)
        msr = meta.scheme == "msr4" and meta.n_outliers > 0
        if not msr:
            # pure-nibble weights: one launch, full fused epilogue
            if spec.is_raw:
                return int8_matmul_pallas(
                    x8, qw.w_packed, qw.bias32, out_bits=32,
                    out_dtype=jnp.int32, bm=bm, bn=bn, bk=bk,
                    packed=True, interpret=self._interp(), **opts)
            if spec.kind == _spec.PER_TENSOR:
                return int8_matmul_pallas(
                    x8, qw.w_packed, qw.bias32, dn=spec.dn,
                    out_bits=spec.out_bits, out_dtype=spec.out_dtype,
                    bm=bm, bn=bn, bk=bk, packed=True,
                    interpret=self._interp(), **opts)
            return int8_matmul_pallas(
                x8, qw.w_packed, qw.bias32, b_vec=qw.b_mult,
                c=spec.c, pre=spec.pre, out_bits=spec.out_bits,
                out_dtype=spec.out_dtype, bm=bm, bn=bn, bk=bk,
                packed=True, interpret=self._interp(), **opts)
        # msr4: raw nibble launch + exact sparse outlier correction,
        # then the same staged dyadic epilogue the kernel would fuse
        acc = int8_matmul_pallas(
            x8, qw.w_packed, None, out_bits=32, out_dtype=jnp.int32,
            bm=bm, bn=bn, bk=bk, packed=True,
            interpret=self._interp(), **opts)
        acc = acc + msr4_correction(x8.astype(jnp.int32), qw)
        if qw.bias32 is not None:
            acc = acc + qw.bias32.astype(jnp.int32)[None, :]
        if spec.is_raw:
            return acc
        if spec.kind == _spec.PER_TENSOR:
            out = apply_dyadic(acc, spec.dn)
        else:
            out = apply_dyadic_perchannel(acc, qw.b_mult, spec.c,
                                          spec.pre)
        return clip_to_bits(out, spec.out_bits).astype(spec.out_dtype)

    # ------------------------------------------------------- attention --

    def int_attention(self, q8, k8, v8, plan, causal: bool = True,
                      window: int = 0, out_bits: int = 8, requant=None,
                      b_vec=None, **opts):
        from repro.kernels.int_attention_fused import int_attention_fused
        opts = self._opts("int_attention", opts)
        if requant is None:
            requant = _spec.RequantSpec.per_tensor(plan.dn_out, out_bits)
        sq, skv = q8.shape[1], k8.shape[1]
        bq = _fit_block(opts.pop("bq", 128), sq)
        bkv = _fit_block(opts.pop("bkv", 128), skv)
        if not self._can_tile(sq, skv, bq, bkv):
            return self._two_pass_fallback(q8, k8, v8, plan, causal,
                                           window, requant, b_vec)
        return int_attention_fused(q8, k8, v8, plan, requant=requant,
                                   b_vec=b_vec, causal=causal,
                                   window=window, bq=bq, bkv=bkv,
                                   interpret=self._interp(), **opts)

    # -------------------------------------------------- decode attention --

    def int_decode_attention(self, q8, k8_cache, v8_cache, plan, valid_len,
                             out_bits: int = 8, requant=None, b_vec=None,
                             pages=None, page_size: int = 0, wo=None,
                             wo_spec=None, kv_shifts=None, **opts):
        from repro.kernels.int_decode_attention import \
            int_decode_attention_fused
        opts = self._opts("int_decode_attention", opts)
        if requant is None:
            requant = _spec.RequantSpec.per_tensor(plan.dn_out, out_bits)
        sq, d = q8.shape[1], q8.shape[3]
        paged = pages is not None
        # under paging the KV block must tile a physical page (the index
        # map translates whole sub-blocks through the table); otherwise
        # it tiles the contiguous cache length
        blk_dim = page_size if paged else k8_cache.shape[1]
        L = pages.shape[1] * page_size if paged else k8_cache.shape[1]
        bkv = _fit_block(opts.pop("bkv", 128), blk_dim)
        can = self._can_tile_decode(sq, L, d, bkv)
        if wo is not None:
            wo = _spec.QuantLinearParams.of(wo)
            if wo_spec is None:
                raise ValueError("folded wo projection needs wo_spec")
            # the folded projection feeds the attention tile to an int8
            # MXU contraction — a non-int8 epilogue can't fold, in the
            # kernel or in the fallback composition (which would wrap)
            if requant.is_raw or requant.out_bits > 8:
                raise ValueError("wo folding needs an int8 attention "
                                 f"epilogue, got {requant}")
        if not can:
            # exact fallback: dequantize packed pools (declared
            # reference) + gather pages (if paged) + full-matrix
            # oracle + unfolded o-projection
            if kv_shifts is not None:
                from repro.ops.packed import unpack_kv_pool
                k8_cache = unpack_kv_pool(k8_cache, kv_shifts[0])
                v8_cache = unpack_kv_pool(v8_cache, kv_shifts[1])
            if paged:
                k8_cache = _gather(k8_cache, pages, page_size)
                v8_cache = _gather(v8_cache, pages, page_size)
            o = _ref.ref_int_decode_attention(
                q8, k8_cache, v8_cache, plan, valid_len,
                requant=requant, b_vec=b_vec)
            if wo is None:
                return o
            return _ref.ref_apply_wo(o, wo.w8, wo.bias32, wo.b_mult,
                                     wo_spec)
        kw = {}
        if paged:
            kw.update(pages=pages, page_size=page_size)
        if kv_shifts is not None:
            kw.update(kv_shifts=kv_shifts)
        if wo is not None:
            kw.update(wo_w8=wo.w8, wo_bias32=wo.bias32, wo_b_vec=wo.b_mult,
                      wo_spec=wo_spec)
        return int_decode_attention_fused(q8, k8_cache, v8_cache, plan,
                                          valid_len, requant=requant,
                                          b_vec=b_vec, bkv=bkv,
                                          interpret=self._interp(),
                                          **kw, **opts)

    # ---------------------------------------------------- paged prefill --

    def int_paged_prefill(self, q8, k8_new, v8_new, k_pool, v_pool, plan,
                          base_pos, pages, page_size: int,
                          out_bits: int = 8, requant=None, b_vec=None,
                          wo=None, wo_spec=None, kv_shifts=None, **opts):
        """Chunked paged prefill: scatter the chunk's K/V through the
        page table (``repro.ops.paged.scatter_chunk`` — shared with the
        oracle, so every path writes identical pool bytes), then run the
        fused prefill attention kernel reading K/V through the
        scalar-prefetched table (``kernels.int_attention_fused.
        int_paged_prefill_fused``).  With ``kv_shifts`` (int4 KV pages)
        the chunk quantizes + nibble-packs through
        ``repro.ops.packed.pack_kv`` before the scatter — one
        quantization policy shared with the OpSet lowering, so pool
        bytes stay backend-independent — and the fused kernel
        dequantizes in-register.  Untileable shapes gather + take the
        stepped-mask decode oracle with identical numerics."""
        from repro.kernels.int_attention_fused import \
            int_paged_prefill_fused
        from repro.ops.paged import scatter_chunk
        import jax.numpy as jnp
        opts = self._opts("int_paged_prefill", opts)
        if requant is None:
            requant = _spec.RequantSpec.per_tensor(plan.dn_out, out_bits)
        c, d = q8.shape[1], q8.shape[3]
        pages = jnp.asarray(pages, jnp.int32)
        L = pages.shape[1] * page_size
        if wo is not None:
            wo = _spec.QuantLinearParams.of(wo)
            if wo_spec is None:
                raise ValueError("folded wo projection needs wo_spec")
            if requant.is_raw or requant.out_bits > 8:
                raise ValueError("wo folding needs an int8 attention "
                                 f"epilogue, got {requant}")
        if kv_shifts is not None:
            from repro.ops.packed import pack_kv
            k8_new = pack_kv(k8_new)
            v8_new = pack_kv(v8_new)
        k_pool = scatter_chunk(k_pool, k8_new, base_pos, pages, page_size)
        v_pool = scatter_chunk(v_pool, v8_new, base_pos, pages, page_size)
        pos_end = jnp.asarray(base_pos, jnp.int32) + c
        bq = _fit_block(opts.pop("bq", 128), c)
        bkv = _fit_block(opts.pop("bkv", 128), page_size)
        if not self._can_tile_prefill(L, d, bq, bkv):
            # exact fallback: dequantize the (post-scatter) packed pools
            # (declared reference), gather, then the stepped-mask oracle
            # + unfolded o-projection
            if kv_shifts is not None:
                from repro.ops.packed import unpack_kv_pool
                kc = _gather(unpack_kv_pool(k_pool, kv_shifts[0]),
                             pages, page_size)
                vc = _gather(unpack_kv_pool(v_pool, kv_shifts[1]),
                             pages, page_size)
            else:
                kc = _gather(k_pool, pages, page_size)
                vc = _gather(v_pool, pages, page_size)
            o = _ref.ref_int_decode_attention(q8, kc, vc, plan, pos_end,
                                              requant=requant, b_vec=b_vec)
            if wo is not None:
                o = _ref.ref_apply_wo(o, wo.w8, wo.bias32, wo.b_mult,
                                      wo_spec)
            return o, k_pool, v_pool
        kw = {}
        if kv_shifts is not None:
            kw.update(kv_shifts=kv_shifts)
        if wo is not None:
            kw.update(wo_w8=wo.w8, wo_bias32=wo.bias32, wo_b_vec=wo.b_mult,
                      wo_spec=wo_spec)
        o = int_paged_prefill_fused(q8, k_pool, v_pool, plan, pos_end,
                                    pages, page_size, requant=requant,
                                    b_vec=b_vec, bq=bq, bkv=bkv,
                                    interpret=self._interp(), **kw, **opts)
        return o, k_pool, v_pool

    # the fused-vs-fallback tiling policy is owned declaratively by
    # repro.analysis.contracts so offline certification predicts the
    # exact same dispatch this backend takes

    def _can_tile_prefill(self, L: int, d: int, bq: int, bkv: int) -> bool:
        return _contracts.can_tile_prefill(L, d, bq, bkv, self.min_block)

    def _can_tile_decode(self, sq: int, L: int, d: int, bkv: int) -> bool:
        return _contracts.can_tile_decode(sq, L, d, bkv, self.min_block)

    def _can_tile(self, sq: int, skv: int, bq: int, bkv: int) -> bool:
        return _contracts.can_tile(sq, skv, bq, bkv, self.min_block)

    def _two_pass_fallback(self, q8, k8, v8, plan, causal, window,
                           requant, b_vec):
        """The pre-fusion formulation, numerics preserved exactly."""
        sq, skv = q8.shape[1], k8.shape[1]
        if skv > MAX_SKV:
            # memory-bounded chunked streaming (per-tensor epilogue: the
            # only form the model datapath carries at such lengths)
            if requant.kind != _spec.PER_TENSOR:
                raise NotImplementedError(
                    f"Skv={skv} needs the chunked streaming path, which "
                    "supports per-tensor requant only")
            from repro.core import attention as iattn
            import jax.numpy as jnp
            h, hkv = q8.shape[2], k8.shape[2]
            if hkv != h:
                k8 = jnp.repeat(k8, h // hkv, axis=2)
                v8 = jnp.repeat(v8, h // hkv, axis=2)
            p = plan._replace(dn_out=requant.dn)
            out = iattn.i_attention_chunked(
                q8, k8, v8, p, chunk=_fit_block(1024, skv), causal=causal,
                window=window, out_bits=requant.out_bits)
            return out.astype(jnp.int8) if requant.out_bits <= 8 else out
        return _ref.ref_int_attention(q8, k8, v8, plan, causal=causal,
                                      window=window, requant=requant,
                                      b_vec=b_vec)
