"""pallas_fused: exact-integer parity with the two-pass reference.

The contract under test (docs/KERNELS.md): the single-launch fused
attention+requant kernel is *bit-exact* against
``kernels.ref.ref_int_attention`` — not ±LSB like the online-softmax
``pallas`` kernel — for every RequantSpec epilogue form, on self- and
cross-attention, across head dims / sequence lengths / masks, including
shapes that force the backend's two-pass fallback.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as iattn
from repro.core.dyadic import fit_dyadic
from repro.ops import RequantSpec, get_backend, resolve_ops

FUSED = get_backend("pallas_fused")
REF = get_backend("ref")


def _qkv(rng, b, sq, skv, h, hkv, d):
    q8 = np.clip(rng.normal(0, 40, (b, sq, h, d)), -127, 127).astype(np.int8)
    k8 = np.clip(rng.normal(0, 40, (b, skv, hkv, d)), -127, 127) \
        .astype(np.int8)
    v8 = np.clip(rng.normal(0, 40, (b, skv, hkv, d)), -127, 127) \
        .astype(np.int8)
    return jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8)


def _plan(d):
    return iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)


@pytest.mark.parametrize("sq,skv,h,hkv,d,causal,window", [
    (256, 256, 4, 2, 64, True, 0),      # causal GQA
    (256, 256, 4, 4, 64, True, 96),     # sliding window
    (128, 256, 4, 4, 64, False, 0),     # cross-attention (rect, no mask)
    (64, 192, 8, 2, 32, False, 0),      # cross + GQA + non-128 seq
    (128, 128, 2, 2, 128, True, 0),     # wide head dim
    (192, 192, 2, 1, 48, True, 0),      # non-multiple-of-block seq + d
])
def test_exact_parity_per_tensor(rng, sq, skv, h, hkv, d, causal, window):
    plan = _plan(d)
    q8, k8, v8 = _qkv(rng, 2, sq, skv, h, hkv, d)
    got = np.asarray(FUSED.int_attention(q8, k8, v8, plan, causal=causal,
                                         window=window, bq=64, bkv=64))
    want = np.asarray(REF.int_attention(q8, k8, v8, plan, causal=causal,
                                        window=window))
    assert np.array_equal(got, want)
    assert got.dtype == np.int8


@pytest.mark.parametrize("form", ["per_tensor", "per_channel", "raw"])
@pytest.mark.parametrize("cross", [False, True])
def test_exact_parity_all_requant_forms(rng, form, cross):
    h, hkv, d = 4, 2, 64
    sq, skv = (64, 192) if cross else (128, 128)
    causal = not cross
    plan = _plan(d)
    q8, k8, v8 = _qkv(rng, 1, sq, skv, h, hkv, d)
    b_vec = None
    if form == "per_tensor":
        spec = RequantSpec.per_tensor(fit_dyadic(plan.dn_out.value * 1.7,
                                                 127 * (1 << 8)))
    elif form == "per_channel":
        spec = RequantSpec.per_channel(c=28, pre=7)
        b_vec = jnp.asarray(np.random.default_rng(1).integers(
            1000, 30000, (h * d,)), jnp.int32)
    else:
        spec = RequantSpec.raw()
    got = np.asarray(FUSED.int_attention(q8, k8, v8, plan, causal=causal,
                                         requant=spec, b_vec=b_vec,
                                         bq=64, bkv=64))
    want = np.asarray(REF.int_attention(q8, k8, v8, plan, causal=causal,
                                        requant=spec, b_vec=b_vec))
    assert np.array_equal(got, want)
    if form == "raw":
        assert got.dtype == np.int32
        # raw == the int32 P*V accumulator, untouched
        assert np.abs(got).max() > 127


@pytest.mark.parametrize("sq,skv", [
    (131, 131),    # prime > 128: largest divisor block is 1
    (8, 128),      # decode-sized query: oracle wins
    (64, 262),     # 2*131 KV: largest usable divisor (2) under min_block
])
def test_untileable_shapes_fall_back_exactly(rng, sq, skv):
    """Divisor-starved / tiny lengths: the backend falls back to the
    two-pass path and stays exact (the kernel is never entered —
    _can_tile refuses the shape)."""
    h, hkv, d = 4, 2, 64
    plan = _plan(d)
    assert not FUSED._can_tile(sq, skv, *_fit2(sq, skv))
    q8, k8, v8 = _qkv(rng, 1, sq, skv, h, hkv, d)
    got = np.asarray(FUSED.int_attention(q8, k8, v8, plan, causal=False))
    want = np.asarray(REF.int_attention(q8, k8, v8, plan, causal=False))
    assert np.array_equal(got, want)


def _fit2(sq, skv):
    from repro.ops.backends.pallas import _fit_block
    return _fit_block(128, sq), _fit_block(128, skv)


def test_oversized_rows_use_chunked_streaming(rng):
    """Skv beyond the exact row-sum budget (2^15) routes to the chunked
    two-pass streaming path; per-channel/raw epilogues raise there — the
    model datapath only carries per-tensor at such lengths."""
    from repro.kernels.int_attention_fused import MAX_SKV
    assert not FUSED._can_tile(128, MAX_SKV + 1, 128, 1)
    h, d = 2, 32
    plan = _plan(d)
    q8 = jnp.zeros((1, 64, h, d), jnp.int8)
    k8 = jnp.zeros((1, MAX_SKV + 64, h, d), jnp.int8)
    with pytest.raises(NotImplementedError):
        FUSED._two_pass_fallback(q8, k8, k8, plan, False, 0,
                                 RequantSpec.raw(), None)


# --------------------------------------------- model-level equivalence ----

def _tiny_attn(rng, arch="llama3-8b", **red):
    import jax
    from repro.configs.registry import get_config
    from repro.models import model as M
    from repro.models import transformer as tf
    from repro.quant import convert

    cfg = M.reduce_config(get_config(arch), dtype="float32", vocab=64,
                          num_layers=1, **red)
    params = tf.init_params(jax.random.key(0), cfg)
    _, plans = convert.quantize_params(params, cfg)
    attn_qp = jax.tree.map(lambda t: t[0], params["layers"][0])["attn"]
    attn_qp = convert._q_attn(attn_qp, plans.attn)
    return cfg, plans, attn_qp


@pytest.mark.parametrize("seq", [64, 96, 127])
def test_fuse_attention_flag_exact_equivalence(rng, seq):
    """fuse_attention=True on pallas_fused == fuse_attention=False (the
    exact two-pass oracle), bit-for-bit, at the model layer — including a
    non-multiple-of-block and a prime (fallback) sequence length."""
    from repro.models import intlayers as il

    cfg, plans, attn_qp = _tiny_attn(rng)
    x8 = jnp.asarray(rng.integers(-127, 128, (2, seq, cfg.d_model)),
                     jnp.int8)
    fused = il.int_attn_fwd(attn_qp, x8, plans.attn, cfg,
                            ops="pallas_fused", fuse_attention=True)
    exact = il.int_attn_fwd(attn_qp, x8, plans.attn, cfg,
                            ops="pallas_fused", fuse_attention=False)
    assert np.array_equal(np.asarray(fused), np.asarray(exact))


def test_fuse_attention_cross_memory8_equivalence(rng):
    """The memory8 (cross-attention) path through int_attn_fwd: fused
    backend == ref oracle exactly."""
    from repro.models import intlayers as il

    cfg, plans, attn_qp = _tiny_attn(rng)
    x8 = jnp.asarray(rng.integers(-127, 128, (1, 32, cfg.d_model)),
                     jnp.int8)
    mem8 = jnp.asarray(rng.integers(-127, 128, (1, 64, cfg.d_model)),
                       jnp.int8)
    fused = il.int_attn_fwd(attn_qp, x8, plans.attn, cfg, memory8=mem8,
                            causal=False, ops="pallas_fused")
    exact = il.int_attn_fwd(attn_qp, x8, plans.attn, cfg, memory8=mem8,
                            causal=False, ops="ref")
    assert np.array_equal(np.asarray(fused), np.asarray(exact))


def test_opset_override_routes_fused_attention():
    """Per-op override: everything on ref, attention on pallas_fused —
    the registry pattern the fused backend was built for."""
    opset = resolve_ops("ref").with_overrides(int_attention="pallas_fused")
    assert opset.backend_for("int_attention").name == "pallas_fused"
    assert opset.backend_for("int8_matmul").name == "ref"
    assert opset.name == "ref[int_attention=pallas_fused]"
