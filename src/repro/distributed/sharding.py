"""Logical-axis sharding (pjit style), DESIGN.md §7.

Model code annotates activations with *logical* axes ("batch", "heads",
"ffn", ...); this module maps them onto whatever physical mesh is in scope
(single-pod ``(data, model)`` or multi-pod ``(pod, data, model)``) and
silently no-ops outside a mesh context (unit tests on one device).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# logical axis -> tuple of physical mesh axes (filtered by availability)
LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "seq": (),                  # sequence kept replicated (SP is a §Perf knob)
    "seq_sharded": ("model",),  # long-context sequence sharding
    "heads": ("model",),
    "kv_heads": ("model",),     # only applied when kv_heads divides
    "ffn": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "embed": (),                # d_model replicated
    "state": (),
    None: (),
}


def get_abstract_mesh():
    """The mesh currently in scope, or an empty mesh.

    jax >= 0.5 exposes ``jax.sharding.get_abstract_mesh``; on older
    releases the ``with Mesh(...)`` context lives in the thread-resources
    env, whose physical mesh carries the same ``empty`` / ``axis_names`` /
    ``axis_sizes`` surface this module needs.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib
    return _mesh_lib.thread_resources.env.physical_mesh


def current_axes() -> Tuple[str, ...]:
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


def pspec(*logical) -> P:
    """Build a PartitionSpec from logical axis names for the current mesh."""
    avail = current_axes()
    out = []
    for name in logical:
        phys = tuple(a for a in LOGICAL_RULES.get(name, ()) if a in avail)
        if len(phys) == 0:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(phys)
    return P(*out)


def shard(x, *logical):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    if not current_axes():
        return x
    return jax.lax.with_sharding_constraint(x, pspec(*logical))


def shard_residual(x):
    """Residual stream: batch over (pod,data) + Megatron-style sequence
    parallelism — the seq dim shards over ``model`` between layers (norms /
    residual adds are pointwise), so remat-saved activations shrink by the
    TP degree.  XLA inserts the all-gather before attention/FFN (whose
    constraints shard heads/ffn instead) and the reduce-scatter after —
    exactly the Megatron-SP collective pair.  Applied only when the seq dim
    divides."""
    axes = current_axes()
    if not axes:
        return x
    mesh = get_abstract_mesh()
    msize = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("model", 1)
    if x.ndim >= 2 and msize > 1 and x.shape[1] % msize == 0 \
            and x.shape[1] >= msize * 16:
        return jax.lax.with_sharding_constraint(
            x, pspec("batch", "seq_sharded", "embed"))
    return shard(x, "batch", "seq", "embed")


def param_pspec(path: Tuple[str, ...], shape: Tuple[int, ...],
                mesh_axes: Tuple[str, ...]) -> P:
    """Fallback parameter spec (used when a param has no explicit rule)."""
    return P(*([None] * len(shape)))


def comm_quant_gather(x, scale: float, enabled: bool = True):
    """INT8 transport for the sequence-parallel gather boundary.

    The residual stream is seq-sharded between layers; attention/FFN need
    the full sequence, so XLA all-gathers here.  Under QAT the value is
    about to be fake-quantized anyway — quantizing *before* the gather
    halves the wire bytes (bf16 -> int8), the paper's Fig.-2 economics
    applied to the interconnect.  Straight-through gradients; the backward
    reduce-scatter stays bf16.
    """
    if not enabled or not current_axes():
        return x
    return _cq_gather(x, scale)


@jax.custom_vjp
def _cq_gather(x, scale):
    # NOTE: custom_vjp (not a stop-gradient STE) — an `x + sg(deq - x)`
    # formulation would keep a full-seq bf16 dependence on x and XLA would
    # gather it anyway, defeating the int8 transport.
    q8 = jnp.clip(jnp.round(x / jnp.asarray(scale, x.dtype)), -127, 127) \
        .astype(jnp.int8)
    if current_axes():
        # pin the int8 value in seq-SHARDED form first, then request the
        # gathered form: without the first constraint XLA hoists the
        # gather above the quantize chain and moves f32 bytes instead
        q8 = jax.lax.with_sharding_constraint(
            q8, pspec("batch", "seq_sharded", "embed"))
        q8 = jax.lax.with_sharding_constraint(
            q8, pspec("batch", "seq", "embed"))  # seq -> full (gather int8)
    return q8.astype(x.dtype) * jnp.asarray(scale, x.dtype)


def _cq_fwd(x, scale):
    return _cq_gather(x, scale), None


def _cq_bwd(_, g):
    # the primal x is seq-sharded: constrain the cotangent likewise so the
    # partitioner emits a reduce-scatter (half the wire of all-reduce+slice)
    if current_axes():
        g = jax.lax.with_sharding_constraint(
            g, pspec("batch", "seq_sharded", "embed"))
    return (g, None)


_cq_gather.defvjp(_cq_fwd, _cq_bwd)


def constrain_like_params(tree):
    """Re-assert the parameter sharding rules on per-layer weight slices
    *inside* a scan body.  Without this, XLA hoists the all-gather of
    FSDP-sharded stacked weights out of the while loop (gathering every
    layer at once — 100+ GiB); with the in-body constraint the gather
    applies to one layer's slice at a time."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return tree
    from repro.launch.shardings import param_pspecs  # lazy: avoid cycle
    specs = param_pspecs(tree, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, specs)
