"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all per-chip seconds:
  compute    = FLOPs / 197e12        (v5e bf16 peak; int8 MXU is 2x — we
                                      report the conservative bf16 number)
  memory     = HBM bytes / 819e9
  collective = wire bytes / 50e9     (per-link ICI)

``cost_analysis`` counts a ``lax.scan`` body once (verified empirically),
so flops/bytes are corrected by compiling 1-group and 2-group variants of
the same cell and extrapolating linearly; collective bytes are parsed from
the optimized HLO with while-loop trip counts multiplied through.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# header: "%name (args) -> type {"  — args may contain nested tuple parens
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE2 = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_result: float
    group_size: int
    computation: str
    multiplier: float = 1.0

    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        frac = (g - 1) / g
        if self.kind == "all-reduce":
            return 2 * self.bytes_result * frac
        if self.kind == "collective-permute":
            return self.bytes_result
        return self.bytes_result * frac


def parse_hlo_collectives(text: str) -> Tuple[List[CollectiveOp],
                                              Dict[str, float]]:
    """Walk optimized HLO; return collectives with while-trip multipliers."""
    comp = "ENTRY"
    comp_lines: Dict[str, List[str]] = {}
    order: List[str] = []
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            comp = m.group(1)
            order.append(comp)
            comp_lines[comp] = []
        else:
            comp_lines.setdefault(comp, []).append(line)

    # while graph: computation -> [(cond, body)]
    whiles: Dict[str, List[Tuple[str, str]]] = {}
    for c, lines in comp_lines.items():
        for line in lines:
            for cond, body in _WHILE_RE.findall(line):
                whiles.setdefault(c, []).append((cond, body))

    def trip_count(cond: str) -> float:
        consts = [int(v) for v in
                  _CONST_RE.findall("\n".join(comp_lines.get(cond, [])))]
        return float(max(consts)) if consts else 1.0

    # propagate multipliers from the entry
    mult: Dict[str, float] = {}
    for c in comp_lines:
        mult.setdefault(c, 1.0)
    mult_final = {c: 1.0 for c in comp_lines}
    changed = True
    it = 0
    while changed and it < 50:
        changed = False
        it += 1
        for c, wl in whiles.items():
            for cond, body in wl:
                t = trip_count(cond)
                want = mult_final.get(c, 1.0) * t
                if body in mult_final and mult_final[body] != want:
                    mult_final[body] = want
                    changed = True

    colls: List[CollectiveOp] = []
    for c, lines in comp_lines.items():
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            type_str, kind = m.group(1), m.group(2)
            if "-done" in line:
                continue
            g = 1
            gm = _GROUPS_RE.search(line)
            if gm:
                g = int(gm.group(2))
            else:
                gm2 = _GROUPS_RE2.search(line)
                if gm2:
                    g = len([x for x in gm2.group(1).split(",") if x])
            colls.append(CollectiveOp(kind, _shape_bytes(type_str), g, c,
                                      mult_final.get(c, 1.0)))
    return colls, mult_final


def collective_wire_bytes(text: str) -> Tuple[float, Dict[str, float]]:
    colls, _ = parse_hlo_collectives(text)
    total = 0.0
    by_kind: Dict[str, float] = {}
    for op in colls:
        b = op.wire_bytes() * op.multiplier
        total += b
        by_kind[op.kind] = by_kind.get(op.kind, 0.0) + b
    return total, by_kind


def roofline_terms(flops_dev: float, bytes_dev: float, coll_bytes_dev: float,
                   int8_compute: bool = False) -> Dict[str, float]:
    peak = PEAK_FLOPS_INT8 if int8_compute else PEAK_FLOPS
    t_c = flops_dev / peak
    t_m = bytes_dev / HBM_BW
    t_x = coll_bytes_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    total = max(t_c, t_m, t_x)
    return {
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bottleneck": dom[0],
        "roofline_fraction_compute": t_c / total if total else 0.0,
    }


def model_flops(cfg, shape, per_device: bool, n_chips: int) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N_active*D prefill,
    2*N_active per token decode."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    total = mult * n_active * tokens
    return total / n_chips if per_device else total
