"""Dyadic requantization unit (paper §III-C) — unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import dyadic


def test_power_of_two_is_exact():
    for k in range(-8, 9):
        dn = dyadic.fit_dyadic(2.0 ** k, 2 ** 20)
        q = jnp.arange(-1000, 1000, dtype=jnp.int32) * 931
        got = np.asarray(dn(q))
        want = np.round(np.asarray(q, np.float64) * 2.0 ** k)
        assert np.abs(got - want).max() <= 1


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=1e-6, max_value=1e4),
       st.integers(min_value=7, max_value=30))
def test_dyadic_relative_error(ratio, qmax_bits):
    qmax = 2 ** qmax_bits
    try:
        dn = dyadic.fit_dyadic(ratio, qmax)
    except ValueError:
        # rejected plans must be near/above the int32 output boundary
        assert ratio * qmax > 2 ** 29
        return
    if ratio * qmax > 2 ** 30:      # saturating region: no precision claim
        return
    q = np.linspace(-qmax, qmax, 257).astype(np.int32)
    got = np.asarray(dn(jnp.asarray(q))).astype(np.float64)
    want = q.astype(np.float64) * ratio
    # error budget: multiplier rounding (2^-14 of full scale) + pre-shift
    tol = max(1.5, 2.0 ** -13 * qmax * ratio + ratio * 2 ** dn.pre)
    assert np.abs(got - want).max() <= tol


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=1e-4, max_value=100.0))
def test_dyadic_monotone(ratio):
    dn = dyadic.fit_dyadic(ratio, 2 ** 16)
    q = jnp.arange(-4096, 4096, dtype=jnp.int32)
    out = np.asarray(dn(q))
    assert (np.diff(out) >= 0).all()


def test_int64_oracle_agreement():
    rng = np.random.default_rng(1)
    for ratio in (0.003, 0.37, 1.0, 42.0):
        dn = dyadic.fit_dyadic(ratio, 2 ** 20)
        q = rng.integers(-2**20, 2**20, 4096).astype(np.int32)
        got = np.asarray(dn(jnp.asarray(q))).astype(np.int64)
        oracle = dyadic.apply_dyadic_exact_np(q, dn)
        # staged int32 path may differ from the ideal single-shift by the
        # pre-shift rounding only
        tol = 1 if dn.pre == 0 else (1 << dn.pre) * dn.b / (1 << dn.c) + 1
        assert np.abs(got - oracle).max() <= tol


def test_overflow_rejected():
    with pytest.raises(ValueError):
        dyadic.fit_dyadic(2.0 ** 40, 2 ** 30)


def test_rshift_round():
    x = jnp.asarray([5, -5, 4, -4, 7, -7], jnp.int32)
    # round-half-up: 1.25->1, -1.25->-1, 1.75->2, -1.75->-2
    assert np.array_equal(np.asarray(dyadic.rshift_round(x, 2)),
                          [1, -1, 1, -1, 2, -2])
