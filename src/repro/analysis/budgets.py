"""The repo's integer bit budgets, in exactly one place.

SwiftTron solves every scaling constant at design time so no int32
accumulator can overflow on the ASIC.  The reproduction's equivalents of
those design-time registers used to be scattered (``core.intmath``,
``core.softmax``, two per-kernel ``MAX_SKV`` copies); they live here now
— a dependency-leaf module (pure Python, no jax) that ``core``, the
kernels and the analyzer can all import without cycles.

Budgets:

  * ``INT32_MAX``       — the accumulator container every static check
    proves against;
  * ``MAX_ROWSUM_LEN``  — longest softmax row whose exact e16 sum stays
    int32: ``rowlen * 2^15 <= 2^30`` (``core.softmax`` requantizes exp
    values to 2^-15 fractions).  Every exact (non-streaming-corrected)
    attention kernel asserts this as its ``MAX_SKV``;
  * ``MAX_SQ``          — speculative query rows the decode kernel holds
    in VMEM scratch for a whole launch.

:class:`BitBudgetError` is the typed diagnostic the analyzer and the
plan constructors raise: a ``ValueError`` (so legacy ``except
ValueError`` call sites keep working) carrying the offending op, layer,
worst-case value and budget as fields.
"""
from __future__ import annotations

INT32_MAX = 2 ** 31 - 1

# longest row whose e16 sum is int32-exact: rowlen * 2^15 <= 2^30 — the
# budget every exact (non-streaming-corrected) attention kernel asserts
MAX_ROWSUM_LEN = 1 << 15

# speculative query budget: decode-kernel scratch rows per head
MAX_SQ = 8


class BitBudgetError(ValueError):
    """A worst-case integer range left its budget.

    Subclasses ``ValueError`` so the pre-existing ``_static_check``
    contract (and callers catching ``ValueError``) is preserved; the
    typed fields are what the certifier and CI surface:

      * ``what``   — which intermediate overflowed (human label);
      * ``value``  — its worst-case magnitude;
      * ``budget`` — the bound it had to stay under;
      * ``op``     — the ``repro.ops`` op being certified (or None);
      * ``layer``  — the model-walk location, e.g. ``"ffn.down"``.
    """

    def __init__(self, what: str, value: int, budget: int = INT32_MAX,
                 op: str | None = None, layer: str | None = None):
        self.what = what
        self.value = int(value)
        self.budget = int(budget)
        self.op = op
        self.layer = layer
        where = "".join(
            f" [{k}={v}]" for k, v in (("op", op), ("layer", layer)) if v)
        if budget == INT32_MAX:
            msg = (f"int32 overflow in {what}: worst case {value} > "
                   f"2^31-1{where}")
        else:
            msg = f"budget exceeded in {what}: {value} > {budget}{where}"
        super().__init__(msg)


def static_check(val: int, what: str, budget: int = INT32_MAX,
                 op: str | None = None, layer: str | None = None) -> int:
    """Design-time bound check; returns ``val`` so checks can inline."""
    if val > budget:
        raise BitBudgetError(what, val, budget, op=op, layer=layer)
    return val


def bits_for(v: int) -> int:
    """Bits needed for magnitude ``v`` (pure-Python twin of
    ``core.dyadic.bits_for``, kept here so this module stays a leaf)."""
    v = int(v)
    return 0 if v <= 0 else v.bit_length()
