from repro.quant import calibrate, convert, pack, plans, qat  # noqa: F401
