"""Pallas TPU kernel: fused integer *decode* attention, bit-exact.

The serving hot path: one (or a few speculative) new query tokens per
sequence against an int8 KV cache whose per-slot occupancy differs —
slot ``b`` has ``valid_len[b]`` live positions, the rest of the cache is
stale.  One kernel launch runs the whole SwiftTron datapath (int8 Q·Kᵀ →
Shiftmax → int8 P·V → RequantSpec epilogue) streaming over KV-cache
blocks, with **data-dependent ``valid_len`` masking**:

  * ``valid_len`` (B,) int32 rides as a *scalar-prefetch* operand
    (``pltpu.PrefetchScalarGridSpec``), so it is resident before the
    kernel body runs and may steer the block pipeline;
  * KV blocks that are entirely dead for a slot are **skipped, not
    computed-and-discarded**: the block index map clamps to the last
    live block (the pipeline re-reads a resident block instead of
    fetching a dead one) and every sweep is predicated off with
    ``pl.when`` — per-step work is O(valid_len), not O(cache_len);
  * inside the boundary block, dead positions contribute ``-2³⁰`` to the
    row max and 0 to the sum and the P·V accumulator, exactly like the
    prefill kernel's causal masking.

Like ``int_attention_fused`` this buys bit-exactness with three
streaming sweeps over the live KV blocks (max → sum → normalise+AV) —
integer maxima and sums are associative, so the result is bit-identical
to the full-matrix decode oracle ``kernels.ref.ref_int_decode_attention``
for every RequantSpec epilogue form.

Speculative queries (1 < Sq ≤ 8): query row ``i`` attends to cache
positions ``< valid_len − (Sq − 1 − i)`` — the *last* row sees exactly
``valid_len`` positions, earlier speculative rows one fewer each (the
stepped causal mask of draft verification).  ``Sq = 1`` reduces to the
plain ``pos < valid_len`` occupancy mask.

Accumulator budget (Sq ≤ 8 rows live in VMEM scratch the whole launch):
row sums need ``valid_len ≤ 2¹⁵`` so ``Σ e16 ≤ 2³⁰`` stays int32-exact —
the same ``MAX_SKV`` budget as the prefill kernel, asserted on the
*cache length* here because ``valid_len ≤ L`` by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.attention import IAttnPlan
from repro.core.softmax import MAX_ROWSUM_LEN
from repro.kernels.int_attention_fused import (_epilogue_setup,
                                               _streaming_attn_body)
from repro.ops.spec import RequantSpec

MAX_SQ = 8                  # speculative query budget (scratch rows/head)
MAX_SKV = MAX_ROWSUM_LEN    # row-sum int32 budget: L * 2^15 <= 2^30


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, *rest, plan: IAttnPlan,
                   requant: RequantSpec, has_bvec: bool, n_kv: int,
                   sq: int, bkv: int):
    if has_bvec:
        b_ref, o_ref, m_ref, s_ref, acc_ref = rest
    else:
        b_ref = None
        o_ref, m_ref, s_ref, acc_ref = rest
    bi = pl.program_id(0)
    phase = pl.program_id(2)
    kv_step = pl.program_id(3)
    vl = vl_ref[bi]

    q8 = q_ref[0, :, 0, :]                      # (sq, d) int8
    k8 = k_ref[0, :, 0, :]                      # (bkv, d) int8
    v8 = v_ref[0, :, 0, :]

    # stepped occupancy mask: row i sees vl - (sq-1-i) positions (sq=1:
    # the plain pos < valid_len cache-occupancy mask)
    qi = jax.lax.broadcasted_iota(jnp.int32, (sq, bkv), 0)
    ki = kv_step * bkv + jax.lax.broadcasted_iota(jnp.int32, (sq, bkv), 1)
    live = ki < vl - (sq - 1 - qi)

    # data-dependent block skip: a block whose first position is already
    # past the widest row's occupancy (the last query row sees vl) is
    # entirely dead — contribute nothing, in any sweep.  The epilogue
    # inside the shared body still runs on the last step, so a slot with
    # valid_len == 0 writes requant(0) (matching the all-masked oracle).
    blk_live = kv_step * bkv < vl

    _streaming_attn_body(phase, kv_step, n_kv, q8, k8, v8, live, blk_live,
                         o_ref, m_ref, s_ref, acc_ref, b_ref,
                         plan=plan, requant=requant)


def int_decode_attention_fused(q8, k8_cache, v8_cache, plan: IAttnPlan,
                               valid_len, requant=None, b_vec=None,
                               bkv: int = 128, out_bits: int = 8,
                               interpret: bool = True):
    """q8: (B, Sq, H, D) int8, Sq ≤ 8; caches: (B, L, Hkv, D) int8
    (GQA: Hkv | H); valid_len: (B,) int32 live positions per slot.

    ``requant``: a :class:`RequantSpec` for the epilogue (default: the
    plan's per-tensor ``dn_out``); ``b_vec``: int32 per-channel
    multipliers, shape (H*D,) or (H, D), required iff per-channel.

    Returns (B, Sq, H, D): int8 when the epilogue clips to ≤ 8 bits,
    int32 otherwise.  Bit-exact against
    ``kernels.ref.ref_int_decode_attention`` for the same arguments.
    """
    b, sq, h, d = q8.shape
    _, L, hkv, _ = k8_cache.shape
    assert h % hkv == 0, (h, hkv)
    assert sq <= MAX_SQ, \
        f"decode kernel holds Sq <= {MAX_SQ} query rows in scratch " \
        f"(got {sq}); use the prefill kernel for larger Sq"
    assert L <= MAX_SKV, \
        f"row-sum int32 budget: cache_len <= {MAX_SKV} (got {L}); " \
        "use the two-pass path (see module docstring)"
    group = h // hkv
    bkv = min(bkv, L)
    assert L % bkv == 0, (L, bkv)
    n_kv = L // bkv
    valid_len = jnp.asarray(valid_len, jnp.int32)

    requant, has_bvec, b2, out_dtype = _epilogue_setup(
        requant, plan, out_bits, b_vec, h, d)

    kernel = functools.partial(
        _decode_kernel, plan=plan, requant=requant, has_bvec=has_bvec,
        n_kv=n_kv, sq=sq, bkv=bkv)

    def _kv_block(ki, vl, bi):
        # clamp dead blocks to the slot's last live block: the pipeline
        # re-reads a resident block instead of DMA-ing a dead one (the
        # compute for those steps is pl.when-ed off anyway)
        last = jnp.maximum(pl.cdiv(vl[bi], bkv) - 1, 0)
        return jnp.minimum(ki, last)

    in_specs = [
        pl.BlockSpec((1, sq, 1, d),
                     lambda bi, hi, ph, ki, vl: (bi, 0, hi, 0)),
        pl.BlockSpec((1, bkv, 1, d),
                     lambda bi, hi, ph, ki, vl:
                     (bi, _kv_block(ki, vl, bi), hi // group, 0)),
        pl.BlockSpec((1, bkv, 1, d),
                     lambda bi, hi, ph, ki, vl:
                     (bi, _kv_block(ki, vl, bi), hi // group, 0)),
    ]
    args = [q8, k8_cache, v8_cache]
    if has_bvec:
        in_specs.append(
            pl.BlockSpec((1, d), lambda bi, hi, ph, ki, vl: (hi, 0)))
        args.append(b2)

    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, 3, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, sq, 1, d),
                               lambda bi, hi, ph, ki, vl: (bi, 0, hi, 0)),
        scratch_shapes=[pltpu.VMEM((sq, 1), jnp.int32),
                        pltpu.VMEM((sq, 1), jnp.int32),
                        pltpu.VMEM((sq, d), jnp.int32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), out_dtype),
        interpret=interpret,
    )(valid_len, *args)
