"""The ``IntRange`` abstract domain + sound transfer functions.

Abstract interpretation over the integer datapath: a value is abstracted
to the closed interval ``[lo, hi]`` of the int32 quantities it can take,
and every transfer function maps worst-case input intervals to a sound
worst-case output interval, raising :class:`~repro.analysis.budgets.
BitBudgetError` the moment any intermediate of the *exact* integer
computation could leave int32.

Soundness contract (tested by ``tests/test_analysis_props.py``): for any
concrete input within the declared input range, the value the real
integer op computes lies inside the transferred ``IntRange``.  All
transfer endpoints are computed with exact Python integers through the
same staged arithmetic the kernels run (``rshift_round`` two-stage
dyadic, round-half-up), so the bounds are tight, not just safe — every
primitive here is monotone in its argument, which is what makes interval
endpoints exact.

Design grid: int8 *operands* are modeled at ±127 (``INT8``), matching
the repo-wide design contract (weights and activations are clipped to
±127 by ``quant.convert``; every ``acc_qmax`` is sized as ``k·127·127``).
The int8 container's ``-128`` corner is reachable only by feeding raw
``jnp.int8`` tensors built outside the quantizer; see docs/ANALYSIS.md
("The −128 corner") for why it is excluded from certification.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.budgets import (BitBudgetError, INT32_MAX,
                                    MAX_ROWSUM_LEN, bits_for, static_check)

# per-channel multipliers are bounded by the fit's mult_bits=15 contract:
# fit_dyadic folds any rounding spill, so b <= 2^15 - 1, and
# quant.plans.perchannel_multipliers derives channel multipliers from the
# worst-channel fit — never larger
PER_CHANNEL_B_MAX = (1 << 15) - 1


def rshift_round_int(x: int, s: int) -> int:
    """Exact Python twin of ``core.dyadic.rshift_round`` (round-half-up
    arithmetic shift; Python's ``>>`` floors, matching lax)."""
    if s == 0:
        return int(x)
    if s < 0:
        return int(x) << (-s)
    return (int(x) + (1 << (s - 1))) >> s


@dataclasses.dataclass(frozen=True)
class IntRange:
    """Closed interval of int32 values: ``lo <= q <= hi``."""

    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty IntRange [{self.lo}, {self.hi}]")

    # ------------------------------------------------------ constructors --

    @classmethod
    def const(cls, v: int) -> "IntRange":
        return cls(int(v), int(v))

    @classmethod
    def symmetric(cls, qmax: int) -> "IntRange":
        return cls(-int(qmax), int(qmax))

    # ------------------------------------------------------- properties --

    @property
    def qmax(self) -> int:
        """Worst-case magnitude |q|."""
        return max(abs(self.lo), abs(self.hi))

    @property
    def bits(self) -> int:
        """Signed bits needed to hold the range (sign bit included)."""
        return bits_for(self.qmax) + 1

    @property
    def headroom_bits(self) -> int:
        """How many doublings until the range leaves int32."""
        return 32 - self.bits

    # ------------------------------------------------------- arithmetic --

    def add(self, other: "IntRange") -> "IntRange":
        return IntRange(self.lo + other.lo, self.hi + other.hi)

    def scale(self, m: int) -> "IntRange":
        """Multiply by a non-negative constant."""
        assert m >= 0, m
        return IntRange(self.lo * m, self.hi * m)

    def neg_abs(self) -> "IntRange":
        """Range of ``-|q|``."""
        return IntRange(-self.qmax, 0 if self.lo <= 0 <= self.hi
                        else -min(abs(self.lo), abs(self.hi)))

    def clamp(self, lo: int, hi: int) -> "IntRange":
        return IntRange(min(max(self.lo, lo), hi), min(max(self.hi, lo), hi))


#: the design-grid int8 operand range (see module docstring)
INT8 = IntRange.symmetric(127)

#: the packed-nibble operand grid: sub-8-bit weights and int4 KV codes
#: store two's-complement nibbles clipped to ±7 (never −8) by
#: ``quant.pack`` / ``ops.packed.quantize_kv``
INT4 = IntRange.symmetric(7)

#: msr4 outlier-lane delta bound on the ±127 design grid:
#: ``delta = w − clip(w, −7, 7)`` so ``|delta| <= 127 − 7``; each lane
#: row is distinct within its group, and element-wise
#: ``|nib| + |delta| == |w| <= 127``, which is why the split accumulator
#: pieces never exceed the dense ``k·127·127`` budget
MSR4_DELTA_MAX = 127 - 7

#: static per-page requant shift of the int4 KV tier — the import-cycle-
#: free twin of ``repro.ops.packed.KV_SHIFT`` (equality is asserted by
#: ``tests/test_pack_props.py``)
KV4_SHIFT = 4

#: the dequantized int4 KV operand range: pages store
#: ``clip(rshift_round(v, KV4_SHIFT), −7, 7)`` and the kernels unpack to
#: ``q4 << KV4_SHIFT`` — magnitude ≤ 7·2⁴ = 112, inside the int8 grid
INT4_KV = IntRange.symmetric(7 << KV4_SHIFT)


def _tag(what, op, layer):
    return dict(op=op, layer=layer) if (op or layer) else {}


# ======================================================================
# primitive transfer functions
# ======================================================================

def t_rshift_round(r: IntRange, s: int, what: str = "rshift_round",
                   op=None, layer=None) -> IntRange:
    """``rshift_round`` is monotone; the rounding addend itself must fit."""
    if s > 0:
        static_check(r.hi + (1 << (s - 1)), f"{what} rounding addend",
                     op=op, layer=layer)
    return IntRange(rshift_round_int(r.lo, s), rshift_round_int(r.hi, s))


def t_clip(r: IntRange, out_bits: int, design_grid: bool = True) -> IntRange:
    """``clip_to_bits``.  ``design_grid=True`` returns the symmetric
    ±(2^(b-1)−1) operand grid (the repo's matmul-operand contract);
    ``False`` keeps the exact container range including −2^(b-1)."""
    hi = (1 << (out_bits - 1)) - 1
    lo = -hi if design_grid else -(1 << (out_bits - 1))
    return r.clamp(lo, hi)


def t_dyadic(r: IntRange, dn, what: str = "dyadic requant",
             op=None, layer=None) -> IntRange:
    """Two-stage dyadic requant ``rr(rr(q, pre) · b, c−pre)``.

    The certifying check is *actual staging safety at the incoming
    worst-case range* — the product of the pre-shifted input with ``b``
    plus the rounding addend must fit int32 (``fit_dyadic``'s
    ``prod_max`` invariant, re-proved here against the analyzer's range
    rather than the constructor's declared ``qmax_in``, which may be
    smaller than the true reachable range; see docs/ANALYSIS.md)."""
    q = r.qmax
    half2 = 1 << max(0, dn.c - dn.pre - 1)
    static_check(((q >> dn.pre) + 1) * dn.b + half2,
                 f"{what} staging product (b={dn.b}, c={dn.c}, "
                 f"pre={dn.pre}, qmax={q})", op=op, layer=layer)
    if dn.pre > 0:
        static_check(q + (1 << (dn.pre - 1)), f"{what} pre-shift addend",
                     op=op, layer=layer)

    def f(v):
        return rshift_round_int(rshift_round_int(v, dn.pre) * dn.b,
                                dn.c - dn.pre)

    return IntRange(f(r.lo), f(r.hi))


def t_dyadic_perchannel(r: IntRange, c: int, pre: int,
                        b_max: int = PER_CHANNEL_B_MAX,
                        what: str = "per-channel requant",
                        op=None, layer=None) -> IntRange:
    """Per-channel staging with the worst-case multiplier ``b_max``."""
    q = r.qmax
    half2 = 1 << max(0, c - pre - 1)
    static_check(((q >> pre) + 1) * b_max + half2,
                 f"{what} staging product (b_max={b_max}, c={c}, "
                 f"pre={pre}, qmax={q})", op=op, layer=layer)
    if pre > 0:
        static_check(q + (1 << (pre - 1)), f"{what} pre-shift addend",
                     op=op, layer=layer)

    def f(v):
        return rshift_round_int(rshift_round_int(v, pre) * b_max, c - pre)

    return IntRange(f(r.lo), f(r.hi))


def t_requant_spec(r: IntRange, spec, b_max: int = PER_CHANNEL_B_MAX,
                   what: str = "requant epilogue", op=None,
                   layer=None) -> IntRange:
    """Transfer through a :class:`repro.ops.RequantSpec` epilogue."""
    if spec.is_raw:
        return r
    if spec.dn is not None:          # per-tensor
        out = t_dyadic(r, spec.dn, what=what, op=op, layer=layer)
    else:                            # per-channel
        out = t_dyadic_perchannel(r, spec.c, spec.pre, b_max=b_max,
                                  what=what, op=op, layer=layer)
    return t_clip(out, spec.out_bits, design_grid=False)


def t_matmul_acc(k_dim: int, x: IntRange = INT8, w_qmax: int = 127,
                 bias: IntRange | None = None,
                 what: str = "matmul accumulator", op=None,
                 layer=None) -> IntRange:
    """int8·int8 → int32 accumulation over ``k_dim`` plus optional bias."""
    acc = IntRange.symmetric(
        static_check(k_dim * x.qmax * w_qmax, what, op=op, layer=layer))
    if bias is not None:
        acc = acc.add(bias)
        static_check(acc.qmax, f"{what} + bias", op=op, layer=layer)
    return acc


# ======================================================================
# composite transfer functions (the core integer pipelines)
# ======================================================================

def t_iexp(plan, what: str = "i-exp", op=None, layer=None) -> IntRange:
    """Output range of ``intmath.i_exp`` for any admissible input.

    The polynomial peak sits at p = 0: ``t = q_b``, ``q_l = q_b² + q_c``
    — the same product ``make_iexp`` statically checks; z-shifts only
    shrink it, and ``q_l >= q_c > 0`` throughout the band."""
    peak = static_check(plan.q_b * plan.q_b + plan.q_c,
                        f"{what} polynomial", op=op, layer=layer)
    static_check(plan.z_max * plan.q_ln2, f"{what} range clip",
                 op=op, layer=layer)
    return IntRange(0, peak)


def t_softmax(sm, score: IntRange, rowlen: int, exact_rowsum: bool = True,
              op=None, layer=None) -> IntRange:
    """``core.softmax.i_softmax`` over rows of ``rowlen`` int32 scores.

    Proves, in pipeline order: the exact max-subtract has headroom
    (``2·qmax_score`` fits); the requantized e16 values fit; the exact
    row sum fits (and, when ``exact_rowsum``, that ``rowlen`` is within
    the ``MAX_ROWSUM_LEN`` kernel budget); and the normalisation product
    ``e16·r`` fits (``e16 <= sum`` elementwise and ``r = 2^30 // sum``,
    so the product is ≤ 2^30 + the rounding addend).  Returns the int8
    probability range [0, 127]."""
    static_check(2 * score.qmax, "softmax max-subtract headroom",
                 op=op, layer=layer)
    # (q - max) clipped to the i-exp band, requantized to S_SM
    sub = IntRange(-sm.q_band, 0)
    q_sm = t_dyadic(sub, sm.dn_in, what="softmax score dyadic",
                    op=op, layer=layer)
    assert q_sm.hi <= 0, q_sm
    e_raw = t_iexp(sm.iexp, what="softmax i-exp", op=op, layer=layer)
    e16 = t_dyadic(e_raw, sm.dn_e16, what="softmax e16 dyadic",
                   op=op, layer=layer)
    if exact_rowsum:
        static_check(rowlen, "softmax row length", budget=MAX_ROWSUM_LEN,
                     op=op, layer=layer)
        static_check(rowlen * e16.hi, "softmax row sum", op=op, layer=layer)
    # p = rr(e16 * r, 23): e16 <= s and r = 2^30 // s, so e16*r <= 2^30;
    # the rounding addend rides on top
    from repro.core.softmax import PROB_SHIFT, RECIP_BITS
    static_check((1 << RECIP_BITS) + (1 << (RECIP_BITS - PROB_SHIFT - 1)),
                 "softmax normalisation product", op=op, layer=layer)
    return IntRange(0, 127)


def prob_rowsum_max(rowlen: int) -> int:
    """Worst-case Σ p8 over a row: the probabilities sum to ≤ 2^7 before
    rounding, and each of the ``rowlen`` round-half-up requants adds at
    most 1/2 — the P·V accumulator bound ``(2^7 + rowlen/2)·127``."""
    from repro.core.softmax import PROB_SHIFT
    return (1 << PROB_SHIFT) + (rowlen + 1) // 2


def t_attention_acc(rowlen: int, v_qmax: int = 127,
                    op=None, layer=None) -> IntRange:
    """The int32 P·V accumulator range (scale ``2^-7 · s_v``)."""
    return IntRange.symmetric(
        static_check(prob_rowsum_max(rowlen) * v_qmax,
                     "attention P*V accumulator", op=op, layer=layer))


def t_gelu(plan, r: IntRange, op=None, layer=None) -> IntRange:
    """``activations.i_gelu_act``: erf polynomial + x·(erf+1) product +
    output dyadic, clipped to int8."""
    static_check(r.qmax, "i-gelu input range", budget=plan.gelu.qmax_in,
                 op=op, layer=layer)
    erf = plan.gelu.erf
    static_check(erf.q_clip * erf.q_clip + abs(erf.q_c),
                 "i-erf polynomial", op=op, layer=layer)
    prod = IntRange.symmetric(
        static_check(r.qmax * 2 * plan.gelu.q_one, "i-gelu product",
                     op=op, layer=layer))
    out = t_dyadic(prod, plan.dn_out, what="i-gelu output dyadic",
                   op=op, layer=layer)
    return t_clip(out, 8)


def t_silu(plan, r: IntRange, op=None, layer=None) -> IntRange:
    """``activations.i_silu``: q·sig16 needs bits(q) + 16 ≤ 31."""
    from repro.core.activations import SIG_FRAC
    static_check(r.qmax, "i-silu input range", budget=plan.qmax_in,
                 op=op, layer=layer)
    static_check(r.qmax << (SIG_FRAC + 1), "i-silu gate product",
                 op=op, layer=layer)
    prod = IntRange.symmetric(r.qmax << SIG_FRAC)
    out = t_dyadic(prod, plan.dn_out, what="i-silu output dyadic",
                   op=op, layer=layer)
    return t_clip(out, 8)


def t_layernorm(plan, r: IntRange, out_bits: int = 8, beta_abs: float = 2.0,
                op=None, layer=None) -> IntRange:
    """``norms.i_norm``: re-proves every phase budget of ``make_inorm``
    against the analyzer's input range (not the declared ``qmax_in``).

    ``beta_abs``: design bound on |beta| in real units (folded bias)."""
    q = static_check(r.qmax, "i-norm input range", budget=plan.qmax_in,
                     op=op, layer=layer)
    d, s, k = plan.d, plan.pre_shift, plan.recip_bits
    if plan.subtract_mean:
        static_check(d * q, "i-norm mean sum", op=op, layer=layer)
        mu = t_dyadic(IntRange.symmetric(d * q), plan.dn_mean,
                      what="i-norm mean dyadic", op=op, layer=layer)
        y_max = q + mu.qmax                      # centred values
    else:
        y_max = q                                # RMSNorm: y = q
    static_check(d * ((y_max >> s) ** 2), "i-norm variance sum",
                 op=op, layer=layer)
    t_dyadic(IntRange(0, d * ((y_max >> s) ** 2)), plan.dn_var,
             what="i-norm variance dyadic", op=op, layer=layer)
    # r = 2^(k+s) // sigma_s with sigma_s >= 1 -> r <= 2^(k+s); the
    # normalisation product y*r plus its 2s rounding addend must fit
    static_check((y_max << (k + s)) + (1 << max(0, 2 * s - 1)),
                 "i-norm normalisation product", op=op, layer=layer)
    # |n| <= sqrt(d) mathematically (sigma^2 >= y_i^2/d); make_inorm
    # declares that design bound as dn_out.qmax_in = n_q_max * 127 —
    # certified at the declared bound (an assumption the walk records)
    n_q = plan.dn_out.qmax_in // 127
    q_beta = int(beta_abs / plan.q_beta_scale) if plan.subtract_mean else 0
    scaled = static_check(n_q * 127 + q_beta, "i-norm gamma/beta product",
                          op=op, layer=layer)
    out = t_dyadic(IntRange.symmetric(scaled), plan.dn_out,
                   what="i-norm output dyadic", op=op, layer=layer)
    return t_clip(out, out_bits)


# ======================================================================
# plan-tree audit
# ======================================================================

def iter_dyadics(obj, prefix: str = ""):
    """Yield ``(path, Dyadic)`` for every dyadic in a plan tree
    (NamedTuples / dataclasses / sequences), e.g. the whole
    ``quant.plans.LayerPlans`` including the Mamba branch."""
    from repro.core.dyadic import Dyadic
    if obj is None:
        return
    if isinstance(obj, Dyadic):
        yield prefix or "dyadic", obj
        return
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):  # NamedTuple
        for name in obj._fields:
            yield from iter_dyadics(getattr(obj, name),
                                    f"{prefix}.{name}" if prefix else name)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        for f in dataclasses.fields(obj):
            yield from iter_dyadics(getattr(obj, f.name),
                                    f"{prefix}.{f.name}" if prefix else f.name)
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            yield from iter_dyadics(v, f"{prefix}[{i}]")


def audit_dyadics(obj, prefix: str = "", op=None, layer=None) -> int:
    """Re-prove the staging invariant of every dyadic in a plan tree at
    its declared ``qmax_in`` — catches hand-built ``Dyadic`` constants
    that drifted from the ``fit_dyadic`` contract.  Returns the count."""
    n = 0
    for path, dn in iter_dyadics(obj, prefix):
        t_dyadic(IntRange.symmetric(dn.qmax_in), dn, what=path,
                 op=op, layer=layer or path)
        n += 1
    return n


__all__ = [
    "INT4", "INT4_KV", "INT8", "IntRange", "KV4_SHIFT",
    "MSR4_DELTA_MAX", "PER_CHANNEL_B_MAX", "BitBudgetError",
    "INT32_MAX", "audit_dyadics", "iter_dyadics", "prob_rowsum_max",
    "rshift_round_int", "t_attention_acc", "t_clip", "t_dyadic",
    "t_dyadic_perchannel", "t_gelu", "t_iexp", "t_layernorm",
    "t_matmul_acc", "t_requant_spec", "t_rshift_round", "t_silu",
    "t_softmax",
]
