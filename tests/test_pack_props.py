"""The sub-8-bit storage tier: pack/unpack exactness + matmul/KV parity.

Property coverage of the compression tier's three contracts:

  * ``quant.pack`` → ``ops.packed.unpack_weights`` is the identity for
    **every** int8 weight value under msr4 (−128 included), and for the
    ±7 grid under plain int4 — with typed refusals outside it;
  * the packed matmul is bit-exact against the dense int8 matmul on the
    same plan, for every backend and every ``RequantSpec`` form (the
    msr4 distributivity ``acc_nib + correction == x @ w`` makes the
    fused path exact, not approximate);
  * int4 KV pages: the in-kernel unpack of the decode / paged-prefill
    launches is bit-exact against the declared dequant reference
    ``ops.packed.unpack_kv_pool`` on every backend.

Deterministic seeds; the randomised shapes sweep odd/even geometry the
fixed-shape unit tests don't.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ranges
from repro.core import attention as iattn
from repro.core.dyadic import fit_dyadic
from repro.ops import QuantLinearParams, RequantSpec, packed, resolve_ops
from repro.ops.paged import gather_pages
from repro.quant.pack import pack_int4, pack_linear, pack_msr4, pack_tree

BACKENDS = ("ref", "pallas", "pallas_fused")


# ------------------------------------------------- pack -> unpack ---------

def test_msr4_roundtrip_is_identity_for_all_int8():
    """Every int8 value — the −128 container corner included — survives
    pack_msr4 → unpack_weights exactly (delta = −121 fits int8)."""
    all_vals = np.arange(-128, 128, dtype=np.int8)
    w = np.stack([all_vals, all_vals[::-1], np.roll(all_vals, 7)], axis=1)
    for group in (0, 16, 64, 256, 100):      # 100 doesn't divide K -> g=K
        packed_w, meta, idx, val = pack_msr4(w, group=group)
        assert packed_w.shape == (128, 3) and packed_w.dtype == np.int8
        assert idx.dtype == np.int16 and val.dtype == np.int8
        qw = QuantLinearParams(w8=None, w_packed=jnp.asarray(packed_w),
                               pack_meta=meta, out_idx=jnp.asarray(idx),
                               out_val=jnp.asarray(val))
        back = np.asarray(packed.unpack_weights(qw))
        assert np.array_equal(back, w), group


def test_msr4_roundtrip_random_and_stacked(rng):
    """Random int8 weights, 2-D and stacked (ng, K, N), random groups."""
    for shape, group in (((64, 5), 16), ((30, 7), 8), ((2, 32, 4), 16),
                         ((128, 3), 0), ((3, 16, 9), 4)):
        w = rng.integers(-128, 128, shape).astype(np.int8)
        qw = pack_linear(QuantLinearParams(w8=jnp.asarray(w)),
                         scheme="msr4", group=group)
        assert qw.is_packed and qw.w8 is None
        assert qw.w_packed.shape[-2] == shape[-2] // 2
        back = np.asarray(packed.unpack_weights(qw))
        assert np.array_equal(back, w), (shape, group)


def test_msr4_outlier_lanes_are_static_and_minimal(rng):
    """Lane arrays are static-shaped (max count over columns), filler
    lanes carry delta 0, and a pure-nibble weight needs zero lanes."""
    w = rng.integers(-128, 128, (64, 8)).astype(np.int8)
    _, meta, idx, val = pack_msr4(w, group=16)
    d = w.astype(np.int32) - np.clip(w, -7, 7).astype(np.int32)
    per_col = (d.reshape(4, 16, 8) != 0).sum(axis=1)
    assert meta.n_outliers == per_col.max()
    assert np.abs(val.astype(np.int32)).max() <= ranges.MSR4_DELTA_MAX + 1
    # within each (group, column) the lane rows are distinct
    for g in range(idx.shape[0]):
        for n in range(idx.shape[2]):
            col = idx[g, :, n]
            assert len(set(col.tolist())) == len(col)
    small = rng.integers(-7, 8, (32, 4)).astype(np.int8)
    _, meta0, idx0, val0 = pack_msr4(small, group=8)
    assert meta0.n_outliers == 0 and idx0.shape[1] == 0


def test_int4_roundtrip_and_refusals(rng):
    w = rng.integers(-7, 8, (48, 6)).astype(np.int8)
    p = pack_int4(w)
    assert np.array_equal(np.asarray(packed.nibble_unpack(p, axis=-2)), w)
    with pytest.raises(ValueError, match="int4 packing"):
        pack_int4(np.full((4, 2), 8, np.int8))
    with pytest.raises(ValueError, match="K must be even"):
        pack_int4(np.zeros((5, 2), np.int8))
    with pytest.raises(ValueError, match="unknown pack scheme"):
        pack_linear(QuantLinearParams(w8=jnp.asarray(w)), scheme="int3")


def test_msr4_distributivity_identity(rng):
    """``x @ nibbles + msr4_correction(x, qw) == x @ w`` exactly — the
    identity the fused packed matmul relies on."""
    w = rng.integers(-128, 128, (64, 12)).astype(np.int8)
    x = rng.integers(-127, 128, (9, 64)).astype(np.int32)
    qw = pack_linear(QuantLinearParams(w8=jnp.asarray(w)),
                     scheme="msr4", group=16)
    nib = np.asarray(packed.nibble_unpack(qw.w_packed, axis=-2))
    acc_nib = x @ nib
    corr = np.asarray(packed.msr4_correction(jnp.asarray(x), qw))
    assert np.array_equal(acc_nib + corr, x @ w.astype(np.int32))


def test_pack_tree_skips_unpackable_leaves(rng):
    """Odd-K, 4-D expert stacks and non-linear leaves pass through."""
    odd = QuantLinearParams(w8=jnp.asarray(
        rng.integers(-128, 128, (7, 4)).astype(np.int8)))
    expert = QuantLinearParams(w8=jnp.asarray(
        rng.integers(-128, 128, (2, 3, 8, 4)).astype(np.int8)))
    ok = QuantLinearParams(w8=jnp.asarray(
        rng.integers(-128, 128, (8, 4)).astype(np.int8)))
    tree = {"a": odd, "b": expert, "c": ok,
            "emb": jnp.zeros((4, 4), jnp.int8)}
    out = pack_tree(tree, scheme="msr4", group=4)
    assert not out["a"].is_packed and not out["b"].is_packed
    assert out["c"].is_packed
    assert out["emb"] is tree["emb"]


# ------------------------------------------------- matmul parity ----------

@pytest.mark.parametrize("form", ["per_tensor", "per_channel", "raw"])
@pytest.mark.parametrize("scheme", ["int4", "msr4"])
def test_packed_matmul_parity_all_backends(rng, form, scheme):
    """Packed-vs-dense matmul bit-parity across random shapes, requant
    forms and backends: the packed path must reproduce the dense int8
    accumulator (and its epilogue) exactly."""
    for m, k, n in ((8, 32, 16), (5, 64, 8), (16, 128, 128), (1, 16, 4)):
        lo, hi = (-7, 8) if scheme == "int4" else (-128, 128)
        w = rng.integers(lo, hi, (k, n)).astype(np.int8)
        x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
        bias = jnp.asarray(rng.integers(-2 ** 14, 2 ** 14, (n,)),
                           jnp.int32)
        b_vec = None
        if form == "per_tensor":
            spec = RequantSpec.per_tensor(
                fit_dyadic(1 / 4000.0, k * 127 * 127 + 2 ** 14))
        elif form == "per_channel":
            spec = RequantSpec.per_channel(c=28, pre=7)
            b_vec = jnp.asarray(rng.integers(1000, 30000, (n,)),
                                jnp.int32)
        else:
            spec = RequantSpec.raw()
        dense = QuantLinearParams(w8=jnp.asarray(w), b_mult=b_vec,
                                  bias32=bias)
        qw = pack_linear(dense, scheme=scheme, group=16)
        assert qw.is_packed
        want = np.asarray(resolve_ops("ref").int8_matmul(
            jnp.asarray(x), jnp.asarray(w), spec, bias32=bias,
            b_vec=b_vec))
        for name in BACKENDS:
            got = np.asarray(
                resolve_ops(name).int8_matmul_packed(x, qw, spec))
            assert np.array_equal(got, want), (name, form, scheme,
                                               (m, k, n))


def test_packed_matmul_dense_fallthrough(rng):
    """A dense QuantLinearParams through int8_matmul_packed is plain
    int8_matmul — no silent repack."""
    w = rng.integers(-128, 128, (32, 8)).astype(np.int8)
    x = jnp.asarray(rng.integers(-127, 128, (4, 32)), jnp.int8)
    qw = QuantLinearParams(w8=jnp.asarray(w))
    spec = RequantSpec.raw()
    got = np.asarray(resolve_ops("ref").int8_matmul_packed(x, qw, spec))
    want = np.asarray(resolve_ops("ref").int8_matmul(
        x, jnp.asarray(w), spec))
    assert np.array_equal(got, want)


# ------------------------------------------------- int4 KV pages ----------

def test_kv_pack_roundtrip_and_idempotence(rng):
    """``unpack_kv_pool`` is the declared reference: packing its output
    again must reproduce the same codes (the tier is a fixed point)."""
    pool = jnp.asarray(rng.integers(-127, 128, (5, 4, 2, 8)), jnp.int8)
    p = packed.pack_kv(pool)
    assert p.shape == (5, 4, 2, 4)
    shifts = jnp.full((5,), packed.KV_SHIFT, jnp.int32)
    deq = packed.unpack_kv_pool(p, shifts)
    assert deq.dtype == jnp.int8
    assert int(jnp.abs(deq.astype(jnp.int32)).max()) <= 7 << packed.KV_SHIFT
    again = packed.pack_kv(deq)
    assert np.array_equal(np.asarray(again), np.asarray(p))


def test_ranges_kv4_constants_twin():
    """The analysis layer's import-cycle-free twins of the runtime
    constants must stay equal to the real ones."""
    assert ranges.KV4_SHIFT == packed.KV_SHIFT
    assert ranges.INT4_KV.qmax == 7 << packed.KV_SHIFT
    assert ranges.INT4.qmax == 7
    assert ranges.MSR4_DELTA_MAX == 127 - 7


def _packed_pool(rng, num_pages, ps, hkv, d):
    kp = jnp.asarray(rng.integers(-127, 128, (num_pages, ps, hkv, d)),
                     jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, (num_pages, ps, hkv, d)),
                     jnp.int8)
    shifts = jnp.full((num_pages,), packed.KV_SHIFT, jnp.int32)
    return (packed.pack_kv(kp), packed.pack_kv(vp), shifts)


def test_packed_decode_matches_dequant_reference(rng):
    """int4 KV decode on every backend == the dense decode over
    ``unpack_kv_pool`` (the declared dequant reference), ragged
    occupancies and the empty slot included."""
    b, sq, h, hkv, d, ps, num_pages = 3, 1, 4, 2, 32, 16, 9
    plan = iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)
    q8 = jnp.asarray(rng.integers(-127, 128, (b, sq, h, d)), jnp.int8)
    kp, vp, shifts = _packed_pool(rng, num_pages, ps, hkv, d)
    pages = jnp.asarray([[0, 0, 0], [5, 2, 0], [7, 1, 4]], jnp.int32)
    vl = jnp.asarray([0, 19, 48], jnp.int32)
    kd = packed.unpack_kv_pool(kp, shifts)
    vd = packed.unpack_kv_pool(vp, shifts)
    want = np.asarray(resolve_ops("ref").int_decode_attention(
        q8, kd, vd, plan, vl, pages=pages, page_size=ps))
    for name in BACKENDS:
        got = np.asarray(resolve_ops(name).int_decode_attention(
            q8, kp, vp, plan, vl, pages=pages, page_size=ps,
            kv_shifts=(shifts, shifts)))
        assert np.array_equal(got, want), name
    assert not np.asarray(want)[0].any()        # empty slot -> requant(0)


def test_packed_prefill_matches_dequant_reference(rng):
    """Paged prefill with packed pools: the scatter quantizes the new
    chunk to int4 codes and the attention runs on the dequantized
    values — bit-equal to scattering pre-quantized values into the
    dequantized dense pools, on every backend."""
    b, c, h, hkv, d, ps, num_pages = 2, 8, 4, 2, 32, 16, 7
    plan = iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)
    q8 = jnp.asarray(rng.integers(-127, 128, (b, c, h, d)), jnp.int8)
    knew = jnp.asarray(rng.integers(-127, 128, (b, c, hkv, d)), jnp.int8)
    vnew = jnp.asarray(rng.integers(-127, 128, (b, c, hkv, d)), jnp.int8)
    kp, vp, shifts = _packed_pool(rng, num_pages, ps, hkv, d)
    pages = jnp.asarray([[3, 1, 0], [5, 2, 6]], jnp.int32)
    base = jnp.asarray([9, 0], jnp.int32)
    outs, pools = {}, {}
    for name in BACKENDS:
        o, k2, v2 = resolve_ops(name).int_paged_prefill(
            q8, knew, vnew, kp, vp, plan, base, pages, ps,
            kv_shifts=(shifts, shifts))
        outs[name] = np.asarray(o)
        pools[name] = (np.asarray(k2), np.asarray(v2))
    for name in BACKENDS[1:]:
        assert np.array_equal(outs[name], outs["ref"]), name
        assert np.array_equal(pools[name][0], pools["ref"][0]), name
        assert np.array_equal(pools[name][1], pools["ref"][1]), name
    # the updated pools hold int4 codes: dequantizing them reproduces
    # the reference composition (quantize chunk -> scatter -> attend)
    k2 = jnp.asarray(pools["ref"][0])
    deq = packed.unpack_kv_pool(k2, shifts)
    rows = gather_pages(deq, pages, ps)
    q4 = packed.quantize_kv(knew)
    assert np.array_equal(
        np.asarray(rows[0, 9:9 + c]),
        np.asarray((q4[0] << packed.KV_SHIFT).astype(jnp.int8)))


def test_certify_packed_tier_reports():
    """certify_config carries the packed-tier ops with headroom."""
    from repro.analysis.interpret import certify_config
    from repro.configs.registry import get_config
    rep = certify_config(get_config("llama3-8b"), seq_len=256,
                         cache_len=2048)
    layers = {o.layer: o for o in rep.ops}
    assert "attn.qkv[msr4]" in layers
    assert "attn.decode[kv4]" in layers
    assert "attn.prefill[kv4]" in layers
    assert layers["attn.qkv[msr4]"].op == "int8_matmul_packed"
    assert all(layers[k].headroom_bits >= 0 for k in layers)
    # the int4 KV operand (<=112) can never certify worse than int8
    assert layers["attn.decode[kv4]"].worst <= layers["attn.decode"].worst
