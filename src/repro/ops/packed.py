"""Runtime pack/unpack primitives for the sub-8-bit storage tier.

Two packed families share one byte layout (two's-complement nibbles,
value ``2i`` in the low nibble of byte ``i``, ``2i + 1`` in the high):

  * **packed weights** (``QuantLinearParams.w_packed``) — nibbles along
    the contraction axis (``-2``), plus optional msr4 outlier lanes
    (``out_idx`` / ``out_val``) that make the reconstruction exact for
    every int8 value;
  * **packed KV pages** — nibbles along the head dim (``-1``) with a
    per-page requant shift: a pool element stores
    ``clip(rshift_round(v, shift), -7, 7)`` and dequantizes to
    ``q4 << shift`` (≤ 112, still int8-range).

All nibble arithmetic is done in int32 with explicit sign extension —
``((x & 15) ^ 8) - 8`` — because jnp's int8 shift behaviour is not part
of any contract we want to rely on.  These helpers are the *declared
dequant reference* the fused in-kernel unpack paths are bit-exact
against (docs/KERNELS.md); lint rule RR004 keeps calls to them out of
``models/`` and ``serving/``.
"""
from __future__ import annotations

import jax.numpy as jnp

# static per-page requant shift of the int4 KV tier: pages store
# clip(rshift_round(v, KV_SHIFT), -7, 7); dequant is q4 << shift (≤ 112)
KV_SHIFT = 4

__all__ = [
    "KV_SHIFT",
    "nibble_pack",
    "nibble_unpack",
    "unpack_weights",
    "msr4_correction",
    "quantize_kv",
    "pack_kv",
    "unpack_kv_pool",
]


def _rshift_round(x, s):
    """Round-half-up arithmetic right shift (the requant unit's primitive)."""
    if s == 0:
        return x
    return (x + (1 << (s - 1))) >> s


def nibble_pack(a, axis: int = -2):
    """Pack int4-range values pairwise into bytes along ``axis``.

    ``a`` must have an even extent along ``axis`` and values in
    ``[-8, 7]`` (callers guarantee ``[-7, 7]``); returns int8 of half
    the extent, low nibble = even index, high nibble = odd index.
    """
    a = jnp.asarray(a).astype(jnp.int32)
    ax = axis % a.ndim
    lo_sl = [slice(None)] * a.ndim
    hi_sl = [slice(None)] * a.ndim
    lo_sl[ax] = slice(0, None, 2)
    hi_sl[ax] = slice(1, None, 2)
    lo, hi = a[tuple(lo_sl)], a[tuple(hi_sl)]
    byte = (lo & 15) | ((hi & 15) << 4)
    return (((byte & 255) ^ 128) - 128).astype(jnp.int8)


def nibble_unpack(p, axis: int = -2):
    """Inverse of :func:`nibble_pack`: int8 bytes → int32 nibble values."""
    p = jnp.asarray(p)
    ax = axis % p.ndim
    p32 = p.astype(jnp.int32)
    lo = ((p32 & 15) ^ 8) - 8
    hi = (((p32 >> 4) & 15) ^ 8) - 8
    pair = jnp.stack([lo, hi], axis=ax + 1)
    shape = p.shape[:ax] + (2 * p.shape[ax],) + p.shape[ax + 1:]
    return pair.reshape(shape)


def unpack_weights(qw):
    """Reconstruct dense int8 weights from a packed ``QuantLinearParams``.

    This is the declared reference lowering: int4 is the plain nibble
    expansion; msr4 additionally scatter-adds the outlier deltas back
    into their within-group rows.  Exact for every int8 weight value.
    Supports leading batch dims (stacked layer-group weights).
    """
    meta = qw.pack_meta
    w = nibble_unpack(qw.w_packed, axis=-2)          # (..., K, N) int32
    if meta.scheme == "msr4" and meta.n_outliers:
        *lead, k, n = w.shape
        g = meta.group
        ngrp = k // g
        wg = w.reshape(*lead, ngrp, g, n)
        idx = qw.out_idx.astype(jnp.int32)           # (..., ngrp, n_out, N)
        val = qw.out_val.astype(jnp.int32)
        lanes = jnp.arange(g, dtype=jnp.int32)
        # one-hot scatter-add: lane rows are distinct per column, filler
        # lanes carry val == 0, so the sum reconstructs exactly
        hit = (idx[..., None, :, :] == lanes[:, None, None]).astype(jnp.int32)
        wg = wg + jnp.sum(hit * val[..., None, :, :], axis=-2)
        w = wg.reshape(*lead, k, n)
    return w.astype(jnp.int8)


def msr4_correction(x32, qw):
    """Outlier-lane contribution ``x @ scatter(out_val)`` as (M, N) int32.

    With ``acc_nib = x @ unpack(nibbles)``, integer distributivity gives
    ``acc_nib + msr4_correction(x, qw) == x @ unpack_weights(qw)``
    exactly — the identity the fused msr4 matmul path relies on.
    ``x32`` is the (M, K) activation in int32; ``qw`` must be 2-D packed.
    """
    meta = qw.pack_meta
    if meta.scheme != "msr4" or not meta.n_outliers:
        return jnp.zeros((x32.shape[0], qw.n_dim), jnp.int32)
    g = meta.group
    ngrp = meta.k // g
    idx = qw.out_idx.astype(jnp.int32)               # (ngrp, n_out, N)
    val = qw.out_val.astype(jnp.int32)
    gidx = idx + (jnp.arange(ngrp, dtype=jnp.int32) * g)[:, None, None]
    xg = x32[:, gidx]                                # (M, ngrp, n_out, N)
    return jnp.sum(xg * val[None], axis=(1, 2))


# ------------------------------------------------------------- KV pages --


def quantize_kv(v8, shift: int = KV_SHIFT):
    """int8 KV value → int4 code: ``clip(rshift_round(v, shift), -7, 7)``."""
    v = jnp.asarray(v8).astype(jnp.int32)
    return jnp.clip(_rshift_round(v, shift), -7, 7)


def pack_kv(v8, shift: int = KV_SHIFT):
    """Quantize + nibble-pack int8 K/V along the head dim (``-1``)."""
    return nibble_pack(quantize_kv(v8, shift), axis=-1)


def unpack_kv_pool(pool, shift_per_page):
    """Dequantize a packed KV page pool back to an int8 pool.

    ``pool`` is ``(num_pages, page_size, Hkv, d // 2)`` int8 nibbles;
    ``shift_per_page`` is ``(num_pages,)`` int32.  Returns the int8
    ``(num_pages, page_size, Hkv, d)`` pool ``q4 << shift`` — the
    declared reference the in-kernel unpack is bit-exact against.
    """
    q4 = nibble_unpack(pool, axis=-1)
    shift = shift_per_page.astype(jnp.int32)
    return (q4 << shift[:, None, None, None]).astype(jnp.int8)
