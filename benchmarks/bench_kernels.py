"""Kernel microbenchmarks: ref (jnp) path timing + Pallas interpret-mode
validation cost, per kernel.  On real TPU the same harness times the
compiled kernels; on CPU it documents the oracle path and asserts
ref/pallas agreement as a by-product."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.core import attention as iattn
from repro.core import norms
from repro.core import softmax as ism
from repro.core.dyadic import fit_dyadic
from repro.ops import RequantSpec


def _t(f, *args, iters=5):
    f(*args)
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []

    be = ops.resolve_ops("ref")
    m, k, n = 512, 2048, 512
    x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    spec = RequantSpec.per_tensor(fit_dyadic(1 / 4000.0, k * 127 * 127))
    f = jax.jit(lambda a, b: be.int8_matmul(a, b, spec))
    us = _t(f, x, w)
    flops = 2 * m * k * n
    rows.append(("kernel_int8_matmul_us", round(us, 1),
                 f"{flops / us / 1e3:.1f} GOP/s (ref path, CPU)"))

    sp = ism.make_isoftmax(3.5e-4, 128 * 127 * 127)
    sc = jnp.asarray(rng.integers(-60000, 60000, (256, 1024)), jnp.int32)
    f = jax.jit(lambda s: be.int_softmax(s, sp))
    rows.append(("kernel_int_softmax_us", round(_t(f, sc), 1),
                 "256x1024 rows"))

    d = 4096
    pl = norms.make_inorm(d, 2**-9, 1 << 13, 2 / 127, 8 / 127)
    g = jnp.ones((d,), jnp.int32) * 64
    q = jnp.asarray(rng.integers(-8192, 8192, (64, d)), jnp.int32)
    f = jax.jit(lambda a: be.int_layernorm(a, g, None, pl))
    rows.append(("kernel_int_layernorm_us", round(_t(f, q), 1), "64x4096"))

    b, s, h, hd = 1, 1024, 8, 128
    ap = iattn.make_iattention(hd, 8/127, 8/127, 4/127, 4/127)
    q8 = jnp.asarray(rng.integers(-127, 128, (b, s, h, hd)), jnp.int8)
    k8 = jnp.asarray(rng.integers(-127, 128, (b, s, h, hd)), jnp.int8)
    f = jax.jit(lambda a, kk: be.int_attention(a, kk, kk, ap))
    rows.append(("kernel_int_attention_us", round(_t(f, q8, k8), 1),
                 "1x1024x8x128 causal (ref path)"))

    # fused-vs-unfused attention: the single-launch pallas_fused kernel
    # against the two-pass reference on the same problem (modest shape —
    # interpret mode on CPU; on TPU the same harness times the compiled
    # kernel).  bench_fused_attention sweeps more shapes.
    b, s, h, hd = 1, 256, 4, 64
    q8 = jnp.asarray(rng.integers(-127, 128, (b, s, h, hd)), jnp.int8)
    k8 = jnp.asarray(rng.integers(-127, 128, (b, s, h, hd)), jnp.int8)
    ap = iattn.make_iattention(hd, 8/127, 8/127, 4/127, 4/127)
    fused_be = ops.resolve_ops("pallas_fused")
    f_ref = jax.jit(lambda a, kk: be.int_attention(a, kk, kk, ap))
    f_fused = jax.jit(lambda a, kk: fused_be.int_attention(a, kk, kk, ap))
    us_ref = _t(f_ref, q8, k8, iters=3)
    us_fused = _t(f_fused, q8, k8, iters=3)
    rows.append(("kernel_attn_two_pass_us", round(us_ref, 1),
                 "1x256x4x64 causal (ref two-pass)"))
    rows.append(("kernel_attn_fused_us", round(us_fused, 1),
                 "1x256x4x64 causal (pallas_fused, one launch)"))
    rows.append(("kernel_attn_fused_vs_two_pass", round(us_fused / us_ref, 2),
                 "wall-clock ratio (interpret mode on CPU; <1 on TPU)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
