"""Activation-range calibration (design-time, paper §III-A).

Runs the float model over calibration batches and collects per-tensor-kind
activation absmax statistics.  The framework's integer plans use fixed
design grids (s_act8/s_act10/s_res, DESIGN.md §4); calibration verifies the
activations fit those grids and returns the measured headroom so configs
can be tightened per deployment.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable

import jax.numpy as jnp

from repro.models.common import ArchConfig


def calibrate_ranges(forward: Callable, params, batches: Iterable,
                     cfg: ArchConfig, percentile: float = 99.9
                     ) -> Dict[str, float]:
    """Collects |activation| statistics at the float model's boundaries.

    ``forward(params, batch) -> (logits, aux)``.  Returns measured absmax
    per tensor kind plus the implied clipping fractions for the design
    grids.
    """
    stats = {"logits_absmax": 0.0, "resid_absmax": 0.0}
    n = 0
    for batch in batches:
        logits, _ = forward(params, batch)
        lmax = float(jnp.percentile(jnp.abs(logits), percentile))
        stats["logits_absmax"] = max(stats["logits_absmax"], lmax)
        n += 1
    stats["n_batches"] = n
    # design-grid coverage summary
    stats["s_act8_cover"] = 8.0          # grid covers +-8.0
    stats["s_res_cover"] = cfg.s_res * cfg.qmax_res
    return stats


def check_residual_fit(x_resid, cfg: ArchConfig) -> float:
    """Fraction of residual-stream values clipped by the s_res grid."""
    lim = cfg.s_res * cfg.qmax_res
    return float(jnp.mean((jnp.abs(x_resid) > lim).astype(jnp.float32)))
