"""RoBERTa-base — the paper's own evaluation model (Table II):
12-layer post-LN encoder, GELU, learned positions, d=768/12H/3072."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="roberta-base", family="encoder", num_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=50265, head_dim=64,
    activation="gelu", norm="layernorm", post_norm=True, pos="learned",
)
