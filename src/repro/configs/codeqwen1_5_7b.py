"""codeqwen1.5-7b [dense]: qwen1.5 arch, MHA (kv=32), QKV bias
[hf:Qwen/CodeQwen1.5-7B]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense", num_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=13440, vocab=92416, head_dim=128,
    attn_bias=True, activation="swiglu", norm="rmsnorm",
    rope_theta=1000000.0,
)
