"""Page-table array utilities for the paged decode-attention operand.

The paged KV layout hands ``int_decode_attention`` a physical pool
``(num_pages, page_size, Hkv, D)`` plus a per-slot page table
``pages: int32[B, max_pages]`` mapping logical block ``j`` of slot ``b``
to physical page ``pages[b, j]``.  Backends that advertise the
``paged_decode`` capability consume the table directly (the
``pallas_fused`` kernel translates block indices through it in the
scalar-prefetch index map); for every other backend the dispatch layer
lowers the operand with :func:`gather_pages` — an exact gather into the
contiguous ``(B, max_pages·page_size, Hkv, D)`` layout the existing
contract already covers, so paged and contiguous decode are
bit-identical by construction.
"""
from __future__ import annotations

import jax.numpy as jnp


def gather_pages(pool, pages, page_size: int):
    """Gather a paged pool into the contiguous per-slot cache layout.

    ``pool``: ``(num_pages, page_size, ...)``; ``pages``: ``(B,
    max_pages) int32``.  Returns ``(B, max_pages·page_size, ...)`` —
    slot ``b``'s logical positions ``[j·page_size, (j+1)·page_size)``
    are page ``pages[b, j]``.  Unmapped blocks point at the null page 0
    whose (stale) contents sit past ``valid_len`` and are masked.
    """
    if pool.shape[1] != page_size:
        raise ValueError(f"pool page dim {pool.shape[1]} != page_size "
                         f"{page_size}")
    pages = jnp.asarray(pages, jnp.int32)
    b, m = pages.shape
    flat = jnp.take(pool, pages.reshape(-1), axis=0)
    return flat.reshape(b, m * page_size, *pool.shape[2:])
