"""Architecture configuration and shared model utilities."""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (src/repro/configs/<id>.py instantiates)."""

    name: str
    family: str                  # dense | encdec | vlm | moe | ssm | hybrid | encoder
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention
    window: int = 0              # sliding-window attention (0 = full)
    attn_bias: bool = False
    rope_theta: float = 10000.0
    pos: str = "rope"            # rope | learned | sinusoidal | none

    # ffn / activation / norm
    activation: str = "swiglu"   # swiglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    post_norm: bool = False      # True: BERT/RoBERTa-style post-LN
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1           # MoE FFN on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (jamba): attention on layers where idx % attn_every == attn_offset
    attn_every: int = 0
    attn_offset: int = 0

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0

    # vlm: cross-attention every ``cross_every`` layers
    cross_every: int = 0
    n_img_tokens: int = 0
    # audio frontend stub
    n_audio_frames: int = 0

    # numerics / execution
    dtype: str = "bfloat16"
    kernel_backend: str = "ref"  # ref | pallas
    remat: bool = True
    scan_layers: bool = True
    # quantization design scales (shared across layers; DESIGN.md §4)
    s_act8: float = 8.0 / 127.0        # int8 activation grid
    s_res: float = 2.0 ** -9           # residual stream (int, ~14 bit)
    qmax_res: int = 1 << 13
    s_act10: float = 16.0 / 1024.0     # 10-bit activation (GELU/SiLU inputs)

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def q_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_causal(self) -> bool:
        return self.family != "encoder"

    def padded_vocab(self, multiple: int = 16) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def padded_experts(self, multiple: int = 16) -> int:
        if self.n_experts == 0:
            return 0
        return ((self.n_experts + multiple - 1) // multiple) * multiple

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for MODEL_FLOPS."""
        d, v = self.d_model, self.padded_vocab()
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.num_layers):
            total += self.layer_param_count(i)
        if self.family == "encdec":
            total += sum(self.layer_param_count(i, cross=True)
                         for i in range(self.dec_layers))
        return total

    def layer_param_count(self, idx: int, cross: bool = False) -> int:
        d, hd = self.d_model, self.hd
        n = 0
        if self._layer_kind(idx) in ("attn", "cross") or cross:
            n += d * (self.n_heads + 2 * self.n_kv_heads) * hd
            n += self.n_heads * hd * d
        if self._layer_kind(idx) == "ssm":
            di = self.ssm_d_inner
            n += d * (2 * di + 2 * self.ssm_groups * self.ssm_state
                      + self.ssm_heads)
            n += di * d + di * self.ssm_conv
        if self._is_moe_layer(idx):
            e = self.n_experts
            fe = self.moe_d_ff or self.d_ff
            per = d * fe * (3 if self.activation == "swiglu" else 2)
            n += e * per + d * e
            n += self.n_shared_experts * per
        elif self._layer_kind(idx) != "ssm":
            n += d * self.d_ff * (3 if self.activation == "swiglu" else 2)
        n += 2 * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        fe = self.moe_d_ff or self.d_ff
        per = d * fe * (3 if self.activation == "swiglu" else 2)
        inactive = 0
        for i in range(self.num_layers):
            if self._is_moe_layer(i):
                inactive += (self.n_experts - self.top_k) * per
        return self.param_count() - inactive

    def _layer_kind(self, idx: int) -> str:
        if self.family == "hybrid" and self.attn_every > 0:
            return ("attn" if idx % self.attn_every == self.attn_offset
                    else "ssm")
        if self.family == "ssm":
            return "ssm"
        if self.family == "vlm" and self.cross_every > 0 \
                and idx % self.cross_every == self.cross_every - 1:
            return "cross"
        return "attn"

    def _is_moe_layer(self, idx: int) -> bool:
        return (self.n_experts > 0
                and idx % self.moe_every == self.moe_offset)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str       # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                    # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2).astype(jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


def truncated_normal_init(key, shape, scale: float, dtype):
    stddev = scale / max(1.0, math.sqrt(shape[0] if shape else 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)
