"""Pallas backend: the TPU kernels, interpret-mode on CPU.

Block shapes are a per-op *configuration* of the backend instance —
``PallasBackend(name="pallas_tuned", blocks={"int8_matmul": dict(bm=256,
bn=256, bk=256)})`` registers a differently-tiled variant without
touching the kernels or the models (the registry's whole point).
Requested blocks are shrunk to the largest divisor of the actual dim so
a tuned profile never trips the kernels' divisibility asserts on odd
shapes.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul import int8_matmul_pallas
from repro.kernels.int_attention import int_attention_pallas
from repro.kernels.int_gelu import int_gelu_pallas
from repro.kernels.int_layernorm import int_layernorm_pallas
from repro.kernels.int_softmax import int_softmax_pallas
from repro.ops import spec as _spec


def _fit_block(blk: int, dim: int) -> int:
    """Largest block <= blk that divides dim (kernels assert dim % blk)."""
    blk = min(blk, dim)
    while dim % blk:
        blk -= 1
    return blk


class PallasBackend:
    fused_attention = True
    fused_decode = False      # no ragged-cache decode kernel (see below)
    # no paged/wo-fold decode or chunked-prefill capabilities either:
    # OpSet lowers the operands exactly before dispatching here
    # (docs/KERNELS.md)
    paged_decode = False
    decode_wo_fold = False
    paged_prefill = False
    prefill_wo_fold = False
    # deliberately does NOT advertise tp_serving: this backend is the
    # serving engine's fallback exerciser — a tp > 1 engine over it
    # takes the exact single-device gather lowering, which is what
    # keeps that path tested (tp_serving on ref + pallas_fused)
    tp_serving = False

    def __init__(self, name: str = "pallas",
                 interpret: Optional[bool] = None,
                 blocks: Optional[Dict[str, Dict[str, int]]] = None):
        self.name = name
        self._interpret = interpret
        self.blocks = {op: dict(kw) for op, kw in (blocks or {}).items()}

    def _interp(self) -> bool:
        if self._interpret is not None:
            return self._interpret
        return jax.default_backend() != "tpu"

    def _opts(self, op: str, call_opts: dict) -> dict:
        merged = dict(self.blocks.get(op, {}))
        merged.update(call_opts)
        return merged

    # ------------------------------------------------------------- ops --

    def int8_matmul(self, x8, w8, spec, *, bias32=None, b_vec=None, **opts):
        if spec.is_raw:
            # no requant epilogue to fuse -> nothing for the kernel to
            # add over XLA's int8 dot, and raw consumers (lm head,
            # router, dt proj) often have odd N where divisor-fitted
            # blocks would degenerate; keep the MXU dot
            acc = jnp.dot(x8, w8, preferred_element_type=jnp.int32)
            if bias32 is not None:
                acc = acc + bias32[None, :]
            return acc
        opts = self._opts("int8_matmul", opts)
        m, k = x8.shape
        n = w8.shape[-1]
        bm = _fit_block(opts.pop("bm", 128), m)
        bn = _fit_block(opts.pop("bn", 128), n)
        bk = _fit_block(opts.pop("bk", 512), k)
        if spec.kind == _spec.PER_TENSOR:
            out = int8_matmul_pallas(x8, w8, bias32, dn=spec.dn,
                                     out_bits=spec.out_bits,
                                     out_dtype=spec.out_dtype,
                                     bm=bm, bn=bn, bk=bk,
                                     interpret=self._interp(), **opts)
        else:
            if b_vec is None:
                raise ValueError("per-channel RequantSpec needs the b_vec "
                                 "multiplier vector "
                                 "(QuantLinearParams.b_mult)")
            out = int8_matmul_pallas(x8, w8, bias32, b_vec=b_vec,
                                     c=spec.c, pre=spec.pre,
                                     out_bits=spec.out_bits,
                                     out_dtype=spec.out_dtype,
                                     bm=bm, bn=bn, bk=bk,
                                     interpret=self._interp(), **opts)
        return out

    def int_softmax(self, scores, plan, **opts):
        opts = self._opts("int_softmax", opts)
        opts.pop("where", None)   # oracle-only kwarg; kernel masks inline
        return int_softmax_pallas(scores, plan, interpret=self._interp(),
                                  **opts)

    def int_gelu(self, q, plan, dn_out, out_bits: int = 8, **opts):
        opts = self._opts("int_gelu", opts)
        return int_gelu_pallas(q, plan, dn_out, out_bits,
                               interpret=self._interp(), **opts)

    def int_layernorm(self, q, q_gamma, q_beta, plan, out_bits: int = 8,
                      **opts):
        opts = self._opts("int_layernorm", opts)
        return int_layernorm_pallas(q, q_gamma, q_beta, plan, out_bits,
                                    interpret=self._interp(), **opts)

    def int_attention(self, q8, k8, v8, plan, causal: bool = True,
                      window: int = 0, out_bits: int = 8, requant=None,
                      b_vec=None, **opts):
        opts = self._opts("int_attention", opts)
        if requant is not None:
            # this kernel hardcodes the per-tensor epilogue; fold the
            # spec's dyadic into the plan (pallas_fused takes all forms)
            if requant.kind != _spec.PER_TENSOR:
                raise NotImplementedError(
                    f"{self.name!r} attention supports per-tensor requant "
                    "only; use the 'pallas_fused' backend for "
                    f"{requant.kind!r}")
            plan = plan._replace(dn_out=requant.dn)
            out_bits = requant.out_bits
        sq, skv = q8.shape[1], k8.shape[1]
        if sq < 16 or skv < 16:
            # decode-sized problems: a degenerate (bq<16) grid costs more
            # than the oracle, which is also exact — same escape hatch as
            # pallas_fused's _can_tile
            from repro.kernels import ref as _ref
            return _ref.ref_int_attention(q8, k8, v8, plan, causal=causal,
                                          window=window, out_bits=out_bits)
        bq = _fit_block(opts.pop("bq", 128), sq)
        bkv = _fit_block(opts.pop("bkv", 128), skv)
        return int_attention_pallas(q8, k8, v8, plan, causal=causal,
                                    window=window, bq=bq, bkv=bkv,
                                    out_bits=out_bits,
                                    interpret=self._interp(), **opts)

    def int_decode_attention(self, q8, k8_cache, v8_cache, plan, valid_len,
                             out_bits: int = 8, requant=None, b_vec=None,
                             **opts):
        # the online-softmax kernel has no ragged-cache decode variant;
        # decode-sized problems take the exact full-matrix oracle here
        # (the 'pallas_fused' backend has the single-launch decode kernel)
        from repro.kernels import ref as _ref
        return _ref.ref_int_decode_attention(q8, k8_cache, v8_cache, plan,
                                             valid_len, out_bits,
                                             requant=requant, b_vec=b_vec)
