"""DEPRECATED string-dispatch wrappers — use :mod:`repro.ops` instead.

This module kept a ``backend="ref"|"pallas"`` string and a loose bag of
requant keywords (``dn`` vs ``b_vec``/``c``/``pre``, ``out_bits``,
``**blocks``) threaded through every call site.  The typed replacement
lives in :mod:`repro.ops`: a frozen :class:`repro.ops.RequantSpec` plus a
pluggable backend registry.  These wrappers translate the old calling
convention and emit ``DeprecationWarning``; they will be removed one
release after the migration (see docs/OPS_API.md).
"""
from __future__ import annotations

import warnings

from repro import ops as _ops
from repro.ops import RequantSpec


def _warn(name: str):
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; use repro.ops "
        "(RequantSpec + backend registry) instead — see docs/OPS_API.md",
        DeprecationWarning, stacklevel=3)


def int8_matmul(x8, w8, bias32=None, dn=None, b_vec=None, c=0, pre=0,
                out_bits=8, backend="ref", **blocks):
    _warn("int8_matmul")
    if dn is not None:
        spec = RequantSpec.per_tensor(dn, out_bits)
    elif b_vec is not None:
        spec = RequantSpec.per_channel(c, pre, out_bits)
    else:
        spec = RequantSpec.raw()
    return _ops.resolve_ops(backend).int8_matmul(
        x8, w8, spec, bias32=bias32, b_vec=b_vec, **blocks)


def int_softmax(scores, plan, backend="ref", **kw):
    _warn("int_softmax")
    return _ops.resolve_ops(backend).int_softmax(scores, plan, **kw)


def int_gelu(q, plan, dn_out, out_bits=8, backend="ref", **kw):
    _warn("int_gelu")
    return _ops.resolve_ops(backend).int_gelu(q, plan, dn_out,
                                              out_bits=out_bits, **kw)


def int_layernorm(q, q_gamma, q_beta, plan, out_bits=8, backend="ref",
                  **kw):
    _warn("int_layernorm")
    return _ops.resolve_ops(backend).int_layernorm(
        q, q_gamma, q_beta, plan, out_bits=out_bits, **kw)


def int_attention(q8, k8, v8, plan, causal=True, window=0, out_bits=8,
                  backend="ref", **kw):
    _warn("int_attention")
    return _ops.resolve_ops(backend).int_attention(
        q8, k8, v8, plan, causal=causal, window=window,
        out_bits=out_bits, **kw)
