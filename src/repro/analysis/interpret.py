"""Whole-model integer-range certification (the abstract interpreter).

:func:`certify_config` walks one architecture's design-time plans
(``quant.plans.build_layer_plans``) layer-kind by layer-kind, pushing
worst-case :class:`~repro.analysis.ranges.IntRange` intervals through the
transfer functions of every op in the ``repro.ops`` API — ``int8_matmul``,
``int8_matmul_packed``, ``int_softmax``, ``int_gelu``, ``int_layernorm``,
``int_attention``, ``int_decode_attention`` / ``int_paged_prefill``
(both also at their int4-KV-page operand ranges) — at a given
``(seq_len, cache_len)``, and raises a typed, location-bearing
:class:`~repro.analysis.budgets.BitBudgetError` if *any* intermediate of
the exact integer computation could leave int32.  On success it returns
a :class:`ConfigReport` with per-op worst-case bits, headroom and the
predicted kernel path (fused vs fallback, via
:mod:`repro.analysis.contracts`).

On top of the op walk, :func:`~repro.analysis.ranges.audit_dyadics`
re-proves the ``fit_dyadic`` staging invariant of **every** dyadic in the
plan tree (including the ~20 Mamba-branch constants) at its declared
``qmax_in`` — so a hand-edited constant that drifts from the fit contract
fails certification even if no op-level transfer touches it.

What is *assumed* rather than proven is returned in
``ConfigReport.assumptions`` (and documented in docs/ANALYSIS.md): the
residual-stream calibration bound ``qmax_res``, the nominal folded-bias
bound, and the ±127 design operand grid.
"""
from __future__ import annotations

import dataclasses

from repro.analysis import contracts
from repro.analysis.budgets import (MAX_ROWSUM_LEN, MAX_SQ, bits_for,
                                    static_check)
from repro.analysis.ranges import (INT4, INT4_KV, INT8, MSR4_DELTA_MAX,
                                   IntRange, audit_dyadics,
                                   t_attention_acc, t_clip,
                                   t_dyadic, t_dyadic_perchannel, t_gelu,
                                   t_layernorm, t_matmul_acc,
                                   t_requant_spec, t_silu, t_softmax)

#: nominal folded-bias bound at accumulator scale: |B| <= 4 real units
#: over s_act8 * S_W8 ~ 1e-3 -> ~4e3; listed as an assumption per config
BIAS_QMAX = 1 << 12


@dataclasses.dataclass(frozen=True)
class OpReport:
    """One certified op instance at one model-walk location."""

    op: str                 # the repro.ops API name
    layer: str              # model-walk location, e.g. "attn.qkv"
    worst: int              # worst-case |q| across the op's intermediates
    path: str = "exact"     # predicted kernel path (fused / fallback / ...)
    note: str = ""

    @property
    def bits(self) -> int:
        return bits_for(self.worst) + 1     # sign bit included

    @property
    def headroom_bits(self) -> int:
        return 32 - self.bits


@dataclasses.dataclass
class ConfigReport:
    """Certification result for one registry config."""

    name: str
    seq_len: int
    cache_len: int
    ops: list
    n_dyadics: int          # plan-tree dyadics whose staging was re-proved
    assumptions: list

    @property
    def worst_bits(self) -> int:
        return max(o.bits for o in self.ops)

    @property
    def min_headroom_bits(self) -> int:
        return min(o.headroom_bits for o in self.ops)


class _Track:
    """Collect named intermediates; ``worst`` is the certified maximum."""

    def __init__(self):
        self.vals = []

    def __call__(self, name: str, r):
        q = r.qmax if isinstance(r, IntRange) else int(r)
        self.vals.append((name, q))
        return r

    @property
    def worst(self) -> int:
        return max(q for _, q in self.vals) if self.vals else 0


# ======================================================================
# the seven per-op checkers
# ======================================================================

def plan_b_max(plan) -> int:
    """The sound per-channel multiplier bound for a ``LinearPlan``.

    The plan's shared ``(c, pre)`` come from ``fit_dyadic`` at the
    worst-case channel ratio (``s_w <= S_W8``, the design's nominal
    weight-scale bound — listed as an assumption), so every channel's
    ``perchannel_multipliers`` entry is bounded by that fit's own ``b``
    — typically in [2^14, 2^15), far tighter than the generic 2^15-1."""
    from repro.core.dyadic import fit_dyadic
    from repro.quant.plans import S_W8
    dn = fit_dyadic(plan.s_in * S_W8 / plan.s_out, plan.acc_qmax)
    assert (dn.c, dn.pre) == (plan.c, plan.pre), (dn, plan)
    return dn.b


def check_int8_matmul(plan, layer: str, x: IntRange = INT8,
                      bias_qmax: int = BIAS_QMAX, op: str = "int8_matmul"):
    """A ``quant.plans.LinearPlan`` matmul: int8·int8 → int32 acc (+bias)
    → per-channel dyadic requant (or raw when ``s_out == 0``)."""
    t = _Track()
    acc = t("accumulator", t_matmul_acc(
        plan.k_dim, x, bias=IntRange.symmetric(bias_qmax),
        op=op, layer=layer))
    if plan.s_out == 0.0:                      # raw int32 logits
        out = acc
    else:
        out = t_clip(t("requant staging", t_dyadic_perchannel(
            acc, plan.c, plan.pre, b_max=plan_b_max(plan),
            op=op, layer=layer)), plan.out_bits)
    return out, OpReport(op, layer, t.worst, path="pallas")


def check_int8_matmul_packed(plan, layer: str, x: IntRange = INT8,
                             bias_qmax: int = BIAS_QMAX,
                             op: str = "int8_matmul_packed"):
    """The sub-8-bit weight tier: the packed matmul accumulates the
    nibble operand (``|w| <= 7``) and — for msr4 — the outlier-lane
    correction (``|delta| <= 120``, distinct rows per group) as separate
    int32 partials whose sum is the dense accumulator.  Element-wise
    ``|nib| + |delta| == |w| <= 127``, so the combined range is exactly
    the dense ``k·|x|·127`` budget; the split pieces are certified
    individually because the kernels materialize them."""
    t = _Track()
    t("nibble accumulator", t_matmul_acc(
        plan.k_dim, x, w_qmax=INT4.qmax,
        what="packed nibble accumulator", op=op, layer=layer))
    t("outlier correction", t_matmul_acc(
        plan.k_dim, x, w_qmax=MSR4_DELTA_MAX,
        what="msr4 outlier correction", op=op, layer=layer))
    acc = t("accumulator", t_matmul_acc(
        plan.k_dim, x, bias=IntRange.symmetric(bias_qmax),
        op=op, layer=layer))
    if plan.s_out == 0.0:
        out = acc
    else:
        out = t_clip(t("requant staging", t_dyadic_perchannel(
            acc, plan.c, plan.pre, b_max=plan_b_max(plan),
            op=op, layer=layer)), plan.out_bits)
    return out, OpReport(op, layer, t.worst, path="pallas", note="msr4")


def check_int_softmax(sm, score: IntRange, rowlen: int, layer: str,
                      exact: bool = True, op: str = "int_softmax"):
    t = _Track()
    t("scores", score)
    out = t_softmax(sm, score, rowlen, exact_rowsum=exact,
                    op=op, layer=layer)
    if exact:
        t("row sum", rowlen * (1 << 15))
    return out, OpReport(op, layer, t.worst,
                         path="exact" if exact else "streaming")


def check_int_gelu(ffn, x: IntRange, layer: str, op: str = "int_gelu"):
    """The FFN activation stage (i-GELU, or i-SiLU + gate for SwiGLU)."""
    t = _Track()
    if ffn.act_gelu is not None:
        t("i-gelu product", x.qmax * 2 * ffn.act_gelu.gelu.q_one)
        out = t_gelu(ffn.act_gelu, x, op=op, layer=layer)
        note = "i-gelu"
    else:
        t("i-silu product", x.qmax << 15)
        gate8 = t_silu(ffn.act_silu, x, op=op, layer=layer)
        prod = IntRange.symmetric(
            static_check(gate8.qmax * x.qmax, "swiglu gate product",
                         op=op, layer=layer))
        t("swiglu gate product", prod)
        out = t_clip(t_dyadic(prod, ffn.dn_gate, what="swiglu gate dyadic",
                              op=op, layer=layer), 8)
        note = "i-silu + swiglu gate"
    return out, OpReport(op, layer, t.worst, note=note)


def check_int_layernorm(plan, layer: str, x: IntRange = None,
                        op: str = "int_layernorm"):
    t = _Track()
    x = IntRange.symmetric(plan.qmax_in) if x is None else x
    y_max = x.qmax * 2 if plan.subtract_mean else x.qmax
    t("normalisation product",
      y_max << (plan.recip_bits + plan.pre_shift))
    out = t_layernorm(plan, x, op=op, layer=layer)
    return out, OpReport(op, layer, t.worst,
                         note="layernorm" if plan.subtract_mean
                         else "rmsnorm")


def _attention_core(ia, rowlen: int, layer: str, op: str, t: _Track,
                    kv_qmax: int = 127):
    """Shared Q·Kᵀ → Shiftmax → P·V → dn_out epilogue range walk.

    ``kv_qmax`` is the K/V operand magnitude: 127 on the int8 grid, or
    ``INT4_KV.qmax`` (7 << KV4_SHIFT = 112) when the pages store packed
    nibbles that the kernel dequantizes in-launch — strictly inside the
    int8 grid, so the packed tier certifies wherever the dense one does."""
    score = t("scores", t_matmul_acc(
        ia.head_dim, w_qmax=kv_qmax,
        what="attention score accumulator", op=op, layer=layer))
    exact = rowlen <= MAX_ROWSUM_LEN
    t_softmax(ia.sm, score, rowlen, exact_rowsum=exact, op=op, layer=layer)
    acc = t("P*V accumulator", t_attention_acc(rowlen, v_qmax=kv_qmax,
                                               op=op, layer=layer))
    out = t_clip(t("epilogue staging", t_dyadic(
        acc, ia.dn_out, what="attention epilogue dyadic",
        op=op, layer=layer)), 8)
    return out, exact


def check_int_attention(ia, seq_len: int, layer: str,
                        op: str = "int_attention"):
    t = _Track()
    out, exact = _attention_core(ia, seq_len, layer, op, t)
    bq = contracts.fit_block(128, seq_len)
    bkv = contracts.fit_block(128, seq_len)
    fused = contracts.can_tile(seq_len, seq_len, bq, bkv)
    path = "fused" if fused else \
        ("fallback:two-pass-streaming" if not exact else "fallback:oracle")
    return out, OpReport(op, layer, t.worst, path=path)


def check_int_decode_attention(ia, cache_len: int, layer: str,
                               sq: int = MAX_SQ, kv_pack: bool = False,
                               op: str = "int_decode_attention"):
    t = _Track()
    kv_qmax = INT4_KV.qmax if kv_pack else 127
    out, exact = _attention_core(ia, cache_len, layer, op, t,
                                 kv_qmax=kv_qmax)
    bkv = contracts.fit_block(128, cache_len)
    fused = contracts.can_tile_decode(sq, cache_len, ia.head_dim, bkv)
    path = "fused" if fused else \
        ("fallback:two-pass-streaming" if not exact else "fallback:oracle")
    return out, OpReport(op, layer, t.worst, path=path,
                         note="int4 kv pages" if kv_pack else "")


def check_int_paged_prefill(ia, cache_len: int, layer: str,
                            chunk: int = 256, page_size: int = 64,
                            wo=None, n_heads: int = 0,
                            kv_pack: bool = False,
                            op: str = "int_paged_prefill"):
    """``wo``: the o-projection ``LinearPlan`` when certifying the
    folded-wo launch epilogue (int8 attention tile → int8 matmul →
    per-channel requant inside the same kernel)."""
    t = _Track()
    kv_qmax = INT4_KV.qmax if kv_pack else 127
    out, exact = _attention_core(ia, cache_len, layer, op, t,
                                 kv_qmax=kv_qmax)
    if wo is not None:
        t("folded wo accumulator", t_matmul_acc(
            wo.k_dim, out, bias=IntRange.symmetric(BIAS_QMAX),
            what="folded wo accumulator", op=op, layer=layer))
        t("folded wo staging", t_dyadic_perchannel(
            IntRange.symmetric(t.vals[-1][1]), wo.c, wo.pre,
            b_max=plan_b_max(wo), what="folded wo requant",
            op=op, layer=layer))
    bq = contracts.fit_block(128, chunk)
    bkv = contracts.fit_block(128, page_size)
    fused = contracts.can_tile_prefill(cache_len, ia.head_dim, bq, bkv)
    path = "fused" if fused else \
        ("fallback:two-pass-streaming" if not exact else "fallback:oracle")
    return out, OpReport(op, layer, t.worst, path=path,
                         note="int4 kv pages" if kv_pack else "")


def check_requant_spec(spec, r: IntRange, op: str, layer: str,
                       b_max: int = None) -> IntRange:
    """Certify one :class:`repro.ops.RequantSpec` epilogue against an
    incoming range — the entry point the regression tests drive with
    deliberately-unsafe specs."""
    kw = {} if b_max is None else {"b_max": b_max}
    return t_requant_spec(r, spec, op=op, layer=layer, **kw)


# ======================================================================
# the model walk
# ======================================================================

def _check_ffn(ffn, prefix: str, ops):
    h10, rep = check_int8_matmul(ffn.up, f"{prefix}.up")
    ops.append(rep)
    a8, rep = check_int_gelu(ffn, h10, f"{prefix}.act")
    ops.append(rep)
    y, rep = check_int8_matmul(ffn.down, f"{prefix}.down")
    ops.append(rep)
    return y


def _check_mamba(m, cfg, ops, assumptions):
    """Targeted checks on the Mamba2/SSD integer path; the plan-tree
    audit covers the remaining dyadics at their declared ranges."""
    _, rep = check_int8_matmul(m.in_proj, "mamba.in_proj")
    ops.append(rep)
    t = _Track()
    lyr = "mamba.ssd"
    opn = "int8_matmul"
    conv_acc = t("conv accumulator", t_matmul_acc(
        cfg.ssm_conv, what="conv accumulator", op=opn, layer=lyr))
    conv10 = t_clip(t_dyadic(conv_acc, m.dn_conv, what="conv dyadic",
                             op=opn, layer=lyr), 11)
    t_silu(m.silu_conv, conv10, op="int_gelu", layer=f"{lyr}.conv_silu")
    # dt path: accumulator -> 10-bit dt_in -> softplus -> 13-bit dt
    t_dyadic(IntRange.symmetric(m.in_proj.acc_qmax), m.dn_dt_in,
             what="dt dyadic", op=opn, layer=f"{lyr}.dt")
    dt = IntRange(0, (1 << 13) - 1)           # softplus clip at out_bits=13
    # decay: dt*A on the 2^-14 grid -> i-exp -> 2^-15 fraction
    t_dyadic(IntRange.symmetric(dt.hi * 1024), m.dn_dtA,
             what="dt*A dyadic", op=opn, layer=f"{lyr}.decay")
    # state update: dt * B * x contribution and the h8/y readout
    xbc = 127                                  # s_xbc int8 grid
    contrib = t("dt*B*x product", static_check(
        dt.hi * xbc * xbc, "dt*B*x product", op=opn, layer=lyr))
    t_dyadic(IntRange.symmetric(contrib), m.dn_h, what="state dyadic",
             op=opn, layer=f"{lyr}.state")
    t_dyadic(IntRange.symmetric(m.qmax_h), m.dn_h8, what="h8 dyadic",
             op=opn, layer=f"{lyr}.h8")
    y_acc = t("C*h8 accumulator", t_matmul_acc(
        cfg.ssm_state, what="C*h8 accumulator", op=opn, layer=lyr))
    t_dyadic(y_acc, m.dn_y, what="y dyadic", op=opn, layer=f"{lyr}.y")
    ops.append(OpReport(opn, lyr, t.worst, note="ssd state path"))
    _, rep = check_int_layernorm(m.norm, "mamba.norm")
    ops.append(rep)
    _, rep = check_int8_matmul(m.out_proj, "mamba.out_proj")
    ops.append(rep)
    assumptions.append(
        f"mamba head state saturates at qmax_h={m.qmax_h} "
        "(runtime clip in the SSD scan)")


def certify_config(cfg, seq_len: int = 4096, cache_len: int = 32768,
                   calib: dict = None) -> ConfigReport:
    """Statically certify one :class:`repro.models.common.ArchConfig`:
    every op of the integer datapath at worst case, at ``(seq_len,
    cache_len)``.  Raises :class:`BitBudgetError` (typed: op + layer +
    worst value) on any int32 overflow; returns the report otherwise."""
    from repro.quant.plans import LinearPlan, build_layer_plans
    plans = build_layer_plans(cfg, calib)
    ops, assumptions = [], [
        f"residual stream bounded by qmax_res={cfg.qmax_res} "
        "(calibration contract — residual adds carry no runtime clip)",
        f"folded biases bounded by {BIAS_QMAX} at accumulator scale "
        "(|B| <= 4 real units over the nominal weight/act scales)",
        "int8 operands certified on the +-127 design grid "
        "(docs/ANALYSIS.md: 'The -128 corner')",
        "per-channel weight scales bounded by S_W8 (the nominal "
        "worst-case channel ratio every LinearPlan's (c, pre) is "
        "fitted at)",
        "i-norm output stage certified at the |n| <= sqrt(d) design "
        "bound (sigma^2 >= y_i^2/d; make_inorm's declared n_q_max)",
        "packed weight tier: nibbles on the +-7 grid, msr4 outlier "
        "deltas <= 120, element-wise |nib| + |delta| == |w| <= 127 "
        "(quant.pack contract)",
        "int4 KV pages dequantize to q4 << 4 (|kv| <= 112, inside the "
        "int8 grid; repro.ops.packed.KV_SHIFT)",
    ]
    # embedding -> residual stream
    t_dyadic(INT8, plans.embed.dn_res, what="embed residual dyadic",
             op="int8_matmul", layer="embed")
    # pre-attention / final norm (the same plan; certified once per site)
    _, rep = check_int_layernorm(plans.norm, "norm")
    ops.append(rep)
    if plans.attn is not None:
        _, rep = check_int8_matmul(plans.attn.qkv, "attn.qkv")
        ops.append(rep)
        _, rep = check_int8_matmul_packed(plans.attn.qkv,
                                          "attn.qkv[msr4]")
        ops.append(rep)
        _, rep = check_int_attention(plans.attn.attn, seq_len, "attn.core")
        ops.append(rep)
        out8 = IntRange.symmetric(127)
        y, rep = check_int8_matmul(plans.attn.out, "attn.out", x=out8)
        ops.append(rep)
        static_check(y.qmax, "attention residual write",
                     budget=cfg.qmax_res, op="int8_matmul",
                     layer="attn.out")
        if cfg.is_causal:
            _, rep = check_int_decode_attention(
                plans.attn.attn, cache_len, "attn.decode")
            ops.append(rep)
            _, rep = check_int_decode_attention(
                plans.attn.attn, cache_len, "attn.decode[kv4]",
                kv_pack=True)
            ops.append(rep)
            _, rep = check_int_paged_prefill(
                plans.attn.attn, cache_len, "attn.prefill",
                wo=plans.attn.out, n_heads=cfg.n_heads)
            ops.append(rep)
            _, rep = check_int_paged_prefill(
                plans.attn.attn, cache_len, "attn.prefill[kv4]",
                wo=plans.attn.out, n_heads=cfg.n_heads, kv_pack=True)
            ops.append(rep)
    elif plans.ffn is not None:
        # no attention projections: certify the packed weight tier on
        # the FFN up-projection so every config proves the sub-8-bit
        # matmul path
        _, rep = check_int8_matmul_packed(plans.ffn.up, "ffn.up[msr4]")
        ops.append(rep)
    elif plans.mamba is not None:
        _, rep = check_int8_matmul_packed(plans.mamba.in_proj,
                                          "mamba.in_proj[msr4]")
        ops.append(rep)
    if plans.cross is not None and plans.cross is not plans.attn:
        _, rep = check_int_attention(plans.cross.attn, seq_len,
                                     "cross.core")
        ops.append(rep)
    if plans.ffn is not None:
        y = _check_ffn(plans.ffn, "ffn", ops)
        static_check(y.qmax, "ffn residual write", budget=cfg.qmax_res,
                     op="int8_matmul", layer="ffn.down")
    if plans.moe is not None:
        logits, rep = check_int8_matmul(plans.moe.router, "moe.router")
        ops.append(rep)
        _, rep = check_int_softmax(plans.moe.gate_sm, logits,
                                   cfg.n_experts, "moe.gate")
        ops.append(rep)
        _check_ffn(plans.moe.expert, "moe.expert", ops)
        if plans.moe.shared is not None:
            _check_ffn(plans.moe.shared, "moe.shared", ops)
        combine = IntRange.symmetric(
            static_check(cfg.top_k * 127 * 127, "moe combine sum",
                         op="int8_matmul", layer="moe.combine"))
        t_dyadic(combine, plans.moe.dn_combine, what="moe combine dyadic",
                 op="int8_matmul", layer="moe.combine")
    if plans.mamba is not None:
        _check_mamba(plans.mamba, cfg, ops, assumptions)
    _, rep = check_int8_matmul(
        LinearPlan(cfg.s_act8, 0.0, 32, 0, 0, cfg.d_model), "head")
    ops.append(rep)
    n_dyadics = audit_dyadics(plans, prefix=cfg.name)
    return ConfigReport(cfg.name, seq_len, cache_len, ops, n_dyadics,
                        assumptions)


__all__ = [
    "BIAS_QMAX", "ConfigReport", "OpReport", "certify_config",
    "check_int8_matmul", "check_int8_matmul_packed", "check_int_attention",
    "check_int_decode_attention", "check_int_gelu",
    "check_int_layernorm", "check_int_paged_prefill",
    "check_int_softmax", "check_requant_spec",
]
