"""Self-speculative draft proposers for the serving engine.

Speculative decoding amortizes the per-step launch overhead SwiftTron's
control unit pays once per token: a cheap *proposer* drafts ``K`` next
tokens per live lane, and the engine verifies all ``K + 1`` positions in
ONE ``int_decode_attention`` launch with the ``Sq = K + 1`` stepped mask
the decode kernel has carried since PR 3 (``docs/KERNELS.md``) — fused
on ``pallas_fused``, exact oracle lowering elsewhere.  Greedy acceptance
keeps the longest prefix of the draft that matches the model's own
argmax stream, so speculation changes *when* tokens are computed, never
*which*: the committed stream is bit-exact with ``spec_k = 0``.

The proposers here are **self-speculative**: no draft model, no extra
weights.  :class:`NgramProposer` is prompt-lookup decoding — match the
context's trailing n-gram against its own earlier occurrences (prompt +
generated tokens) and propose the continuation.  This is exactly right
for the engine's prefix-cached serving traffic (templated prompts,
structured/repetitive continuations), and costs O(context) host-side
python per step.

Rejected drafts roll back for free: the paged cache truncates the
session's page list (``PagedKVCache.truncate``) and ``valid_len``
masking hides the stale K/V — no data movement, the invariant the
paged pool was designed around.

Typed errors: :class:`SpeculationError` (a ``ValueError``) for config
mistakes, :class:`SpeculationUnsupported` for arch / sampling modes the
verify step cannot serve.  :func:`validate_spec` is the single
validation entry point the engine constructor and the serve CLI share.
"""
from __future__ import annotations

from typing import Dict, List, Protocol, Sequence, Type

from repro.analysis.budgets import MAX_SQ
from repro.models.common import ArchConfig
from repro.models.inttransformer import speculative_decode_supported


class SpeculationError(ValueError):
    """Invalid speculative-decoding configuration (bad ``spec_k``,
    unknown proposer mode)."""


class SpeculationUnsupported(SpeculationError):
    """Speculative decoding cannot serve this request or arch.

    Raised for sliding-window / SSM / cross-attention archs (their
    lane-indexed or rolling state breaks the batched multi-position
    verify) and for ``temperature > 0`` requests (greedy longest-prefix
    acceptance is only bit-exact against the argmax stream — a sampled
    stream would silently diverge).
    """


class Proposer(Protocol):
    """Drafts up to ``k`` next tokens from the decoded context."""

    name: str

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        """Return ``<= k`` draft tokens continuing ``context`` (the
        session's ``prompt + out_tokens``).  An empty list is always a
        legal answer (the engine then verifies only the bonus token —
        one launch, one token, exactly the non-speculative step)."""
        ...


class NgramProposer:
    """Prompt-lookup decoding: propose the continuation of the most
    recent earlier occurrence of the context's trailing n-gram.

    Tries suffix lengths ``max_n`` down to ``min_n``; for the first
    suffix that re-occurs earlier in the context, proposes the ``k``
    tokens that followed it (preferring the most recent occurrence, so
    generated cycles and templated boilerplate are predicted exactly).
    No match -> empty draft, and the engine's verify step degenerates to
    a plain decode step.
    """

    name = "ngram"

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise SpeculationError(
                f"need 1 <= min_n <= max_n, got min_n={min_n}, "
                f"max_n={max_n}")
        self.max_n = max_n
        self.min_n = min_n

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        ctx = list(context)
        n_ctx = len(ctx)
        if k <= 0 or n_ctx < self.min_n + 1:
            return []
        for n in range(min(self.max_n, n_ctx - 1), self.min_n - 1, -1):
            suffix = ctx[n_ctx - n:]
            # scan right-to-left so cycles continue from their most
            # recent period; prefer the latest occurrence whose
            # continuation is a full k tokens — matches hugging the
            # context end (constant / short-cycle tails re-match their
            # own last period) would otherwise truncate every draft to
            # a token or two
            best: List[int] = []
            for start in range(n_ctx - n - 1, -1, -1):
                if ctx[start:start + n] == suffix:
                    cont = ctx[start + n:start + n + k]
                    if len(cont) == k:
                        return [int(t) for t in cont]
                    if cont and not best:
                        best = cont
            if best:
                return [int(t) for t in best]
        return []


PROPOSERS: Dict[str, Type] = {NgramProposer.name: NgramProposer}


def get_proposer(mode: str, **kwargs) -> Proposer:
    """Instantiate a registered proposer by name; typed error on an
    unknown mode (the serve CLI surfaces it as an argparse error)."""
    cls = PROPOSERS.get(mode)
    if cls is None:
        raise SpeculationError(
            f"unknown spec_mode {mode!r}; registered proposers: "
            f"{sorted(PROPOSERS)}")
    return cls(**kwargs)


def validate_spec(cfg: ArchConfig, spec_k: int, spec_mode: str) -> None:
    """Typed validation of a speculative-decoding configuration, shared
    by the engine constructor and the serve CLI (fail at the boundary,
    not as a kernel-shape error inside a launch)."""
    if spec_k < 0:
        raise SpeculationError(f"spec_k must be >= 0, got {spec_k}")
    if spec_k == 0:
        return
    if spec_k > MAX_SQ - 1:
        raise SpeculationError(
            f"spec_k={spec_k} exceeds the decode kernel's speculative "
            f"query budget: the Sq = spec_k + 1 verify launch holds at "
            f"most MAX_SQ={MAX_SQ} rows in scratch "
            f"(analysis.budgets), so spec_k <= {MAX_SQ - 1}")
    if not speculative_decode_supported(cfg):
        raise SpeculationUnsupported(
            f"speculative decoding is unsupported for arch "
            f"{cfg.name!r}: the batched verify step needs full "
            "(window == 0) causal attention and attention+ffn/moe "
            "sublayers only — sliding-window caches interleave rolling-"
            "buffer writes and reads token-by-token, and SSM / cross-"
            "attention archs carry lane-indexed state a rejected draft "
            "cannot roll back; serve with spec_k=0")
    get_proposer(spec_mode)         # raises SpeculationError on typos


__all__ = [
    "NgramProposer", "PROPOSERS", "Proposer", "SpeculationError",
    "SpeculationUnsupported", "get_proposer", "validate_spec",
]
