"""Launcher drivers: train -> checkpoint -> serve round trip (subprocess,
the same commands a user runs)."""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(args, timeout=600, retries=2):
    """Subprocess runner with one retry — the drivers spawn fresh JAX
    processes and can hit transient resource contention when the whole
    suite runs in parallel."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)     # don't inherit fake-device settings
    out = None
    for _ in range(retries):
        out = subprocess.run([sys.executable, "-m"] + args, env=env,
                             capture_output=True, text=True,
                             timeout=timeout)
        if out.returncode == 0:
            return out
    return out


def test_train_then_serve_roundtrip(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out = _run(["repro.launch.train", "--arch", "granite-3-2b",
                "--reduced", "--steps", "6", "--batch", "2", "--seq",
                "32", "--ckpt-dir", ckpt, "--ckpt-every", "3"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout
    out = _run(["repro.launch.serve", "--arch", "granite-3-2b",
                "--reduced", "--requests", "2", "--max-new", "3",
                "--cache-len", "48", "--ckpt-dir", ckpt])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 2 requests" in out.stdout
    assert "int8" in out.stdout
