"""Model assembly for all six architecture families.

Float path (training / QAT producer) and integer path (SwiftTron serving
datapath) share the same parameter layout so ``quant.convert`` is a pure
per-tensor transformation and ``lax.scan`` stacks stay homogeneous.

Layer grouping for scan:
  dense / moe / ssm / encoder : all layers identical -> one stacked scan
  vlm                         : blocks of (cross_every-1 self + 1 cross)
  hybrid (jamba)              : blocks of ``attn_every`` sublayers
                                (1 attn + rest mamba; MoE per moe_every)
  encdec                      : separate encoder and decoder stacks; the
                                decoder sublayer = self-attn + cross + ffn
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard, shard_residual
from repro.models import layers as fl
from repro.models import mamba as mb
from repro.models.common import ArchConfig, sinusoidal_pos

Pytree = Any


# ============================================================ init =========

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def layer_group_spec(cfg: ArchConfig):
    """(group_len, n_groups, kinds); kinds[j] = (mixer, ffn_kind, cross?)."""
    if cfg.family == "vlm" and cfg.cross_every > 0:
        gl = cfg.cross_every
        kinds = [("attn", "ffn", False)] * (gl - 1) + [("cross", "ffn",
                                                        False)]
    elif cfg.family == "hybrid" and cfg.attn_every > 0:
        gl = cfg.attn_every
        kinds = []
        for j in range(gl):
            mix = "attn" if j == cfg.attn_offset else "ssm"
            ff = "moe" if (cfg.n_experts and j % cfg.moe_every
                           == cfg.moe_offset) else "ffn"
            kinds.append((mix, ff, False))
    elif cfg.family == "ssm":
        gl, kinds = 1, [("ssm", None, False)]
    elif cfg.family == "encdec":
        gl, kinds = 1, [("attn", "ffn", True)]     # decoder sublayer
    else:
        gl = 1
        ff = "moe" if (cfg.n_experts and cfg.moe_every == 1) else "ffn"
        kinds = [("attn", ff, False)]
    n = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    assert n % gl == 0, (n, gl)
    return gl, n // gl, kinds


def _init_sublayer(key, cfg: ArchConfig, mix: str, ff: Optional[str],
                   cross: bool, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": fl.init_norm(cfg, dtype)}
    if mix in ("attn", "cross"):
        p["attn"] = fl.init_attn(ks[0], cfg, dtype, cross=(mix == "cross"))
    elif mix == "ssm":
        p["ssm"] = mb.init_mamba(ks[0], cfg, dtype)
    if cross:
        p["cross"] = fl.init_attn(ks[2], cfg, dtype, cross=True)
        p["norm_cross"] = fl.init_norm(cfg, dtype)
    if ff is not None:
        p["norm2"] = fl.init_norm(cfg, dtype)
        p[ff] = fl.init_moe(ks[1], cfg, dtype) if ff == "moe" \
            else fl.init_ffn(ks[1], cfg, dtype)
    return p


def init_params(key, cfg: ArchConfig) -> Pytree:
    dtype = jnp.dtype(cfg.dtype)
    gl, ng, kinds = layer_group_spec(cfg)
    keys = jax.random.split(key, ng * gl + 8)
    v = cfg.padded_vocab()
    params: Dict[str, Pytree] = {
        "embed": fl._init(keys[-1], (v, cfg.d_model), dtype, scale=1.0),
        "final_norm": fl.init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings and cfg.family != "encoder":
        params["lm_head"] = fl._init(keys[-2], (cfg.d_model, v), dtype)
    if cfg.pos == "learned":
        params["pos_embed"] = fl._init(keys[-3], (65536, cfg.d_model),
                                       dtype)
    params["layers"] = [
        _stack([_init_sublayer(keys[i * gl + j], cfg, *kinds[j], dtype)
                for i in range(ng)])
        for j in range(gl)
    ]
    if cfg.family == "encdec":
        ekeys = jax.random.split(keys[-4], cfg.enc_layers)
        params["enc_layers"] = [_stack([
            _init_sublayer(ekeys[i], cfg, "attn", "ffn", False, dtype)
            for i in range(cfg.enc_layers)])]
        params["enc_final_norm"] = fl.init_norm(cfg, dtype)
    return params


# ===================================================== float forward ======

def _sublayer_fwd_float(p, x, cfg: ArchConfig, kind, positions, qat,
                        causal=True, memory=None):
    mix, ff, has_cross = kind
    window = cfg.window if mix == "attn" else 0
    aux = jnp.zeros((), jnp.float32)

    def mixer(h):
        if mix in ("attn", "cross"):
            return fl.attn_fwd(p["attn"], h, cfg, positions, causal=causal,
                               window=window,
                               memory=memory if mix == "cross" else None,
                               qat=qat)
        return mb.mamba_fwd(p["ssm"], h, cfg, qat=qat)

    def ffn(h):
        if ff == "moe":
            return fl.moe_fwd(p["moe"], h, cfg, qat=qat)
        return fl.ffn_fwd(p["ffn"], h, cfg, qat=qat), None

    if cfg.post_norm:
        x = fl.norm_fwd(p["norm1"], x + mixer(x), cfg)
        if has_cross:
            c = fl.attn_fwd(p["cross"], x, cfg, positions, causal=False,
                            memory=memory, qat=qat)
            x = fl.norm_fwd(p["norm_cross"], x + c, cfg)
        if ff is not None:
            f, a = ffn(x)
            x = fl.norm_fwd(p["norm2"], x + f, cfg)
            if a is not None:
                aux = aux + a
        return x, aux
    x = x + mixer(fl.norm_fwd(p["norm1"], x, cfg))
    if has_cross:
        h = fl.norm_fwd(p["norm_cross"], x, cfg)
        x = x + fl.attn_fwd(p["cross"], h, cfg, positions, causal=False,
                            memory=memory, qat=qat)
    if ff is not None:
        f, a = ffn(fl.norm_fwd(p["norm2"], x, cfg))
        x = x + f
        if a is not None:
            aux = aux + a
    return x, aux


def _run_stack_float(layer_params: List, x, cfg: ArchConfig, kinds,
                     positions, qat, causal=True, memory=None):
    from repro.distributed.sharding import constrain_like_params

    def body(carry, xs):
        x, aux = carry
        xs = constrain_like_params(xs)
        for j, kind in enumerate(kinds):
            x, a = _sublayer_fwd_float(xs[j], x, cfg, kind, positions, qat,
                                       causal=causal, memory=memory)
            aux = aux + a
        return (x, aux), None

    carry0 = (x, jnp.zeros((), jnp.float32))
    if not cfg.scan_layers:
        # unrolled: keeps FSDP weight gathers per-layer (XLA hoists
        # loop-invariant stack gathers out of while loops — DESIGN.md §7)
        ng = jax.tree.leaves(layer_params[0])[0].shape[0]
        fn = jax.remat(body) if cfg.remat else body
        carry = carry0
        for i in range(ng):
            xs_i = jax.tree.map(lambda t: t[i], tuple(layer_params))
            carry, _ = fn(carry, xs_i)
        return carry
    fn = jax.remat(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(fn, carry0, tuple(layer_params))
    return x, aux


def embed_tokens(params, tokens, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos == "learned":
        s = tokens.shape[1]
        x = x + params["pos_embed"][:s][None]
    elif cfg.pos == "sinusoidal":
        x = x + sinusoidal_pos(tokens.shape[1], cfg.d_model, x.dtype)[None]
    return shard_residual(x)


def logits_fwd(params, x, cfg: ArchConfig, qat=False):
    x = fl.norm_fwd(params["final_norm"], x, cfg)
    x = fl.maybe_fq(x, cfg.s_act8, enabled=qat)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, fl.fq_weight(w, 1, qat))
    return shard(logits, "batch", "seq", "vocab")


def forward_float(params, batch, cfg: ArchConfig, qat: bool = False,
                  return_hidden: bool = False):
    """Returns (logits | final hidden, aux_loss) for every family.

    batch: tokens (B,S) [+ img_embeds (B,Ni,D) | src_embeds (B,Sf,D)].
    """
    gl, ng, kinds = layer_group_spec(cfg)
    memory = None
    if cfg.family == "encdec":
        src = batch["src_embeds"].astype(jnp.dtype(cfg.dtype))
        epos = jnp.arange(src.shape[1])[None]
        enc_x, _ = _run_stack_float(params["enc_layers"], src, cfg,
                                    [("attn", "ffn", False)], epos, qat,
                                    causal=False)
        memory = fl.norm_fwd(params["enc_final_norm"], enc_x, cfg)
    elif cfg.family == "vlm":
        memory = batch["img_embeds"].astype(jnp.dtype(cfg.dtype))
    x = embed_tokens(params, batch["tokens"], cfg)
    positions = jnp.arange(x.shape[1])[None]
    x, aux = _run_stack_float(params["layers"], x, cfg, kinds, positions,
                              qat, causal=cfg.is_causal, memory=memory)
    if return_hidden:
        return x, aux
    return logits_fwd(params, x, cfg, qat), aux


def encoder_fwd_float(params, embeds, cfg: ArchConfig, qat: bool = False):
    """Encoder-only forward from pre-embedded inputs (RoBERTa/DeiT benches)."""
    gl, ng, kinds = layer_group_spec(cfg)
    positions = jnp.arange(embeds.shape[1])[None]
    x, _ = _run_stack_float(params["layers"], embeds, cfg, kinds,
                            positions, qat, causal=False)
    return fl.norm_fwd(params["final_norm"], x, cfg)
