"""Typed paged KV-cache layer: layouts, page tables, block allocator.

The serving engine's cache abstraction (the "block-sparse paged KV
cache" the ROADMAP queued on top of PR 3's valid_len machinery).  A
contiguous per-slot cache spends ``num_slots × max_len`` tokens of HBM
whether slots are full or empty; a *paged* cache keeps one physical pool
of fixed-size pages and gives each live session only the pages its
tokens occupy — memory scales with **live tokens**, not provisioned
capacity.  The pieces:

  * :class:`CacheLayout`   — the frozen geometry: batch lanes, logical
    per-session length, page size, physical pool size;
  * :class:`BlockAllocator`— ref-counted free-list over physical pages
    (alloc / retain / release); exhaustion raises the typed
    :class:`PagePoolExhausted`;
  * :class:`PageTable`     — the ``int32[num_slots, max_pages]`` logical
    block → physical page map that rides into the decode kernel as a
    scalar-prefetch operand (next to ``valid_len``);
  * :class:`Session`       — a request's cache identity: the page list
    it *owns* (survives lane preemption) plus its decode position;
  * :class:`PagedKVCache`  — the host-side controller tying the three
    together for the engine (bind / ensure / unbind / release).

Invariants (normative — the kernel and the allocator both rely on them):

  * **Page 0 is the null page.**  It is never allocated.  Page-table
    entries for unmapped logical blocks stay 0, so dead lanes write
    their (masked, discarded) K/V into page 0 and the kernel's
    dead-block DMA clamp always lands on a resident page.
  * Pages are written append-only per session and are **never zeroed on
    reuse**: ``valid_len`` masking makes stale contents unobservable, so
    an evict → re-admit cycle reuses freed pages bit-exactly.
  * A page's refcount is the number of holders — sessions *plus*
    :class:`PrefixIndex` entries; it returns to the free list exactly
    when the count reaches zero.  Live lanes never share a page **they
    write**: read-only prompt-prefix pages may be mapped by several
    sessions at once (that is the whole point of prefix sharing), and
    the engine copy-on-writes any page with refcount > 1 before the
    first write lands on it.
  * **Page ids are device-agnostic.**  Under tensor-parallel serving
    (``distributed.tp_serving``) the physical K/V pools shard on their
    *head* axis — every device holds ``Hkv/tp`` heads of every page —
    so this entire host-side layer (allocator, page table, prefix
    index, sessions) stays replicated untouched: one allocation maps
    the same page id into every device's pool slice, and CoW /
    preempt / evict need no distributed bookkeeping.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

NULL_PAGE = 0

# page-element storage tiers (CacheLayout.kv_dtype)
KV_DTYPES = ("int8", "int4")


class PagePoolExhausted(RuntimeError):
    """No free physical pages: the pool is smaller than the live token
    working set.  Evict or preempt a session, or provision more pages
    (``CacheLayout.num_pages``)."""


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Frozen geometry of a paged KV pool.

    ``num_slots`` — batch lanes the engine decodes in lock-step;
    ``max_len``   — logical cache length per session (the engine's
                    ``cache_len``, or the attention window when smaller);
    ``page_size`` — tokens per physical page;
    ``num_pages`` — physical pool size *including* the reserved null
                    page 0 (so ``num_pages - 1`` pages are allocatable);
    ``kv_dtype``  — page-element storage: ``"int8"`` (one byte per
                    element) or ``"int4"`` (two head-dim nibbles per
                    byte plus a per-page requant shift; every page byte
                    holds two elements, so an equal-HBM pool admits 2×
                    the sessions).  This is the *storage* tier only —
                    kernels dequantize in-register
                    (``q4 << shift``, ``repro.ops.packed``), the
                    attention datapath stays int8.
    """

    num_slots: int
    max_len: int
    page_size: int
    num_pages: int
    kv_dtype: str = "int8"

    def __post_init__(self):
        if self.num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {self.num_slots}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is the "
                             f"reserved null page), got {self.num_pages}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                             f"got {self.kv_dtype!r}")

    @property
    def max_pages(self) -> int:
        """Pages needed to map one full-length session (page-table width)."""
        return -(-self.max_len // self.page_size)

    @property
    def logical_len(self) -> int:
        """The kernel-visible logical cache length, ``max_pages ×
        page_size`` (≥ ``max_len``; the tail past ``max_len`` is never
        valid)."""
        return self.max_pages * self.page_size

    @property
    def capacity_tokens(self) -> int:
        """Tokens the allocatable pool can hold (null page excluded)."""
        return (self.num_pages - 1) * self.page_size

    @property
    def bytes_per_element(self) -> float:
        """HBM bytes per stored KV element (0.5 under int4 packing)."""
        return 0.5 if self.kv_dtype == "int4" else 1.0

    @classmethod
    def fit(cls, num_slots: int, max_len: int, page_size: int = 16,
            num_pages: Optional[int] = None,
            kv_dtype: str = "int8") -> "CacheLayout":
        """Layout for ``num_slots`` lanes of ``max_len`` tokens.  Without
        an explicit ``num_pages`` the pool is fully provisioned (every
        lane can reach ``max_len`` simultaneously) — undersubscribe it to
        make memory O(live tokens).  Under ``kv_dtype="int4"`` each page
        costs half the HBM, so the auto-provisioned pool doubles its
        page count at equal byte budget (2× admissible sessions)."""
        max_pages = -(-max_len // page_size)
        if num_pages is None:
            num_pages = num_slots * max_pages + 1
            if kv_dtype == "int4":
                num_pages = 2 * (num_pages - 1) + 1
        return cls(num_slots, max_len, page_size, num_pages, kv_dtype)


class BlockAllocator:
    """Ref-counted free-list over the physical pages of a pool.

    LIFO free list: the page freed last is handed out first, so an
    evict → re-admit cycle touches the smallest possible page set (and
    the bit-exact-reuse property is exercised constantly, not rarely).
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.refcount = np.zeros(num_pages, np.int32)
        self.refcount[NULL_PAGE] = 1          # pinned forever
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        # optional pressure hook: invoked once when alloc() finds the
        # free list empty, *before* raising — the engine points it at
        # the prefix-index LRU eviction so cached-but-unreferenced
        # prefix pages are reclaimed instead of failing the allocation
        self.reclaim: Optional[Callable[[], None]] = None

    # ------------------------------------------------------------ alloc --

    def alloc(self) -> int:
        """Hand out a free page at refcount 1, or raise
        :class:`PagePoolExhausted`."""
        if not self._free and self.reclaim is not None:
            self.reclaim()
        if not self._free:
            raise PagePoolExhausted(
                f"page pool exhausted: all {self.num_pages - 1} "
                "allocatable pages are held by live or preempted "
                "sessions (evict one, or provision a larger "
                "CacheLayout.num_pages)")
        page = self._free.pop()
        self.refcount[page] = 1
        return page

    def retain(self, page: int):
        """Add a reference to an allocated page."""
        if page == NULL_PAGE or not 0 <= page < self.num_pages:
            raise ValueError(f"cannot retain page {page}")
        if self.refcount[page] <= 0:
            raise ValueError(f"retain of unallocated page {page}")
        self.refcount[page] += 1

    def release(self, page: int):
        """Drop a reference; the page returns to the free list at zero."""
        if page == NULL_PAGE or not 0 <= page < self.num_pages:
            raise ValueError(f"cannot release page {page}")
        if self.refcount[page] <= 0:
            raise ValueError(f"release of unallocated page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)

    # ------------------------------------------------------------- stats --

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def check(self):
        """Invariant sweep (tests call this after every schedule step):
        free list and refcounts partition the allocatable pages."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page on free list"
        assert NULL_PAGE not in free, "null page leaked onto the free list"
        for p in range(1, self.num_pages):
            held = self.refcount[p] > 0
            assert held != (p in free), \
                f"page {p}: refcount {self.refcount[p]} vs free-list " \
                f"membership {p in free}"
        assert self.refcount[NULL_PAGE] == 1, "null page refcount moved"


class PageTable:
    """The device-facing logical-block → physical-page map.

    One int32 row per batch lane, ``max_pages`` wide, default-filled
    with the null page.  ``snapshot()`` hands the decode step a *copy*
    (same aliasing rule as the engine's ``pos`` snapshot: jnp.asarray
    may zero-copy a numpy buffer while dispatch is still async)."""

    def __init__(self, layout: CacheLayout):
        self.layout = layout
        self.table = np.full((layout.num_slots, layout.max_pages),
                             NULL_PAGE, np.int32)

    def set_row(self, slot: int, pages: List[int]):
        if len(pages) > self.layout.max_pages:
            raise ValueError(f"{len(pages)} pages > max_pages="
                             f"{self.layout.max_pages}")
        self.table[slot] = NULL_PAGE
        self.table[slot, :len(pages)] = pages

    def clear_row(self, slot: int):
        self.table[slot] = NULL_PAGE

    def snapshot(self) -> np.ndarray:
        return self.table.copy()


@dataclasses.dataclass
class Session:
    """A request's cache identity: the pages it owns and where it is.

    Sessions — not lanes — own pages: a preempted session keeps its
    ``pages`` (and ``pos``/``prefill_pos``/``last_token``) while
    freeing its lane, so a later resume continues bit-exactly from the
    same physical cache — mid-prefill preemption included (the chunked
    scheduler resumes the prompt at ``prefill_pos``)."""

    uid: int
    request: object = None
    # queued | prefilling | active | preempted | done
    state: str = "queued"
    slot: Optional[int] = None     # lane while on one, else None
    pages: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0
    prefill_pos: int = 0      # prompt tokens whose K/V are in pages
    last_token: Optional[int] = None

    @property
    def live_tokens(self) -> int:
        return self.pos


@dataclasses.dataclass
class PrefixEntry:
    """One cached prompt prefix: the physical pages holding the K/V of
    ``tokens`` (positions ``[0, count)``; the last page may be partial —
    a sharer's first write into it copy-on-writes)."""

    tokens: Tuple[int, ...]
    pages: Tuple[int, ...]
    count: int
    stamp: int = 0                 # LRU clock tick of the last touch


class PrefixIndex:
    """Per-engine cross-session prompt-prefix table.

    Maps token prefixes to the physical pages already holding their K/V,
    so a session whose prompt starts with a previously-prefilled prefix
    maps the *same* pages instead of recomputing them.  Correctness rests
    on full causal attention: K/V at position ``i`` depend only on tokens
    ``0..i``, so any two prompts sharing their first ``c`` tokens share
    the first ``c`` positions of K/V bit-for-bit (the engine gates the
    index to ``window == 0`` attention-only archs accordingly).

    The index holds its **own** refcount on every page an entry maps —
    entries outlive the sessions that created them, and the pages stay
    immutable because the engine copy-on-writes any page with
    refcount > 1 before writing it.  Under pool pressure the allocator's
    ``reclaim`` hook evicts entries LRU-first, so cached prefixes cost
    only otherwise-idle pages.
    """

    def __init__(self, allocator: BlockAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self.entries: Dict[Tuple[int, ...], PrefixEntry] = {}
        self.clock = 0
        self.hits = 0
        self.misses = 0
        self.tokens_reused = 0
        self.evictions = 0

    # ----------------------------------------------------------- lookup --

    def lookup(self, prompt, n_pre: int) -> Optional[PrefixEntry]:
        """Longest registered prefix of ``prompt[:n_pre]``; retains the
        entry's pages *for the caller* (who must release them if it
        abandons the admission)."""
        self.clock += 1
        lengths = sorted({e.count for e in self.entries.values()
                          if e.count <= n_pre}, reverse=True)
        for ln in lengths:
            entry = self.entries.get(tuple(prompt[:ln]))
            if entry is not None:
                entry.stamp = self.clock
                for page in entry.pages:
                    self.allocator.retain(page)
                self.hits += 1
                self.tokens_reused += entry.count
                return entry
        self.misses += 1
        return None

    def register(self, prompt, n_pre: int, pages: List[int]):
        """Register a freshly prefilled prompt's prefixes: one entry per
        full-page boundary plus the (possibly page-unaligned) full
        ``n_pre`` length, each retaining its pages.  Existing entries are
        kept (their pages are already immutable)."""
        ps = self.page_size
        marks = list(range(ps, n_pre + 1, ps))
        if n_pre > 0 and (not marks or marks[-1] != n_pre):
            marks.append(n_pre)
        for count in marks:
            key = tuple(prompt[:count])
            if key in self.entries:
                self.entries[key].stamp = self.clock
                continue
            held = tuple(pages[:-(-count // ps)])
            for page in held:
                self.allocator.retain(page)
            self.clock += 1
            self.entries[key] = PrefixEntry(key, held, count, self.clock)

    # --------------------------------------------------------- eviction --

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry (its pages return to the
        free list once no session holds them).  Returns False on an
        empty index."""
        if not self.entries:
            return False
        key = min(self.entries, key=lambda k: self.entries[k].stamp)
        for page in self.entries[key].pages:
            self.allocator.release(page)
        del self.entries[key]
        self.evictions += 1
        return True

    def clear(self):
        while self.evict_lru():
            pass

    # ------------------------------------------------------------- stats --

    def stats(self) -> dict:
        return {
            "entries": len(self.entries),
            "hits": self.hits,
            "misses": self.misses,
            "tokens_reused": self.tokens_reused,
            "evictions": self.evictions,
        }


class PagedKVCache:
    """Host-side paged-cache controller for the serving engine.

    Owns the allocator and the page table; the engine owns the device
    pools (they live in the model cache pytree) and the lane scheduling.
    """

    def __init__(self, layout: CacheLayout):
        self.layout = layout
        self.allocator = BlockAllocator(layout.num_pages)
        self.page_table = PageTable(layout)

    # ---------------------------------------------------------- binding --

    def bind(self, session: Session, slot: int):
        """Attach a session to a lane, restoring its page-table row
        (empty for new sessions, its owned pages for resumed ones)."""
        session.slot = slot
        session.state = "active"
        self.page_table.set_row(slot, session.pages)

    def unbind(self, session: Session):
        """Free the lane but keep the pages (preemption)."""
        if session.slot is not None:
            self.page_table.clear_row(session.slot)
        session.slot = None
        session.state = "preempted"

    def release(self, session: Session):
        """Drop every page the session owns (retire / cancel)."""
        if session.slot is not None:
            self.page_table.clear_row(session.slot)
        for page in session.pages:
            self.allocator.release(page)
        session.pages = []
        session.slot = None
        session.state = "done"

    def truncate(self, session: Session, keep_tokens: int) -> int:
        """Speculative-rollback helper: drop the session's trailing
        pages beyond the ones backing its first ``keep_tokens`` logical
        positions, releasing each through the allocator (pages the
        prefix index also holds stay cached — the release only drops
        *this session's* reference).  The stale K/V a rejected draft
        wrote into the kept tail page needs no cleanup: ``valid_len``
        masking hides it, and the next decode write overwrites it —
        rollback is a position decrement plus this table truncation, no
        data movement.  Returns the number of pages released."""
        if keep_tokens < 0:
            raise ValueError(f"keep_tokens must be >= 0, got "
                             f"{keep_tokens}")
        keep_blocks = -(-keep_tokens // self.layout.page_size)
        released = 0
        while len(session.pages) > keep_blocks:
            page = session.pages.pop()
            if session.slot is not None:
                self.page_table.table[session.slot,
                                      len(session.pages)] = NULL_PAGE
            self.allocator.release(page)
            released += 1
        return released

    def ensure(self, session: Session, write_pos: int):
        """Make the page backing logical position ``write_pos`` resident
        before the decode step writes there.  Pages map append-only, so
        this allocates at most the next sequential block; raises
        :class:`PagePoolExhausted` when the pool is out."""
        blk = write_pos // self.layout.page_size
        if blk >= self.layout.max_pages:
            raise ValueError(f"write_pos {write_pos} past max_len "
                             f"{self.layout.max_len}")
        while len(session.pages) <= blk:
            page = self.allocator.alloc()
            session.pages.append(page)
            if session.slot is not None:
                self.page_table.table[session.slot,
                                      len(session.pages) - 1] = page
        return session.pages[blk]

    # ------------------------------------------------------------- stats --

    def stats(self) -> dict:
        a = self.allocator
        return {
            "page_size": self.layout.page_size,
            "num_pages": self.layout.num_pages,
            "pages_used": a.used_pages,
            "pages_free": a.free_pages,
            "capacity_tokens": self.layout.capacity_tokens,
        }
