"""granite-3-2b [dense]: GQA, tied embeddings
[hf:ibm-granite/granite-3.0-2b-base].  vocab 49155 padded to 49168 for
16-way vocab sharding (DESIGN.md §7)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense", num_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, head_dim=64,
    tie_embeddings=True, activation="swiglu", norm="rmsnorm",
    rope_theta=10000.0,
)
