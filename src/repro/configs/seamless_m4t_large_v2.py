"""seamless-m4t-large-v2 [audio]: enc-dec backbone [arXiv:2308.11596].

The speech frontend is a STUB: input_specs() supplies precomputed frame
embeddings (B, n_frames, d_model); 24 encoder + 24 decoder layers.
vocab 256206 padded to 256208."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec", num_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206,
    head_dim=64, enc_layers=24, dec_layers=24, activation="gelu",
    norm="layernorm", pos="sinusoidal",
)
