"""Integer serving path: convert -> prefill -> decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import intlayers as il
from repro.models import inttransformer as it
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert

FAMS = ["llama3-8b", "h2o-danube-3-4b", "mamba2-130m", "qwen2-moe-a2.7b"]


def _setup(name, b=2, s=16):
    cfg = M.reduce_config(get_config(name), dtype="float32",
                          capacity_factor=8.0)
    params = tf.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                          cfg.vocab)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            jax.random.key(2), (b, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            jax.random.key(2), (b, s, cfg.d_model))
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, params, batch, qp, plans


@pytest.mark.parametrize("name", FAMS)
def test_int_prefill_correlates_with_float(name):
    cfg, params, batch, qp, plans = _setup(name)
    batch_f = dict(batch, labels=batch["tokens"])
    logits_f, _ = tf.forward_float(params, batch_f, cfg, qat=False)
    lg_int = np.asarray(it.int_prefill(qp, batch, plans, cfg))
    lg_f = np.asarray(logits_f[:, -1], np.float32)
    corr = np.corrcoef(lg_int.ravel(), lg_f.ravel())[0, 1]
    # random-init floors: SSM recurrence quantization compounds (DESIGN.md
    # §6) and random-init MoE routing ties break differently between the
    # paths; trained-model agreement is much higher (test_e2e_quant).
    floor = {"ssm": 0.35, "hybrid": 0.35, "moe": 0.25}.get(cfg.family, 0.5)
    assert corr > floor, f"{name}: int/float corr {corr}"
    assert np.isfinite(lg_int).all()


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_prefill_exactly(name):
    cfg, params, batch, qp, plans = _setup(name)
    b, s = batch["tokens"].shape
    lg_pre = np.asarray(it.int_prefill(qp, batch, plans, cfg))
    memory8 = None
    caches = it.init_decode_cache(cfg, b, 32, memory8, qp, plans)
    rope_tab = il.build_rope_table(33, cfg.hd, cfg.rope_theta) \
        if cfg.pos == "rope" else None
    step = jax.jit(lambda qp_, c, t, p: it.int_decode_step(
        qp_, c, t, p, plans, cfg, rope_tab))
    lg = None
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        lg, caches = step(qp, caches, batch["tokens"][:, t], pos)
    assert np.abs(np.asarray(lg) - lg_pre).max() < 1e-4, \
        f"{name}: decode != prefill"


def test_sliding_window_decode_rolls():
    """SWA decode with cache shorter than the sequence still matches a
    windowed prefill (rolling buffer semantics)."""
    cfg = M.reduce_config(get_config("h2o-danube-3-4b"), dtype="float32",
                          window=8)
    params = tf.init_params(jax.random.key(0), cfg)
    b, s = 1, 24
    batch = {"tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                          cfg.vocab)}
    qp, plans = convert.quantize_params(params, cfg)
    lg_pre = np.asarray(it.int_prefill(qp, batch, plans, cfg))
    caches = it.init_decode_cache(cfg, b, s, None, qp, plans)
    rope_tab = il.build_rope_table(s + 1, cfg.hd, cfg.rope_theta)
    lg = None
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        lg, caches = it.int_decode_step(qp, caches, batch["tokens"][:, t],
                                        pos, plans, cfg, rope_tab)
    corr = np.corrcoef(np.asarray(lg).ravel(), lg_pre.ravel())[0, 1]
    assert corr > 0.98
