"""Pallas TPU kernel: integer-only softmax (SwiftTron §III-F).

The ASIC instantiates m row-parallel Softmax units, each running three
phases (max search, i-exp, divide).  On TPU the m-way row parallelism
becomes the grid's row-block dimension, and the three phases become three
vectorised passes over a VMEM-resident (block_rows, row_len) tile — the
scores are read from HBM exactly once.

Rows are assumed int32 at the plan's score scale; output is int8
probabilities at 2^-7 (see core.softmax for the scale plan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.softmax import ISoftmaxPlan, PROB_SHIFT, RECIP_BITS


def _rshift_round(x, s: int):
    if s == 0:
        return x
    return (x + (1 << (s - 1))) >> s


def _exp16_tile(q_sub, plan: ISoftmaxPlan):
    """Inlined core.softmax._exp16 on a tile (all constants static)."""
    q = jnp.maximum(q_sub, jnp.int32(-plan.q_band))
    q = _rshift_round(_rshift_round(q, plan.dn_in.pre) *
                      jnp.int32(plan.dn_in.b),
                      plan.dn_in.c - plan.dn_in.pre)
    # i-exp: x = p - z*ln2
    q = jnp.minimum(q, 0)
    ie = plan.iexp
    qn = jnp.maximum(q, jnp.int32(-ie.z_max * ie.q_ln2))
    z = (-qn) // jnp.int32(ie.q_ln2)
    q_p = qn + z * jnp.int32(ie.q_ln2)
    t = q_p + jnp.int32(ie.q_b)
    q_l = t * t + jnp.int32(ie.q_c)
    e = jax.lax.shift_right_arithmetic(q_l, z)
    d = plan.dn_e16
    return _rshift_round(_rshift_round(e, d.pre) * jnp.int32(d.b),
                         d.c - d.pre)


def _softmax_kernel(x_ref, o_ref, *, plan: ISoftmaxPlan, masked: bool,
                    valid_len: int):
    q = x_ref[...].astype(jnp.int32)
    if masked:
        pos = jax.lax.broadcasted_iota(jnp.int32, q.shape, q.ndim - 1)
        live = pos < valid_len
        q = jnp.where(live, q, jnp.int32(-(2 ** 30)))
    q_max = jnp.max(q, axis=-1, keepdims=True)
    e16 = _exp16_tile(q - q_max, plan)
    if masked:
        e16 = jnp.where(live, e16, 0)
    s = jnp.sum(e16, axis=-1, keepdims=True)
    r = jnp.int32(1 << RECIP_BITS) // jnp.maximum(s, 1)
    p = _rshift_round(e16 * r, RECIP_BITS - PROB_SHIFT)
    o_ref[...] = jnp.clip(p, 0, 127).astype(jnp.int8)


def int_softmax_pallas(scores, plan: ISoftmaxPlan, valid_len: int = -1,
                       block_rows: int = 8, interpret: bool = True):
    """scores: (..., rows, row_len) int32 -> int8 probs, same shape.

    ``valid_len`` >= 0 masks trailing positions (static padding mask);
    data-dependent masks are handled by the attention kernel instead.
    """
    shape = scores.shape
    rows = 1
    for d in shape[:-1]:
        rows *= d
    row_len = shape[-1]
    x2 = scores.reshape(rows, row_len)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    kernel = functools.partial(_softmax_kernel, plan=plan,
                               masked=valid_len >= 0, valid_len=valid_len)
    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, row_len), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, row_len), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, row_len), jnp.int8),
        interpret=interpret,
    )(x2)
    return out.reshape(shape)
