"""Serving engine: batched generation == sequential decode."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          capacity_factor=8.0)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


def test_engine_generates(engine_setup):
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64)
    reqs = [Request(uid=i, prompt=[1 + i, 7, 42], max_new_tokens=5)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    for r in reqs:
        assert r.done and len(r.out_tokens) == 5
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_batch_independence(engine_setup):
    """A request's greedy output must not depend on its batch neighbours."""
    cfg, qp, plans = engine_setup
    eng1 = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64)
    solo = Request(uid=0, prompt=[5, 9, 13], max_new_tokens=4)
    eng1.submit(solo)
    eng1.run_until_done()

    eng2 = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64)
    a = Request(uid=1, prompt=[5, 9, 13], max_new_tokens=4)
    b = Request(uid=2, prompt=[100, 3], max_new_tokens=4)
    eng2.submit(a)
    eng2.submit(b)
    eng2.run_until_done()
    assert a.out_tokens == solo.out_tokens
