"""End-to-end driver: QAT-train a ~100M-parameter llama-style model on the
synthetic corpus for a few hundred steps with the full production substrate
— sharded step (on whatever devices exist), fault-tolerant loop with async
checkpointing, straggler detection, LR schedule — then convert and report
integer-path accuracy.

Run:  PYTHONPATH=src python examples/train_qat.py [--steps 200]
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.distributed.fault import FaultTolerantLoop, StragglerDetector
from repro.models import inttransformer as it
from repro.models import transformer as tf
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.launch.steps import make_train_step
from repro.quant import convert
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_qat_ckpt")
    args = ap.parse_args()

    # ~100M params: 8 layers, d=768, vocab 8192
    cfg = dataclasses.replace(
        get_config("llama3-8b"), num_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192, dtype="float32")
    print(f"params ~{cfg.param_count() / 1e6:.1f}M")
    data = SyntheticLMDataset(cfg.vocab, 256, 8, seed=0)
    params = tf.init_params(jax.random.key(0), cfg)

    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.01)
    opt = adamw_init(params, opt_cfg)
    lr_fn = linear_warmup_cosine(20, args.steps)
    train_step = jax.jit(make_train_step(cfg, opt_cfg, lr_fn))

    def step_fn(state, batch):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = train_step(params, opt, batch)
        return (params, opt), metrics

    loop = FaultTolerantLoop(step_fn, CheckpointManager(args.ckpt_dir),
                             data, ckpt_every=50,
                             straggler=StragglerDetector())
    (params, opt), log = loop.run((params, opt), args.steps)
    print(f"loss: first={log[0]['loss']:.3f} last={log[-1]['loss']:.3f} "
          f"(restarts={loop.restarts}, stragglers="
          f"{loop.straggler.flagged})")

    qp, plans = convert.quantize_params(params, cfg)
    accs = []
    for _ in range(4):
        b = next(data)
        li = it.int_prefill(qp, {"tokens": jnp.asarray(b["tokens"])},
                            plans, cfg, ops="ref")
        accs.append(float((np.argmax(np.asarray(li)[:, :cfg.vocab], -1)
                           == b["labels"][:, -1]).mean()))
    print(f"integer-path last-token accuracy: {np.mean(accs):.2%}")


if __name__ == "__main__":
    main()
