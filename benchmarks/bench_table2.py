"""Paper Table II (accuracy column): integer-only inference preserves task
accuracy.

We cannot run GLUE/ImageNet offline, so the claim is reproduced on the
synthetic language task: train a small model in float+QAT, convert, and
measure next-token accuracy on the float path vs the SwiftTron integer
path (the paper reports <= ~1pt degradation; we require the same)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models import inttransformer as it
from repro.models import model as M
from repro.models import transformer as tf
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWConfig
from repro.quant import convert, qat


def run(train_steps: int = 120):
    cfg = M.reduce_config(get_config("roberta-base"), dtype="float32",
                          vocab=256, num_layers=2)
    import dataclasses
    cfg = dataclasses.replace(cfg, family="dense", post_norm=False,
                              pos="rope", norm="layernorm",
                              activation="gelu")
    data = SyntheticLMDataset(cfg.vocab, 32, 16, seed=1)
    params = tf.init_params(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.01)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(qat.loss_fn, has_aux=True)(
            params, batch, cfg, qat=True)
        params, opt, _ = adamw_update(g, opt, params, opt_cfg)
        return params, opt, loss

    first = last = None
    for i in range(train_steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, loss = step(params, opt, batch)
        if first is None:
            first = float(loss)
        last = float(loss)

    qp, plans = convert.quantize_params(params, cfg)
    accf = accq = acci = n = 0.0
    for _ in range(10):
        b = next(data)
        toks = jnp.asarray(b["tokens"])
        lf, _ = tf.forward_float(params, {"tokens": toks, "labels": toks},
                                 cfg, qat=False)
        lq, _ = tf.forward_float(params, {"tokens": toks, "labels": toks},
                                 cfg, qat=True)       # the trained graph
        li = it.int_prefill(qp, {"tokens": toks}, plans, cfg)
        lab = b["labels"][:, -1]
        accf += float((np.argmax(np.asarray(lf[:, -1, :cfg.vocab]), -1)
                       == lab).mean())
        accq += float((np.argmax(np.asarray(lq[:, -1, :cfg.vocab]), -1)
                       == lab).mean())
        acci += float((np.argmax(np.asarray(li[:, :cfg.vocab]), -1)
                       == lab).mean())
        n += 1
    accf, accq, acci = accf / n, accq / n, acci / n
    return [
        ("table2_loss_first", round(first, 3), ""),
        ("table2_loss_last", round(last, 3), ""),
        ("table2_acc_fp32", round(accf, 4), ""),
        ("table2_acc_qat_float", round(accq, 4),
         "the trained (fake-quant) graph — the I-BERT-style baseline"),
        ("table2_acc_integer", round(acci, 4),
         f"delta_vs_qat={100 * (accq - acci):+.2f}pt (paper: <=1pt)"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
