"""Pytree key-path formatting shared by checkpointing and sharding rules.

jax's ``tree_flatten_with_path`` yields heterogeneous key types —
``DictKey(.key)``, ``SequenceKey(.idx)``, ``GetAttrKey(.name)`` (NamedTuple
fields such as ``QuantLinearParams``) — and both the checkpoint format and
the param-sharding pattern matcher need the same stable string per entry.
"""
from __future__ import annotations


def path_parts(path) -> list:
    """One plain string per key-path entry."""
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "idx"):
            out.append(str(e.idx))
        elif hasattr(e, "name"):
            out.append(str(e.name))
        else:
            out.append(str(e))
    return out
