"""Tensor-parallel sharded serving: bit-exact vs the single-device
engine on a forced-4-device CPU mesh.

Each test hands a script to ``mesh_runner.run_with_devices`` (subprocess
isolation: ``conftest.py``'s no-multi-device rule for smoke tests still
holds, and the child asserts the device count it actually got).  Locked
in here:

  * token-stream parity sharded-vs-single-device for tp ∈ {2, 4} across
    backend × cache_mode × chunked/streaming prefill;
  * the ``tp_serving`` capability negotiation — the plain pallas backend
    does not advertise it, so a tp=4 engine over it takes the exact
    single-device gather lowering (same tokens, no mesh, no API change);
  * ``describe()`` reporting mesh geometry and per-device KV bytes;
  * mesh geometry in the compiled-step cache key: tp=2 / tp=4 / unsharded
    engines land distinct entries, same-mesh engines share one;
  * prefix sharing and mid-prefill preempt/resume making identical
    scheduler decisions (hits, CoW copies) and identical tokens at every
    tp degree — the replicated-scheduler invariant.
"""
from mesh_runner import run_with_devices

_SETUP = """
from repro.configs.registry import get_config
from repro.models import model as M, transformer as tf
from repro.quant import convert
from repro.serving import Request, ServingEngine

# tp=4 must divide Hkv: lift the reduced config's head counts to 4/4
cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                      vocab=128, num_layers=1, n_heads=4, n_kv_heads=4)
params = tf.init_params(jax.random.key(0), cfg)
qp, plans = convert.quantize_params(params, cfg)
"""

BODY_PARITY = _SETUP + """
import repro.serving.engine as eng_mod
# the matrix below compiles more distinct steps than the default LRU
# bound keeps; widen it so the cache-key assertions at the end see
# every entry (correctness never depends on the bound)
eng_mod._STEP_CACHE_MAX = 64

PROMPTS = [[1, 7, 42, 9, 3], [2, 7, 42], [11] * 18, [5]]

def serve(tp, ops, **kw):
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops=ops, tp=tp, **kw)
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=5)
            for i, p in enumerate(PROMPTS)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng

MODES = {
    "chunked":   dict(cache_mode="paged", prefill_chunk=16),
    "streaming": dict(cache_mode="paged", prefill_chunk=0),
    "contig":    dict(cache_mode="contiguous"),
}
MATRIX = [("ref", "chunked"), ("ref", "contig"),
          ("pallas_fused", "chunked"), ("pallas_fused", "streaming")]
base = {}
for ops, mode in MATRIX:
    base[(ops, mode)], _ = serve(1, ops, **MODES[mode])
for ops, mode in MATRIX:
    for tp in (2, 4):
        got, eng = serve(tp, ops, **MODES[mode])
        assert got == base[(ops, mode)], (ops, mode, tp, got)
        d = eng.describe()
        assert d["tp"]["mode"] == "sharded", (ops, mode, tp, d["tp"])
        assert d["tp"]["mesh"] == {"axis": "tp", "shape": [tp],
                                   "devices": list(range(tp))}
        assert d["tp"]["per_device_kv_bytes"] \
            == d["cache"]["kv_bytes"] // tp
        assert d["fold_wo"] is False        # requant-rounds-once
        assert f"tp={tp}:sharded" in eng.describe_str()

# the pallas backend does not advertise tp_serving: a tp=4 engine over
# it takes the exact single-device gather lowering — same API, same
# tokens, no mesh
b_pal, _ = serve(1, "pallas", **MODES["chunked"])
got, eng = serve(4, "pallas", **MODES["chunked"])
assert eng.describe()["tp"]["mode"] == "gathered"
assert eng.mesh is None and got == b_pal

# mesh geometry is part of the compiled-step cache key: sharded tp=2 /
# tp=4 engines and every unsharded engine (tp=1 AND the gathered
# fallback) landed on distinct mesh key elements ...
mesh_keys = set()
for key in eng_mod._STEP_CACHE:
    mesh_keys.update(k for k in key if isinstance(k, tuple)
                     and len(k) >= 2 and k[0] == "mesh")
assert ("mesh", 1) in mesh_keys, mesh_keys
assert any(k[:2] == ("mesh", 2) for k in mesh_keys), mesh_keys
assert any(k[:2] == ("mesh", 4) for k in mesh_keys), mesh_keys
# ... and rebuilding a same-geometry same-mesh engine hits its entry
n = len(eng_mod._STEP_CACHE)
_, e2 = serve(4, "ref", **MODES["chunked"])
assert len(eng_mod._STEP_CACHE) == n
"""

BODY_SCENARIO = _SETUP + """
import numpy as np

rng = np.random.default_rng(3)
stem = list(map(int, rng.integers(1, 100, 20)))
p1 = stem                                   # registers its prefix
p2 = stem[:-1] + [101]                      # shares 19, then diverges
long = list(map(int, rng.integers(1, 100, 40)))

def scenario(tp):
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", tp=tp, prefill_chunk=16,
                        prefill_budget=16)
    a = Request(uid=0, prompt=list(p1), max_new_tokens=4)
    eng.submit(a)
    eng.run_until_done()
    b = Request(uid=1, prompt=list(p2), max_new_tokens=4)
    eng.submit(b)
    eng.run_until_done()
    d = eng.describe()["cache"]
    hits, cow = d["prefix"]["hits"], d["cow_copies"]
    # mid-prefill preempt: the 40-token prompt needs 3 budgeted chunk
    # rounds; stop it after the first, bump it off the lane, resume
    c = Request(uid=2, prompt=list(long), max_new_tokens=4)
    sc = eng.submit(c)
    eng.step()
    assert sc.state == "prefilling" and 0 < sc.prefill_pos < len(long) - 1
    eng.preempt(sc)
    assert sc.state == "preempted" and sc.pages
    eng.submit(Request(uid=3, prompt=[7, 8], max_new_tokens=2))
    eng.run_until_done()
    eng.kv.allocator.check()
    return [a.out_tokens, b.out_tokens, c.out_tokens], (hits, cow)

base, acct1 = scenario(1)
assert acct1[0] >= 1 and acct1[1] > 0       # sharing + CoW exercised
for tp in (2, 4):
    got, acct = scenario(tp)
    assert got == base, (tp, got, base)
    # the scheduler is replicated host-side: identical prefix hits and
    # copy-on-write decisions at every tp degree
    assert acct == acct1, (tp, acct, acct1)
"""


def test_sharded_stream_parity(tmp_path):
    run_with_devices(BODY_PARITY, 4, tmp_path)


def test_sharded_prefix_sharing_and_preempt(tmp_path):
    run_with_devices(BODY_SCENARIO, 4, tmp_path)
