"""Fused decode attention: exact-integer parity over ragged KV caches.

The contract under test (docs/KERNELS.md "Decode kernel contract"): the
single-launch ``pallas_fused`` decode kernel — valid_len scalar-prefetch
masking, dead cache blocks skipped, Shiftmax, int8 P·V, RequantSpec
epilogue — is *bit-exact* against the full-matrix decode oracle
``kernels.ref.ref_int_decode_attention`` for every (valid_len, head_dim,
RequantSpec) combination, including ragged batches where every slot has
a different occupancy, and falls back with identical numerics on shapes
it can't tile.  Randomised coverage lives in
``test_decode_attention_props.py`` (hypothesis).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as iattn
from repro.core.dyadic import fit_dyadic
from repro.kernels.int_decode_attention import (MAX_SKV, MAX_SQ,
                                                int_decode_attention_fused)
from repro.ops import RequantSpec, get_backend

FUSED = get_backend("pallas_fused")
REF = get_backend("ref")


def _plan(d):
    return iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)


def _qkv(rng, b, sq, L, h, hkv, d):
    q8 = np.clip(rng.normal(0, 40, (b, sq, h, d)), -127, 127).astype(np.int8)
    k8 = np.clip(rng.normal(0, 40, (b, L, hkv, d)), -127, 127).astype(np.int8)
    v8 = np.clip(rng.normal(0, 40, (b, L, hkv, d)), -127, 127).astype(np.int8)
    return jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8)


def _spec(form, plan, h, d, rng):
    if form == "per_tensor":
        return RequantSpec.per_tensor(
            fit_dyadic(plan.dn_out.value * 1.7, 127 * (1 << 8))), None
    if form == "per_channel":
        b_vec = jnp.asarray(rng.integers(1000, 30000, (h * d,)), jnp.int32)
        return RequantSpec.per_channel(c=28, pre=7), b_vec
    return RequantSpec.raw(), None


# ragged occupancy edge set for a 64-slot cache tiled at bkv=16: empty,
# single token, block boundary -1/0/+1, full cache
EDGE_VALID = [0, 1, 15, 16, 17, 63, 64]


@pytest.mark.parametrize("form", ["per_tensor", "per_channel", "raw"])
def test_exact_parity_ragged_batch(rng, form):
    """One ragged batch covering every edge occupancy at once — every
    slot has a different valid_len, including 0 and full."""
    b, sq, L, h, hkv, d = len(EDGE_VALID), 1, 64, 4, 2, 32
    plan = _plan(d)
    q8, k8, v8 = _qkv(rng, b, sq, L, h, hkv, d)
    vl = jnp.asarray(EDGE_VALID, jnp.int32)
    spec, b_vec = _spec(form, plan, h, d, rng)
    got = np.asarray(int_decode_attention_fused(
        q8, k8, v8, plan, vl, requant=spec, b_vec=b_vec, bkv=16))
    want = np.asarray(REF.int_decode_attention(
        q8, k8, v8, plan, vl, requant=spec, b_vec=b_vec))
    assert np.array_equal(got, want)
    assert got.dtype == (np.int32 if form == "raw" else np.int8)
    if form != "raw":
        # dead slots produce requant(0) == 0, live slots are non-trivial
        assert not got[0].any() and got[-1].any()


@pytest.mark.parametrize("sq", [1, 4, MAX_SQ])
@pytest.mark.parametrize("d", [16, 64])
def test_exact_parity_speculative_and_head_dims(rng, sq, d):
    """Speculative Sq>1 uses the stepped mask (row i sees valid_len -
    (Sq-1-i) positions); exact across head dims, through the backend."""
    b, L, h, hkv = 3, 96, 4, 1
    plan = _plan(d)
    q8, k8, v8 = _qkv(rng, b, sq, L, h, hkv, d)
    vl = jnp.asarray([sq, 41, 96], jnp.int32)
    got = np.asarray(FUSED.int_decode_attention(q8, k8, v8, plan, vl,
                                                bkv=32))
    want = np.asarray(REF.int_decode_attention(q8, k8, v8, plan, vl))
    assert np.array_equal(got, want)


def test_int8_extremes_saturate_identically(rng):
    """All-(-128) operands drive the accumulator to its negative rail;
    the epilogue clip must saturate identically to the oracle."""
    b, sq, L, h, d = 2, 1, 32, 2, 16
    plan = _plan(d)
    full = jnp.full((b, sq, h, d), -128, jnp.int8)
    kv = jnp.full((b, L, h, d), -128, jnp.int8)
    vl = jnp.asarray([7, 32], jnp.int32)
    got = np.asarray(int_decode_attention_fused(full, kv, kv, plan, vl,
                                                bkv=16))
    want = np.asarray(REF.int_decode_attention(full, kv, kv, plan, vl))
    assert np.array_equal(got, want)
    # (-128)·(-128) scores are positive, V is the negative rail: the
    # requantized output actually exercises the lower clip bound
    assert want.min() < 0


def test_decode_core_oracle_agrees_with_legacy_decode(rng):
    """Sq=1 decode == the historical core i_attention_decode (head-
    repeated caches), so the backend migration changed no numerics."""
    b, L, h, d = 2, 64, 2, 32
    plan = _plan(d)
    q8, k8, v8 = _qkv(rng, b, 1, L, h, h, d)
    vl = jnp.asarray([5, 64], jnp.int32)
    legacy = np.asarray(iattn.i_attention_decode(q8, k8, v8, plan, vl))
    via_ref = np.asarray(REF.int_decode_attention(q8, k8, v8, plan, vl))
    fused = np.asarray(FUSED.int_decode_attention(q8, k8, v8, plan, vl))
    assert np.array_equal(legacy, via_ref)
    assert np.array_equal(via_ref, fused.astype(via_ref.dtype))


# ------------------------------------------------------ negative paths ----

@pytest.mark.parametrize("sq,L,d,why", [
    (1, 64, 31, "odd head dim"),
    (MAX_SQ + 1, 64, 16, "speculative budget exceeded"),
    (1, 8, 16, "tiny cache below min_block: oracle wins"),
])
def test_untileable_decode_shapes_fall_back_exactly(rng, sq, L, d, why):
    """Shapes the kernel refuses take the full-matrix oracle with
    identical numerics — callers never observe which path ran."""
    h, hkv = 2, 1
    plan = _plan(d)
    bkv = L
    while L % bkv:
        bkv -= 1
    assert not FUSED._can_tile_decode(sq, L, d, min(bkv, 128)), why
    q8, k8, v8 = _qkv(rng, 2, sq, L, h, hkv, d)
    vl = jnp.asarray([sq + 3, L], jnp.int32)
    got = np.asarray(FUSED.int_decode_attention(q8, k8, v8, plan, vl))
    want = np.asarray(REF.int_decode_attention(q8, k8, v8, plan, vl))
    assert np.array_equal(got, want)


def test_oversized_cache_falls_back_exactly(rng):
    """cache_len beyond the exact row-sum budget (2^15): the kernel's
    int32 e16 sum could overflow, so the backend must not enter it."""
    L = MAX_SKV + 16
    assert not FUSED._can_tile_decode(1, L, 8, 128)
    plan = _plan(8)
    q8, k8, v8 = _qkv(np.random.default_rng(3), 1, 1, L, 1, 1, 8)
    vl = jnp.asarray([L - 5], jnp.int32)
    got = np.asarray(FUSED.int_decode_attention(q8, k8, v8, plan, vl))
    want = np.asarray(REF.int_decode_attention(q8, k8, v8, plan, vl))
    assert np.array_equal(got, want)


def test_per_channel_without_bvec_raises(rng):
    plan = _plan(16)
    q8, k8, v8 = _qkv(rng, 1, 1, 32, 2, 2, 16)
    vl = jnp.asarray([32], jnp.int32)
    spec = RequantSpec.per_channel(c=28, pre=7)
    for be in (REF, FUSED):
        with pytest.raises(ValueError, match="b_vec"):
            be.int_decode_attention(q8, k8, v8, plan, vl, requant=spec)


def test_unknown_backend_and_malformed_spec_raise():
    """The documented error surface: unknown backend name lists the
    registered ones; RequantSpec validation fires at construction."""
    with pytest.raises(KeyError, match="registered"):
        get_backend("nonexistent")
    with pytest.raises(ValueError, match="Dyadic"):
        RequantSpec("per_tensor", 8)           # per-tensor needs a Dyadic
    with pytest.raises(ValueError, match="pre <= c"):
        RequantSpec.per_channel(c=3, pre=9)
    with pytest.raises(ValueError, match="int32"):
        RequantSpec("raw", 8)                  # raw is 32-bit by definition
