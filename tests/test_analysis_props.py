"""Soundness property of the bit-budget abstract interpreter.

For *any* design constants the repo's fitters can produce and *any*
concrete input inside the declared range — endpoints forced — the value
the real integer op computes must lie inside the ``IntRange`` the
transfer function predicts, and no intermediate the transfer certified
may be exceeded by the concrete run.  Needs the optional ``hypothesis``
dev dependency (importorskip'd, like ``test_kvcache_props.py``).
"""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import numpy as np
import jax.numpy as jnp

from repro.analysis.interpret import check_requant_spec
from repro.analysis.ranges import (INT8, IntRange, rshift_round_int,
                                   t_dyadic, t_iexp, t_matmul_acc,
                                   t_softmax)
from repro.core import intmath
from repro.core.dyadic import fit_dyadic, rshift_round
from repro.core.softmax import make_isoftmax, i_softmax
from repro.ops.spec import RequantSpec


def _sample(qmax: int, picks):
    """Concrete int32 inputs: forced extremes + hypothesis-drawn interior."""
    return np.array([-qmax, qmax, 0] + [max(-qmax, min(qmax, p))
                                        for p in picks], np.int32)


@given(ratio=st.floats(1e-6, 0.9), qmax=st.integers(2 ** 8, 2 ** 26),
       picks=st.lists(st.integers(-(2 ** 26), 2 ** 26), min_size=1,
                      max_size=32))
@settings(max_examples=200, deadline=None)
def test_fitted_dyadic_stays_in_predicted_range(ratio, qmax, picks):
    dn = fit_dyadic(ratio, qmax)
    r = t_dyadic(IntRange.symmetric(qmax), dn)
    q = _sample(qmax, picks)
    out = np.asarray(dn(jnp.asarray(q)))          # the real integer op
    assert out.min() >= r.lo and out.max() <= r.hi, (dn, r, out)
    # staging stays int32 in exact arithmetic too (what t_dyadic proved)
    for v in q.tolist():
        staged = rshift_round_int(v, dn.pre) * dn.b
        assert abs(staged) <= 2 ** 31 - 1


@given(ratio=st.floats(1e-6, 0.9), qmax=st.integers(2 ** 8, 2 ** 24),
       out_bits=st.sampled_from([8, 16, 32]),
       picks=st.lists(st.integers(-(2 ** 24), 2 ** 24), min_size=1,
                      max_size=16))
@settings(max_examples=100, deadline=None)
def test_requant_spec_epilogue_soundness(ratio, qmax, out_bits, picks):
    dn = fit_dyadic(ratio, qmax)
    spec = RequantSpec.per_tensor(dn, out_bits=out_bits)
    r = check_requant_spec(spec, IntRange.symmetric(qmax),
                           op="int8_matmul", layer="prop")
    lo, hi = -(1 << (out_bits - 1)), (1 << (out_bits - 1)) - 1
    q = _sample(qmax, picks)
    out = np.clip(np.asarray(dn(jnp.asarray(q))), lo, hi)
    assert out.min() >= r.lo and out.max() <= r.hi


@given(k=st.integers(1, 4096), picks=st.lists(st.integers(-127, 127),
                                              min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_matmul_accumulator_soundness(k, picks):
    r = t_matmul_acc(k, INT8)
    x = _sample(127, picks).astype(np.int64)
    w = -x[::-1]                                  # adversarial signs
    n = min(k, len(x))
    acc = int(np.dot(x[:n], w[:n]))
    assert r.lo <= acc <= r.hi


# make_iexp's own static check rejects s_in finer than 2^-14 (q_b^2
# leaves int32) — the admissible design band is [2^-14, 2^-10]
@given(exp=st.integers(10, 14), picks=st.lists(st.integers(-(2 ** 20), 0),
                                               min_size=1, max_size=32))
@settings(max_examples=100, deadline=None)
def test_iexp_output_within_predicted_range(exp, picks):
    s_in = 2.0 ** -exp
    plan = intmath.make_iexp(s_in)
    r = t_iexp(plan)
    band = plan.z_max * plan.q_ln2
    q = _sample(band, picks)
    q = np.minimum(q, 0)                          # i-exp takes q <= 0
    out = np.asarray(intmath.i_exp(jnp.asarray(q), plan))
    assert out.min() >= r.lo and out.max() <= r.hi, (plan, r)


@given(scale_exp=st.integers(8, 14), qmax=st.integers(2 ** 10, 2 ** 22),
       rowlen=st.integers(1, 64),
       seed=st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_softmax_probs_within_predicted_range(scale_exp, qmax, rowlen,
                                              seed):
    s_score = 2.0 ** -scale_exp
    plan = make_isoftmax(s_score, qmax)
    r = t_softmax(plan, IntRange.symmetric(qmax), rowlen)
    rng = np.random.default_rng(seed)
    scores = rng.integers(-qmax, qmax + 1, size=(4, rowlen),
                          dtype=np.int64).astype(np.int32)
    scores[0, 0] = qmax                           # force the extremes
    scores[1, 0] = -qmax
    p = np.asarray(i_softmax(jnp.asarray(scores), plan))
    assert p.min() >= r.lo and p.max() <= r.hi
    # exact row sums of e16 stay int32 whenever the analyzer said so
    assert rowlen * (1 << 15) <= 2 ** 31 - 1
