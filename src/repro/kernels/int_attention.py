"""Pallas TPU kernel: fused integer attention (beyond-paper, DESIGN.md §3).

SwiftTron's Attention unit (Fig. 10) streams Q*K^T -> Softmax -> Requant ->
P*V through separate hardware blocks, writing the O(m^2) INT32 score matrix
between them.  On TPU that materialisation is pure HBM traffic, so we fuse
the whole flow into one VMEM-resident kernel with an **integer online
softmax**:

  * running row max is kept in the exact raw score scale (int32 compare),
  * when the max moves, previous partial sums and the int32 P*V accumulator
    are rescaled by ``exp16(m_old - m_new)`` — an i-exp evaluation plus a
    split 32x16 multiply (all int32-safe),
  * probabilities enter the MXU as unnormalised int8 weights (e16 >> 8) and
    the output is normalised once at the end by the accumulated sum using
    an exact two-step integer division (quotient + 7 fraction bits).

A nice inversion of the paper's cost model: the ASIC normalises all m
probabilities per row (m divider uses); the fused kernel normalises the
d-dimensional *output* instead — head_dim << seq_len divider uses per row.

Bit budget: acc <= (sum_e16 >> 8) * 127 <= L * 2^14, int32-safe for rows up
to 2^16; the wrapper asserts L <= 65536.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.contracts import check_launch, require_launch
from repro.core.attention import IAttnPlan
from repro.kernels.int_softmax import _exp16_tile, _rshift_round

NEG = -(2 ** 30)


def _rescale32(x, corr16):
    """(x * corr16) >> 15 via hi/lo split (x up to 2^30, corr16 <= 2^15)."""
    return (x >> 15) * corr16 + _rshift_round((x & 0x7FFF) * corr16, 15)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, s_ref, acc_ref, *,
                 plan: IAttnPlan, n_kv: int, bq: int, bkv: int,
                 causal: bool, window: int, out_lo: int, out_hi: int):
    kv_step = pl.program_id(3)
    q_blk = pl.program_id(2)

    @pl.when(kv_step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q8 = q_ref[0, :, 0, :]                      # (bq, d) int8
    k8 = k_ref[0, :, 0, :]                      # (bkv, d) int8
    v8 = v_ref[0, :, 0, :]

    qi = q_blk * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    ki = kv_step * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    live = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        live = live & (ki <= qi)
    if window > 0:
        live = live & (ki > qi - window)

    def _update():
        scores = jax.lax.dot_general(
            q8, k8, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)       # (bq, bkv) raw scale
        scores = jnp.where(live, scores, jnp.int32(NEG))
        m_c = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_ref[...], m_c)
        corr16 = _exp16_tile(m_ref[...] - m_new, plan.sm)
        e16 = _exp16_tile(scores - m_new, plan.sm)
        e16 = jnp.where(live, e16, 0)
        u8 = (e16 >> 8).astype(jnp.int8)            # unnormalised weights
        s_ref[...] = _rescale32(s_ref[...], corr16) \
            + jnp.sum(e16, axis=-1, keepdims=True)
        acc_ref[...] = _rescale32(acc_ref[...], corr16) + \
            jax.lax.dot_general(u8, v8, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked blocks (upper triangle)
        pl.when(kv_step * bkv <= q_blk * bq + bq - 1)(_update)
    else:
        _update()

    @pl.when(kv_step == n_kv - 1)
    def _finalize():
        acc = acc_ref[...]
        s8 = jnp.maximum(s_ref[...] >> 8, 1)        # sums in u8 units
        whole = acc // s8                           # <= 127 in v units
        rem = acc - whole * s8
        frac7 = (rem << 7) // s8                    # exact 7 fraction bits
        out7 = whole * 128 + frac7                  # scale s_v * 2^-7
        dn = plan.dn_out
        out = _rshift_round(_rshift_round(out7, dn.pre) * jnp.int32(dn.b),
                            dn.c - dn.pre)
        out = jnp.clip(out, out_lo, out_hi)
        o_ref[0, :, 0, :] = out.astype(jnp.int8)


def int_attention_pallas(q8, k8, v8, plan: IAttnPlan, causal: bool = True,
                         window: int = 0, bq: int = 128, bkv: int = 128,
                         out_bits: int = 8, interpret: bool = True):
    """q8: (B, Sq, H, D) int8; k8/v8: (B, Skv, Hkv, D) int8 (GQA: Hkv | H).

    Returns int8 (B, Sq, H, D) at plan.s_out.
    """
    b, sq, h, d = q8.shape
    _, skv, hkv, _ = k8.shape
    require_launch(check_launch(
        "int_attention", b=b, sq=sq, skv=skv, h=h, hkv=hkv, d=d,
        bq=bq, bkv=bkv, out_bits=out_bits, online=True))
    group = h // hkv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    n_kv = skv // bkv
    kernel = functools.partial(
        _attn_kernel, plan=plan, n_kv=n_kv, bq=bq, bkv=bkv, causal=causal,
        window=window, out_lo=-(1 << (out_bits - 1)),
        out_hi=(1 << (out_bits - 1)) - 1)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
            pl.BlockSpec((1, bkv, 1, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, d), jnp.int32)],
        interpret=interpret,
    )(q8, k8, v8)
