"""Paper Table I + Fig. 18: SwiftTron synthesis results via an analytical
cycle/area/power model of the published architecture.

The paper's latency comes from a cycle-accurate simulator (worst-case
sqrt iterations, §IV-B fn.3); we rebuild that model from the block
structure of §III and check it against the published numbers:

  * clock 143 MHz (7 ns), 65 nm, d=768, k=12 heads, m=256, d_ff=3072
  * MatMul block: R x C MAC array, one column per cycle after R-cycle fill
  * Softmax: 3 pipeline phases; LayerNorm: mean/std/out with <=16-cycle
    iterative sqrt (worst case); GELU: combinational (pipelined)
  * area/power split calibrated once against Fig. 18's MatMul share, the
    rest distributed by published percentages.
"""
import dataclasses

CLK_NS = 7.0
FREQ_HZ = 1 / (CLK_NS * 1e-9)

# Fig. 18 published breakdowns
AREA_PCT = {"matmul": 55, "softmax": 17, "layernorm": 25, "gelu": 3}
POWER_PCT = {"matmul": 79, "softmax": 14, "layernorm": 6, "gelu": 1}
TOTAL_AREA_MM2 = 273.0
TOTAL_POWER_W = 33.64


@dataclasses.dataclass
class BlockModel:
    """Cycle model with a 128x128 MAC array.

    Calibration note (reproduction finding): array=128 matches the paper's
    RoBERTa-large latency to 5% (45.7 ms), but then RoBERTa-base should be
    ~6.1 ms, not the reported 1.83 ms — the paper's large/base latency
    ratio (25x) cannot follow from the compute ratio (~3.3x) on any single
    array size.  We calibrate against the larger, utilization-bound model
    and record the discrepancy.
    """
    array: int = 128

    def matmul_cycles(self, m, k, n):
        """(m,k)x(k,n): tile the array; k-step accumulate, column readout."""
        import math
        tiles = math.ceil(m / self.array) * math.ceil(n / self.array)
        return tiles * (k + self.array)

    def softmax_cycles(self, rows, length):
        # 3 phases over the row, m row-units in parallel
        import math
        per_row = 3 * length
        return per_row * math.ceil(rows / min(rows, 256))

    def layernorm_cycles(self, rows, d):
        import math
        per_row = 2 * d + 16 + d          # mean, var, sqrt(16), out
        return per_row * math.ceil(rows / min(rows, 256))

    def gelu_cycles(self, n_elem):
        return n_elem // (self.array * self.array) + 1


def encoder_layer_cycles(bm: BlockModel, d, heads, m, d_ff):
    hd = d // heads
    c = 0
    c += 3 * bm.matmul_cycles(m, d, d)            # QKV
    c += heads * bm.matmul_cycles(m, hd, m)       # QK^T per head
    c += bm.softmax_cycles(m * heads, m)
    c += heads * bm.matmul_cycles(m, m, hd)       # PV
    c += bm.matmul_cycles(m, d, d)                # output proj
    c += bm.layernorm_cycles(m, d)
    c += bm.matmul_cycles(m, d, d_ff)
    c += bm.gelu_cycles(m * d_ff)
    c += bm.matmul_cycles(m, d_ff, d)
    c += bm.layernorm_cycles(m, d)
    return c


MODELS = {
    # name: (layers, d, heads, m, d_ff, paper_latency_ms)
    "roberta-base": (12, 768, 12, 256, 3072, 1.83),
    "roberta-large": (24, 1024, 16, 256, 4096, 45.70),
    "deit-s": (12, 384, 6, 197, 1536, 1.13),
}


def run():
    rows = []
    bm = BlockModel()
    for name, (L, d, h, m, dff, paper_ms) in MODELS.items():
        cyc = L * encoder_layer_cycles(bm, d, h, m, dff)
        ms = cyc * CLK_NS * 1e-6
        rows.append((f"table2_latency_model_{name}_ms", round(ms, 3),
                     f"paper={paper_ms}ms ratio={ms / paper_ms:.2f}"))
    for blk in AREA_PCT:
        rows.append((f"fig18_area_{blk}_mm2",
                     round(TOTAL_AREA_MM2 * AREA_PCT[blk] / 100, 1),
                     f"{AREA_PCT[blk]}%"))
        rows.append((f"fig18_power_{blk}_w",
                     round(TOTAL_POWER_W * POWER_PCT[blk] / 100, 2),
                     f"{POWER_PCT[blk]}%"))
    rows.append(("table1_total_area_mm2", TOTAL_AREA_MM2, "65nm"))
    rows.append(("table1_total_power_w", TOTAL_POWER_W, "143MHz"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
