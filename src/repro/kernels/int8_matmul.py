"""Pallas TPU kernel: INT8 x INT8 -> INT32 matmul with fused requantization.

This is the SwiftTron MatMul block (§III-B) + Requantization unit (§III-C)
re-targeted to the TPU MXU:

  * the MAC array becomes a (bm, bn) MXU tile accumulating int32 over
    K-steps of ``bk`` (INT8 operands feed the MXU at 2x bf16 throughput);
  * the "read output column-by-column, adding the bias" epilogue becomes a
    fused bias + dyadic-requant + clip on the *last* K-step while the tile
    is still VMEM-resident — the INT32 accumulator never round-trips HBM;
  * per-channel weight scales are a (N,) vector of dyadic multipliers
    blocked along with the output columns.

Block shapes default to MXU-aligned (128, 128) tiles with bk=512 int8 —
VMEM per step: bm*bk + bk*bn (int8) + bm*bn*4 (int32 acc) = 192 KiB,
comfortably under the ~16 MiB v5e VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.contracts import check_launch, require_launch
from repro.core.dyadic import Dyadic


def _rshift_round(x, s: int):
    if s == 0:
        return x
    return (x + (1 << (s - 1))) >> s


def _requant_tile(acc, b_mult, c: int, pre: int):
    """Dyadic requant of an int32 tile; b_mult scalar int32 or (1,bn)."""
    return _rshift_round(_rshift_round(acc, pre) * b_mult, c - pre)


def _unpack_nibbles_k(w_ref, bk: int, bn: int):
    """In-register nibble expansion of a (bk // 2, bn) packed weight
    block to (bk, bn) int8: low nibble = even K row, high = odd.  All
    arithmetic in int32 with explicit sign extension — bit-exact twin of
    ``repro.ops.packed.nibble_unpack(axis=-2)``."""
    p32 = w_ref[...].astype(jnp.int32)
    lo = ((p32 & 15) ^ 8) - 8
    hi = (((p32 >> 4) & 15) ^ 8) - 8
    return jnp.stack([lo, hi], axis=1).reshape(bk, bn).astype(jnp.int8)


def _mm_kernel(*refs, n_k: int, has_bias: bool, has_bvec: bool,
               dn_b: Optional[int], dn_c: int, dn_pre: int,
               out_lo: int, out_hi: int, out_dtype, raw: bool = False,
               packed: bool = False, bk: int = 0, bn: int = 0):
    it = iter(refs)
    x_ref, w_ref = next(it), next(it)
    bias_ref = next(it) if has_bias else None
    bvec_ref = next(it) if has_bvec else None
    o_ref, acc_ref = next(it), next(it)
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_nibbles_k(w_ref, bk, bn) if packed else w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_bias:
            acc = acc + bias_ref[...].astype(jnp.int32)[None, :]
        if raw:                                        # int32 accumulator out
            o_ref[...] = acc.astype(out_dtype)
            return
        if has_bvec:                                   # per-channel requant
            b = bvec_ref[...].astype(jnp.int32)[None, :]
            out = _requant_tile(acc, b, dn_c, dn_pre)
        else:                                          # per-tensor requant
            out = _requant_tile(acc, jnp.int32(dn_b), dn_c, dn_pre)
        out = jnp.clip(out, out_lo, out_hi)
        o_ref[...] = out.astype(out_dtype)


def int8_matmul_pallas(x8, w8, bias32=None, dn: Dyadic = None,
                       b_vec=None, c: int = 0, pre: int = 0,
                       out_bits: int = 8, out_dtype=jnp.int8,
                       bm: int = 128, bn: int = 128, bk: int = 512,
                       packed: bool = False, interpret: bool = True):
    """x8: (M, K) int8; w8: (K, N) int8; bias32: (N,) int32 or None.

    Epilogue: ``dn`` (per-tensor) / (``b_vec``, c, pre) (per-channel) /
    neither (**raw**: the int32 accumulator plus bias is written out,
    ``out_dtype`` must be int32).  M/K/N must divide by the (clamped)
    block shapes.

    ``packed=True`` switches the weight operand to int4 nibbles:
    ``w8`` is the ``(K // 2, N)`` packed array
    (``QuantLinearParams.w_packed``), streamed as ``(bk // 2, bn)``
    blocks and expanded in-register — packed weights never materialize
    as dense int8 in HBM.  Bit-exact vs unpacking first (msr4 outlier
    lanes are the *caller's* sparse correction on a raw launch).
    """
    m, k = x8.shape
    if packed:
        k_half, n = w8.shape
        assert k == 2 * k_half, (x8.shape, w8.shape)
    else:
        k2, n = w8.shape
        assert k == k2, (x8.shape, w8.shape)
    raw = dn is None and b_vec is None
    if raw:
        assert out_bits == 32 and out_dtype == jnp.int32, \
            "raw epilogue returns the int32 accumulator"
    require_launch(check_launch(
        "int8_matmul", m=m, n=n, k=k, bm=bm, bn=bn, bk=bk,
        out_bits=out_bits, has_bias=bias32 is not None,
        per_channel=b_vec is not None, packed=packed))
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    n_k = k // bk
    if dn is not None:
        dn_b, dn_c, dn_pre = dn.b, dn.c, dn.pre
    else:
        dn_b, dn_c, dn_pre = None, c, pre
    out_lo, out_hi = -(1 << (out_bits - 1)), (1 << (out_bits - 1)) - 1

    kernel = functools.partial(
        _mm_kernel, n_k=n_k, has_bias=bias32 is not None,
        has_bvec=b_vec is not None, dn_b=dn_b, dn_c=dn_c, dn_pre=dn_pre,
        out_lo=out_lo, out_hi=out_hi, out_dtype=out_dtype, raw=raw,
        packed=packed, bk=bk, bn=bn)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bk // 2 if packed else bk, bn),
                     lambda i, j, s: (s, j)),
    ]
    args = [x8, w8]
    if bias32 is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, s: (j,)))
        args.append(bias32)
    if b_vec is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, s: (j,)))
        args.append(b_vec)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*args)
