"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` mirrors the exact integer semantics of its kernel (same
rounding, same staging) by delegating to ``repro.core`` — the kernels are
*implementations* of the core numerics with explicit VMEM tiling, so kernel
vs. ref mismatches beyond +-1 LSB are bugs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import attention as iattn
from repro.core import norms as inorms
from repro.core import softmax as ism
from repro.core.dyadic import Dyadic, apply_dyadic, clip_to_bits
from repro.core.intmath import IGeluPlan, i_gelu


def ref_int8_matmul(x8, w8, bias32, dn: Dyadic, out_bits: int = 8):
    """int8 (M,K) x int8 (K,N) -> int32, +bias, dyadic requant, clip.

    bias32: int32 (N,) at the accumulator scale (s_x * s_w), or None.
    """
    acc = jnp.dot(x8, w8, preferred_element_type=jnp.int32)
    if bias32 is not None:
        acc = acc + bias32[None, :]
    return clip_to_bits(apply_dyadic(acc, dn), out_bits)


def ref_int8_matmul_perchannel(x8, w8, bias32, b_vec, c: int, pre: int,
                               out_bits: int = 8):
    from repro.core.dyadic import apply_dyadic_perchannel
    acc = jnp.dot(x8, w8, preferred_element_type=jnp.int32)
    if bias32 is not None:
        acc = acc + bias32[None, :]
    out = apply_dyadic_perchannel(acc, b_vec, c, pre, axis=-1)
    return clip_to_bits(out, out_bits)


def ref_int_softmax(q_scores, plan: ism.ISoftmaxPlan, where=None):
    return ism.i_softmax(q_scores, plan, axis=-1, where=where)


def ref_int_gelu(q, plan: IGeluPlan, dn_out: Dyadic, out_bits: int = 8):
    return clip_to_bits(apply_dyadic(i_gelu(q.astype(jnp.int32), plan),
                                     dn_out), out_bits)


def ref_int_layernorm(q, q_gamma, q_beta, plan: inorms.INormPlan,
                      out_bits: int = 8):
    return inorms.i_norm(q, q_gamma, q_beta, plan, out_bits)


def ref_int_attention(q8, k8, v8, plan: iattn.IAttnPlan, causal: bool = True,
                      window: int = 0, out_bits: int = 8, requant=None,
                      b_vec=None):
    """Oracle for the fused attention kernels: full-matrix integer attention.

    ``requant``: optional :class:`repro.ops.RequantSpec` epilogue applied
    to the int32 P·V accumulator (scale ``2^-7 * s_v``).  ``None`` keeps
    the historical behaviour — the plan's per-tensor ``dn_out``.  For the
    per-channel form, ``b_vec`` holds int32 multipliers over the
    flattened (head, head_dim) output channels, shape (H*D,) or (H, D).
    """
    sq, sk = q8.shape[1], k8.shape[1]
    mask = iattn.causal_mask(sq, sk, window=window)[None, None] \
        if (causal or window > 0) else None
    # GQA: repeat kv heads if needed
    h, hkv = q8.shape[2], k8.shape[2]
    if hkv != h:
        rep = h // hkv
        k8 = jnp.repeat(k8, rep, axis=2)
        v8 = jnp.repeat(v8, rep, axis=2)
    if requant is None:
        return iattn.i_attention_full(q8, k8, v8, plan, mask=mask,
                                      out_bits=out_bits)
    acc = iattn.i_attention_acc(q8, k8, v8, plan, mask=mask)
    return apply_attn_requant(acc, requant, b_vec)


def ref_int_decode_attention(q8, k8_cache, v8_cache, plan: iattn.IAttnPlan,
                             valid_len, out_bits: int = 8, requant=None,
                             b_vec=None):
    """Oracle for the fused decode kernel: full-matrix attention of a few
    query rows against a ragged int8 KV cache.

    q8: (B, Sq, H, D); caches: (B, L, Hkv, D) (GQA: Hkv | H);
    ``valid_len``: (B,) int32 live cache positions per slot.  Query row
    ``i`` attends to positions ``< valid_len − (Sq − 1 − i)`` — the
    stepped mask of speculative decode; Sq = 1 is the plain
    ``pos < valid_len`` occupancy mask.  ``requant``/``b_vec``: epilogue
    exactly as :func:`ref_int_attention` (default: the plan's per-tensor
    ``dn_out``).
    """
    b, sq, h, d = q8.shape
    L, hkv = k8_cache.shape[1], k8_cache.shape[2]
    if hkv != h:
        rep = h // hkv
        k8_cache = jnp.repeat(k8_cache, rep, axis=2)
        v8_cache = jnp.repeat(v8_cache, rep, axis=2)
    valid_len = jnp.asarray(valid_len, jnp.int32)
    pos = jnp.arange(L)[None, None, None, :]
    limit = valid_len[:, None, None, None] \
        - (sq - 1 - jnp.arange(sq))[None, None, :, None]
    mask = pos < limit                                   # (B,1,Sq,L)
    if requant is None:
        return iattn.i_attention_full(q8, k8_cache, v8_cache, plan,
                                      mask=mask, out_bits=out_bits)
    acc = iattn.i_attention_acc(q8, k8_cache, v8_cache, plan, mask=mask)
    return apply_attn_requant(acc, requant, b_vec)


def ref_int_paged_decode_attention(q8, k_pool, v_pool, plan, valid_len,
                                   pages, page_size: int,
                                   out_bits: int = 8, requant=None,
                                   b_vec=None):
    """Decode oracle for the *paged* cache layout: gather the page pool
    ``(num_pages, page_size, Hkv, D)`` through ``pages (B, max_pages)``
    into the contiguous per-slot layout, then delegate to
    :func:`ref_int_decode_attention` — paged decode is *defined* as
    bit-identical to this composition."""
    from repro.ops.paged import gather_pages
    k8 = gather_pages(k_pool, pages, page_size)
    v8 = gather_pages(v_pool, pages, page_size)
    return ref_int_decode_attention(q8, k8, v8, plan, valid_len, out_bits,
                                    requant=requant, b_vec=b_vec)


def ref_int_paged_prefill(q8, k8_new, v8_new, k_pool, v_pool, plan,
                          base_pos, pages, page_size: int,
                          out_bits: int = 8, requant=None, b_vec=None,
                          wo_w8=None, wo_bias32=None, wo_b_vec=None,
                          wo_spec=None):
    """Oracle for the chunked paged-prefill op: scatter the chunk's new
    K/V through the page table, gather the updated pools into the
    contiguous layout, and run the stepped-mask decode oracle with
    ``valid_len = base_pos + C`` — chunk row ``i`` (global position
    ``base_pos[b] + i``) then attends to exactly the positions
    ``≤ base_pos[b] + i``, the causal-over-history mask of chunked
    prefill.  Paged prefill is *defined* as bit-identical to this
    composition.

    ``q8``/``k8_new``/``v8_new``: ``(B, C, H|Hkv, D)`` int8 chunk
    projections (RoPE already applied); pools ``(num_pages, page_size,
    Hkv, D)``; ``base_pos (B,) int32``; ``wo_*``: the optional folded
    o-projection, exactly as :func:`ref_apply_wo`.  Returns
    ``(o, k_pool, v_pool)`` — the chunk attention output plus the
    updated pools.
    """
    from repro.ops.paged import gather_pages, scatter_chunk
    c = q8.shape[1]
    k_pool = scatter_chunk(k_pool, k8_new, base_pos, pages, page_size)
    v_pool = scatter_chunk(v_pool, v8_new, base_pos, pages, page_size)
    kc = gather_pages(k_pool, pages, page_size)
    vc = gather_pages(v_pool, pages, page_size)
    vl = jnp.asarray(base_pos, jnp.int32) + c
    o = ref_int_decode_attention(q8, kc, vc, plan, vl, out_bits,
                                 requant=requant, b_vec=b_vec)
    if wo_w8 is not None:
        o = ref_apply_wo(o, wo_w8, wo_bias32, wo_b_vec, wo_spec)
    return o, k_pool, v_pool


def ref_apply_wo(o8, wo_w8, wo_bias32, wo_b_vec, wo_spec):
    """The unfolded o-projection a folded decode launch must match:
    int8 attention output ``(B, Sq, H, D)`` × ``wo_w8 (H·D, N)`` with
    bias and the wo :class:`RequantSpec` epilogue → ``(B, Sq, N)``.
    Exactly ``models.intlayers.int_linear``'s math on the ref backend."""
    from repro.core.dyadic import apply_dyadic_perchannel
    from repro.ops.spec import PER_TENSOR
    b, sq = o8.shape[0], o8.shape[1]
    x8 = o8.astype(jnp.int8).reshape(b * sq, -1)
    acc = jnp.dot(x8, wo_w8, preferred_element_type=jnp.int32)
    if wo_bias32 is not None:
        acc = acc + wo_bias32[None, :]
    if wo_spec.is_raw:
        return acc.reshape(b, sq, -1)
    if wo_spec.kind == PER_TENSOR:
        out = apply_dyadic(acc, wo_spec.dn)
    else:
        if wo_b_vec is None:
            raise ValueError("per-channel wo_spec needs the wo_b_vec "
                             "multiplier vector")
        out = apply_dyadic_perchannel(acc, jnp.asarray(wo_b_vec, jnp.int32),
                                      wo_spec.c, wo_spec.pre, axis=-1)
    out = clip_to_bits(out, wo_spec.out_bits)
    out = out.astype(jnp.int8) if wo_spec.out_bits <= 8 else out
    return out.reshape(b, sq, -1)


def apply_attn_requant(acc, requant, b_vec=None):
    """Apply a RequantSpec epilogue to the (B, Sq, H, D) int32 P·V
    accumulator — the exact rounding the fused kernel replicates.  The
    per-channel axis is the flattened (head, head_dim) output channel."""
    from repro.core.dyadic import apply_dyadic_perchannel
    from repro.ops.spec import PER_TENSOR
    if requant.is_raw:
        return acc
    if requant.kind == PER_TENSOR:
        out = apply_dyadic(acc, requant.dn)
    else:
        if b_vec is None:
            raise ValueError("per-channel RequantSpec needs the b_vec "
                             "multiplier vector")
        b, sq, h, d = acc.shape
        out = apply_dyadic_perchannel(
            acc.reshape(b, sq, h * d),
            jnp.asarray(b_vec, jnp.int32).reshape(h * d),
            requant.c, requant.pre, axis=-1).reshape(b, sq, h, d)
    out = clip_to_bits(out, requant.out_bits)
    return out.astype(jnp.int8) if requant.out_bits <= 8 else out
