"""Checkpointing: pytree -> sharded .npz + msgpack manifest.

Features needed for the fault-tolerance story (DESIGN.md §3):
  * atomic writes (tmp dir + rename) — a killed save never corrupts the
    latest checkpoint,
  * async saves on a background thread (device_get on the main thread,
    serialisation off-thread) so the train loop isn't blocked,
  * step-based retention, ``latest_step`` discovery for restarts,
  * arbitrary auxiliary state (optimizer, data-iterator cursor, RNG).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.treepath import path_parts

Pytree = Any
_SEP = "|"


def _key_of(path) -> str:
    return _SEP.join(path_parts(path))


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key_of(path)] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template: Pytree, flat: Dict[str, np.ndarray]
                    ) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        key = _key_of(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    extra: Optional[Dict] = None, keep: int = 3):
    """Atomic synchronous save."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp_step_{step}_{os.getpid()}")
    final = os.path.join(directory, f"step_{step:012d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "time": time.time(),
                   "extra": extra or {}, "n_leaves": len(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def load_checkpoint(directory: str, template: Pytree,
                    step: Optional[int] = None) -> Tuple[Pytree, Dict]:
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:012d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    return _unflatten_into(template, flat), meta


class CheckpointManager:
    """Async checkpointing: device_get on caller thread, file IO off-thread.

    ``save`` returns immediately; ``wait`` blocks until the last save
    landed (called before exit and before restore-after-failure)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Pytree, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra,
                                self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, template: Pytree, step: Optional[int] = None):
        self.wait()
        return load_checkpoint(self.directory, template, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
