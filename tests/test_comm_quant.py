"""int8 comm-quant boundary (EXPERIMENTS.md §Perf C2): forward quantizes
onto the int8 grid, gradients pass straight through."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import comm_quant_gather, _cq_gather


def test_forward_quantizes():
    x = jnp.asarray([0.03, -0.51, 7.99, -8.2], jnp.float32)
    s = 8.0 / 127.0
    out = np.asarray(_cq_gather(x, s))
    want = np.clip(np.round(np.asarray(x) / s), -127, 127) * s
    assert np.allclose(out, want, atol=1e-6)


def test_straight_through_gradient():
    x = jnp.linspace(-4.0, 4.0, 16)
    s = 8.0 / 127.0
    g = jax.grad(lambda v: jnp.sum(jnp.sin(_cq_gather(v, s))))(x)
    g_ref = jnp.cos(_cq_gather(x, s))    # d/dx passes through the quant
    assert np.allclose(np.asarray(g), np.asarray(g_ref), atol=1e-6)


def test_disabled_without_mesh():
    x = jnp.ones((4, 8))
    out = comm_quant_gather(x, 0.1, enabled=True)   # no mesh -> identity
    assert np.allclose(np.asarray(out), 1.0)
