"""Step functions the launcher / dry-run lower: QAT train step and the
integer serving steps (prefill / decode)."""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import inttransformer as it
from repro.models.common import ArchConfig
from repro.ops import resolve_ops
from repro.optim import adamw_update
from repro.optim.adamw import AdamWConfig
from repro.quant import plans as qplans
from repro.quant import qat

Pytree = Any


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    lr_fn: Optional[Callable] = None,
                    qat_enabled: bool = True, param_specs=None,
                    accum_steps: int = 1):
    """QAT train step; ``accum_steps`` > 1 runs microbatched gradient
    accumulation (activation memory / accum_steps) via lax.scan."""
    lr_fn = lr_fn or (lambda step: 1.0)

    def grad_fn(params, batch):
        return jax.value_and_grad(qat.loss_fn, has_aux=True)(
            params, batch, cfg, qat=qat_enabled)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def _pin(g):
                if param_specs is None:
                    return g
                return jax.tree.map(jax.lax.with_sharding_constraint, g,
                                    param_specs)

            def acc(carry, mb):
                g_acc, l_acc, a_acc = carry
                (l, (ce_i, a)), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda ga, gi: ga + gi.astype(jnp.float32), g_acc,
                    _pin(g))
                return (_pin(g_acc), l_acc + ce_i, a_acc + a), None

            g0 = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, ce, aux), _ = jax.lax.scan(
                acc, (g0, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            ce, aux = ce / accum_steps, aux / accum_steps
            loss = ce
        if param_specs is not None:
            # pin gradient shardings to the param layout: the optimizer
            # update then stays fully sharded elementwise (otherwise XLA
            # may all-gather f32 moments to meet the output sharding)
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 param_specs)
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, opt_cfg,
            lr_scale=lr_fn(opt_state.step))
        metrics.update({"loss": loss, "ce": ce, "aux": aux})
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, plans: qplans.LayerPlans,
                      ops=None):
    """RoPE tables are explicit inputs (multi-MB design constants must not
    be baked into the HLO)."""
    ops = resolve_ops(ops, cfg)
    if cfg.pos == "rope":
        def prefill(qparams, batch, rope_tab):
            return it.int_prefill(qparams, batch, plans, cfg,
                                  ops=ops, rope_tab=rope_tab)
    else:
        def prefill(qparams, batch):
            return it.int_prefill(qparams, batch, plans, cfg,
                                  ops=ops)
    return prefill


def make_decode_step(cfg: ArchConfig, plans: qplans.LayerPlans,
                     cache_len: int, ops=None):
    ops = resolve_ops(ops, cfg)
    if cfg.pos == "rope":
        def decode(qparams, caches, tokens, pos, rope_tab):
            return it.int_decode_step(qparams, caches, tokens, pos, plans,
                                      cfg, rope_tab, ops=ops)
    else:
        def decode(qparams, caches, tokens, pos):
            return it.int_decode_step(qparams, caches, tokens, pos, plans,
                                      cfg, None, ops=ops)
    return decode


def rope_table_spec(cfg: ArchConfig, max_len: int):
    sds = jax.ShapeDtypeStruct((max_len + 1, cfg.hd // 2), jnp.int32)
    return (sds, sds)
