"""Integer nonlinear primitives vs float oracles (paper §III-F/H/I)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import intmath


def test_isqrt_exact_small():
    n = np.arange(0, 100000, dtype=np.int32)
    got = np.asarray(intmath.i_sqrt(jnp.asarray(n)))
    want = np.array([math.isqrt(int(v)) for v in n])
    assert np.array_equal(got, want)


@settings(max_examples=300, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_isqrt_exact_property(n):
    got = int(intmath.i_sqrt(jnp.asarray([n], jnp.int32))[0])
    assert got == math.isqrt(n)


def test_iexp_error_bound():
    s = 2.0 ** -14
    plan = intmath.make_iexp(s)
    x = np.linspace(-25, 0, 20000)
    q = np.round(x / s).astype(np.int32)
    got = np.asarray(intmath.i_exp(jnp.asarray(q), plan)) * plan.s_out
    ref = np.exp(q * s)
    assert np.abs(got - ref).max() < 4e-3          # I-BERT-grade
    rel = np.abs((got - ref) / np.maximum(ref, 1e-9))[x > -5]
    assert rel.max() < 1e-2


def test_iexp_monotone():
    s = 2.0 ** -14
    plan = intmath.make_iexp(s)
    q = jnp.arange(-300000, 1, 37, dtype=jnp.int32)
    out = np.asarray(intmath.i_exp(q, plan))
    assert (np.diff(out) >= 0).all()


def test_igelu_error_bound():
    s = 8 / 1024
    plan = intmath.make_igelu(s, 1024)
    x = np.linspace(-8, 8, 4001)
    q = np.round(x / s).astype(np.int32)
    got = np.asarray(intmath.i_gelu(jnp.asarray(q), plan)) * plan.s_out
    erf = np.vectorize(math.erf)
    ref = 0.5 * x * (1 + erf(x / np.sqrt(2)))
    assert np.abs(got - ref).max() < 3e-2          # paper/I-BERT-grade


def test_int_bit_length():
    n = jnp.asarray([0, 1, 2, 3, 4, 255, 256, 2**30, 2**31 - 1], jnp.int32)
    got = np.asarray(intmath.int_bit_length(n))
    want = [v.bit_length() for v in [0, 1, 2, 3, 4, 255, 256, 2**30,
                                     2**31 - 1]]
    assert np.array_equal(got, want)


def test_iln1p():
    s_in, s_out = 2.0 ** -15, 2.0 ** -12
    plan = intmath.make_iln1p(s_in, s_out, 1 << 15)
    e = np.linspace(0, 1, 2001)
    q = np.round(e / s_in).astype(np.int32)
    got = np.asarray(intmath.i_ln1p(jnp.asarray(q), plan)) * s_out
    assert np.abs(got - np.log1p(e)).max() < 8e-3
