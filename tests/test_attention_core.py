"""Integer attention composition (paper Figs. 8-10)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as iattn


def _rand_qkv(rng, b, s, h, d, hkv=None):
    hkv = hkv or h
    q = rng.integers(-127, 128, (b, s, h, d)).astype(np.int8)
    k = rng.integers(-127, 128, (b, s, hkv, d)).astype(np.int8)
    v = rng.integers(-127, 128, (b, s, hkv, d)).astype(np.int8)
    return q, k, v


def _float_oracle(q8, k8, v8, plan, causal=True, window=0):
    d = q8.shape[-1]
    h, hkv = q8.shape[2], k8.shape[2]
    rep = h // hkv
    kf = np.repeat(k8, rep, 2) * plan.s_k
    vf = np.repeat(v8, rep, 2) * plan.s_v
    qf = q8 * plan.s_q
    sc = np.einsum("bqhd,bkhd->bhqk", qf, kf) / math.sqrt(d)
    s = q8.shape[1]
    mask = np.tril(np.ones((s, s), bool))
    if window:
        mask &= ~np.tril(np.ones((s, s), bool), -window)
    if causal or window:
        sc = np.where(mask, sc, -1e9)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, vf)


def test_full_attention_vs_float(rng):
    b, s, h, d = 2, 128, 4, 64
    plan = iattn.make_iattention(d, 8/127, 8/127, 4/127, 4/127)
    q8, k8, v8 = _rand_qkv(rng, b, s, h, d)
    mask = iattn.causal_mask(s, s)[None, None]
    got = np.asarray(iattn.i_attention_full(
        jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8), plan,
        mask=mask)) * plan.s_out
    ref = _float_oracle(q8, k8, v8, plan)
    assert np.abs(got - ref).max() < 0.12           # ~3 int8 LSB


@pytest.mark.parametrize("window", [0, 48])
def test_chunked_matches_full(rng, window):
    b, s, h, d = 2, 192, 2, 32
    plan = iattn.make_iattention(d, 8/127, 8/127, 4/127, 4/127)
    q8, k8, v8 = _rand_qkv(rng, b, s, h, d)
    mask = iattn.causal_mask(s, s, window=window)[None, None]
    full = np.asarray(iattn.i_attention_full(
        jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8), plan,
        mask=mask))
    chk = np.asarray(iattn.i_attention_chunked(
        jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8), plan,
        chunk=64, causal=True, window=window))
    assert np.abs(chk.astype(int) - full.astype(int)).max() <= 2


def test_decode_matches_full_last_row(rng):
    b, s, h, d = 2, 64, 2, 32
    plan = iattn.make_iattention(d, 8/127, 8/127, 4/127, 4/127)
    q8, k8, v8 = _rand_qkv(rng, b, s, h, d)
    mask = iattn.causal_mask(s, s)[None, None]
    full = np.asarray(iattn.i_attention_full(
        jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8), plan,
        mask=mask))
    dec = np.asarray(iattn.i_attention_decode(
        jnp.asarray(q8[:, -1:]), jnp.asarray(k8), jnp.asarray(v8), plan,
        valid_len=jnp.full((b,), s, jnp.int32)))
    assert np.abs(dec[:, 0].astype(int) - full[:, -1].astype(int)).max() <= 1
