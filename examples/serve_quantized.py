"""Batched integer serving: train briefly, convert, then serve a batch of
requests through the INT8 engine (int8 KV cache, greedy + sampled).

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models import model as M
from repro.models import transformer as tf
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWConfig
from repro import ops as rops
from repro.quant import convert, qat
from repro.serving import Request, ServingEngine


def main():
    cfg = M.reduce_config(get_config("h2o-danube-3-4b"), dtype="float32",
                          vocab=256, num_layers=2)
    data = SyntheticLMDataset(cfg.vocab, 32, 8, seed=0)
    params = tf.init_params(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(qat.loss_fn, has_aux=True)(
            params, batch, cfg, qat=True)
        params, opt, _ = adamw_update(g, opt, params, opt_cfg)
        return params, opt, loss

    for _ in range(20):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, _ = step(params, opt, batch)

    qp, plans = convert.quantize_params(params, cfg)
    # the engine takes one OpSet handle at construction (repro.ops
    # registry); swap "ref" for "pallas"/"pallas_tuned"/"pallas_fused"
    # — or set the REPRO_BACKEND env var — without touching the model
    # code (docs/OPS_API.md lists the built-ins).  The default cache is
    # the paged pool; num_pages undersubscribes it so KV memory tracks
    # live tokens, not batch x cache_len (repro.serving.kvcache)
    engine = ServingEngine(qp, plans, cfg, batch_size=4, cache_len=64,
                           ops=rops.resolve_ops("ref"),
                           page_size=16, num_pages=9)
    print(f"engine: {engine.describe_str()}")
    reqs = [Request(uid=i, prompt=[1 + 3 * i, 7, 42, 5],
                    max_new_tokens=12,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(6)]
    for r in reqs:
        engine.submit(r)
    steps = 0
    while engine.queue or any(s is not None for s in engine.slots):
        engine.step()
        steps += 1
    print(f"served {len(reqs)} requests in {steps} batched decode steps "
          f"(batch={engine.batch}, int8 KV cache, window="
          f"{cfg.window})")
    for r in reqs:
        mode = "greedy" if r.temperature == 0 else "sampled"
        print(f"  req {r.uid} ({mode}): {r.prompt} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
