"""Pallas TPU kernel: fused integer *decode* attention, bit-exact.

The serving hot path: one (or a few speculative) new query tokens per
sequence against an int8 KV cache whose per-slot occupancy differs —
slot ``b`` has ``valid_len[b]`` live positions, the rest of the cache is
stale.  One kernel launch runs the whole SwiftTron datapath (int8 Q·Kᵀ →
Shiftmax → int8 P·V → RequantSpec epilogue) streaming over KV-cache
blocks, with **data-dependent ``valid_len`` masking**:

  * ``valid_len`` (B,) int32 rides as a *scalar-prefetch* operand
    (``pltpu.PrefetchScalarGridSpec``), so it is resident before the
    kernel body runs and may steer the block pipeline;
  * KV blocks that are entirely dead for a slot are **skipped, not
    computed-and-discarded**: the block index map clamps to the last
    live block (the pipeline re-reads a resident block instead of
    fetching a dead one) and every sweep is predicated off with
    ``pl.when`` — per-step work is O(valid_len), not O(cache_len);
  * inside the boundary block, dead positions contribute ``-2³⁰`` to the
    row max and 0 to the sum and the P·V accumulator, exactly like the
    prefill kernel's causal masking.

**Paged KV caches** (``pages=``): instead of a contiguous per-slot cache
``(B, L, Hkv, D)``, the K/V operands may be a physical page pool
``(num_pages, page_size, Hkv, D)`` plus a page table ``pages: int32[B,
max_pages]`` riding as a *second* scalar-prefetch operand next to
``valid_len``.  The kernel body is unchanged — masking works in logical
positions — only the KV block index map differs: logical block ``k`` of
slot ``b`` resolves to physical page ``pages[b, k·bkv // page_size]``
(sub-block ``k·bkv % page_size // bkv``).  Dead logical blocks clamp to
the last live block *before* translation, so the DMA always lands on a
resident page; unmapped table entries hold the null page 0, which every
pool reserves (see ``repro.serving.kvcache``).  Numerics are
bit-identical to gathering the pages into the contiguous layout first.

**Folded wo projection** (``wo_w8=``): the decode epilogue can absorb
the attention output projection — per head, the requantized int8
``(Sq, D)`` tile is contracted against that head's ``(D, N)`` slab of
``wo`` and accumulated across the head grid dimension in VMEM scratch;
the *last* head adds ``bias32`` and applies the wo ``RequantSpec``
(typically per-channel over the N output channels, the same two-stage
rounding the attention epilogue already implements).  The launch then
returns the ``(B, Sq, N)`` projected output directly — one kernel for
attention *and* o-projection, bit-exact against the unfolded
attention-then-``int8_matmul`` composition.

Like ``int_attention_fused`` this buys bit-exactness with three
streaming sweeps over the live KV blocks (max → sum → normalise+AV) —
integer maxima and sums are associative, so the result is bit-identical
to the full-matrix decode oracle ``kernels.ref.ref_int_decode_attention``
for every RequantSpec epilogue form.

Speculative queries (1 < Sq ≤ 8): query row ``i`` attends to cache
positions ``< valid_len − (Sq − 1 − i)`` — the *last* row sees exactly
``valid_len`` positions, earlier speculative rows one fewer each (the
stepped causal mask of draft verification).  ``Sq = 1`` reduces to the
plain ``pos < valid_len`` occupancy mask.

Accumulator budget (Sq ≤ 8 rows live in VMEM scratch the whole launch):
row sums need ``valid_len ≤ 2¹⁵`` so ``Σ e16 ≤ 2³⁰`` stays int32-exact —
the same ``MAX_SKV`` budget as the prefill kernel, asserted on the
*logical cache length* here because ``valid_len ≤ L`` by construction.
The folded-wo scratch adds ``(Sq, N)`` int32 (N = H·D out channels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.budgets import MAX_ROWSUM_LEN
from repro.analysis.budgets import MAX_SQ as _MAX_SQ
from repro.analysis.contracts import check_launch, require_launch
from repro.core.attention import IAttnPlan
from repro.kernels.int_attention_fused import (_epilogue_setup,
                                               _requant_tile,
                                               _streaming_attn_body,
                                               _unpack_kv_tile)
from repro.ops.spec import PER_CHANNEL, RequantSpec

# both budgets are owned by repro.analysis.budgets; re-exported here
# because callers (and tests) import them from the kernel module
MAX_SQ = _MAX_SQ            # speculative query budget (scratch rows/head)
MAX_SKV = MAX_ROWSUM_LEN    # row-sum int32 budget: L * 2^15 <= 2^30


def _decode_kernel(*refs, plan: IAttnPlan, requant: RequantSpec,
                   has_bvec: bool, n_kv: int, sq: int, bkv: int,
                   paged: bool, fold: bool, wo_spec, wo_has_bias: bool,
                   wo_has_bvec: bool, n_heads: int,
                   packed_kv: bool = False, sub: int = 1):
    refs = list(refs)
    vl_ref = refs.pop(0)
    pt_ref = ks_ref = vs_ref = None
    if paged:
        # page table: read by index maps only — except under packed KV,
        # where the body re-derives the physical page for the shift
        # lookup
        pt_ref = refs.pop(0)
    if packed_kv:
        ks_ref, vs_ref = refs.pop(0), refs.pop(0)
    q_ref, k_ref, v_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    b_ref = refs.pop(0) if has_bvec else None
    wo_ref = wob_ref = wobv_ref = None
    if fold:
        wo_ref = refs.pop(0)
        if wo_has_bias:
            wob_ref = refs.pop(0)
        if wo_has_bvec:
            wobv_ref = refs.pop(0)
    o_ref = refs.pop(0)
    m_ref, s_ref, acc_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    # with the folded projection the per-head attention tile lands in
    # VMEM scratch (same (1, sq, 1, d) indexing as the real output ref)
    attn_out = refs.pop(0) if fold else o_ref
    wacc_ref = refs.pop(0) if fold else None

    bi = pl.program_id(0)
    head = pl.program_id(1)
    phase = pl.program_id(2)
    kv_step = pl.program_id(3)
    vl = vl_ref[bi]

    q8 = q_ref[0, :, 0, :]                      # (sq, d) int8
    if packed_kv:
        # re-derive the physical page exactly as the KV index map did
        # (same dead-block clamp) and dequantize the nibble tile with
        # that page's requant shift, in-register — packed pages never
        # exist as dense int8 outside the launch
        last = jnp.maximum(pl.cdiv(vl, bkv) - 1, 0)
        kc = jnp.minimum(kv_step, last)
        page = pt_ref[bi, kc // sub]
        k8 = _unpack_kv_tile(k_ref[0, :, 0, :], ks_ref[page])
        v8 = _unpack_kv_tile(v_ref[0, :, 0, :], vs_ref[page])
    else:
        k8 = k_ref[0, :, 0, :]                  # (bkv, d) int8
        v8 = v_ref[0, :, 0, :]

    # stepped occupancy mask: row i sees vl - (sq-1-i) positions (sq=1:
    # the plain pos < valid_len cache-occupancy mask).  ki is the
    # *logical* position — under paging the index map already translated
    # the block to its physical page, the mask math is unchanged.
    qi = jax.lax.broadcasted_iota(jnp.int32, (sq, bkv), 0)
    ki = kv_step * bkv + jax.lax.broadcasted_iota(jnp.int32, (sq, bkv), 1)
    live = ki < vl - (sq - 1 - qi)

    # data-dependent block skip: a block whose first position is already
    # past the widest row's occupancy (the last query row sees vl) is
    # entirely dead — contribute nothing, in any sweep.  The epilogue
    # inside the shared body still runs on the last step, so a slot with
    # valid_len == 0 writes requant(0) (matching the all-masked oracle).
    blk_live = kv_step * bkv < vl

    _streaming_attn_body(phase, kv_step, n_kv, q8, k8, v8, live, blk_live,
                         attn_out, m_ref, s_ref, acc_ref, b_ref,
                         plan=plan, requant=requant)

    if fold:
        @pl.when((phase == 2) & (kv_step == n_kv - 1))
        def _wo_accumulate():
            # this head's slab of the o-projection: (sq, d) @ (d, n_out)
            o8 = attn_out[0, :, 0, :]
            part = jax.lax.dot_general(o8, wo_ref[...],
                                       (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.int32)
            prev = jnp.where(head == 0, jnp.zeros_like(part),
                             wacc_ref[...])
            wacc_ref[...] = prev + part

        @pl.when((phase == 2) & (kv_step == n_kv - 1)
                 & (head == n_heads - 1))
        def _wo_epilogue():
            acc = wacc_ref[...]
            if wo_has_bias:
                acc = acc + wob_ref[0, :][None, :]
            b_row = None if wobv_ref is None \
                else wobv_ref[0, :].astype(jnp.int32)[None, :]
            o_ref[0, :, :] = _requant_tile(acc, wo_spec,
                                           b_row).astype(o_ref.dtype)


def int_decode_attention_fused(q8, k8_cache, v8_cache, plan: IAttnPlan,
                               valid_len, requant=None, b_vec=None,
                               bkv: int = 128, out_bits: int = 8,
                               interpret: bool = True,
                               pages=None, page_size: int = 0,
                               wo_w8=None, wo_bias32=None, wo_b_vec=None,
                               wo_spec=None, kv_shifts=None):
    """q8: (B, Sq, H, D) int8, Sq ≤ 8; valid_len: (B,) int32 live
    positions per slot.  Caches, either layout:

      * contiguous — k8/v8 ``(B, L, Hkv, D)`` int8 (GQA: Hkv | H);
      * paged      — k8/v8 ``(num_pages, page_size, Hkv, D)`` pools plus
        ``pages: int32 (B, max_pages)`` (logical block → physical page;
        unmapped entries = null page 0) and ``page_size``.  The logical
        length is ``max_pages · page_size``.

    ``kv_shifts``: a ``(k_shift, v_shift)`` pair of int32
    ``(num_pages,)`` per-page requant shifts switches the paged pools to
    the **packed int4** layout ``(num_pages, page_size, Hkv, D // 2)`` —
    two head-dim nibbles per byte, expanded and left-shifted in-register
    (``kernels.int_attention_fused._unpack_kv_tile``); packed pages
    never materialize as dense int8 in HBM.  Paged layout only.

    ``requant``: a :class:`RequantSpec` for the epilogue (default: the
    plan's per-tensor ``dn_out``); ``b_vec``: int32 per-channel
    multipliers, shape (H*D,) or (H, D), required iff per-channel.

    ``wo_w8`` (+ ``wo_bias32`` / ``wo_b_vec`` / ``wo_spec``): fold the
    output projection into the launch — ``wo_w8 (H·D, N)`` int8,
    ``wo_spec`` its epilogue (``wo_b_vec (N,)`` iff per-channel).  The
    attention epilogue must clip to ≤ 8 bits (it feeds the int8 MXU
    contraction); the return becomes ``(B, Sq, N)``.

    Returns (B, Sq, H, D) — or (B, Sq, N) when folded: int8 when the
    final epilogue clips to ≤ 8 bits, int32 otherwise.  Bit-exact
    against ``kernels.ref.ref_int_decode_attention`` (+ the unfolded
    per-channel matmul when folding) for the same arguments.

    Under tensor-parallel serving this wrapper runs inside a shard_map
    body with the head axes already sliced, so the ``require_launch``
    below validates the *local* (H/tp, Hkv/tp) launch each device
    makes; ``analysis.contracts.check_tp_launch`` is its offline twin.
    """
    b, sq, h, d = q8.shape
    paged = pages is not None
    packed_kv = kv_shifts is not None
    if packed_kv and not paged:
        raise ValueError("kv_shifts (packed int4 KV) needs the paged "
                         "cache layout")
    if paged:
        ps, hkv = k8_cache.shape[1], k8_cache.shape[2]
        assert page_size == ps, (page_size, ps)
        pages = jnp.asarray(pages, jnp.int32)
        assert pages.ndim == 2 and pages.shape[0] == b, pages.shape
        L = pages.shape[1] * ps
    else:
        _, L, hkv, _ = k8_cache.shape
    num_pages = k8_cache.shape[0] if paged else 0
    k_shift = v_shift = None
    if packed_kv:
        assert k8_cache.shape[3] == d // 2, (k8_cache.shape, d)
        k_shift = jnp.asarray(kv_shifts[0], jnp.int32)
        v_shift = jnp.asarray(kv_shifts[1], jnp.int32)
        assert k_shift.shape == v_shift.shape == (num_pages,), \
            (k_shift.shape, v_shift.shape, num_pages)
    require_launch(check_launch(
        "int_decode_attention", b=b, sq=sq, h=h, hkv=hkv, d=d,
        L=None if paged else L, bkv=bkv,
        max_pages=pages.shape[1] if paged else 0,
        page_size=page_size, out_bits=out_bits, kv_pack=packed_kv,
        num_pages=num_pages))
    group = h // hkv
    bkv = min(bkv, ps if paged else L)
    sub = ps // bkv if paged else 1     # KV sub-blocks per physical page
    n_kv = L // bkv
    valid_len = jnp.asarray(valid_len, jnp.int32)

    requant, has_bvec, b2, out_dtype = _epilogue_setup(
        requant, plan, out_bits, b_vec, h, d)

    fold = wo_w8 is not None
    wo_has_bias = wo_has_bvec = False
    if fold:
        assert wo_spec is not None, "folded wo projection needs wo_spec"
        assert not requant.is_raw and requant.out_bits <= 8, \
            "wo folding needs an int8 attention epilogue"
        wo_w8 = jnp.asarray(wo_w8)
        n_out = wo_w8.shape[-1]
        assert wo_w8.shape == (h * d, n_out), (wo_w8.shape, h, d)
        wo_has_bias = wo_bias32 is not None
        wo_has_bvec = wo_spec.kind == PER_CHANNEL
        if wo_has_bvec and wo_b_vec is None:
            raise ValueError("per-channel wo_spec needs the wo_b_vec "
                             "multiplier vector")
        out_dtype = jnp.int8 if (not wo_spec.is_raw
                                 and wo_spec.out_bits <= 8) else jnp.int32

    kernel = functools.partial(
        _decode_kernel, plan=plan, requant=requant, has_bvec=has_bvec,
        n_kv=n_kv, sq=sq, bkv=bkv, paged=paged, fold=fold, wo_spec=wo_spec,
        wo_has_bias=wo_has_bias, wo_has_bvec=wo_has_bvec, n_heads=h,
        packed_kv=packed_kv, sub=sub)

    def _kv_block(ki, vl):
        # clamp dead blocks to the slot's last live block: the pipeline
        # re-reads a resident block instead of DMA-ing a dead one (the
        # compute for those steps is pl.when-ed off anyway)
        last = jnp.maximum(pl.cdiv(vl, bkv) - 1, 0)
        return jnp.minimum(ki, last)

    # index maps: scalar-prefetch refs arrive as trailing args — one
    # (valid_len) for the contiguous layout, two (valid_len, pages) for
    # the paged layout, where the KV map translates logical block →
    # physical (page, sub-block) through the prefetched table.
    if paged:
        # ``*_`` absorbs the k_shift/v_shift scalar-prefetch refs under
        # the packed int4 layout (read by the kernel body, not the maps)
        def q_map(bi, hi, ph, ki, vl, pt, *_):
            return (bi, 0, hi, 0)

        def kv_map(bi, hi, ph, ki, vl, pt, *_):
            kc = _kv_block(ki, vl[bi])
            return (pt[bi, kc // sub], kc % sub, hi // group, 0)

        def head_row_map(bi, hi, ph, ki, vl, pt, *_):
            return (hi, 0)

        def one_row_map(bi, hi, ph, ki, vl, pt, *_):
            return (0, 0)

        def out_map(bi, hi, ph, ki, vl, pt, *_):
            return (bi, 0, 0) if fold else (bi, 0, hi, 0)
    else:
        def q_map(bi, hi, ph, ki, vl):
            return (bi, 0, hi, 0)

        def kv_map(bi, hi, ph, ki, vl):
            return (bi, _kv_block(ki, vl[bi]), hi // group, 0)

        def head_row_map(bi, hi, ph, ki, vl):
            return (hi, 0)

        def one_row_map(bi, hi, ph, ki, vl):
            return (0, 0)

        def out_map(bi, hi, ph, ki, vl):
            return (bi, 0, 0) if fold else (bi, 0, hi, 0)

    kv_blk = (1, bkv, 1, d // 2 if packed_kv else d)
    in_specs = [
        pl.BlockSpec((1, sq, 1, d), q_map),
        pl.BlockSpec(kv_blk, kv_map),
        pl.BlockSpec(kv_blk, kv_map),
    ]
    args = [q8, k8_cache, v8_cache]
    if has_bvec:
        in_specs.append(pl.BlockSpec((1, d), head_row_map))
        args.append(b2)
    if fold:
        in_specs.append(pl.BlockSpec((d, n_out), head_row_map))
        args.append(wo_w8)
        if wo_has_bias:
            in_specs.append(pl.BlockSpec((1, n_out), one_row_map))
            args.append(jnp.asarray(wo_bias32, jnp.int32).reshape(1, n_out))
        if wo_has_bvec:
            in_specs.append(pl.BlockSpec((1, n_out), one_row_map))
            args.append(jnp.asarray(wo_b_vec, jnp.int32).reshape(1, n_out))

    from jax.experimental.pallas import tpu as pltpu
    scratch = [pltpu.VMEM((sq, 1), jnp.int32),
               pltpu.VMEM((sq, 1), jnp.int32),
               pltpu.VMEM((sq, d), jnp.int32)]
    if fold:
        # per-head attention tile (int8: asserted above) + the (Sq, N)
        # o-projection accumulator carried across the head grid dim
        scratch += [pltpu.VMEM((1, sq, 1, d), jnp.int8),
                    pltpu.VMEM((sq, n_out), jnp.int32)]
        out_specs = pl.BlockSpec((1, sq, n_out), out_map)
        out_shape = jax.ShapeDtypeStruct((b, sq, n_out), out_dtype)
    else:
        out_specs = pl.BlockSpec((1, sq, 1, d), out_map)
        out_shape = jax.ShapeDtypeStruct((b, sq, h, d), out_dtype)

    if packed_kv:
        scalar_args = (valid_len, pages, k_shift, v_shift)
    elif paged:
        scalar_args = (valid_len, pages)
    else:
        scalar_args = (valid_len,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(b, h, 3, n_kv),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*scalar_args, *args)
