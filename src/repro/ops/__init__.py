"""repro.ops — the unified operator API for the integer datapath.

Single entry point for SwiftTron's six integer ops (INT8 matmul,
Attention, Decode Attention, Softmax, GELU, LayerNorm):

  * :class:`RequantSpec` — typed, validated union of the three requant
    epilogue forms (per-tensor dyadic / per-channel vector / raw int32);
  * :class:`QuantLinearParams` — typed quantized-linear parameter pytree;
  * :class:`Backend` protocol + registry (``register_backend`` /
    ``get_backend``), the ``REPRO_BACKEND`` env override and the
    :func:`use_backend` context;
  * :class:`OpSet` — the handle models take once at construction
    (default backend + per-op overrides).  Its ``int_decode_attention``
    negotiates the optional decode capabilities (``paged_decode`` /
    ``decode_wo_fold``), and ``int_paged_prefill`` the chunked-prefill
    ones (``paged_prefill`` / ``prefill_wo_fold``) — lowering the
    page-table, chunk-scatter and folded-wo operands exactly for
    backends without them (see ``repro.ops.paged``).

See docs/OPS_API.md for the full API (the old ``repro.kernels.ops``
string-dispatch wrappers are gone; the migration table lives there).
"""
from __future__ import annotations

from repro.ops.registry import (Backend, OpSet, available_backends,
                                current_opset, get_backend,
                                register_backend, resolve_ops,
                                unregister_backend, use_backend,
                                DEFAULT_BACKEND, ENV_VAR, OP_NAMES,
                                REQUIRED_OPS)
from repro.ops.spec import (PER_CHANNEL, PER_TENSOR, RAW, PackMeta,
                            QuantLinearParams, RequantSpec)

__all__ = [
    "Backend", "OpSet", "PackMeta", "QuantLinearParams", "RequantSpec",
    "available_backends", "current_opset", "get_backend",
    "register_backend", "resolve_ops", "unregister_backend",
    "use_backend", "DEFAULT_BACKEND", "ENV_VAR", "OP_NAMES",
    "REQUIRED_OPS", "PER_CHANNEL", "PER_TENSOR", "RAW",
    "int8_matmul", "int8_matmul_packed", "int_softmax", "int_gelu",
    "int_layernorm", "int_attention", "int_decode_attention",
    "int_paged_prefill",
]


def _register_builtin_backends():
    from repro.ops.backends.pallas import PallasBackend
    from repro.ops.backends.pallas_fused import PallasFusedBackend
    from repro.ops.backends.ref import RefBackend
    register_backend("ref", RefBackend(), overwrite=True)
    register_backend("pallas", lambda: PallasBackend(), overwrite=True)
    # single-launch attention+requant kernel, bit-exact vs the two-pass
    # reference — see docs/KERNELS.md
    register_backend("pallas_fused", lambda: PallasFusedBackend(),
                     overwrite=True)
    # tuned tile profile: wider matmul K-blocks + deeper row-blocking for
    # the elementwise kernels; exists to prove per-op backend config needs
    # no model changes (swap via REPRO_BACKEND=pallas_tuned)
    register_backend(
        "pallas_tuned",
        lambda: PallasBackend(name="pallas_tuned", blocks={
            "int8_matmul": dict(bm=256, bn=256, bk=1024),
            "int_attention": dict(bq=256, bkv=256),
            "int_softmax": dict(block_rows=16),
            "int_layernorm": dict(block_rows=16),
            "int_gelu": dict(block=8192),
        }), overwrite=True)


_register_builtin_backends()


# Module-level convenience entry points: dispatch through the ambient
# OpSet (use_backend context > REPRO_BACKEND env > "ref"), or an explicit
# ``ops=`` handle.

def int8_matmul(x8, w8, spec, *, bias32=None, b_vec=None, ops=None, **opts):
    return resolve_ops(ops).int8_matmul(x8, w8, spec, bias32=bias32,
                                        b_vec=b_vec, **opts)


def int8_matmul_packed(x8, qw, spec, *, ops=None, **opts):
    return resolve_ops(ops).int8_matmul_packed(x8, qw, spec, **opts)


def int_softmax(scores, plan, *, ops=None, **opts):
    return resolve_ops(ops).int_softmax(scores, plan, **opts)


def int_gelu(q, plan, dn_out, out_bits: int = 8, *, ops=None, **opts):
    return resolve_ops(ops).int_gelu(q, plan, dn_out, out_bits=out_bits,
                                     **opts)


def int_layernorm(q, q_gamma, q_beta, plan, out_bits: int = 8, *,
                  ops=None, **opts):
    return resolve_ops(ops).int_layernorm(q, q_gamma, q_beta, plan,
                                          out_bits=out_bits, **opts)


def int_attention(q8, k8, v8, plan, causal: bool = True, window: int = 0,
                  out_bits: int = 8, *, ops=None, **opts):
    return resolve_ops(ops).int_attention(q8, k8, v8, plan, causal=causal,
                                          window=window, out_bits=out_bits,
                                          **opts)


def int_decode_attention(q8, k8_cache, v8_cache, plan, valid_len,
                         out_bits: int = 8, *, ops=None, **opts):
    return resolve_ops(ops).int_decode_attention(
        q8, k8_cache, v8_cache, plan, valid_len, out_bits=out_bits, **opts)


def int_paged_prefill(q8, k8_new, v8_new, k_pool, v_pool, plan, base_pos,
                      pages, page_size: int, out_bits: int = 8, *,
                      ops=None, **opts):
    return resolve_ops(ops).int_paged_prefill(
        q8, k8_new, v8_new, k_pool, v_pool, plan, base_pos, pages,
        page_size, out_bits=out_bits, **opts)
