"""AST repo-rule linter: invariants the codebase learned the hard way.

Run as ``python -m repro.analysis.lint [paths...]`` (default:
``src/repro``).  Emits ``path:line:col CODE message`` per finding and
exits non-zero if any fire — the CI ``static-analysis`` job gates on it.

Rule catalog (docs/ANALYSIS.md has the full rationale):

  RR001  no direct ``repro.kernels.*`` imports outside
         ``repro/kernels/`` and ``repro/ops/backends/``.  Kernels are
         reached through the backend registry (``repro.ops``) so
         fallback dispatch, interpret-mode plumbing and launch contracts
         stay in one place.

  RR002  no ``jnp.asarray(<attribute>)`` on mutable engine state in
         ``repro/serving/`` — ``jnp.asarray`` on a numpy array may alias
         its buffer (zero-copy), so later in-place mutation of e.g.
         ``self.pos`` silently changes a value captured by a pending
         dispatch (the PR 3 serving flake).  Snapshot first:
         ``jnp.asarray(x.copy())`` / ``jnp.asarray(t.snapshot())``.

  RR003  no float dtypes (``float16/32/64``, ``bfloat16``) in
         ``repro/core/`` integer modules — the integer datapath must
         stay integer; the only sanctioned float boundary is
         ``core/quant.py`` (dequantization helpers).

  RR004  no ``unpack*(...)`` calls in ``repro/models/`` or
         ``repro/serving/`` — packed weight / KV buffers are unpacked
         only inside ``repro/kernels/`` and ``repro/ops/backends/``
         (the declared dequant references and the fused in-kernel
         paths).  A model- or serving-layer unpack would materialize
         the int8 tensor the compression tier exists to avoid; dispatch
         through ``ops.int8_matmul_packed`` / the ``kv_shifts``-aware
         attention ops instead.

``lint_source(src, path)`` is the unit-test entry point; ``lint_paths``
drives the CLI.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import sys

#: rel-path prefixes (within src/) allowed to import repro.kernels.*
KERNEL_IMPORT_ALLOWED = ("repro/kernels", "repro/ops/backends")

#: core modules sanctioned to use float dtypes (the dequant boundary)
CORE_FLOAT_ALLOWED = ("repro/core/quant.py",)

#: rel-path prefixes (within src/) where RR004 bans unpack*() calls:
#: packed buffers stay packed above the kernel/backend boundary
UNPACK_BANNED = ("repro/models/", "repro/serving/")

FLOAT_DTYPES = frozenset(
    {"float16", "float32", "float64", "bfloat16", "half", "double"})

SNAPSHOT_METHODS = frozenset({"copy", "snapshot", "tolist", "item"})


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}:{self.col} {self.code} " \
               f"{self.message}"


def _norm(path: str) -> str:
    """Repo-relative posix-ish path for scope matching."""
    p = path.replace(os.sep, "/")
    if "/src/" in p:
        p = p.split("/src/", 1)[1]
    elif p.startswith("src/"):
        p = p[4:]
    return p


def _in_scope(norm: str, prefixes) -> bool:
    return any(norm.startswith(p) for p in prefixes)


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, norm: str):
        self.path = path
        self.norm = norm
        self.findings = []
        self.check_kernels = (
            norm.startswith("repro/")
            and not _in_scope(self.norm, KERNEL_IMPORT_ALLOWED))
        self.check_asarray = norm.startswith("repro/serving/")
        self.check_floats = (norm.startswith("repro/core/")
                             and norm not in CORE_FLOAT_ALLOWED)
        self.check_unpack = _in_scope(norm, UNPACK_BANNED)

    def _emit(self, node, code, message):
        self.findings.append(Finding(self.path, node.lineno,
                                     node.col_offset, code, message))

    # RR001 ------------------------------------------------------------
    def visit_Import(self, node):
        if self.check_kernels:
            for a in node.names:
                if a.name == "repro.kernels" or \
                        a.name.startswith("repro.kernels."):
                    self._emit(node, "RR001",
                               f"direct kernel import '{a.name}' — go "
                               "through the repro.ops backend registry")
        self.generic_visit(node)

    def visit_ImportFrom(self, node):
        mod = node.module or ""
        if self.check_kernels and (
                mod == "repro.kernels" or mod.startswith("repro.kernels.")):
            self._emit(node, "RR001",
                       f"direct kernel import 'from {mod}' — go through "
                       "the repro.ops backend registry")
        self.generic_visit(node)

    # RR002 / RR003 / RR004 --------------------------------------------
    def visit_Call(self, node):
        if self.check_asarray and self._is_jnp_asarray(node.func) \
                and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Attribute):
                self._emit(
                    node, "RR002",
                    f"jnp.asarray({ast.unparse(arg)}) may alias mutable "
                    "engine state (zero-copy) — snapshot first: "
                    f"jnp.asarray({ast.unparse(arg)}.copy())")
        if self.check_unpack:
            name = self._call_name(node.func)
            if name.startswith("unpack"):
                self._emit(
                    node, "RR004",
                    f"'{name}(' call outside kernels/ and ops/backends/ "
                    "— packed buffers are unpacked only below the "
                    "backend boundary; dispatch through the packed ops "
                    "(repro.ops.int8_matmul_packed / kv_shifts)")
        self.generic_visit(node)

    @staticmethod
    def _call_name(func) -> str:
        """The called name: bare ``f(...)`` or the terminal attribute of
        ``mod.f(...)`` — empty for computed callees."""
        if isinstance(func, ast.Name):
            return func.id
        if isinstance(func, ast.Attribute):
            return func.attr
        return ""

    @staticmethod
    def _is_jnp_asarray(func) -> bool:
        return (isinstance(func, ast.Attribute)
                and func.attr == "asarray"
                and isinstance(func.value, ast.Name)
                and func.value.id in ("jnp", "jax"))

    def visit_Attribute(self, node):
        if self.check_floats and node.attr in FLOAT_DTYPES:
            self._emit(node, "RR003",
                       f"float dtype '{ast.unparse(node)}' in an integer "
                       "core module — the integer datapath must stay "
                       "integer (dequant belongs in core/quant.py)")
        self.generic_visit(node)


def lint_source(src: str, path: str = "<memory>"):
    """Lint one source string; returns a list of :class:`Finding`."""
    tree = ast.parse(src, filename=path)
    v = _Visitor(path, _norm(path))
    v.visit(tree)
    return v.findings


def lint_paths(paths):
    """Lint files / directory trees; returns all findings."""
    findings = []
    for root in paths:
        if os.path.isfile(root):
            files = [root]
        else:
            files = sorted(
                os.path.join(dp, f)
                for dp, _, fs in os.walk(root)
                for f in fs if f.endswith(".py"))
        for f in files:
            with open(f, encoding="utf-8") as fh:
                findings.extend(lint_source(fh.read(), f))
    return findings


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = argv or [os.path.join("src", "repro")]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    if findings:
        print(f"{len(findings)} repo-rule violation(s)", file=sys.stderr)
        return 1
    print(f"lint ok: {', '.join(paths)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
