"""Paged vs contiguous serving decode: tokens/s and cache bytes.

Drives the same request schedule through two `ServingEngine`
configurations — the contiguous per-lane cache and the paged pool
(undersubscribed, so cache memory is O(live tokens)) — asserting
bit-identical token streams as a by-product, and reports decode
throughput plus the KV bytes each layout provisions.

Besides the usual CSV rows this module writes the machine-readable
``benchmarks/BENCH_serving.json`` (schema: ``{"configs": {name:
{"tokens_per_s", "kv_bytes", "pages", "tokens"}}, "parity": bool}``) —
the artifact the bench-smoke CI job uploads, so the serving perf
trajectory is tracked per commit.  On CPU both paths run through
XLA/interpret so the ratio mostly documents overhead; on TPU the same
harness times compiled kernels and the bytes column is what matters.
"""
import json
import os
import time

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_serving.json")


def _build(quick: bool):
    import jax
    from repro.configs.registry import get_config
    from repro.models import model as M
    from repro.models import transformer as tf
    from repro.quant import convert

    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1 if quick else 2)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


def _serve(cfg, qp, plans, n_req: int, max_new: int, **engine_kw):
    import numpy as np
    from repro.serving import Request, ServingEngine

    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", **engine_kw)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=list(rng.integers(1, cfg.vocab, 3)),
                    max_new_tokens=max_new) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_done()
    dt = time.perf_counter() - t0
    toks = [r.out_tokens for r in reqs]
    n_tok = sum(len(t) for t in toks)
    stats = eng.describe()["cache"]
    return {
        "tokens": n_tok,
        "tokens_per_s": round(n_tok / dt, 2),
        "kv_bytes": stats["kv_bytes"],
        "pages": {k: stats[k] for k in ("page_size", "num_pages")
                  if k in stats},
        "mode": stats["mode"],
    }, toks


def run(quick: bool = False):
    cfg, qp, plans = _build(quick)
    n_req, max_new = (3, 4) if quick else (6, 8)
    configs = {}
    contiguous, toks_c = _serve(cfg, qp, plans, n_req, max_new,
                                cache_mode="contiguous")
    configs["contiguous"] = contiguous
    # undersubscribed pool: far less than batch x cache_len provisioned
    paged, toks_p = _serve(cfg, qp, plans, n_req, max_new,
                           cache_mode="paged", page_size=16, num_pages=5)
    configs["paged"] = paged
    parity = toks_p == toks_c
    assert parity, "paged tokens diverged from contiguous"

    with open(JSON_PATH, "w") as f:
        json.dump({"configs": configs, "parity": parity,
                   "arch": cfg.name, "quick": quick}, f, indent=2)

    rows = []
    for name, c in configs.items():
        rows.append((f"serving_tokens_per_s[{name}]", c["tokens_per_s"],
                     "parity verified"))
        rows.append((f"serving_kv_bytes[{name}]", c["kv_bytes"],
                     f"mode={c['mode']}"))
    saved = 100.0 * (1 - paged["kv_bytes"] / contiguous["kv_bytes"])
    rows.append(("serving_kv_bytes_saved_pct", round(saved, 1),
                 f"paged pool undersubscribed; JSON at {JSON_PATH}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
