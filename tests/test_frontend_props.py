"""Property-based lifecycle sweep for the async serving front end.

Hypothesis drives random schedules of arrivals, front-end steps,
cancellations, deadline expiries (via an injected fake clock) and
clock advances against a ``ServingFrontend`` over a paged engine with
an undersubscribed pool, asserting after every operation:

  * the allocator's partition invariant and **exact refcount
    accounting** — every physical page's refcount equals the number of
    session page-lists plus prefix-index entries holding it, so a
    cancelled/expired request can neither leak a page nor free one a
    prefix-sharing sibling still reads;
  * terminal-state bookkeeping: every handle ends in exactly one of
    completed/cancelled/timeout (rejected never gets a handle), and
    ``describe()``'s counts reconcile with ``submitted``;
  * **bit-exactness**: completed streams equal the solo synchronous
    reference of the same prompt; cancelled/expired streams are a
    prefix of it (the front end distributes tokens, it never invents
    or reorders them).

Deterministic lifecycle cases live in ``test_frontend.py``; this module
needs the optional ``hypothesis`` dev dependency and runs in the
multi-device CI matrix.
"""
import asyncio
import collections

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.analysis.contracts import RequestInfeasible
from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import (PagePoolExhausted, QueueFull, Request,
                           ServingEngine, ServingFrontend)
from repro.serving.frontend import _EOS

MAX_NEW = 3


@pytest.fixture(scope="module")
def setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans, {}               # {} = expected-stream cache


def _prompt_pool():
    rng = np.random.default_rng(3)
    stem = [int(t) for t in rng.integers(1, 100, 20)]
    return [
        stem,                                # full stem
        stem[:-1] + [101],                   # shared prefix, diverges
        stem[:9],                            # shorter shared prefix
        [int(t) for t in rng.integers(1, 100, 13)],  # disjoint
        [5, 9],                              # tiny
        [42],                                # single token (no prefill)
    ]


PROMPTS = _prompt_pool()


def _expected(setup, prompt):
    cfg, qp, plans, cache = setup
    key = tuple(prompt)
    if key not in cache:
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                            ops="ref", cache_mode="contiguous")
        req = Request(uid=0, prompt=list(prompt), max_new_tokens=MAX_NEW)
        eng.submit(req)
        eng.run_until_done()
        cache[key] = list(req.out_tokens)
    return cache[key]


def _check_refcounts(eng, sessions):
    eng.kv.allocator.check()
    held = collections.Counter()
    for sess in sessions:
        held.update(sess.pages)
    if eng.prefix is not None:
        for entry in eng.prefix.entries.values():
            held.update(entry.pages)
    for page in range(1, eng.layout.num_pages):
        assert eng.kv.allocator.refcount[page] == held.get(page, 0), \
            f"page {page}: refcount {eng.kv.allocator.refcount[page]} " \
            f"vs holders {held.get(page, 0)}"


@given(
    schedule=st.lists(
        st.tuples(st.sampled_from(["submit", "step", "cancel", "tick"]),
                  st.integers(0, 5)),
        max_size=24),
    num_pages=st.integers(6, 11),
    prefix=st.booleans(),
    deadlines=st.lists(st.sampled_from([None, 2.0, 6.0]), min_size=8,
                       max_size=8),
)
@settings(max_examples=8, deadline=None)
def test_random_lifecycles_are_bit_exact_and_leak_free(
        setup, schedule, num_pages, prefix, deadlines):
    cfg, qp, plans, _ = setup
    t = [0.0]                               # injected fake clock
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", page_size=8, num_pages=num_pages,
                        prefix_cache=prefix)
    fe = ServingFrontend(eng, max_pending=4, clock=lambda: t[0])
    handles = []

    async def step_relieving():
        """One front-end step; transient pool exhaustion under the
        undersubscribed pool is relieved the way an operator would —
        cancel a live request (whose pages the lifecycle reclaims)."""
        try:
            await fe.step()
        except PagePoolExhausted:
            live = [h for h in handles if h.terminal is None
                    and (h.session.pages or h.session.slot is not None)]
            if live:
                live[0].cancel()

    async def drive():
        for op, arg in schedule:
            if op == "submit":
                try:
                    handles.append(
                        fe.submit(list(PROMPTS[arg]), MAX_NEW,
                                  deadline_s=deadlines[
                                      len(handles) % len(deadlines)]))
                except (QueueFull, RequestInfeasible):
                    pass                    # typed backpressure: legal
            elif op == "step":
                await step_relieving()
            elif op == "cancel":
                live = [h for h in handles if h.terminal is None]
                if live:
                    live[arg % len(live)].cancel()
            elif op == "tick":
                t[0] += 1.0 + (arg % 3)     # may expire deadlines
            _check_refcounts(eng, [h.session for h in handles])
        for _ in range(400):                # drain
            await step_relieving()
            if fe._engine_idle():
                fe._apply_lifecycle(t[0])
                if all(h.terminal is not None for h in handles):
                    break
        _check_refcounts(eng, [h.session for h in handles])

    asyncio.run(drive())

    d = fe.describe()
    assert d["pending"] == 0
    assert sum(d["terminal"].values()) == d["submitted"]
    assert d["terminal"]["completed"] + d["terminal"]["cancelled"] \
        + d["terminal"]["timeout"] == len(handles)
    for h in handles:
        want = _expected(setup, h.request.prompt)
        if h.terminal == "completed":
            assert h.tokens == want, h.request.prompt
            assert h.request.done
        else:
            assert h.terminal in ("cancelled", "timeout")
            assert h.tokens == want[: len(h.tokens)], h.request.prompt
        # the stream queue holds exactly the committed tokens + EOS:
        # a consumer attaching late still sees the full stream
        drained = []
        while not h._q.empty():
            drained.append(h._q.get_nowait())
        assert drained[-1] is _EOS and drained[:-1] == h.tokens
