import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (the two lines above MUST precede any jax import).

For every (architecture x input shape) cell this lowers + compiles the
real step function — QAT train step for train shapes, the integer
prefill / decode for serving shapes — against the production mesh
(16x16 single pod, 2x16x16 multi-pod), prints memory_analysis() and
cost_analysis(), and records everything benchmarks/roofline.py needs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k [--multi-pod] [--layers-probe] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import ASSIGNED, get_config
from repro.launch import shardings as shd
from repro.launch import steps as steps_mod
from repro import ops as rops
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.models import model as M
from repro.models.common import SHAPES, ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.quant import plans as qplans

SDS = jax.ShapeDtypeStruct


from repro.launch.cells import cell_supported  # noqa: E402 (re-export)


def _train_variant(cfg, n_groups):
    """Probe variant: n_groups layer groups, UNROLLED (a lax.scan body is
    cost-counted once regardless of trip count, so the probe must unroll
    to expose the per-group delta)."""
    from repro.models.transformer import layer_group_spec
    gl, ng, _ = layer_group_spec(cfg)
    upd = {"num_layers": gl * n_groups, "scan_layers": False}
    if cfg.family == "encdec":
        upd.update(enc_layers=n_groups, dec_layers=n_groups,
                   num_layers=n_groups)
    return dataclasses.replace(cfg, **upd)


def lower_cell(cfg, shape: ShapeConfig, mesh, zero1=None):
    """Returns (lowered, jit_fn, arg_specs) for one cell."""
    zero1 = True if zero1 is None else zero1
    fsdp = cfg.param_count() > 2e10
    with set_mesh(mesh):
        if shape.kind == "train":
            opt_cfg = AdamWConfig(zero1=zero1)
            pspec = M.params_spec(cfg)
            p_sh = shd.param_pspecs(pspec, mesh, fsdp=fsdp)
            accum = 4 if fsdp else 1
            step = steps_mod.make_train_step(cfg, opt_cfg,
                                             param_specs=p_sh,
                                             accum_steps=accum)
            from repro.optim import adamw_init
            ospec = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), pspec)
            o_sh = _opt_pspecs(ospec, p_sh)
            batch = M.input_specs(cfg, shape)
            b_sh = shd.batch_pspecs(batch, mesh)
            from jax.sharding import PartitionSpec as P
            metrics_sh = {"grad_norm": P(), "loss": P(), "ce": P(),
                          "aux": P()}
            fn = jax.jit(
                step,
                in_shardings=shd.as_shardings((p_sh, o_sh, b_sh), mesh),
                out_shardings=shd.as_shardings((p_sh, o_sh, metrics_sh),
                                               mesh),
                donate_argnums=(0, 1))
            lowered = fn.lower(pspec, ospec, batch)
            return lowered
        plans = qplans.build_layer_plans(cfg)
        qspec = M.qparams_spec(cfg, plans)
        q_sh = shd.param_pspecs(qspec, mesh)
        ops = rops.resolve_ops(None, cfg)
        if shape.kind == "prefill":
            step = steps_mod.make_prefill_step(cfg, plans, ops)
            batch = M.input_specs(cfg, shape)
            b_sh = shd.batch_pspecs(batch, mesh)
            args = [qspec, batch]
            shards = [q_sh, b_sh]
            if cfg.pos == "rope":
                rspec = steps_mod.rope_table_spec(cfg, shape.seq_len)
                args.append(rspec)
                shards.append(jax.tree.map(
                    lambda _: jax.sharding.PartitionSpec(), rspec))
            fn = jax.jit(step, in_shardings=shd.as_shardings(
                tuple(shards), mesh))
            return fn.lower(*args)
        # decode
        step = steps_mod.make_decode_step(cfg, plans, shape.seq_len, ops)
        b = shape.global_batch
        with_mem = cfg.family in ("vlm", "encdec")
        cache = _decode_cache_spec(cfg, b, shape.seq_len, with_mem)
        c_sh = shd.cache_pspecs(cache, mesh, cfg)
        batch = M.input_specs(cfg, shape)
        tok, pos = batch["tokens"], batch["pos"]
        tp_sh = shd.batch_pspecs({"tokens": tok, "pos": pos}, mesh)
        args = [qspec, cache, tok, pos]
        shards = [q_sh, c_sh, tp_sh["tokens"], tp_sh["pos"]]
        if cfg.pos == "rope":
            rspec = steps_mod.rope_table_spec(cfg, shape.seq_len)
            args.append(rspec)
            shards.append(jax.tree.map(
                lambda _: jax.sharding.PartitionSpec(), rspec))
        fn = jax.jit(step, in_shardings=shd.as_shardings(tuple(shards),
                                                         mesh),
                     donate_argnums=(1,))
        return fn.lower(*args)


def _decode_cache_spec(cfg, batch, cache_len, with_mem):
    from repro.models import inttransformer as it

    def build():
        mem8 = None
        if with_mem:
            n = cfg.n_img_tokens if cfg.family == "vlm" else 4096
            mem8 = jnp.zeros((batch, n, cfg.d_model), jnp.int8)
        plans = qplans.build_layer_plans(cfg)
        qspec_real = None
        if mem8 is not None:
            # cross K/V need qparams; use zeros-like from spec
            qs = M.qparams_spec(cfg, plans)
            qspec_real = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), qs)
        return it.init_decode_cache(cfg, batch, cache_len, mem8,
                                    qspec_real, plans)
    return jax.eval_shape(build)


def _opt_pspecs(ospec, p_sh):
    """ZeRO-1 moment shardings: the param spec plus 'data' on the first
    still-unsharded divisible dim — optimizer state spreads over the DP
    axis (scalars replicated)."""
    from jax.sharding import PartitionSpec as P

    def zero1(spec, leaf):
        if leaf.ndim == 0:
            return P()
        out = list(spec) + [None] * (leaf.ndim - len(spec))
        flat = [a for s in out if s for a in
                (s if isinstance(s, tuple) else (s,))]
        if "data" in flat:                 # already data-sharded (2-D MoE)
            return P(*out)
        for i, (s, dim) in enumerate(zip(out, leaf.shape)):
            if s is None and dim % 16 == 0 and dim >= 16:
                out[i] = "data"
                break
        return P(*out)

    m_sh = jax.tree.map(zero1, p_sh, ospec.m)
    return type(ospec)(step=P(), m=m_sh, v=m_sh)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             layers_probe: bool = False, tag: str = ""):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_supported(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag}
    if skip:
        rec["skipped"] = skip
        _dump(rec, out_dir)
        print(f"[SKIP] {arch} x {shape_name}: {skip}")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gib": ma.argument_size_in_bytes / 2**30,
            "output_gib": ma.output_size_in_bytes / 2**30,
            "temp_gib": ma.temp_size_in_bytes / 2**30,
            "alias_gib": ma.alias_size_in_bytes / 2**30,
            "peak_gib": (ma.argument_size_in_bytes
                         + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes
                         - ma.alias_size_in_bytes) / 2**30,
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops": ca.get("flops", 0.0),
                       "bytes": ca.get("bytes accessed", 0.0)}
        from benchmarks.roofline import collective_wire_bytes
        wire, by_kind = collective_wire_bytes(compiled.as_text())
        rec["collective_bytes_dev"] = wire
        rec["collective_by_kind"] = by_kind
        print(f"[OK]   {arch} x {shape_name} ({rec['mesh']}): "
              f"peak {rec['memory']['peak_gib']:.2f} GiB/dev, "
              f"flops/dev {rec['cost']['flops']:.3e}, "
              f"coll {wire/2**30:.3f} GiB/dev  "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
        if layers_probe and not multi_pod:
            rec["probe"] = _probe_layers(cfg, shape, mesh)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch} x {shape_name}: {rec['error'][:200]}")
    _dump(rec, out_dir)
    return rec


def _probe_layers(cfg, shape, mesh):
    """Compile 1-group and 2-group UNROLLED variants at reduced batch ->
    per-layer-group flops/bytes for the scan-undercount correction
    (benchmarks/roofline.py).  Flops/bytes scale linearly in batch, so the
    probe batch is shrunk to one sequence per data shard and the report
    rescales by ``batch_scale``."""
    out = {}
    b_probe = min(shape.global_batch, 16)
    out["batch_scale"] = shape.global_batch / b_probe
    out["b_probe"] = b_probe
    batches = [b_probe]
    if shape.global_batch >= 32:
        batches.append(32)        # second point: affine-in-batch fit
    for bp in batches:
        pshape = dataclasses.replace(shape, global_batch=bp)
        for ng in (1, 2):
            c = _train_variant(cfg, ng)
            comp = lower_cell(c, pshape, mesh).compile()
            ca = comp.cost_analysis() or {}
            key = f"ng{ng}" if bp == b_probe else f"ng{ng}b{bp}"
            out[key] = {"flops": ca.get("flops", 0.0),
                        "bytes": ca.get("bytes accessed", 0.0)}
    return out


def _dump(rec, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
    if rec.get("tag"):
        name += f"_{rec['tag']}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--layers-probe", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.all:
        ok = fail = 0
        for arch in ASSIGNED:
            for shape in SHAPES:
                r = run_cell(arch, shape, args.multi_pod, args.out,
                             args.layers_probe, args.tag)
                if "error" in r:
                    fail += 1
                else:
                    ok += 1
        print(f"done: {ok} ok, {fail} failed")
        sys.exit(1 if fail else 0)
    assert args.arch and args.shape
    r = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                 args.layers_probe, args.tag)
    sys.exit(1 if "error" in r else 0)


if __name__ == "__main__":
    main()
