"""REMOVED: the ``repro.kernels.ops`` string-dispatch wrappers.

These wrappers threaded ``backend="ref"|"pallas"`` strings and a loose
bag of requant keywords through every call site.  They were deprecated
(with ``DeprecationWarning``) when the typed operator API landed and are
now gone, one release later, as scheduled.

Use :mod:`repro.ops` instead — a frozen :class:`repro.ops.RequantSpec`
describes the epilogue and the backend registry owns dispatch; see
docs/OPS_API.md for the old-to-new migration table.
"""
raise ImportError(
    "repro.kernels.ops was removed (it warned for one release): use "
    "repro.ops instead — RequantSpec for the requant epilogue and the "
    "backend registry (get_backend/use_backend/OpSet) for dispatch. "
    "Migration table: docs/OPS_API.md."
)
