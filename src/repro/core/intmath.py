"""Integer-only math primitives (SwiftTron §III-F/H/I, after I-BERT [7]).

Everything here operates on int32 jnp arrays with *design-time* scaling
factors (Python floats that never enter the traced graph — only the derived
integer constants do, mirroring "q_{1..8} computed at design time and
provided as constant values to the SwiftTron architecture").

Bit budgets are enforced by static asserts: callers declare the worst-case
|q| of each input and we verify no intermediate can exceed int32.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# INT32_MAX re-exported: sibling modules bound their budgets to
# ``intmath.INT32_MAX`` before the analysis package centralized it
from repro.analysis.budgets import INT32_MAX  # noqa: F401
from repro.analysis.budgets import static_check
from repro.core.dyadic import Dyadic, bits_for, fit_dyadic, rshift_round

# I-BERT second-order polynomial coefficients.
EXP_A, EXP_B, EXP_C = 0.35815147, 1.353, 0.344   # exp(p) ~ a(p+b)^2+c on (-ln2, 0]
ERF_A, ERF_B, ERF_C = -0.2888, -1.769, 1.0       # erf(p) ~ a(p+b)^2+c on [0, -b]
LN2 = math.log(2.0)

# ln(1+e) on e in [0, 1]: design-time least-squares fit (i-softplus extension).
_e = np.linspace(0.0, 1.0, 4097)
LN1P_COEFS = tuple(np.polyfit(_e, np.log1p(_e), 2).tolist())  # (a2, a1, a0)
del _e


def _static_check(val: int, what: str):
    """Design-time bound check — delegates to the central analyzer
    budget (``repro.analysis.budgets``), raising its typed
    ``BitBudgetError`` (a ``ValueError``, message unchanged)."""
    static_check(val, what)


def int_bit_length(n):
    """Vectorised bit length of non-negative int32 ``n`` (integer-only)."""
    b = jnp.zeros_like(n)
    v = n
    for s in (16, 8, 4, 2, 1):
        t = v >> s
        go = t > 0
        b = jnp.where(go, b + s, b)
        v = jnp.where(go, t, v)
    return b + (v > 0).astype(n.dtype)


def i_sqrt(n, iters: int = 16):
    """Integer sqrt via the paper's §III-I Babylonian recursion.

    The ASIC early-exits when x_{i+1} >= x_i (Valid/z flags); on TPU a
    data-dependent trip count is hostile to SIMD, so we run a fixed
    ``iters`` (= the paper's own worst-case accounting, §IV-B fn.3) and
    clamp.  Exact floor(sqrt(n)) for all 0 <= n <= 2^31-1.
    """
    n = n.astype(jnp.int32)
    bl = int_bit_length(n)
    x0 = jnp.left_shift(jnp.int32(1), (bl + 1) >> 1)  # 2^ceil(bits/2) >= sqrt(n)
    x0 = jnp.maximum(x0, 1)

    def body(_, x):
        nx = (x + n // x) >> 1
        # monotone envelope: once below true sqrt it oscillates by <=1
        return jnp.minimum(x, jnp.maximum(nx, 1))

    x = jax.lax.fori_loop(0, iters, body, x0)
    x = jnp.minimum(x, 46340)  # floor(sqrt(2^31-1)); keeps x*x in int32
    for _ in range(2):         # final correction (floor-div oscillation)
        x = jnp.where(x * x > n, x - 1, x)
    # increment guard: (x+1)^2 would overflow int32 at x == 46340
    x = jnp.where((x < 46340) & ((x + 1) * (x + 1) <= n), x + 1, x)
    return jnp.where(n <= 0, 0, x)


class IExpPlan(NamedTuple):
    """Design-time constants for i-exp at a fixed input scale."""
    s_in: float
    s_out: float
    q_ln2: int
    q_b: int
    q_c: int
    z_max: int

    @property
    def q_one(self) -> int:
        """Integer representing 1.0 at the output scale (= exp(0))."""
        return int(round(1.0 / self.s_out))


def make_iexp(s_in: float, z_max: int = 30) -> IExpPlan:
    q_ln2 = int(math.floor(LN2 / s_in))
    if q_ln2 < 16:
        raise ValueError(f"i-exp input scale too coarse: {s_in}")
    q_b = int(math.floor(EXP_B / s_in))
    s_out = EXP_A * s_in * s_in
    q_c = int(math.floor(EXP_C / s_out))
    _static_check(q_b * q_b + q_c, "i-exp polynomial")
    _static_check(z_max * q_ln2, "i-exp range clip")
    return IExpPlan(s_in, s_out, q_ln2, q_b, q_c, z_max)


def i_exp(q, plan: IExpPlan):
    """exp(x) for x = q * s_in <= 0.  Returns int32 at scale ``plan.s_out``.

    Decomposition (paper Fig. 12): x = p - z*ln2, p in (-ln2, 0];
    exp(x) = exp(p) >> z with exp(p) ~ a(p+b)^2 + c.
    """
    q = jnp.minimum(q, 0)
    qn = jnp.maximum(q, jnp.int32(-plan.z_max * plan.q_ln2))
    z = (-qn) // jnp.int32(plan.q_ln2)
    q_p = qn + z * jnp.int32(plan.q_ln2)            # in (-q_ln2, 0]
    t = q_p + jnp.int32(plan.q_b)
    q_l = t * t + jnp.int32(plan.q_c)
    return jax.lax.shift_right_arithmetic(q_l, z)   # exp(p) * 2^-z


class IErfPlan(NamedTuple):
    s_in: float
    s_out: float
    q_clip: int
    q_bneg: int
    q_c: int


def make_ierf(s_in: float) -> IErfPlan:
    q_clip = int(math.floor(-ERF_B / s_in))
    q_bneg = int(math.floor(ERF_B / s_in))
    s_poly = ERF_A * s_in * s_in                    # negative
    q_c = int(math.floor(ERF_C / s_poly))           # negative
    _static_check(q_clip * q_clip + abs(q_c), "i-erf polynomial")
    return IErfPlan(s_in, -s_poly, q_clip, q_bneg, q_c)


def i_erf(q, plan: IErfPlan):
    """erf(x) for x = q * s_in.  Returns int32 at scale ``plan.s_out`` (>0)."""
    sgn = jnp.sign(q).astype(jnp.int32)
    q_abs = jnp.minimum(jnp.abs(q), jnp.int32(plan.q_clip))
    t = q_abs + jnp.int32(plan.q_bneg)              # in [q_bneg, 0]
    bracket = t * t + jnp.int32(plan.q_c)           # <= 0
    return sgn * (-bracket)


class IGeluPlan(NamedTuple):
    s_in: float
    s_out: float
    erf: IErfPlan
    q_one: int
    qmax_in: int


def make_igelu(s_in: float, qmax_in: int) -> IGeluPlan:
    erf = make_ierf(s_in / math.sqrt(2.0))
    q_one = int(math.floor(1.0 / erf.s_out))
    _static_check(qmax_in * (2 * q_one), "i-gelu product")
    s_out = s_in * erf.s_out / 2.0
    return IGeluPlan(s_in, s_out, erf, q_one, qmax_in)


def i_gelu(q, plan: IGeluPlan):
    """GELU(x) = x * 0.5 * (1 + erf(x/sqrt(2))) — paper §III-H / Fig. 14."""
    q_erf = i_erf(q, plan.erf)
    return q * (q_erf + jnp.int32(plan.q_one))


class IPoly2Plan(NamedTuple):
    d2: Dyadic
    d1: Dyadic
    sign1: int
    c0: int
    s0: int


def make_ipoly2(coeffs: Tuple[float, float, float], s_in: float,
                s_out: float, qmax_in: int) -> IPoly2Plan:
    """Generic integer 2nd-order polynomial a2 x^2 + a1 x + a0 evaluated at
    x = q*s_in, emitted at scale s_out (used for i-ln1p)."""
    a2, a1, a0 = coeffs
    s0 = max(0, bits_for(qmax_in) - 15)
    q_sq_max = (qmax_in >> s0) ** 2
    d2 = fit_dyadic(abs(a2) * (s_in * (1 << s0)) ** 2 / s_out, q_sq_max) \
        if a2 != 0 else None
    d1 = fit_dyadic(abs(a1) * s_in / s_out, qmax_in) if a1 != 0 else None
    c0 = int(round(a0 / s_out))
    return IPoly2Plan(d2, d1, 1 if a1 >= 0 else -1, c0, s0)


def i_poly2(q, plan: IPoly2Plan, a2_sign: int = 1):
    qs = rshift_round(q, plan.s0)
    out = jnp.full_like(q, plan.c0)
    if plan.d2 is not None:
        out = out + a2_sign * plan.d2(qs * qs)
    if plan.d1 is not None:
        out = out + plan.sign1 * plan.d1(q)
    return out


class ILn1pPlan(NamedTuple):
    poly: IPoly2Plan
    a2_sign: int
    s_in: float
    s_out: float


def make_iln1p(s_in: float, s_out: float, qmax_in: int) -> ILn1pPlan:
    a2, a1, a0 = LN1P_COEFS
    poly = make_ipoly2((a2, a1, a0), s_in, s_out, qmax_in)
    return ILn1pPlan(poly, 1 if a2 >= 0 else -1, s_in, s_out)


def i_ln1p(q, plan: ILn1pPlan):
    """ln(1+e) for e = q*s_in in [0, 1]."""
    q = jnp.clip(q, 0, int(round(1.0 / plan.s_in)))
    return i_poly2(q, plan.poly, plan.a2_sign)
