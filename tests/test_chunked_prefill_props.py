"""Property-based schedule sweep for the chunked-prefill scheduler.

Hypothesis drives random submit/step/preempt/evict interleavings —
prompts drawn from a small pool of shared-prefix stems, engines spanning
pool sizes, chunk sizes, budgets and prefix-cache on/off — against the
full ``ServingEngine`` and asserts, after every operation:

  * the allocator's partition invariant (``check()``);
  * exact refcount accounting: every page's refcount equals the number
    of session page-lists plus prefix-index entries holding it;
  * greedy determinism: each retired request's token stream equals the
    solo reference run of the same prompt (batch independence + chunked
    prefill + prefix sharing + copy-on-write must not change a single
    token); partially-generated (evicted) requests match a prefix.

Pool exhaustion mid-schedule is legal under pressure: the sweep evicts
a random live session and carries on.  Deterministic edge cases live in
``test_chunked_prefill.py``; this module needs the optional
``hypothesis`` dev dependency.
"""
import collections

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import PagePoolExhausted, Request, ServingEngine

MAX_NEW = 3


@pytest.fixture(scope="module")
def setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans, {}               # {} = expected-stream cache


def _prompt_pool():
    rng = np.random.default_rng(3)
    stem = list(map(int, rng.integers(1, 100, 20)))
    return [
        stem,                                # full stem
        stem[:-1] + [101],                   # shared prefix, diverges
        stem[:9],                            # shorter shared prefix
        list(map(int, rng.integers(1, 100, 13))),   # disjoint
        [5, 9],                              # tiny
        [42],                                # single token (no prefill)
    ]


PROMPTS = _prompt_pool()


def _expected(setup, prompt):
    """Solo greedy reference for one prompt (contiguous, streaming, no
    sharing) — memoized across hypothesis examples."""
    cfg, qp, plans, cache = setup
    key = tuple(prompt)
    if key not in cache:
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                            ops="ref", cache_mode="contiguous")
        req = Request(uid=0, prompt=list(prompt), max_new_tokens=MAX_NEW)
        eng.submit(req)
        eng.run_until_done()
        cache[key] = list(req.out_tokens)
    return cache[key]


def _check_refcounts(eng, sessions):
    eng.kv.allocator.check()
    held = collections.Counter()
    for sess in sessions:
        held.update(sess.pages)
    if eng.prefix is not None:
        for entry in eng.prefix.entries.values():
            held.update(entry.pages)
    for page in range(1, eng.layout.num_pages):
        assert eng.kv.allocator.refcount[page] == held.get(page, 0), \
            f"page {page}: refcount {eng.kv.allocator.refcount[page]} " \
            f"vs holders {held.get(page, 0)}"


@given(
    schedule=st.lists(
        st.tuples(st.sampled_from(["submit", "step", "preempt", "evict"]),
                  st.integers(0, 5)),
        max_size=24),
    num_pages=st.integers(5, 11),
    chunk=st.sampled_from([0, 8, 16, 32]),
    budget=st.sampled_from([None, 4, 16]),
    prefix=st.booleans(),
)
@settings(max_examples=8, deadline=None)
def test_random_schedules_are_bit_exact_and_leak_free(
        setup, schedule, num_pages, chunk, budget, prefix):
    cfg, qp, plans, _ = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", page_size=8, num_pages=num_pages,
                        prefill_chunk=chunk, prefill_budget=budget,
                        prefix_cache=prefix)
    requests, sessions = [], []
    uid = 0

    def relieve():
        live = [s for s in sessions
                if s.state in ("prefilling", "active", "preempted")]
        if live:
            eng.evict(live[0])

    for op, arg in schedule:
        try:
            if op == "submit":
                req = Request(uid=uid, prompt=list(PROMPTS[arg]),
                              max_new_tokens=MAX_NEW)
                uid += 1
                requests.append(req)
                sessions.append(eng.submit(req))
            elif op == "step":
                eng.step()
            elif op == "preempt":
                live = [s for s in sessions
                        if s.state in ("active", "prefilling")]
                if live:
                    eng.preempt(live[arg % len(live)])
            elif op == "evict":
                live = [s for s in sessions if s.state not in ("done",)]
                live = [s for s in live
                        if s.pages or s in eng.queue or s.slot is not None]
                if live:
                    eng.evict(live[arg % len(live)])
        except PagePoolExhausted:
            relieve()                        # legal under pool pressure
        _check_refcounts(eng, sessions)

    for _ in range(400):                     # drain, relieving pressure
        if not eng.queue and all(s is None for s in eng.slots):
            break
        try:
            eng.step()
        except PagePoolExhausted:
            relieve()
    _check_refcounts(eng, sessions)

    for req in requests:
        want = _expected(setup, req.prompt)
        if req.done:
            assert req.out_tokens == want, req.prompt
        else:
            assert req.out_tokens == want[:len(req.out_tokens)], req.prompt
