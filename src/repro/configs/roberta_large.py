"""RoBERTa-large — paper Table II row 2: 24-layer post-LN encoder."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="roberta-large", family="encoder", num_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=50265, head_dim=64,
    activation="gelu", norm="layernorm", post_norm=True, pos="learned",
)
