"""End-to-end system behaviour: the full SwiftTron flow (paper Fig. 17)
float train -> calibrate/convert -> integer serve, plus cell accounting."""

import jax
import numpy as np

from repro.configs.registry import ASSIGNED, get_config
from repro.launch.cells import cell_supported
from repro.models import inttransformer as it
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert


def test_full_flow_dense():
    cfg = M.reduce_config(get_config("granite-3-2b"), dtype="float32")
    params = tf.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 24), 0,
                                          cfg.vocab)}
    qp, plans = convert.quantize_params(params, cfg)
    logits = it.int_prefill(qp, batch, plans, cfg)
    assert logits.shape == (2, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits)).all()


def test_cell_matrix_accounting():
    """All 40 assigned cells are either runnable or documented skips."""
    from repro.models.common import SHAPES
    runnable, skipped = 0, 0
    for arch in ASSIGNED:
        for shape in SHAPES:
            if cell_supported(arch, shape):
                skipped += 1
            else:
                runnable += 1
    assert runnable + skipped == 40
    assert skipped == 7          # 7 documented long_500k skips


def test_kernel_backend_flag():
    """Models run with the Pallas kernel backend (interpret mode on CPU)."""
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          kernel_backend="pallas")
    params = tf.init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (1, 16), 0,
                                          cfg.vocab)}
    qp, plans = convert.quantize_params(params, cfg)
    ref_logits = it.int_prefill(qp, batch, plans, cfg, ops="ref")
    pl_logits = it.int_prefill(qp, batch, plans, cfg, ops="pallas")
    corr = np.corrcoef(np.asarray(ref_logits).ravel(),
                       np.asarray(pl_logits).ravel())[0, 1]
    # fused online-softmax attention differs from the two-pass ref by
    # +-2 int8 LSB per layer (see test_fused_attention_kernel)
    assert corr > 0.99
