"""Learning-rate schedules (scalar jnp functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float = 1.0):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_schedule(total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return final_frac + (1 - final_frac) * cos
    return fn


def linear_warmup_cosine(warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(max(total_steps - warmup, 1), final_frac)

    def fn(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return fn
