"""Regenerate the data-driven sections of EXPERIMENTS.md from
experiments/dryrun/*.json (run after every sweep / hillclimb iteration)."""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.report import full_table, render_markdown
from repro.configs.registry import ASSIGNED
from repro.models.common import SHAPES

DIR = "experiments/dryrun"


def dryrun_section() -> str:
    rows = ["| arch | shape | mesh | peak GiB/dev | flops/dev (HLO) | "
            "coll GiB/dev | compile s |",
            "|---|---|---|---|---|---|---|"]
    for arch in ASSIGNED:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                p = os.path.join(DIR, f"{arch}_{shape}_{mesh}.json")
                if not os.path.exists(p):
                    rows.append(f"| {arch} | {shape} | {mesh} | MISSING | "
                                "| | |")
                    continue
                r = json.load(open(p))
                if "skipped" in r:
                    rows.append(f"| {arch} | {shape} | {mesh} | — | — | — "
                                f"| SKIP ({r['skipped'][:46]}) |")
                    continue
                if "error" in r:
                    rows.append(f"| {arch} | {shape} | {mesh} | FAIL | | "
                                f"| {r['error'][:60]} |")
                    continue
                rows.append(
                    f"| {arch} | {shape} | {mesh} | "
                    f"{r['memory']['peak_gib']:.2f} | "
                    f"{r['cost']['flops']:.2e} | "
                    f"{r['collective_bytes_dev'] / 2**30:.2f} | "
                    f"{r.get('compile_s', 0)} |")
    return "\n".join(rows)


def summary_counts():
    ok = fail = skip = 0
    over = []
    for f in glob.glob(os.path.join(DIR, "*.json")):
        r = json.load(open(f))
        if "skipped" in r:
            skip += 1
        elif "error" in r:
            fail += 1
        else:
            ok += 1
            if r["memory"]["peak_gib"] > 16.0:
                over.append((r["arch"], r["shape"], r["mesh"],
                             r["memory"]["peak_gib"]))
    return ok, fail, skip, sorted(over, key=lambda t: -t[3])


def write_tables():
    os.makedirs("experiments", exist_ok=True)
    ok, fail, skip, over = summary_counts()
    with open("experiments/roofline_table.md", "w") as f:
        f.write("# Roofline table (single-pod 16x16, per device)\n\n")
        f.write(f"cells: {ok} ok / {fail} fail / {skip} skip "
                "(both meshes)\n\n")
        f.write(render_markdown(full_table()))
        f.write("\n\n# Dry-run records (both meshes)\n\n")
        f.write(dryrun_section())
        f.write("\n\nover 16 GiB/chip:\n")
        for a, s, m, g in over:
            f.write(f"* {a} {s} {m}: {g:.1f} GiB\n")
    print("wrote experiments/roofline_table.md")


if __name__ == "__main__":
    if "--write" in sys.argv:
        write_tables()
        sys.exit(0)
    ok, fail, skip, over = summary_counts()
    print(f"cells: {ok} ok / {fail} fail / {skip} skip")
    print("over 16 GiB:", *[f"\n  {a} {s} {m}: {g:.1f}" for a, s, m, g
                            in over])
