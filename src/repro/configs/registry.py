"""--arch <id> registry: the 10 assigned architectures + the paper's own
evaluation models (RoBERTa-base/large, DeiT-S)."""
from repro.configs import (codeqwen1_5_7b, deit_s, granite_3_2b,
                           h2o_danube_3_4b, jamba_v0_1_52b, llama3_8b,
                           llama3_2_vision_90b, mamba2_130m,
                           qwen2_moe_a2_7b, qwen3_moe_235b_a22b,
                           roberta_base, roberta_large,
                           seamless_m4t_large_v2)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    h2o_danube_3_4b, llama3_8b, codeqwen1_5_7b, granite_3_2b,
    seamless_m4t_large_v2, llama3_2_vision_90b, qwen3_moe_235b_a22b,
    qwen2_moe_a2_7b, mamba2_130m, jamba_v0_1_52b,
    roberta_base, roberta_large, deit_s,
)}

ASSIGNED = [
    "h2o-danube-3-4b", "llama3-8b", "codeqwen1.5-7b", "granite-3-2b",
    "seamless-m4t-large-v2", "llama-3.2-vision-90b", "qwen3-moe-235b-a22b",
    "qwen2-moe-a2.7b", "mamba2-130m", "jamba-v0.1-52b",
]

# long_500k applicability (DESIGN.md §6): sub-quadratic archs only
LONG_OK = {"h2o-danube-3-4b", "mamba2-130m", "jamba-v0.1-52b"}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}")
    return ARCHS[name]
