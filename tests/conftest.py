import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets its own flag).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
