"""Design-time quantization plans (SwiftTron §III-A "Scaling Factor Design").

A *plan* is the frozen set of integer constants one layer kind needs:
dyadic requant pairs, i-exp/i-erf polynomial constants, reciprocal widths.
Plans are plain NamedTuples of Python ints/floats — they are **static**
(closed over by the traced functions, appearing as scalar constants in the
lowered HLO), exactly like the ASIC's design-time q_{1..8} registers.

Activation scales are shared across layers of the same kind (DESIGN.md §4)
so stacked-parameter ``lax.scan`` layers stay homogeneous; per-channel
weight scales live in the quantized parameter pytree as int32 multiplier
vectors with a plan-level shared shift.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.core import activations as iact
from repro.core import attention as iattn
from repro.core import intmath, norms
from repro.core import softmax as ism
from repro.core.dyadic import Dyadic, fit_dyadic
from repro.models.common import ArchConfig


class LinearPlan(NamedTuple):
    """INT8 matmul + per-channel dyadic requant epilogue."""
    s_in: float
    s_out: float            # 0.0 -> keep int32 accumulator (no requant)
    out_bits: int
    c: int                  # shared shift for the per-channel multipliers
    pre: int
    k_dim: int              # contraction size (accumulator bound)

    @property
    def acc_qmax(self) -> int:
        return self.k_dim * 127 * 127


def make_linear_plan(s_in: float, s_w_max: float, s_out: float, k_dim: int,
                     out_bits: int = 8) -> LinearPlan:
    """Size the shared (c, pre) for the worst-case channel ratio."""
    acc_qmax = k_dim * 127 * 127
    if s_out == 0.0:
        return LinearPlan(s_in, 0.0, 32, 0, 0, k_dim)
    ratio_max = s_in * s_w_max / s_out
    dn = fit_dyadic(ratio_max, acc_qmax)
    return LinearPlan(s_in, s_out, out_bits, dn.c, dn.pre, k_dim)


def perchannel_multipliers(plan: LinearPlan, s_w: np.ndarray) -> np.ndarray:
    """int32 multiplier per out-channel for the plan's shared (c, pre)."""
    ratios = plan.s_in * np.asarray(s_w, np.float64) / plan.s_out
    b = np.round(ratios * (1 << plan.c)).astype(np.int64)
    assert (b >= 0).all() and (b < 2 ** 31).all()
    return b.astype(np.int32)


class AttnPlan(NamedTuple):
    qkv: LinearPlan
    attn: iattn.IAttnPlan
    out: LinearPlan          # o-proj: s_act8 -> s_res


class FfnPlan(NamedTuple):
    up: LinearPlan           # w1 (and w3): s_act8 -> s_act10
    act_gelu: Optional[iact.IGeluActPlan]
    act_silu: Optional[iact.ISiluPlan]
    dn_gate: Optional[Dyadic]   # silu(h1)*h3 product -> s_act8
    down: LinearPlan         # w2: s_act8 -> s_res


class MoePlan(NamedTuple):
    router: LinearPlan       # s_act8 -> int32 logits
    gate_sm: ism.ISoftmaxPlan
    expert: FfnPlan
    dn_combine: Dyadic       # sum_k gate*y (s_act8 * 2^-7) -> s_res
    shared: Optional[FfnPlan]


class NormPlan(NamedTuple):
    plan: norms.INormPlan    # s_res int32 -> s_act8 int8


class EmbedPlan(NamedTuple):
    s_emb: float             # int8 embedding table scale
    dn_res: Dyadic           # s_emb -> s_res


class HeadPlan(NamedTuple):
    s_in: float              # logits stay int32 at s_in * s_w (dequant host-side)


class MambaPlan(NamedTuple):
    in_proj: LinearPlan      # s_act8 -> s_act8 (z,x,B,C) ; dt handled below
    dn_dt_in: Dyadic         # accumulator -> s_dt_in (10 bit)
    s_dt_in: float
    softplus: iact.ISoftplusPlan     # -> s_dt
    s_dt: float
    s_A: float
    dn_dtA: Dyadic                   # (s_dt * s_A) -> 2^-14 i-exp grid
    iexp_decay: intmath.IExpPlan     # at 2^-14
    dn_decay16: Dyadic
    dn_h: Dyadic             # dt*B*x contribution -> s_h
    s_h: float
    qmax_h: int
    dn_h8: Dyadic            # h -> int8 at s_h8
    s_h8: float
    dn_y: Dyadic             # C*h8 acc -> s_act8
    silu_z: iact.ISiluPlan
    dn_z10: Dyadic           # z (int8, s_act8) -> 10-bit grid for i-exp
    dn_gate: Dyadic          # y * sig16 -> s_act8
    norm: norms.INormPlan
    out_proj: LinearPlan
    dn_conv: Dyadic          # conv acc (s8 * s_conv) -> conv grid (+-32)
    silu_conv: iact.ISiluPlan    # conv activation -> s_xbc
    s_xbc: float             # x/B/C grid after conv+silu (wider than s8)


class LayerPlans(NamedTuple):
    """Everything the integer path of one architecture needs."""
    cfg_name: str
    embed: EmbedPlan
    norm: norms.INormPlan
    attn: Optional[AttnPlan]
    ffn: Optional[FfnPlan]
    moe: Optional[MoePlan]
    mamba: Optional[MambaPlan]
    cross: Optional[AttnPlan]
    head: HeadPlan
    final_norm: norms.INormPlan


S_W8 = 2.0 / 127.0          # nominal per-channel weight scale bound


def _ffn_plan(cfg: ArchConfig, d_in: int, d_ff: int) -> FfnPlan:
    s8, s10 = cfg.s_act8, cfg.s_act10
    up = make_linear_plan(s8, S_W8, s10, d_in, out_bits=11)
    if cfg.activation == "swiglu":
        silu = iact.make_isilu(s10, 1024, s_out=s8)
        # gate: silu_out(int8, s8) * h3(10bit, s10) -> requant to s8
        dn_gate = fit_dyadic(s8 * s10 / s8, 127 * 1024)
        gelu = None
    else:
        gelu = iact.make_igelu_act(s10, 1024, s_out=s8)
        silu, dn_gate = None, None
    down = make_linear_plan(s8, S_W8, cfg.s_res, d_ff, out_bits=14)
    return FfnPlan(up, gelu, silu, dn_gate, down)


def build_layer_plans(cfg: ArchConfig, calib: Optional[dict] = None
                      ) -> LayerPlans:
    """``calib``: measured per-tensor scales from quant.convert — keys
    s_emb / s_router / s_conv / s_dtw (defaults are the design nominals)."""
    calib = dict(calib or {})
    s8 = cfg.s_act8
    d = cfg.d_model
    norm_plan = norms.make_inorm(d, cfg.s_res, cfg.qmax_res,
                                 s_gamma=2.0 / 127.0, s_out=s8,
                                 subtract_mean=(cfg.norm == "layernorm"))
    s_emb = calib.get("s_emb", s8)
    embed = EmbedPlan(s_emb, fit_dyadic(s_emb / cfg.s_res, 127))

    attn = cross = None
    if cfg.family in ("dense", "encdec", "vlm", "moe", "hybrid", "encoder"):
        qkv = make_linear_plan(s8, S_W8, s8, d)
        ia = iattn.make_iattention(cfg.hd, s8, s8, s8, s8)
        out = make_linear_plan(s8, S_W8, cfg.s_res,
                               cfg.n_heads * cfg.hd, out_bits=14)
        attn = AttnPlan(qkv, ia, out)
        if cfg.family in ("encdec", "vlm"):
            cross = attn

    ffn = moe = None
    if cfg.n_experts > 0:
        router = make_linear_plan(s8, S_W8, 0.0, d)
        # router logits int32 at s8 * s_router (per-tensor router weights)
        s_router = calib.get("s_router", S_W8)
        gate_sm = ism.make_isoftmax(s8 * s_router, router.acc_qmax)
        f = cfg.moe_d_ff or cfg.d_ff
        expert = _ffn_plan(cfg, d, f)
        dn_combine = fit_dyadic(s8 * ism.S_PROB / cfg.s_res,
                                cfg.top_k * 127 * 127)
        shared = _ffn_plan(cfg, d, f * cfg.n_shared_experts) \
            if cfg.n_shared_experts else None
        moe = MoePlan(router, gate_sm, expert, dn_combine, shared)
    if cfg.family != "ssm" and not (cfg.n_experts and cfg.moe_every == 1):
        ffn = _ffn_plan(cfg, d, cfg.d_ff)

    mamba = None
    if cfg.family in ("ssm", "hybrid"):
        mamba = _mamba_plan(cfg, calib)

    head = HeadPlan(s8)
    return LayerPlans(cfg.name, embed, norm_plan, attn, ffn, moe, mamba,
                      cross, head, norm_plan)


def _mamba_plan(cfg: ArchConfig, calib: Optional[dict] = None) -> MambaPlan:
    calib = dict(calib or {})
    s8, s10 = cfg.s_act8, cfg.s_act10
    d = cfg.d_model
    in_proj = make_linear_plan(s8, S_W8, s8, d)
    acc_q = in_proj.acc_qmax
    s_dt_in = 16.0 / 1024.0
    s_dtw = calib.get("s_dtw", S_W8)
    dn_dt_in = fit_dyadic(s8 * s_dtw / s_dt_in, acc_q)
    # Δt grid: fine resolution over [0, 2] (typical trained Δt is 1e-3..1;
    # i_softplus clips at out_bits=13 -> saturation at 8191*s_dt = 2.0)
    s_dt = 1.0 / (1 << 12)
    softplus = iact.make_isoftplus(s_dt_in, 1024, s_out=s_dt)
    s_A = 16.0 / 1024.0
    # bring dt*A onto the shared 2^-14 i-exp grid (its own scale is too
    # fine for representable polynomial constants)
    qmax_dtA = (1 << 13) * 1024
    dn_dtA = fit_dyadic(s_dt * s_A / 2.0 ** -14, qmax_dtA)
    iexp_decay = intmath.make_iexp(2.0 ** -14)
    dn_decay16 = fit_dyadic(iexp_decay.s_out / 2.0 ** -15,
                            iexp_decay.q_one + 1)
    # SSD state: typical |h| is O(1) (geometric sum ~ B*x/A); keep 2^-16
    # resolution with saturation at +-32 (qmax 2^21)
    s_h = 2.0 ** -16
    qmax_h = 1 << 27          # +-2048 head-state range before saturation
    # contribution dt * B * x: scale s_dt * s8 * s8, |q| <= 2^13*127*127
    dn_h = fit_dyadic(s_dt * s8 * s8 / s_h, (1 << 13) * 127 * 127)
    s_h8 = 4.0 / 127.0
    dn_h8 = fit_dyadic(s_h / s_h8, qmax_h)
    # y = C * h8 over ssm_state: acc <= N*127*127, scale s8*s_h8 -> s8
    dn_y = fit_dyadic(s_h8, cfg.ssm_state * 127 * 127)
    silu_z = iact.make_isilu(s10, 1024, s_out=s8)   # gate on the 10-bit grid
    dn_z10 = fit_dyadic(s8 / s10, 127)
    dn_gate = fit_dyadic(2.0 ** -15, 127 << 15)     # (unused on the BFP path)
    # pre-norm y is unnormalised by construction (mamba2 applies RMSNorm
    # exactly because y = C*h grows); the integer path feeds the norm a
    # per-row dynamic block-floating-point value at <=12 bits — RMSNorm is
    # scale-invariant so the row shift cancels exactly.
    norm = norms.make_inorm(cfg.ssm_d_inner, 1.0, 1 << 11,
                            s_gamma=2.0 / 127.0, s_out=s8,
                            subtract_mean=False)
    out_proj = make_linear_plan(s8, S_W8, cfg.s_res, cfg.ssm_d_inner,
                                out_bits=14)
    s_conv = calib.get("s_conv", S_W8)
    # conv+silu outputs (x/B/C) have a wider dynamic range than the s8
    # grid: accumulate at +-32 (10-bit) and emit int8 on a +-16 grid
    s_conv_grid = 32.0 / 1024.0
    s_xbc = 16.0 / 127.0
    dn_conv = fit_dyadic(s8 * s_conv / s_conv_grid,
                         cfg.ssm_conv * 127 * 127)
    silu_conv = iact.make_isilu(s_conv_grid, 1024, s_out=s_xbc)
    # refit the state-path dyadics for the s_xbc operand grid
    dn_h = fit_dyadic(s_dt * s_xbc * s_xbc / s_h, (1 << 13) * 127 * 127)
    dn_y = fit_dyadic(s_xbc * s_h8 / s8, cfg.ssm_state * 127 * 127)
    return MambaPlan(in_proj, dn_dt_in, s_dt_in, softplus, s_dt, s_A,
                     dn_dtA, iexp_decay, dn_decay16, dn_h, s_h, qmax_h,
                     dn_h8, s_h8, dn_y, silu_z, dn_z10, dn_gate, norm,
                     out_proj, dn_conv, silu_conv, s_xbc)
