"""Tensor-parallel sharding of the serving engine over a device mesh.

The software analogue of "more PEs" on the SwiftTron array: the paged
serving engine partitions its attention datapath along the **head axis**
across a 1-D ``("tp",)`` mesh — each device owns ``Hkv/tp`` KV heads
(and the matching ``H/tp`` query heads) of *every* physical page:

  * ``wq``/``wk``/``wv`` weights shard by output column (head-major
    layout from ``quant.convert._q_attn``: columns ``[d·N/tp, (d+1)·N/tp)``
    are exactly device ``d``'s head slice), together with their
    per-channel ``b_mult`` / ``bias32`` vectors;
  * ``wo`` shards by *row* (its K dim is the flattened head axis); each
    device computes a raw int32 partial o-projection which
    :func:`repro.distributed.collectives.psum_int32` combines exactly,
    and the per-channel requant epilogue runs **once, after** the
    all-reduce — so it rounds on the same accumulator a single device
    would have produced (the requant-rounds-once rule);
  * the K/V pools shard on their ``Hkv`` axis (axis 3 of both the paged
    ``(ng, num_pages, page_size, Hkv, hd)`` and contiguous
    ``(ng, B, L, Hkv, hd)`` layouts) — page *ids* are device-agnostic,
    so the allocator, page table, prefix index and scheduler stay
    replicated host-side and CoW / preempt / evict logic is untouched.

Everything that is not attention (embedding, norms, FFN/MoE, logits)
runs replicated in lock-step: its inputs are identical on every device
after the exact psum, so its outputs are too — bit-exact by
construction, no further collectives.

GQA stays aligned under the shard: ``H/tp = q_group · Hkv/tp``, so a
device's local query head ``j`` maps to its local KV head
``j // q_group`` exactly as in the global layout.

Speculative decoding composes transparently: the engine's batched
verify launch widens the query axis to ``Sq = spec_k + 1`` rows per
lane, and ``Sq`` — like batch — is a *replicated* dimension under this
mesh (only the head axes shard).  The same per-head pspecs serve both
the ``Sq = 1`` decode step and the verify step, psum'd partial
o-projections included, so sharded spec streams are bit-exact against
single-device spec streams and against ``spec_k = 0``.
"""
from __future__ import annotations

import dataclasses

from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig
from repro.models.transformer import layer_group_spec
from repro.ops import OP_NAMES
from repro.ops.spec import QuantLinearParams

#: the serving tensor-parallel mesh axis.  Deliberately NOT one of the
#: logical-rule axes in ``distributed.sharding.LOGICAL_RULES`` ("data" /
#: "model") — the model layers' ``shard()`` constraints can never bind
#: to it (and they no-op inside shard_map bodies anyway).
TP_AXIS = "tp"


def shard_map_fn():
    """The shard_map entry point, version-compatible: ``jax.shard_map``
    on new releases, ``jax.experimental.shard_map.shard_map`` on 0.4.x."""
    import jax
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    return shard_map


def tp_arch_supported(cfg: ArchConfig) -> bool:
    """Whether the head-sharded serving step serves this arch: every
    sublayer must be plain self-attention (+ dense FFN or MoE — both run
    replicated).  SSM state and cross-attention memory are lane-indexed,
    not head-shaped, so those archs keep single-device serving."""
    _, _, kinds = layer_group_spec(cfg)
    return all(mix == "attn" and not has_cross
               for (mix, ff, has_cross) in kinds)


def validate_tp(cfg: ArchConfig, tp: int) -> None:
    """Typed validation of a tensor-parallel degree (engine / CLI
    boundary — fail here, not as a kernel-shape error inside a launch).
    Device availability is checked separately (the exact single-device
    gather lowering needs no devices at all)."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp == 1:
        return
    hkv = cfg.n_kv_heads
    if hkv == 0 or hkv % tp:
        raise ValueError(
            f"tp={tp} must divide the KV head count (n_kv_heads={hkv}): "
            "each device owns Hkv/tp heads of every page")
    # tp | Hkv implies tp | H (H = q_group * Hkv), asserted for clarity
    assert cfg.n_heads % tp == 0
    if not tp_arch_supported(cfg):
        raise ValueError(
            f"tp={tp} is unsupported for arch {cfg.name!r}: tensor-"
            "parallel serving shards attention heads, but SSM / cross-"
            "attention sublayers carry lane-indexed state that has no "
            "head axis; serve this arch with tp=1")


def backends_support_tp(ops) -> bool:
    """Capability negotiation (the PR 4-5 story): every backend in the
    OpSet must advertise ``tp_serving`` for the sharded step to trace
    its ops under shard_map.  A single non-advertising backend drops the
    engine to the exact single-device gather lowering."""
    return all(getattr(ops.backend_for(op), "tp_serving", False)
               for op in OP_NAMES)


def make_tp_mesh(tp: int):
    """1-D ``("tp",)`` mesh over the first ``tp`` devices."""
    from repro.launch.mesh import make_mesh
    return make_mesh((tp,), (TP_AXIS,))


def local_cfg(cfg: ArchConfig, tp: int) -> ArchConfig:
    """The per-device view of the arch: ``H/tp`` query heads and
    ``Hkv/tp`` KV heads, with ``head_dim`` pinned explicitly so the
    derived ``hd`` property cannot drift when ``n_heads`` shrinks."""
    if tp == 1:
        return cfg
    return dataclasses.replace(cfg, n_heads=cfg.n_heads // tp,
                               n_kv_heads=cfg.n_kv_heads // tp,
                               head_dim=cfg.hd)


# ------------------------------------------------------ PartitionSpecs --

def _replicated(tree):
    import jax
    return jax.tree.map(lambda _: P(), tree)


def _col_sharded(x):
    """Shard the last (output-channel) axis: head-major columns."""
    return P(*([None] * (x.ndim - 1)), TP_AXIS)


def _attn_pspecs(attn: dict) -> dict:
    """Specs for one attention sublayer's parameter dict."""
    out = {}
    for name, qw in attn.items():
        q = QuantLinearParams.of(qw)
        if name == "wo":
            # rows (the flattened head axis, dim -2); the per-channel
            # requant vector and bias stay replicated — they apply once,
            # after the psum of the partial int32 slabs
            w8 = P(*([None] * (q.w8.ndim - 2)), TP_AXIS, None)
            out[name] = QuantLinearParams(
                w8,
                None if q.b_mult is None else P(),
                None if q.bias32 is None else P())
        else:                       # wq / wk / wv: head-major columns
            out[name] = QuantLinearParams(
                _col_sharded(q.w8),
                None if q.b_mult is None else _col_sharded(q.b_mult),
                None if q.bias32 is None else _col_sharded(q.bias32))
    return out


def qparam_pspecs(qparams) -> dict:
    """PartitionSpec pytree for the quantized parameters: attention
    projections sharded per :mod:`~repro.distributed.tp_serving`,
    everything else (embedding, norms, FFN/MoE, head) replicated."""
    specs = {k: _replicated(v) for k, v in qparams.items()
             if k != "layers"}
    layers = []
    for group in qparams["layers"]:
        g = {}
        for k, v in group.items():
            g[k] = _attn_pspecs(v) if k == "attn" else _replicated(v)
        layers.append(g)
    specs["layers"] = layers
    return specs


def cache_pspecs(caches) -> list:
    """PartitionSpec pytree for the decode caches: the K/V pools shard
    on their ``Hkv`` axis (axis 3 in both the paged and contiguous
    layouts); any other cache leaf would be lane-indexed state, which
    :func:`tp_arch_supported` rules out."""
    specs = []
    for c in caches:
        s = {}
        for key, leaf in c.items():
            assert key in ("k8", "v8", "k_shift", "v_shift"), \
                f"unexpected cache leaf {key!r} under tensor parallelism"
            if key in ("k_shift", "v_shift"):
                # per-page requant shifts (ng, num_pages): page ids are
                # device-agnostic, so the shifts replicate
                s[key] = P(None, None)
            else:
                s[key] = P(None, None, None, TP_AXIS, None)
        specs.append(s)
    return specs


def shard_put(tree, specs, mesh):
    """``device_put`` every leaf with its NamedSharding(mesh, spec)."""
    import jax
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, specs)
