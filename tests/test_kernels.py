"""Per-kernel shape/dtype sweeps: pallas (interpret) vs ref.py oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as iattn
from repro.core import intmath, norms
from repro.core import softmax as ism
from repro.core.dyadic import fit_dyadic
from repro.kernels import ref
from repro.ops import RequantSpec, get_backend

PALLAS = get_backend("pallas")
REF = get_backend("ref")


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 128, 128, 128, 256),
    (256, 1024, 384, 128, 128, 256),
    (64, 128, 512, 64, 128, 128),
    (128, 896, 128, 128, 128, 128),
])
def test_int8_matmul_shapes(rng, m, k, n, bm, bn, bk):
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    bias = rng.integers(-2**18, 2**18, (n,)).astype(np.int32)
    dn = fit_dyadic(1 / 4000.0, k * 127 * 127 + 2**18)
    got = np.asarray(PALLAS.int8_matmul(
        jnp.asarray(x), jnp.asarray(w), RequantSpec.per_tensor(dn),
        bias32=jnp.asarray(bias), bm=bm, bn=bn, bk=bk))
    want = np.asarray(ref.ref_int8_matmul(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), dn))
    assert np.array_equal(got, want)


def test_int8_matmul_perchannel(rng):
    m, k, n = 128, 512, 256
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    bvec = rng.integers(1000, 30000, (n,)).astype(np.int32)
    got = np.asarray(PALLAS.int8_matmul(
        jnp.asarray(x), jnp.asarray(w), RequantSpec.per_channel(28, 7),
        b_vec=jnp.asarray(bvec)))
    want = np.asarray(ref.ref_int8_matmul_perchannel(
        jnp.asarray(x), jnp.asarray(w), None, jnp.asarray(bvec), 28, 7))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("rows,rowlen", [(8, 128), (32, 256), (5, 96)])
def test_int_softmax_kernel(rng, rows, rowlen):
    sp = ism.make_isoftmax(s_score=3.5e-4, qmax_score=128 * 127 * 127)
    sc = rng.integers(-60000, 60000, (rows, rowlen)).astype(np.int32)
    got = np.asarray(PALLAS.int_softmax(jnp.asarray(sc), sp))
    want = np.asarray(REF.int_softmax(jnp.asarray(sc), sp))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("shape", [(512,), (3, 7, 512), (16, 1024)])
def test_int_gelu_kernel(rng, shape):
    s = 16 / 1024
    plan = intmath.make_igelu(s, 1024)
    dn = fit_dyadic(plan.s_out / (8 / 127), 1024 * 2 * plan.q_one)
    q = rng.integers(-1024, 1025, shape).astype(np.int32)
    got = np.asarray(PALLAS.int_gelu(jnp.asarray(q), plan, dn))
    want = np.asarray(REF.int_gelu(jnp.asarray(q), plan, dn))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("d,subtract_mean", [(768, True), (512, False),
                                             (384, True)])
def test_int_layernorm_kernel(rng, d, subtract_mean):
    s = 8 / 1024
    plan = norms.make_inorm(d, s, 1024, 2 / 127, 8 / 127,
                            subtract_mean=subtract_mean)
    gamma = rng.normal(1, 0.2, d).astype(np.float32)
    beta = rng.normal(0, 0.2, d).astype(np.float32) if subtract_mean \
        else None
    qg, qb = norms.quantize_norm_weights(
        jnp.asarray(gamma), jnp.asarray(beta) if beta is not None else
        None, plan)
    q = rng.integers(-1024, 1025, (16, d)).astype(np.int32)
    got = np.asarray(PALLAS.int_layernorm(jnp.asarray(q), qg, qb, plan))
    want = np.asarray(REF.int_layernorm(jnp.asarray(q), qg, qb, plan))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("h,hkv,window", [(4, 2, 0), (4, 4, 0), (2, 1, 96),
                                          (8, 2, 0)])
def test_fused_attention_kernel(rng, h, hkv, window):
    b, s, d = 2, 256, 64
    plan = iattn.make_iattention(d, 8/127, 8/127, 4/127, 4/127)
    q8 = np.clip(rng.normal(0, 40, (b, s, h, d)), -127, 127).astype(np.int8)
    k8 = np.clip(rng.normal(0, 40, (b, s, hkv, d)), -127, 127) \
        .astype(np.int8)
    v8 = np.clip(rng.normal(0, 40, (b, s, hkv, d)), -127, 127) \
        .astype(np.int8)
    got = np.asarray(PALLAS.int_attention(
        jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8), plan,
        causal=True, window=window, bq=64, bkv=64))
    want = np.asarray(REF.int_attention(
        jnp.asarray(q8), jnp.asarray(k8), jnp.asarray(v8), plan,
        causal=True, window=window))
    diff = np.abs(got.astype(int) - want.astype(int))
    # online rescaling vs exact normalisation: <=1% of elements off by >1
    assert diff.max() <= 4
    assert (diff > 1).mean() < 0.02


def test_int8_matmul_wide_output_bits(rng):
    """Regression: out_bits=11 results must stay int32 (the FFN up-proj);
    an int8 out_dtype silently truncated them (see ops.int8_matmul)."""
    from repro.quant.plans import make_linear_plan
    import repro.models.intlayers as il
    plan = make_linear_plan(8 / 127, 2 / 127, 16 / 1024, 128, out_bits=11)
    x8 = jnp.asarray(rng.integers(-127, 128, (16, 128)), jnp.int8)
    w = rng.normal(0, 0.1, (128, 256))
    from repro.quant.convert import _q_linear
    qw, _ = _q_linear(jnp.asarray(w), plan)
    a = np.asarray(il.int_linear(x8, qw, plan, ops="ref"))
    b = np.asarray(il.int_linear(x8, qw, plan, ops="pallas"))
    assert a.dtype == b.dtype == np.int32
    assert np.array_equal(a, b)
    assert np.abs(a).max() > 127          # exercises the >int8 range
