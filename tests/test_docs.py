"""Docs can't rot: every ``python`` code block in docs/*.md + README.md
must execute, and every intra-repo markdown link must resolve.

Conventions:
  * fenced blocks whose info string is exactly ``python`` are executed
    (in one namespace per file, in document order — later blocks may use
    earlier definitions);
  * put ``<!-- no-run -->`` on the line above a fence to skip it;
  * ``bash``/``text``/unlabelled fences are never executed;
  * links: ``[...](path)`` with no scheme must point at an existing file
    (anchors are stripped; bare ``#anchor`` links are skipped).

The CI docs job runs exactly this module (see .github/workflows/ci.yml).
"""
import os
import re
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = sorted(
    [os.path.join(ROOT, "README.md")]
    + [os.path.join(ROOT, "docs", f)
       for f in sorted(os.listdir(os.path.join(ROOT, "docs")))
       if f.endswith(".md")])

# fences may be indented up to 3 spaces (markdown spec; e.g. inside a
# list item) — 4+ is an indented code block, not a fence
_FENCE = re.compile(r"^ {0,3}```(\S*)\s*$")
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _blocks(path):
    """(start_line, info, source, skip) per fenced block in ``path``."""
    import textwrap
    out, info, buf, start, skip_next = [], None, [], 0, False
    prev_nonblank = ""
    with open(path) as f:
        for i, line in enumerate(f, 1):
            m = _FENCE.match(line)
            if m and info is None:
                info, buf, start = m.group(1), [], i
                skip_next = "<!-- no-run -->" in prev_nonblank
            elif m and not m.group(1):
                out.append((start, info, textwrap.dedent("".join(buf)),
                            skip_next))
                info = None
            elif info is not None:
                buf.append(line)
            if line.strip():
                prev_nonblank = line
    assert info is None, f"{path}: unterminated fence at line {start}"
    return out


def _doc_id(path):
    return os.path.relpath(path, ROOT)


@pytest.mark.parametrize("path", DOCS, ids=_doc_id)
def test_python_snippets_run(path, tmp_path, monkeypatch):
    blocks = [(ln, src) for ln, info, src, skip in _blocks(path)
              if info == "python" and not skip]
    if not blocks:
        pytest.skip("no runnable python blocks")
    monkeypatch.chdir(ROOT)          # snippets use sys.path.insert("src")
    ns = {"__name__": f"doc_{os.path.basename(path)}"}
    path_before = list(sys.path)
    try:
        for ln, src in blocks:
            try:
                exec(compile(src, f"{path}:{ln}", "exec"), ns)
            except Exception as e:
                raise AssertionError(
                    f"{_doc_id(path)} line {ln}: snippet raised "
                    f"{type(e).__name__}: {e}") from e
    finally:
        sys.path[:] = path_before    # snippets insert a relative "src"


@pytest.mark.parametrize("path", DOCS, ids=_doc_id)
def test_intra_repo_links_resolve(path):
    base = os.path.dirname(path)
    broken = []
    in_fence = False
    with open(path) as f:
        for i, line in enumerate(f, 1):
            if _FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in _LINK.findall(line):
                if "://" in target or target.startswith(("mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not os.path.exists(os.path.join(base, rel)):
                    broken.append(f"line {i}: {target}")
    assert not broken, f"{_doc_id(path)}: broken links:\n  " + \
        "\n  ".join(broken)


def test_docs_exist():
    """The documented doc set itself (ISSUE 2 acceptance)."""
    for f in ("docs/ARCHITECTURE.md", "docs/KERNELS.md", "docs/OPS_API.md",
              "README.md"):
        assert os.path.exists(os.path.join(ROOT, f)), f
