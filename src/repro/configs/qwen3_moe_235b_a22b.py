"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, per-expert d_ff 1536
[hf:Qwen/Qwen3-30B-A3B family].  EP: 128 experts / 16-way model axis."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", num_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, moe_d_ff=1536, moe_every=1,
    activation="swiglu", norm="rmsnorm", rope_theta=1000000.0,
)
