"""Integer-only softmax (SwiftTron §III-F, Figs. 11-12).

Pipeline per row (the ASIC's three phases):
  1. maximum search                         -> comparator tree
  2. i-exp of (x - max)                     -> polynomial + shift (intmath)
  3. output generation: e_i / sum(e)        -> the one integer divider

The divider is realised as one reciprocal per row (r = 2^30 // sum) followed
by multiplies — the paper's "most complex operator is the divider" appears
exactly once per row.

Scale plan (all frozen at design time):
  * the max is subtracted in the RAW score scale (exact integer subtract),
    then the non-positive difference is clipped to the i-exp band
    (-z_max*ln2, 0] and requantized to the shared ``S_SM = 2^-14`` — the
    clip bounds the requant input range so the dyadic keeps full precision,
  * exp values are requantized to ``2^-15`` so a row sum of up to 2^15
    elements fits int32,
  * probabilities leave as int8 at scale ``2^-7`` (ready for the P*V INT8
    matmul, Fig. 10's Requantization block).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp

# the row-length budget lives with the other bit budgets in the analysis
# package (single source of truth); re-exported here for compatibility
from repro.analysis.budgets import MAX_ROWSUM_LEN  # noqa: F401
from repro.core import intmath
from repro.core.dyadic import Dyadic, fit_dyadic, rshift_round

S_SM = 2.0 ** -14        # shared i-exp input scale
S_EXP16 = 2.0 ** -15     # exp values as 16-bit fractions
S_PROB = 2.0 ** -7       # int8 probability scale
PROB_SHIFT = 7
RECIP_BITS = 30
Z_MAX = 30               # exp(-z_max*ln2) == 2^-30 ~ 0


class ISoftmaxPlan(NamedTuple):
    dn_in: Dyadic                 # (score - max) scale -> S_SM
    iexp: intmath.IExpPlan
    dn_e16: Dyadic                # iexp out -> S_EXP16
    s_in: float
    q_band: int                   # clip: q - max >= -q_band (raw units)

    @property
    def s_out(self) -> float:
        return S_PROB


def make_isoftmax(s_score: float, qmax_score: int) -> ISoftmaxPlan:
    """``s_score``: scale of the int32 attention scores; ``qmax_score``:
    design-time bound on |q_score| (used only for the exact max-subtract,
    which needs headroom: 2*qmax_score must fit int32)."""
    if 2 * qmax_score > intmath.INT32_MAX:
        raise ValueError(f"score range too wide: {qmax_score}")
    q_band = int(math.ceil(Z_MAX * intmath.LN2 / s_score))
    dn_in = fit_dyadic(s_score / S_SM, q_band)
    iexp = intmath.make_iexp(S_SM, z_max=Z_MAX)
    dn_e16 = fit_dyadic(iexp.s_out / S_EXP16, iexp.q_one + 1)
    return ISoftmaxPlan(dn_in, iexp, dn_e16, s_score, q_band)


def _exp16(q_sub, plan: ISoftmaxPlan):
    """(q - rowmax) in raw scale (<= 0) -> exp as 2^-15 fraction."""
    q_sub = jnp.maximum(q_sub, jnp.int32(-plan.q_band))
    q_sm = plan.dn_in(q_sub)                            # -> S_SM
    e = intmath.i_exp(q_sm, plan.iexp)
    return plan.dn_e16(e)                               # scale 2^-15


def i_softmax(q_scores, plan: ISoftmaxPlan, axis: int = -1, where=None):
    """int32 scores -> int8 probabilities (scale 2^-7) along ``axis``.

    ``where``: optional boolean mask (True = attend). Masked positions get
    probability 0 and are excluded from max/sum — the integer analogue of
    additive -inf masking.
    """
    q = q_scores.astype(jnp.int32)
    neg = jnp.int32(-(2 ** 30))
    if where is not None:
        q = jnp.where(where, q, neg)
    q_max = jnp.max(q, axis=axis, keepdims=True)
    e16 = _exp16(q - q_max, plan)
    if where is not None:
        e16 = jnp.where(where, e16, 0)
    s = jnp.sum(e16, axis=axis, keepdims=True)          # <= rowlen * 2^15
    r = jnp.int32(1 << RECIP_BITS) // jnp.maximum(s, 1)
    p = rshift_round(e16 * r, RECIP_BITS - PROB_SHIFT)  # prob * 2^7
    return jnp.clip(p, 0, 127).astype(jnp.int8)


def i_softmax_stats(q_scores, plan: ISoftmaxPlan, axis: int = -1,
                    where=None):
    """Chunk-local stats for two-pass / online attention.

    Returns (e16, chunk_max_raw, chunk_sum).  ``chunk_max_raw`` stays in the
    exact raw score scale so running maxima combine losslessly; sums are
    rescaled across chunks with ``combine_correction`` (an i-exp multiply).
    """
    q = q_scores.astype(jnp.int32)
    neg = jnp.int32(-(2 ** 30))
    if where is not None:
        q = jnp.where(where, q, neg)
    q_max = jnp.max(q, axis=axis, keepdims=True)
    e16 = _exp16(q - q_max, plan)
    if where is not None:
        e16 = jnp.where(where, e16, 0)
    s = jnp.sum(e16, axis=axis, keepdims=True)
    return e16, q_max, s


def combine_correction(old_max_raw, new_max_raw, plan: ISoftmaxPlan):
    """int32 multiplier (scale 2^-15) rescaling old-chunk stats to the new
    running max: exp(old_max - new_max), maxes in the raw score scale."""
    return _exp16(old_max_raw - new_max_raw, plan)


def rescale_sum(s, corr16):
    """(s * corr16) >> 15 via a hi/lo split so the int32 product never
    overflows even for s up to 2^30 (split 32x16 multiply, as the ASIC's
    wide product register would)."""
    s_hi = s >> 15
    s_lo = s & 0x7FFF
    return s_hi * corr16 + rshift_round(s_lo * corr16, 15)


def finalize_probs(e16, s):
    """Normalise e16 values (computed against the global max) by the global
    sum -> int8 probs."""
    r = jnp.int32(1 << RECIP_BITS) // jnp.maximum(s, 1)
    p = rshift_round(e16 * r, RECIP_BITS - PROB_SHIFT)
    return jnp.clip(p, 0, 127).astype(jnp.int8)
