"""SSD correctness: chunked == naive recurrence; state carry; decode step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import mamba as mb
from repro.models import model as M


def _naive(x, dt, A, B, C, h0=None):
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bf, Cf = np.repeat(B, rep, 2), np.repeat(C, rep, 2)
    hs = np.zeros((b, h, n, p)) if h0 is None else np.asarray(h0)
    y = np.zeros_like(x)
    for t in range(l):
        dec = np.exp(dt[:, t] * A[None])
        hs = hs * dec[:, :, None, None] + np.einsum(
            "bhn,bh,bhp->bhnp", Bf[:, t], dt[:, t], x[:, t])
        y[:, t] = np.einsum("bhn,bhnp->bhp", Cf[:, t], hs)
    return y, hs


def test_ssd_chunked_matches_naive(rng):
    b, l, h, p, g, n = 2, 64, 4, 8, 2, 16
    x = rng.normal(0, 1, (b, l, h, p)).astype(np.float32)
    dt = rng.uniform(0.001, 0.1, (b, l, h)).astype(np.float32)
    A = -rng.uniform(0.5, 4, (h,)).astype(np.float32)
    B = rng.normal(0, 1, (b, l, g, n)).astype(np.float32)
    C = rng.normal(0, 1, (b, l, g, n)).astype(np.float32)
    y, hl = mb.ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                           jnp.asarray(B), jnp.asarray(C), chunk=16)
    y_ref, h_ref = _naive(x, dt, A, B, C)
    assert np.abs(np.asarray(y) - y_ref).max() < 1e-4
    assert np.abs(np.asarray(hl) - h_ref).max() < 1e-4


def test_ssd_state_carry(rng):
    b, l, h, p, g, n = 1, 32, 2, 4, 1, 8
    args = (rng.normal(0, 1, (b, l, h, p)).astype(np.float32),
            rng.uniform(0.01, 0.1, (b, l, h)).astype(np.float32),
            -rng.uniform(0.5, 2, (h,)).astype(np.float32),
            rng.normal(0, 1, (b, l, g, n)).astype(np.float32),
            rng.normal(0, 1, (b, l, g, n)).astype(np.float32))
    x, dt, A, B, C = [jnp.asarray(a) for a in args]
    y_full, _ = mb.ssd_chunked(x, dt, A, B, C, chunk=8)
    y1, h1 = mb.ssd_chunked(x[:, :16], dt[:, :16], A, B[:, :16],
                            C[:, :16], chunk=8)
    y2, _ = mb.ssd_chunked(x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:],
                           chunk=8, h0=h1)
    joined = jnp.concatenate([y1, y2], axis=1)
    assert float(jnp.abs(joined - y_full).max()) < 1e-4


def test_mamba_float_decode_matches_fwd(rng):
    cfg = M.reduce_config(get_config("mamba2-130m"), dtype="float32")
    p = mb.init_mamba(jax.random.key(0), cfg, jnp.float32)
    b, l = 2, 12
    u = jnp.asarray(rng.normal(0, 1, (b, l, cfg.d_model)), jnp.float32)
    full = mb.mamba_fwd(p, u, cfg, chunk=4)
    state = mb.init_mamba_state(cfg, b)
    outs = []
    for t in range(l):
        o, state = mb.mamba_step(p, u[:, t], state, cfg)
        outs.append(o)
    stepped = jnp.stack(outs, axis=1)
    assert float(jnp.abs(stepped - full).max()) < 1e-3
