"""Typed operator-API datatypes: the requant epilogue and linear params.

SwiftTron freezes every scale ratio at design time; at the API boundary
that means each integer op carries exactly one of three epilogue forms:

  * **per-tensor**  — a single :class:`~repro.core.dyadic.Dyadic` pair
    ``(b, c, pre)`` applied to the whole accumulator;
  * **per-channel** — an int32 multiplier *vector* (a runtime array,
    ``QuantLinearParams.b_mult``) with plan-level shared shifts
    ``(c, pre)`` (the paper's per-channel weight scales folded into the
    requant unit);
  * **raw**         — no requant: the int32 accumulator is returned
    untouched (router logits, lm-head, Δt projection).

:class:`RequantSpec` is the frozen, validated union of the three; it
replaces the ``dn= / b_vec= / c= / pre= / out_bits=`` keyword spaghetti
the kernels used to take.  :class:`QuantLinearParams` replaces the
untyped ``{"w8", "b_mult", "bias32"}`` dicts in the quantized parameter
pytree (NamedTuples are jax pytrees, so scan / tree_map / checkpointing
all keep working).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from repro.core.dyadic import Dyadic

PER_TENSOR = "per_tensor"
PER_CHANNEL = "per_channel"
RAW = "raw"

_KINDS = (PER_TENSOR, PER_CHANNEL, RAW)


@dataclasses.dataclass(frozen=True)
class RequantSpec:
    """Frozen description of an op's requantization epilogue.

    Use the constructors — ``per_tensor`` / ``per_channel`` / ``raw`` /
    ``for_linear`` — rather than the raw dataclass fields.
    """

    kind: str
    out_bits: int = 8
    dn: Optional[Dyadic] = None   # per-tensor dyadic pair
    c: int = 0                    # per-channel shared total shift
    pre: int = 0                  # per-channel shared pre-shift

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"RequantSpec kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if not 2 <= self.out_bits <= 32:
            raise ValueError("out_bits must be in [2, 32], got "
                             f"{self.out_bits}")
        if self.kind == PER_TENSOR:
            if not isinstance(self.dn, Dyadic):
                raise ValueError("per-tensor RequantSpec needs a Dyadic "
                                 f"(got dn={self.dn!r})")
        elif self.kind == PER_CHANNEL:
            if self.dn is not None:
                raise ValueError("per-channel RequantSpec takes (c, pre), "
                                 "not a Dyadic")
            if not 0 <= self.pre <= self.c:
                raise ValueError(f"need 0 <= pre <= c, got c={self.c} "
                                 f"pre={self.pre}")
        else:  # RAW
            if self.dn is not None or self.c or self.pre:
                raise ValueError("raw RequantSpec carries no requant "
                                 "constants")
            if self.out_bits != 32:
                raise ValueError("raw accumulators are int32 "
                                 f"(out_bits=32), got {self.out_bits}")

    # ------------------------------------------------------ constructors --

    @classmethod
    def per_tensor(cls, dn: Dyadic, out_bits: int = 8) -> "RequantSpec":
        """Whole-tensor dyadic requant (``q_out = (q_in * b) >> c``)."""
        return cls(PER_TENSOR, out_bits, dn=dn)

    @classmethod
    def per_channel(cls, c: int, pre: int, out_bits: int = 8
                    ) -> "RequantSpec":
        """Per-out-channel multipliers with shared static shifts.

        The multiplier vector itself is a runtime array and travels with
        the weights (``QuantLinearParams.b_mult``); only the shifts are
        frozen here.
        """
        return cls(PER_CHANNEL, out_bits, c=c, pre=pre)

    @classmethod
    def raw(cls) -> "RequantSpec":
        """Keep the int32 accumulator (requant happens downstream)."""
        return cls(RAW, 32)

    @classmethod
    def for_linear(cls, plan) -> "RequantSpec":
        """The epilogue a ``quant.plans.LinearPlan`` describes."""
        if plan.s_out == 0.0:
            return cls.raw()
        return cls.per_channel(plan.c, plan.pre, plan.out_bits)

    # -------------------------------------------------------- properties --

    @property
    def is_raw(self) -> bool:
        return self.kind == RAW

    @property
    def out_dtype(self):
        """Narrowest container for the clipped output."""
        return jnp.int8 if self.out_bits <= 8 else jnp.int32


class QuantLinearParams(NamedTuple):
    """Quantized linear-layer parameters (a jax pytree).

    ``w8``     — int8 weights ``(..., K, N)``;
    ``b_mult`` — optional int32 per-out-channel requant multipliers
                 ``(..., N)`` (present iff the layer's plan requantizes);
    ``bias32`` — optional int32 bias at the accumulator scale ``(..., N)``.
    """

    w8: Any
    b_mult: Optional[Any] = None
    bias32: Optional[Any] = None

    @classmethod
    def of(cls, obj) -> "QuantLinearParams":
        """Normalize a legacy ``{"w8", ...}`` dict or pass through."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(w8=obj["w8"], b_mult=obj.get("b_mult"),
                       bias32=obj.get("bias32"))
        raise TypeError(f"cannot interpret {type(obj).__name__} as "
                        "QuantLinearParams")
