"""Built-in backend implementations (registered by ``repro.ops``)."""
from repro.ops.backends.ref import RefBackend
from repro.ops.backends.pallas import PallasBackend

__all__ = ["RefBackend", "PallasBackend"]
