"""Production serving driver: float checkpoint -> SwiftTron integer
parameters -> batched INT8 engine behind the async front end.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
      --reduced --requests 8 --max-new 16 [--ckpt-dir DIR]

Without --ckpt-dir the driver quantizes a fresh (random-init) model —
useful for throughput measurement; with one it restores the trained
params saved by launch.train.

Requests flow through :class:`repro.serving.ServingFrontend` — the
asyncio admission/streaming layer — rather than a hand-rolled drain
loop, so the driver gets backpressure (``--max-pending``), per-request
deadlines (``--timeout-s``), open-loop Poisson load (``--arrival-rate``
requests/s; 0 = submit everything up front) and p50/p99 TTFT /
inter-token latency in the summary, with the engine's ``EngineStalled``
detection intact.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops as rops
from repro.analysis import contracts
from repro.checkpoint import load_checkpoint
from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import QueueFull, ServingEngine, ServingFrontend


def _fmt_pct(p: dict | None, unit_ms: bool = True) -> str:
    if p is None:
        return "n/a"
    k = 1e3 if unit_ms else 1.0
    u = "ms" if unit_ms else "s"
    return (f"p50 {p['p50'] * k:.1f}{u} / p99 {p['p99'] * k:.1f}{u} "
            f"(n={p['n']})")


async def _serve(fe: ServingFrontend, prompts, args) -> list:
    """Open-loop client: submit ``prompts`` at ``--arrival-rate`` req/s
    (exp-distributed gaps; 0 = all at once), drain every stream, return
    the handles (None where admission rejected)."""
    rng = np.random.default_rng(1)
    runner = asyncio.create_task(fe.run())
    handles, drains = [], []
    for prompt in prompts:
        if args.arrival_rate > 0:
            await asyncio.sleep(rng.exponential(1.0 / args.arrival_rate))
        try:
            h = fe.submit(prompt, args.max_new,
                          temperature=args.temperature,
                          deadline_s=args.timeout_s)
        except QueueFull as e:
            print(f"  rejected (queue full, {e.pending} in flight)")
            handles.append(None)
            continue
        handles.append(h)
        drains.append(asyncio.create_task(h.result()))
    await asyncio.gather(*drains)
    fe.close()
    await runner
    return handles


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--cache-mode", default="paged",
                    choices=["paged", "contiguous"],
                    help="KV layout: paged pool (memory O(live tokens)) "
                         "or one contiguous slab per lane")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per physical KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical pool size incl. the null page "
                         "(default: fully provisioned; smaller values "
                         "undersubscribe the pool)")
    ap.add_argument("--no-fold-wo", action="store_true",
                    help="keep the o-projection requant outside the "
                         "decode/prefill epilogues (numerics identical)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens per batched prefill launch "
                         "(paged mode; must divide or be a multiple of "
                         "--page-size; 0 = token-streaming prefill; "
                         "default: auto ~32 on eligible archs)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prompt tokens prefilled per engine step, "
                         "so decoding sessions keep emitting a token "
                         "every step (default: unbounded)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-session prompt-prefix sharing "
                         "(shared prefixes otherwise map the same "
                         "physical KV pages)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard attention heads "
                         "over a tp-device mesh (must divide the "
                         "arch's KV head count); backends without the "
                         "tp_serving capability — or a box without the "
                         "devices — serve through an exact single-"
                         "device lowering instead")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to K tokens per "
                         "live lane and verify all K+1 positions in one "
                         "decode launch (greedy acceptance; streams stay "
                         "bit-exact with --spec-k 0); bounded by the "
                         "kernel's MAX_SQ query budget; 0 = off")
    ap.add_argument("--spec-mode", default="ngram",
                    help="draft proposer (self-speculative, no draft "
                         "model); 'ngram' = prompt-lookup over the "
                         "session's own context")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="admission bound: requests in flight before "
                         "submit() raises QueueFull (default: 4x batch)")
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="per-request deadline in seconds; an expired "
                         "request is evicted (pages reclaimed) and its "
                         "stream ends with terminal state 'timeout'")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in requests/s "
                         "(exp-distributed gaps); 0 = submit every "
                         "request up front (closed batch)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--backend", default=None,
                    help="registered op backend (default: REPRO_BACKEND "
                         "env or the arch's kernel_backend); one of "
                         f"{rops.available_backends()}")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    # resolve up front: a typo'd --backend should fail before the
    # (slow) quantization pass, not after
    ops = rops.resolve_ops(args.backend, cfg)
    # ... and reject incoherent prefill flags just as early, with a
    # typed error instead of a kernel-shape failure deep in a launch
    if args.prefill_chunk is not None and args.prefill_chunk > 0:
        if args.cache_mode != "paged":
            ap.error("--prefill-chunk needs --cache-mode paged (chunked "
                     "prefill writes K/V through the page table)")
        if args.prefill_chunk % args.page_size \
                and args.page_size % args.prefill_chunk:
            ap.error(f"--prefill-chunk {args.prefill_chunk} must divide "
                     f"or be a multiple of --page-size {args.page_size} "
                     "so chunk writes tile physical pages")
    if args.prefill_budget is not None and args.prefill_budget < 1:
        ap.error("--prefill-budget must be >= 1 token/step")
    if args.max_pending is not None and args.max_pending < 1:
        ap.error("--max-pending must be >= 1 request")
    if args.timeout_s is not None and args.timeout_s <= 0:
        ap.error("--timeout-s must be > 0 seconds")
    if args.arrival_rate < 0:
        ap.error("--arrival-rate must be >= 0 requests/s")
    if args.reduced:
        cfg = M.reduce_config(cfg, dtype="float32", vocab=1024)
    # --tp validates against the FINAL config (--reduced shrinks the
    # head counts), same early-typed-error policy as the flags above
    try:
        from repro.distributed.tp_serving import validate_tp
        validate_tp(cfg, args.tp)
    except ValueError as e:
        ap.error(f"--tp {args.tp}: {e}")
    # --spec-k likewise validates against the FINAL config: sliding-
    # window / SSM / cross-attention archs (and unknown proposers, and
    # K beyond the kernel's MAX_SQ budget) fail here as an argparse
    # error, not as a shape error inside the verify launch
    if args.spec_k:
        if args.temperature > 0:
            ap.error("--spec-k needs --temperature 0: greedy longest-"
                     "prefix acceptance is only bit-exact against the "
                     "argmax stream; a sampled stream would silently "
                     "diverge")
        try:
            from repro.serving.speculate import validate_spec
            validate_spec(cfg, args.spec_k, args.spec_mode)
        except ValueError as e:
            ap.error(f"--spec-k {args.spec_k}: {e}")
    # the request shape every client will submit must be feasible on
    # the cache geometry this engine is about to build — reject at the
    # CLI boundary with the same typed check frontend.submit() applies
    prompt_len = 4
    try:
        contracts.require_request(prompt_len, args.max_new,
                                  args.cache_len, window=cfg.window)
    except contracts.RequestInfeasible as e:
        ap.error(f"--max-new {args.max_new} with --cache-len "
                 f"{args.cache_len}: {e}")
    params = tf.init_params(jax.random.key(0), cfg)
    if args.ckpt_dir:
        params, meta = load_checkpoint(args.ckpt_dir, (params, None))
        params = params[0]
        print(f"restored step {meta['step']} from {args.ckpt_dir}")
    print("quantizing to the integer datapath ...")
    qp, plans = convert.quantize_params(params, cfg)
    n_int8 = sum(l.size for l in jax.tree.leaves(qp)
                 if hasattr(l, "dtype") and l.dtype == jnp.int8)
    print(f"  {n_int8/1e6:.1f}M int8 weights "
          f"({n_int8/2**20:.0f} MiB vs {n_int8*2/2**20:.0f} MiB bf16)")

    eng = ServingEngine(qp, plans, cfg, batch_size=args.batch,
                        cache_len=args.cache_len, ops=ops,
                        cache_mode=args.cache_mode,
                        page_size=args.page_size,
                        num_pages=args.num_pages,
                        fold_wo=not args.no_fold_wo,
                        prefill_chunk=args.prefill_chunk,
                        prefill_budget=args.prefill_budget,
                        prefix_cache=not args.no_prefix_cache,
                        tp=args.tp, spec_k=args.spec_k,
                        spec_mode=args.spec_mode)
    print(f"engine: {eng.describe_str()}")
    fe = ServingFrontend(eng, max_pending=args.max_pending)
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab, prompt_len)]
               for _ in range(args.requests)]
    t0 = time.time()
    handles = asyncio.run(_serve(fe, prompts, args))
    dt = time.time() - t0

    d = fe.describe()
    n_tok = d["tokens"]
    print(f"served {d['submitted']} requests / {n_tok} tokens in "
          f"{d['steps']} batched steps, {dt:.1f}s ({n_tok/dt:.1f} tok/s, "
          "int8 KV cache)")
    term = d["terminal"]
    print("  terminal: " + ", ".join(f"{k}={v}" for k, v in term.items()))
    lat = d["latency"]
    print(f"  ttft: {_fmt_pct(lat['ttft_s'])}   inter-token: "
          f"{_fmt_pct(lat['inter_token_s'])}   queue-wait: "
          f"{_fmt_pct(lat['queue_wait_s'])}")
    print(f"  occupancy: mean {d['occupancy']['mean']:.2f}/"
          f"{args.batch} lanes, queue depth: mean "
          f"{d['queue_depth']['mean']:.2f} max {d['queue_depth']['max']}")
    sp = eng.describe()["spec"]
    if sp["k"]:
        rate = f"{sp['accept_rate']:.0%}" \
            if sp["accept_rate"] is not None else "n/a"
        print(f"speculation ({sp['mode']}, k={sp['k']}): "
              f"{sp['accepted']}/{sp['drafted']} drafts accepted "
              f"({rate}), {sp['wasted']} wasted verify rows")
    px = eng.describe()["cache"].get("prefix")
    if px:
        print(f"prefix cache: {px['hits']} hits / {px['misses']} misses, "
              f"{px['tokens_reused']} prompt tokens reused")
    for h in [h for h in handles if h is not None][:4]:
        r = h.request
        print(f"  req {h.uid} [{h.terminal}]: {r.prompt} -> "
              f"{r.out_tokens[:10]}...")


if __name__ == "__main__":
    main()
