"""Serving engine: batched generation == sequential decode."""
import jax
import pytest

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          capacity_factor=8.0)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


def test_engine_generates(engine_setup):
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64)
    reqs = [Request(uid=i, prompt=[1 + i, 7, 42], max_new_tokens=5)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert sorted(r.uid for r in finished) == [r.uid for r in reqs]
    for r in reqs:
        assert r.done and len(r.out_tokens) == 5
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_batch_independence(engine_setup):
    """A request's greedy output must not depend on its batch neighbours."""
    cfg, qp, plans = engine_setup
    eng1 = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64)
    solo = Request(uid=0, prompt=[5, 9, 13], max_new_tokens=4)
    eng1.submit(solo)
    eng1.run_until_done()

    eng2 = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64)
    a = Request(uid=1, prompt=[5, 9, 13], max_new_tokens=4)
    b = Request(uid=2, prompt=[100, 3], max_new_tokens=4)
    eng2.submit(a)
    eng2.submit(b)
    eng2.run_until_done()
    assert a.out_tokens == solo.out_tokens


def _drive(engine_setup, ops, prompts, max_new=5, batch_size=2,
           cache_len=64):
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=batch_size,
                        cache_len=cache_len, ops=ops)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [r.out_tokens for r in reqs]


def test_engine_fused_decode_token_parity_across_slot_recycling(
        engine_setup):
    """Prefill-then-decode token streams must be identical between the
    `pallas_fused` (fused valid_len-masked decode kernel) and `ref`
    (full-matrix oracle) engines — across admit/evict/re-admit cycles,
    where a recycled slot's cache tail holds the previous occupant's
    stale K/V.  A stale-tail read shows up as a token divergence here
    long before any shape test would notice."""
    # 5 requests through 2 slots with different prompt lengths: every
    # slot is evicted and re-admitted at a different position at least
    # once, with ragged per-slot valid_len throughout
    prompts = [[1, 7, 42], [9, 3], [17, 2, 5, 11], [4], [23, 8, 31]]
    eng_ref, toks_ref = _drive(engine_setup, "ref", prompts)
    eng_fused, toks_fused = _drive(engine_setup, "pallas_fused", prompts)
    assert not eng_ref.decode_fused and eng_fused.decode_fused
    assert toks_fused == toks_ref


def test_describe_is_structured_with_derived_string(engine_setup):
    """describe() returns the structured dict (backend ids, decode mode,
    page-pool stats); describe_str() is derived from it — drivers print
    the string, tooling consumes the dict (no more string matching)."""
    from repro.ops import OP_NAMES

    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    d = eng.describe()
    assert d["ops"] == "ref" and d["decode"] == "oracle"
    assert set(d["backends"]) == set(OP_NAMES)
    assert all(name == "ref" for name in d["backends"].values())
    assert d["cache"]["mode"] == "paged"
    for key in ("page_size", "num_pages", "pages_used", "pages_free",
                "kv_bytes", "live_tokens"):
        assert key in d["cache"], key
    # the dict is the source of truth; the one-liner derives from it
    s = eng.describe_str()
    assert "ops=ref" in s and "decode=oracle" in s and "paged" in s
    # pool stats are live: admitting a request consumes pages
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    eng.step()
    assert eng.describe()["cache"]["pages_used"] > 0
    eng.run_until_done()
    # the prefix index keeps the prompt's pages cached after the drain;
    # clearing it returns them all
    eng.prefix.clear()
    assert eng.describe()["cache"]["pages_used"] == 0

    cont = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                         ops="ref", cache_mode="contiguous")
    dc = cont.describe()
    assert dc["cache"]["mode"] == "contiguous"
    # the paged pool is provisioned lane-for-lane by default (plus the
    # null page), so it spends no less than the contiguous layout;
    # undersubscribing num_pages is where the O(live tokens) saving
    # comes from (see test_paged_decode)
    assert dc["cache"]["kv_bytes"] <= d["cache"]["kv_bytes"]


def test_engine_decode_dispatches_through_backend(engine_setup):
    """No hardcoded oracle call on the decode path: every engine step's
    attention goes through the configured backend's
    ``int_decode_attention`` (here: a recording override)."""
    from repro.ops import OpSet, get_backend

    calls = []

    class Recording:
        name = "recording-decode"
        fused_attention = False

        def __getattr__(self, op):
            return getattr(get_backend("ref"), op)

        def int_decode_attention(self, *a, **kw):
            calls.append("int_decode_attention")
            return get_backend("ref").int_decode_attention(*a, **kw)

    cfg, qp, plans = engine_setup
    opset = OpSet("ref", {"int_decode_attention": Recording()})
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops=opset)
    eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
    eng.run_until_done()
    # dispatched at trace time (the engine jits the step): >= once
    assert calls
