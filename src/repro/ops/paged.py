"""Page-table array utilities for the paged attention operands.

The paged KV layout hands ``int_decode_attention`` (and the chunked
``int_paged_prefill``) a physical pool ``(num_pages, page_size, Hkv,
D)`` plus a per-slot page table ``pages: int32[B, max_pages]`` mapping
logical block ``j`` of slot ``b`` to physical page ``pages[b, j]``.
Backends that advertise the ``paged_decode`` / ``paged_prefill``
capabilities consume the table directly (the ``pallas_fused`` kernels
translate block indices through it in the scalar-prefetch index map);
for every other backend the dispatch layer lowers the operand with
:func:`gather_pages` — an exact gather into the contiguous ``(B,
max_pages·page_size, Hkv, D)`` layout the existing contract already
covers, so paged and contiguous attention are bit-identical by
construction.  :func:`scatter_chunk` is the write-side twin: it lands a
prefill chunk's new K/V in the physical pages a lane's table row maps —
shared by the lowering, the oracle and the fused backend, so every path
writes identical pool bytes.
"""
from __future__ import annotations

import jax.numpy as jnp


def gather_pages(pool, pages, page_size: int):
    """Gather a paged pool into the contiguous per-slot cache layout.

    ``pool``: ``(num_pages, page_size, ...)``; ``pages``: ``(B,
    max_pages) int32``.  Returns ``(B, max_pages·page_size, ...)`` —
    slot ``b``'s logical positions ``[j·page_size, (j+1)·page_size)``
    are page ``pages[b, j]``.  Unmapped blocks point at the null page 0
    whose (stale) contents sit past ``valid_len`` and are masked.
    """
    if pool.shape[1] != page_size:
        raise ValueError(f"pool page dim {pool.shape[1]} != page_size "
                         f"{page_size}")
    pages = jnp.asarray(pages, jnp.int32)
    b, m = pages.shape
    flat = jnp.take(pool, pages.reshape(-1), axis=0)
    return flat.reshape(b, m * page_size, *pool.shape[2:])


def scatter_chunk(pool, chunk, base_pos, pages, page_size: int):
    """Write a prefill chunk's K/V through the page table.

    ``pool``: ``(num_pages, page_size, ...)``; ``chunk``: ``(B, C, ...)``
    new values for slot ``b``'s logical positions ``[base_pos[b],
    base_pos[b] + C)``; ``pages``: ``(B, max_pages) int32``.  Returns
    the updated pool: position ``p = base_pos[b] + j`` lands at
    ``(pages[b, p // page_size], p % page_size)``.

    Positions at or past the table span (``max_pages · page_size`` — a
    padded chunk tail) and positions of lanes whose table row is unmapped
    are routed to the reserved null page 0, whose contents are never
    valid (``repro.serving.kvcache``): a chunk write can therefore never
    corrupt a live position it does not own.  Overlapping null-page
    writes from several lanes are fine for the same reason — nothing
    observable reads them.
    """
    if pool.shape[1] != page_size:
        raise ValueError(f"pool page dim {pool.shape[1]} != page_size "
                         f"{page_size}")
    pages = jnp.asarray(pages, jnp.int32)
    base_pos = jnp.asarray(base_pos, jnp.int32)
    b, m = pages.shape
    c = chunk.shape[1]
    pos = base_pos[:, None] + jnp.arange(c, dtype=jnp.int32)[None]  # (B,C)
    blk = jnp.minimum(pos // page_size, m - 1)
    page = jnp.take_along_axis(pages, blk, axis=1)            # (B, C)
    page = jnp.where(pos < m * page_size, page, 0)            # pad -> null
    off = pos % page_size
    return pool.at[page, off].set(chunk)
