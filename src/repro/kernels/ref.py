"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` mirrors the exact integer semantics of its kernel (same
rounding, same staging) by delegating to ``repro.core`` — the kernels are
*implementations* of the core numerics with explicit VMEM tiling, so kernel
vs. ref mismatches beyond +-1 LSB are bugs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import activations as iact
from repro.core import attention as iattn
from repro.core import norms as inorms
from repro.core import softmax as ism
from repro.core.dyadic import Dyadic, apply_dyadic, clip_to_bits
from repro.core.intmath import IGeluPlan, i_gelu


def ref_int8_matmul(x8, w8, bias32, dn: Dyadic, out_bits: int = 8):
    """int8 (M,K) x int8 (K,N) -> int32, +bias, dyadic requant, clip.

    bias32: int32 (N,) at the accumulator scale (s_x * s_w), or None.
    """
    acc = jnp.dot(x8, w8, preferred_element_type=jnp.int32)
    if bias32 is not None:
        acc = acc + bias32[None, :]
    return clip_to_bits(apply_dyadic(acc, dn), out_bits)


def ref_int8_matmul_perchannel(x8, w8, bias32, b_vec, c: int, pre: int,
                               out_bits: int = 8):
    from repro.core.dyadic import apply_dyadic_perchannel
    acc = jnp.dot(x8, w8, preferred_element_type=jnp.int32)
    if bias32 is not None:
        acc = acc + bias32[None, :]
    out = apply_dyadic_perchannel(acc, b_vec, c, pre, axis=-1)
    return clip_to_bits(out, out_bits)


def ref_int_softmax(q_scores, plan: ism.ISoftmaxPlan, where=None):
    return ism.i_softmax(q_scores, plan, axis=-1, where=where)


def ref_int_gelu(q, plan: IGeluPlan, dn_out: Dyadic, out_bits: int = 8):
    return clip_to_bits(apply_dyadic(i_gelu(q.astype(jnp.int32), plan),
                                     dn_out), out_bits)


def ref_int_layernorm(q, q_gamma, q_beta, plan: inorms.INormPlan,
                      out_bits: int = 8):
    return inorms.i_norm(q, q_gamma, q_beta, plan, out_bits)


def ref_int_attention(q8, k8, v8, plan: iattn.IAttnPlan, causal: bool = True,
                      window: int = 0, out_bits: int = 8):
    """Oracle for the fused attention kernel: full-matrix integer attention."""
    sq, sk = q8.shape[1], k8.shape[1]
    mask = iattn.causal_mask(sq, sk, window=window)[None, None] \
        if (causal or window > 0) else None
    # GQA: repeat kv heads if needed
    h, hkv = q8.shape[2], k8.shape[2]
    if hkv != h:
        rep = h // hkv
        k8 = jnp.repeat(k8, rep, axis=2)
        v8 = jnp.repeat(v8, rep, axis=2)
    return iattn.i_attention_full(q8, k8, v8, plan, mask=mask,
                                  out_bits=out_bits)
