"""The unified operator API: RequantSpec forms, backend registry dispatch,
ref<->pallas parity across the ops, and the removed deprecation shims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core import attention as iattn
from repro.core import intmath, norms
from repro.core import softmax as ism
from repro.core.dyadic import fit_dyadic
from repro.ops import (OpSet, QuantLinearParams, RequantSpec, get_backend,
                       register_backend, resolve_ops, unregister_backend,
                       use_backend)


# ------------------------------------------------------ RequantSpec -------

def test_requant_spec_forms():
    dn = fit_dyadic(1 / 100.0, 2 ** 20)
    pt = RequantSpec.per_tensor(dn, out_bits=8)
    assert pt.kind == ops.PER_TENSOR and pt.dn is dn
    assert pt.out_dtype == jnp.int8
    pc = RequantSpec.per_channel(c=28, pre=7, out_bits=11)
    assert pc.kind == ops.PER_CHANNEL and (pc.c, pc.pre) == (28, 7)
    assert pc.out_dtype == jnp.int32
    raw = RequantSpec.raw()
    assert raw.is_raw and raw.out_bits == 32


def test_requant_spec_validation():
    dn = fit_dyadic(1 / 100.0, 2 ** 20)
    with pytest.raises(ValueError):
        RequantSpec("per_tensor", 8)               # missing Dyadic
    with pytest.raises(ValueError):
        RequantSpec("per_channel", 8, dn=dn)       # Dyadic on per-channel
    with pytest.raises(ValueError):
        RequantSpec.per_channel(c=4, pre=9)        # pre > c
    with pytest.raises(ValueError):
        RequantSpec("raw", 8)                      # raw must be 32-bit
    with pytest.raises(ValueError):
        RequantSpec("volumetric", 8)               # unknown kind


def test_requant_spec_for_linear():
    from repro.quant.plans import make_linear_plan
    plan = make_linear_plan(8 / 127, 2 / 127, 8 / 127, 256)
    spec = RequantSpec.for_linear(plan)
    assert spec.kind == ops.PER_CHANNEL
    assert (spec.c, spec.pre, spec.out_bits) == (plan.c, plan.pre,
                                                 plan.out_bits)
    raw_plan = make_linear_plan(8 / 127, 2 / 127, 0.0, 256)
    assert RequantSpec.for_linear(raw_plan).is_raw


def test_quant_linear_params_of():
    qw = QuantLinearParams.of({"w8": 1, "b_mult": 2})
    assert (qw.w8, qw.b_mult, qw.bias32) == (1, 2, None)
    assert QuantLinearParams.of(qw) is qw
    with pytest.raises(TypeError):
        QuantLinearParams.of([1, 2])


# ------------------------------------------------- registry dispatch ------

class _Recorder:
    """Delegating backend that counts dispatched ops."""

    fused_attention = False

    def __init__(self, inner, name="recorder"):
        self._inner = inner
        self.name = name
        self.calls = []

    def __getattr__(self, op):
        inner_fn = getattr(self._inner, op)
        if op in ops.OP_NAMES:
            def wrapper(*a, **kw):
                self.calls.append(op)
                return inner_fn(*a, **kw)
            return wrapper
        return inner_fn


@pytest.fixture
def recorder():
    rec = _Recorder(get_backend("ref"))
    register_backend("recorder", rec, overwrite=True)
    yield rec
    unregister_backend("recorder")


def _tiny_matmul(opset):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-127, 128, (8, 32)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (32, 16)), jnp.int8)
    dn = fit_dyadic(1 / 4000.0, 32 * 127 * 127)
    return opset.int8_matmul(x, w, RequantSpec.per_tensor(dn))


def test_use_backend_context_changes_dispatch(recorder, monkeypatch):
    monkeypatch.delenv(ops.ENV_VAR, raising=False)
    assert resolve_ops(None).name == "ref"
    with use_backend("recorder"):
        _tiny_matmul(resolve_ops(None))
    assert recorder.calls == ["int8_matmul"]
    # context popped: default again
    assert resolve_ops(None).name == "ref"


def test_env_override_changes_dispatch(recorder, monkeypatch):
    monkeypatch.setenv(ops.ENV_VAR, "recorder")
    _tiny_matmul(resolve_ops(None))
    assert recorder.calls == ["int8_matmul"]
    # explicit argument and context both beat the env
    assert resolve_ops("ref").name == "ref"
    with use_backend("ref"):
        assert resolve_ops(None).name == "ref"


def test_per_op_override_routes_single_op(recorder):
    opset = OpSet("ref", {"int_gelu": "recorder"})
    _tiny_matmul(opset)                      # default backend
    plan = intmath.make_igelu(16 / 1024, 1024)
    dn = fit_dyadic(plan.s_out / (8 / 127), 1024 * 2 * plan.q_one)
    opset.int_gelu(jnp.arange(-32, 32, dtype=jnp.int32), plan, dn)
    assert recorder.calls == ["int_gelu"]    # matmul did NOT go through
    assert opset.name == "ref[int_gelu=recorder]"


def test_resolve_ops_cfg_and_errors(monkeypatch):
    monkeypatch.delenv(ops.ENV_VAR, raising=False)
    from repro.configs.registry import get_config
    from repro.models import model as M
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          kernel_backend="pallas")
    assert resolve_ops(None, cfg).name == "pallas"
    with pytest.raises(KeyError):
        get_backend("no-such-backend")
    with pytest.raises(KeyError):
        OpSet("ref", {"int_conv": "ref"})    # unknown op name


def test_register_backend_class_as_factory():
    """A registered class is a factory: instantiated once, not called
    with misbound self."""
    from repro.ops.backends.ref import RefBackend

    class MyBackend(RefBackend):
        name = "my_class_backend"

    register_backend("my_class_backend", MyBackend, overwrite=True)
    try:
        be = get_backend("my_class_backend")
        assert isinstance(be, MyBackend)
        _tiny_matmul(resolve_ops("my_class_backend"))   # self bound right
    finally:
        unregister_backend("my_class_backend")


def test_fuse_attention_false_uses_exact_oracle(rng):
    """fuse_attention=False must not re-enter a fused backend — it asks
    for the exact two-pass numerics."""
    import jax
    from repro.configs.registry import get_config
    from repro.models import intlayers as il
    from repro.models import model as M
    from repro.models import transformer as tf
    from repro.quant import convert

    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=64, num_layers=1)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    attn_qp = jax.tree.map(lambda t: t[0], params["layers"][0])["attn"]
    attn_qp = convert._q_attn(attn_qp, plans.attn)
    x8 = jnp.asarray(rng.integers(-127, 128, (1, 16, cfg.d_model)),
                     jnp.int8)
    unfused = il.int_attn_fwd(attn_qp, x8, plans.attn, cfg, ops="pallas",
                              fuse_attention=False)
    exact = il.int_attn_fwd(attn_qp, x8, plans.attn, cfg, ops="ref")
    assert np.array_equal(np.asarray(unfused), np.asarray(exact))


# -------------------------------------------- ref<->pallas parity ---------

@pytest.mark.parametrize("form", ["per_tensor", "per_channel", "raw"])
def test_matmul_parity_all_requant_forms(rng, form):
    m, k, n = 64, 256, 128
    x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    bias = jnp.asarray(rng.integers(-2 ** 16, 2 ** 16, (n,)), jnp.int32)
    b_vec = None
    if form == "per_tensor":
        spec = RequantSpec.per_tensor(
            fit_dyadic(1 / 4000.0, k * 127 * 127 + 2 ** 16))
    elif form == "per_channel":
        spec = RequantSpec.per_channel(c=28, pre=7)
        b_vec = jnp.asarray(rng.integers(1000, 30000, (n,)), jnp.int32)
    else:
        spec = RequantSpec.raw()
    got = {}
    for name in ("ref", "pallas"):
        got[name] = np.asarray(resolve_ops(name).int8_matmul(
            x, w, spec, bias32=bias, b_vec=b_vec))
    assert np.array_equal(got["ref"], got["pallas"])
    if form == "raw":
        assert got["pallas"].dtype == np.int32
        # raw == plain int32 accumulator + bias
        acc = np.asarray(x, np.int64) @ np.asarray(w, np.int64) \
            + np.asarray(bias)[None, :]
        assert np.array_equal(got["ref"], acc)


def test_all_five_ops_parity_through_registry(rng):
    """Every op of the Backend protocol: ref vs pallas via the registry."""
    ref, pall = resolve_ops("ref"), resolve_ops("pallas")

    sp = ism.make_isoftmax(s_score=3.5e-4, qmax_score=128 * 127 * 127)
    sc = jnp.asarray(rng.integers(-60000, 60000, (16, 128)), jnp.int32)
    assert np.array_equal(ref.int_softmax(sc, sp), pall.int_softmax(sc, sp))

    gplan = intmath.make_igelu(16 / 1024, 1024)
    gdn = fit_dyadic(gplan.s_out / (8 / 127), 1024 * 2 * gplan.q_one)
    q = jnp.asarray(rng.integers(-1024, 1025, (4, 512)), jnp.int32)
    assert np.array_equal(ref.int_gelu(q, gplan, gdn),
                          pall.int_gelu(q, gplan, gdn))

    d = 512
    nplan = norms.make_inorm(d, 8 / 1024, 1024, 2 / 127, 8 / 127)
    qg, _ = norms.quantize_norm_weights(
        jnp.ones((d,), jnp.float32), None, nplan)
    qn = jnp.asarray(rng.integers(-1024, 1025, (8, d)), jnp.int32)
    assert np.array_equal(ref.int_layernorm(qn, qg, None, nplan),
                          pall.int_layernorm(qn, qg, None, nplan))

    plan = iattn.make_iattention(64, 8 / 127, 8 / 127, 4 / 127, 4 / 127)
    q8 = jnp.asarray(np.clip(rng.normal(0, 40, (1, 128, 4, 64)), -127,
                             127), jnp.int8)
    k8 = jnp.asarray(np.clip(rng.normal(0, 40, (1, 128, 2, 64)), -127,
                             127), jnp.int8)
    a_ref = np.asarray(ref.int_attention(q8, k8, k8, plan), int)
    a_pl = np.asarray(pall.int_attention(q8, k8, k8, plan, bq=64,
                                         bkv=64), int)
    # online-softmax rescaling vs exact normalisation: +-LSB tolerance
    assert np.abs(a_ref - a_pl).max() <= 4

    mm = _tiny_matmul(ref), _tiny_matmul(pall)
    assert np.array_equal(np.asarray(mm[0]), np.asarray(mm[1]))


def test_pallas_tuned_backend_parity(rng):
    """Third registered backend (per-op tiled blocks) needs no model code."""
    x = jnp.asarray(rng.integers(-127, 128, (96, 192)), jnp.int8)   # odd
    w = jnp.asarray(rng.integers(-127, 128, (192, 48)), jnp.int8)   # shapes
    spec = RequantSpec.per_channel(c=28, pre=7)
    bv = jnp.asarray(rng.integers(1000, 30000, (48,)), jnp.int32)
    a = resolve_ops("ref").int8_matmul(x, w, spec, b_vec=bv)
    b = resolve_ops("pallas_tuned").int8_matmul(x, w, spec, b_vec=bv)
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- deprecation shims ------

def test_kernels_ops_shims_removed_with_pointer():
    """The old string-dispatch import path is gone (it warned for one
    release); the tombstone must point migrators at repro.ops."""
    with pytest.raises(ImportError, match=r"repro\.ops"):
        import repro.kernels.ops  # noqa: F401


def test_engine_backend_kwarg_deprecated():
    import inspect
    from repro.serving import ServingEngine
    sig = inspect.signature(ServingEngine.__init__)
    assert sig.parameters["backend"].default is None   # shim, not a string
    assert "ops" in sig.parameters
