"""Batched integer serving engine.

The serving counterpart of the ASIC's control unit (§III-J): admits
requests into fixed batch slots, runs the INT8 prefill/decode datapath
(int8 KV caches = the paper's quantization applied to the cache), and
retires finished sequences — a continuous-batching-lite scheduler suitable
for the fixed-shape XLA world.  Slots fill raggedly (each has its own
``pos``), so every decode step is a batched ragged-cache attention: it
dispatches through the configured backend's ``int_decode_attention``,
which on ``pallas_fused`` is one valid_len-masked kernel launch that
skips dead cache blocks instead of computing over the full ``cache_len``.

Slots are recycled between requests without recompiling: every shape
(batch, cache length) is fixed at engine construction.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import intlayers as il
from repro.models import inttransformer as it
from repro.models.common import ArchConfig
from repro.ops import OP_NAMES, resolve_ops
from repro.quant import plans as qplans

# Process-level cache of compiled decode steps, keyed by everything the
# traced closure captures (cfg, plans, shapes, the resolved backend per
# op).  Two engines with the same key share ONE executable, so (a)
# engine construction stops paying an XLA recompile and (b) identical
# request streams produce identical tokens across engine instances —
# separately compiled executables of the same program are not guaranteed
# to agree to the last integer on every input (XLA CPU compile variance),
# which shows up as cross-engine token divergence in parity tests.
# Bounded LRU (insertion order): a process sweeping many distinct
# (shape, plan) combinations evicts the oldest executable instead of
# pinning one per combination forever.
_DECODE_STEP_CACHE: Dict[tuple, Callable] = {}
_DECODE_STEP_CACHE_MAX = 8


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, qparams, plans: qplans.LayerPlans, cfg: ArchConfig,
                 batch_size: int = 8, cache_len: int = 512,
                 ops=None, seed: int = 0, backend=None):
        if backend is not None:
            warnings.warn("ServingEngine(backend=...) is deprecated; pass "
                          "ops= (an OpSet or backend name)",
                          DeprecationWarning, stacklevel=2)
            ops = backend if ops is None else ops
        self.cfg = cfg
        self.plans = plans
        self.qparams = qparams
        self.batch = batch_size
        self.cache_len = cache_len
        self.ops = resolve_ops(ops, cfg)
        # whether prefill/cross attention runs as one fused kernel launch
        # (pallas / pallas_fused) or the two-pass oracle path (ref)
        self.attn_fused = \
            self.ops.backend_for("int_attention").fused_attention
        # whether the per-step decode attention over the ragged KV cache
        # runs as the backend's single-launch valid_len-masked kernel
        # (the ``fused_decode`` capability flag; pallas_fused only) or
        # the full-matrix oracle; either way the step dispatches through
        # the backend — there is no hardcoded oracle call on the decode
        # path (models.intlayers.int_attn_decode)
        self.decode_fused = getattr(
            self.ops.backend_for("int_decode_attention"), "fused_decode",
            False)
        self.rng = np.random.default_rng(seed)
        self.rope_tab = il.build_rope_table(cache_len + 1, cfg.hd,
                                            cfg.rope_theta) \
            if cfg.pos == "rope" else None
        self.caches = it.init_decode_cache(cfg, batch_size, cache_len)
        self.pos = np.zeros(batch_size, np.int32)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self._decode = self._shared_decode_step()

    def _decode_impl(self, qparams, caches, tokens, pos):
        return it.int_decode_step(qparams, caches, tokens, pos,
                                  self.plans, self.cfg, self.rope_tab,
                                  ops=self.ops)

    def _shared_decode_step(self) -> Callable:
        """The jitted decode step, shared across same-shaped engines via
        ``_DECODE_STEP_CACHE`` (falls back to a private jit when the key
        is unhashable, e.g. exotic plan objects).

        The cached callable closes over (plans, cfg, rope_tab, ops) only
        — never ``self`` — so a retired engine's weights, caches and
        request slots are not pinned by the process-global cache."""
        try:
            key = (self.cfg, self.plans, self.batch, self.cache_len,
                   tuple(id(self.ops.backend_for(op)) for op in OP_NAMES))
            hash(key)
        except TypeError:
            return jax.jit(self._decode_impl)
        fn = _DECODE_STEP_CACHE.pop(key, None)
        if fn is None:
            plans, cfg, rope_tab, ops = (self.plans, self.cfg,
                                         self.rope_tab, self.ops)

            def step(qparams, caches, tokens, pos):
                return it.int_decode_step(qparams, caches, tokens, pos,
                                          plans, cfg, rope_tab, ops=ops)

            fn = jax.jit(step)
        _DECODE_STEP_CACHE[key] = fn            # (re-)insert most recent
        while len(_DECODE_STEP_CACHE) > _DECODE_STEP_CACHE_MAX:
            _DECODE_STEP_CACHE.pop(next(iter(_DECODE_STEP_CACHE)))
        return fn

    # ------------------------------------------------------ scheduling ---

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.batch):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[slot] = req
                self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill by streaming prompt tokens through decode (slot-local);
        keeps every shape static."""
        self.pos[slot] = 0
        self._reset_slot_cache(slot)
        for t in req.prompt[:-1]:
            self._step_one(slot, t)
        req._last_token = req.prompt[-1]

    def _reset_slot_cache(self, slot: int):
        def zero_slot(leaf):
            if leaf.ndim >= 2 and leaf.shape[1] == self.batch:
                return leaf.at[:, slot].set(0)
            return leaf
        self.caches = jax.tree.map(zero_slot, self.caches)

    def _snap_pos(self):
        """Snapshot ``self.pos`` for a decode call.

        ``jnp.asarray`` on a numpy array may alias its buffer (zero-copy)
        while dispatch is asynchronous; the engine then mutates
        ``self.pos`` in place (``+= 1``), racing the executing step and
        intermittently decoding at the wrong position.  An explicit copy
        makes the hand-off a snapshot.  (This was a real, observed ~1/10
        token-stream flake on CPU.)
        """
        return jnp.asarray(self.pos.copy())

    def _step_one(self, slot: int, token: int):
        toks = np.zeros(self.batch, np.int32)
        toks[slot] = token
        logits, self.caches = self._decode(self.qparams, self.caches,
                                           jnp.asarray(toks),
                                           self._snap_pos())
        self.pos[slot] += 1
        return np.asarray(logits[slot])

    # ---------------------------------------------------------- decode ---

    def step(self) -> int:
        """One engine step: admit + one batched decode for live slots.
        Returns the number of live requests."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return 0
        toks = np.zeros(self.batch, np.int32)
        for i in live:
            toks[i] = self.slots[i]._last_token
        logits, self.caches = self._decode(self.qparams, self.caches,
                                           jnp.asarray(toks),
                                           self._snap_pos())
        logits = np.asarray(logits)
        for i in live:
            req = self.slots[i]
            self.pos[i] += 1
            row = logits[i][:self.cfg.vocab]
            if req.temperature <= 0:
                nxt = int(np.argmax(row))
            else:
                p = np.exp((row - row.max()) / req.temperature)
                p /= p.sum()
                nxt = int(self.rng.choice(len(p), p=p))
            req.out_tokens.append(nxt)
            req._last_token = nxt
            if len(req.out_tokens) >= req.max_new_tokens \
                    or self.pos[i] >= self.cache_len - 1:
                req.done = True
                self.slots[i] = None
                self.pos[i] = 0
        return len(live)

    def describe(self) -> str:
        """One-line engine signature for drivers/logs."""
        return (f"ops={self.ops.name} "
                f"attn={'fused' if self.attn_fused else 'two-pass'} "
                f"decode={'fused' if self.decode_fused else 'oracle'} "
                f"batch={self.batch} cache_len={self.cache_len}")

    def run_until_done(self, max_steps: int = 10000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return finished
