"""Benchmark harness: one function per paper table/figure.

Prints ``name,value,derived`` CSV rows.  Tables:
  Fig 2    -> bench_operators   (INT8 vs FP32 operator cost)
  Table I  -> bench_asic_model  (area/power/cycle model of the ASIC)
  Fig 18   -> bench_asic_model  (block-level area/power breakdown)
  Table II -> bench_table2      (accuracy: float vs integer path)
             + bench_asic_model latency rows (cycle model)
  §III     -> bench_approx_error (per-unit approximation error)
  kernels  -> bench_kernels     (per-kernel microbench)
  fusion   -> bench_fused_attention (fused vs two-pass attention)
"""
import sys
import traceback


def main() -> None:
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import (bench_approx_error, bench_asic_model,
                            bench_fused_attention, bench_kernels,
                            bench_operators, bench_table2)
    print("name,value,derived")
    ok = True
    for mod in (bench_operators, bench_asic_model, bench_approx_error,
                bench_kernels, bench_fused_attention, bench_table2):
        try:
            for row in mod.run():
                print(",".join(str(x) for x in row))
        except Exception as e:
            ok = False
            print(f"{mod.__name__},ERROR,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
