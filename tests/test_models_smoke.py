"""Per-architecture REDUCED-config smoke tests (deliverable f).

Each assigned arch instantiates a small config of the same family and runs
one forward + one QAT train step on CPU, asserting output shapes and no
NaNs.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWConfig
from repro.quant import qat


def _batch(cfg, b=2, s=32, seed=0):
    k = jax.random.key(seed)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(k, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            k, (b, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(
            k, (b, s, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_forward_and_train_step(name):
    cfg = M.reduce_config(get_config(name), dtype="float32")
    params = tf.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, aux = tf.forward_float(params, batch, cfg, qat=False)
    assert logits.shape == (2, 32, cfg.padded_vocab())
    assert not bool(jnp.isnan(logits).any())

    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    (loss, _), grads = jax.value_and_grad(qat.loss_fn, has_aux=True)(
        params, batch, cfg, qat=True)
    assert np.isfinite(float(loss))
    new_params, opt, metrics = adamw_update(grads, opt, params, opt_cfg)
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("name", ["roberta-base", "deit-s"])
def test_paper_models_forward(name):
    cfg = M.reduce_config(get_config(name), dtype="float32")
    params = tf.init_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out = tf.encoder_fwd_float(params, x, cfg)
    assert out.shape == (2, 16, cfg.d_model)
    assert not bool(jnp.isnan(out).any())


def test_param_count_sane():
    # llama3-8b ~ 8e9 params (analytic counter drives MODEL_FLOPS)
    n = get_config("llama3-8b").param_count()
    assert 7.5e9 < n < 9e9
    n_moe = get_config("qwen3-moe-235b-a22b").param_count()
    assert 2.0e11 < n_moe < 2.6e11
    n_act = get_config("qwen3-moe-235b-a22b").active_param_count()
    assert 1.5e10 < n_act < 3.0e10
