"""Cell matrix accounting (side-effect-free: importable without touching
jax device state, unlike launch.dryrun which pins 512 host devices)."""
from repro.configs.registry import LONG_OK, get_config
from repro.models.common import SHAPES


def cell_supported(arch: str, shape: str) -> str:
    """'' if runnable, else the reason it is skipped (DESIGN.md §6)."""
    cfg = get_config(arch)
    if shape == "long_500k" and arch not in LONG_OK:
        return "full quadratic attention at 524288 tokens (skip per brief)"
    if cfg.family == "encoder" and SHAPES[shape].kind == "decode":
        return "encoder-only arch has no decode step"
    return ""
