"""Public model API: configs -> params/specs -> train & serve entry points.

``input_specs`` / ``qparams_spec`` produce ShapeDtypeStruct stand-ins so
the multi-pod dry-run lowers full-size architectures without allocating
a byte (the 235B MoE's int8 weights exist only as avals).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import inttransformer as it
from repro.models import transformer as tf
from repro.models.common import ArchConfig, ShapeConfig
from repro.models.transformer import layer_group_spec
from repro.quant import plans as qplans
from repro.ops import QuantLinearParams

Pytree = Any
SDS = jax.ShapeDtypeStruct


def reduce_config(cfg: ArchConfig, **over) -> ArchConfig:
    """Smoke-test-sized config of the same family (structure preserved)."""
    gl, ng, kinds = layer_group_spec(cfg)
    upd = dict(
        num_layers=gl * min(ng, 2),
        d_model=128,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 64) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_shared_experts=min(cfg.n_shared_experts, 2),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        n_img_tokens=min(cfg.n_img_tokens, 16) if cfg.n_img_tokens else 0,
        enc_layers=min(cfg.enc_layers, 2) if cfg.enc_layers else 0,
        dec_layers=min(cfg.dec_layers, 2) if cfg.dec_layers else 0,
    )
    upd.update(over)
    return dataclasses.replace(cfg, **upd)


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, SDS]:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        spec = {"tokens": SDS((b, s), jnp.int32),
                "labels": SDS((b, s), jnp.int32)}
    elif shape.kind == "prefill":
        spec = {"tokens": SDS((b, s), jnp.int32)}
    else:  # decode: one new token against a cache of length s
        spec = {"tokens": SDS((b,), jnp.int32),
                "pos": SDS((b,), jnp.int32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        spec["img_embeds"] = SDS((b, cfg.n_img_tokens, cfg.d_model), dtype)
    if cfg.family == "encdec" and shape.kind != "decode":
        spec["src_embeds"] = SDS((b, s, cfg.d_model), dtype)
    return spec


# ------------------------------------------------------ qparams specs -----

def _lin_spec(ng, k, n, plan: qplans.LinearPlan, bias=False, lead=()):
    base = (ng,) + lead if ng else lead
    return QuantLinearParams(
        w8=SDS(base + (k, n), jnp.int8),
        b_mult=SDS(base + (n,), jnp.int32) if plan.s_out != 0.0 else None,
        bias32=SDS(base + (n,), jnp.int32) if bias else None)


def _norm_spec(ng, d, cfg):
    out = {"gamma_q": SDS((ng, d) if ng else (d,), jnp.int32)}
    if cfg.norm == "layernorm":
        out["beta_q"] = SDS((ng, d) if ng else (d,), jnp.int32)
    return out


def _attn_spec(ng, cfg: ArchConfig, plans: qplans.AttnPlan):
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": _lin_spec(ng, d, cfg.n_heads * hd, plans.qkv, cfg.attn_bias),
        "wk": _lin_spec(ng, d, cfg.n_kv_heads * hd, plans.qkv,
                        cfg.attn_bias),
        "wv": _lin_spec(ng, d, cfg.n_kv_heads * hd, plans.qkv,
                        cfg.attn_bias),
        "wo": _lin_spec(ng, cfg.n_heads * hd, d, plans.out),
    }


def _ffn_spec(ng, cfg: ArchConfig, plans: qplans.FfnPlan, f=None):
    d = cfg.d_model
    f = f or cfg.d_ff
    gelu_bias = cfg.activation != "swiglu"
    out = {"w1": _lin_spec(ng, d, f, plans.up, gelu_bias),
           "w2": _lin_spec(ng, f, d, plans.down, gelu_bias)}
    if cfg.activation == "swiglu":
        out["w3"] = _lin_spec(ng, d, f, plans.up)
    return out


def _moe_spec(ng, cfg: ArchConfig, plans: qplans.MoePlan):
    d, e = cfg.d_model, cfg.padded_experts()
    f = cfg.moe_d_ff or cfg.d_ff
    out = {
        "router": QuantLinearParams(
            SDS((ng, d, e) if ng else (d, e), jnp.int8)),
        "w1": _lin_spec(ng, d, f, plans.expert.up, lead=(e,)),
        "w2": _lin_spec(ng, f, d, plans.expert.down, lead=(e,)),
    }
    if cfg.activation == "swiglu":
        out["w3"] = _lin_spec(ng, d, f, plans.expert.up, lead=(e,))
    if cfg.n_shared_experts:
        out["shared"] = _ffn_spec(ng, cfg, plans.shared,
                                  f=f * cfg.n_shared_experts)
    return out


def _mamba_spec(ng, cfg: ArchConfig, mp: qplans.MambaPlan):
    d, di, h = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_heads
    w = 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state
    conv_ch = di + 2 * cfg.ssm_groups * cfg.ssm_state
    lead = (ng,) if ng else ()
    return {
        "in_proj": _lin_spec(ng, d, w, mp.in_proj),
        "dt_proj": QuantLinearParams(SDS(lead + (d, h), jnp.int8)),
        "conv_w8": SDS(lead + (cfg.ssm_conv, conv_ch), jnp.int8),
        "A_q": SDS(lead + (h,), jnp.int32),
        "D_q": SDS(lead + (h,), jnp.int32),
        "dt_bias_q": SDS(lead + (h,), jnp.int32),
        "norm_gamma_q": SDS(lead + (di,), jnp.int32),
        "out_proj": _lin_spec(ng, di, d, mp.out_proj),
    }


def _sublayer_spec(ng, cfg, plans, kind):
    mix, ff, has_cross = kind
    out = {"norm1": _norm_spec(ng, cfg.d_model, cfg)}
    if mix == "attn":
        out["attn"] = _attn_spec(ng, cfg, plans.attn)
    elif mix == "cross":
        out["attn"] = _attn_spec(ng, cfg, plans.cross)
    else:
        out["ssm"] = _mamba_spec(ng, cfg, plans.mamba)
    if has_cross:
        out["cross"] = _attn_spec(ng, cfg, plans.cross)
        out["norm_cross"] = _norm_spec(ng, cfg.d_model, cfg)
    if ff == "moe":
        out["moe"] = _moe_spec(ng, cfg, plans.moe)
        out["norm2"] = _norm_spec(ng, cfg.d_model, cfg)
    elif ff == "ffn":
        out["ffn"] = _ffn_spec(ng, cfg, plans.ffn)
        out["norm2"] = _norm_spec(ng, cfg.d_model, cfg)
    return out


def qparams_spec(cfg: ArchConfig,
                 plans: Optional[qplans.LayerPlans] = None) -> Pytree:
    plans = plans or qplans.build_layer_plans(cfg)
    gl, ng, kinds = layer_group_spec(cfg)
    v, d = cfg.padded_vocab(), cfg.d_model
    spec: Dict[str, Pytree] = {
        "embed_w8": SDS((v, d), jnp.int8),
        "final_norm": _norm_spec(0, d, cfg),
        "head": QuantLinearParams(SDS((d, v), jnp.int8)),
        "head_scale": SDS((v,), jnp.float32),
        "layers": [_sublayer_spec(ng, cfg, plans, kinds[j])
                   for j in range(gl)],
    }
    if cfg.family == "encdec":
        spec["enc_layers"] = [_sublayer_spec(cfg.enc_layers, cfg, plans,
                                             ("attn", "ffn", False))]
        spec["enc_final_norm"] = _norm_spec(0, d, cfg)
    return spec


def params_spec(cfg: ArchConfig) -> Pytree:
    return jax.eval_shape(
        lambda k: tf.init_params(k, cfg), jax.random.key(0))


def decode_cache_spec(cfg: ArchConfig, batch: int, cache_len: int,
                      with_memory: bool = False):
    def build():
        mem8 = jnp.zeros((batch,
                          cfg.n_img_tokens or 1, cfg.d_model), jnp.int8) \
            if with_memory else None
        return it.init_decode_cache(cfg, batch, cache_len, memory8=mem8)
    return jax.eval_shape(build)
