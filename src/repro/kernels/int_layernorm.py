"""Pallas TPU kernel: integer LayerNorm / RMSNorm (SwiftTron §III-I).

One (block_rows, d) VMEM tile per grid step runs the ASIC's three phases —
integer mean (dyadic 1/d), variance with the design-time pre-shift, the
iterative integer square root (fixed 16 Newton steps, see
core.intmath.i_sqrt for why the early-exit became a fixed trip count), and
the reciprocal + per-channel gamma/beta output phase.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.norms import INormPlan


def _rshift_round(x, s: int):
    if s == 0:
        return x
    return (x + (1 << (s - 1))) >> s


def _apply_dn(x, dn):
    return _rshift_round(_rshift_round(x, dn.pre) * jnp.int32(dn.b),
                         dn.c - dn.pre)


def _i_sqrt_tile(n, iters: int = 16):
    """In-kernel integer sqrt (mirror of core.intmath.i_sqrt)."""
    b = jnp.zeros_like(n)
    v = n
    for s in (16, 8, 4, 2, 1):
        t = v >> s
        go = t > 0
        b = jnp.where(go, b + s, b)
        v = jnp.where(go, t, v)
    bl = b + (v > 0).astype(n.dtype)
    x = jnp.maximum(jnp.left_shift(jnp.int32(1), (bl + 1) >> 1), 1)
    for _ in range(iters):
        nx = (x + n // x) >> 1
        x = jnp.minimum(x, jnp.maximum(nx, 1))
    x = jnp.minimum(x, 46340)
    for _ in range(2):
        x = jnp.where(x * x > n, x - 1, x)
    x = jnp.where((x < 46340) & ((x + 1) * (x + 1) <= n), x + 1, x)
    return jnp.where(n <= 0, 0, x)


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, plan: INormPlan,
               has_beta: bool, out_lo: int, out_hi: int):
    q = x_ref[...].astype(jnp.int32)
    if plan.subtract_mean:
        mu = _apply_dn(jnp.sum(q, axis=-1, keepdims=True), plan.dn_mean)
        y = q - mu
    else:
        y = q
    ys = _rshift_round(y, plan.pre_shift)
    var = _apply_dn(jnp.sum(ys * ys, axis=-1, keepdims=True), plan.dn_var)
    sigma_s = _i_sqrt_tile(var)
    r = jnp.int32(1 << (plan.recip_bits + plan.pre_shift)) \
        // jnp.maximum(sigma_s, 1)
    n_q = _rshift_round(y * r, 2 * plan.pre_shift)
    n_q = jnp.where(sigma_s == 0, 0, n_q)
    out = n_q * g_ref[...].astype(jnp.int32)[None, :]
    if has_beta:
        out = out + b_ref[...].astype(jnp.int32)[None, :]
    out = _apply_dn(out, plan.dn_out)
    o_ref[...] = jnp.clip(out, out_lo, out_hi).astype(jnp.int32)


def int_layernorm_pallas(q, q_gamma, q_beta, plan: INormPlan,
                         out_bits: int = 8, block_rows: int = 8,
                         interpret: bool = True):
    """q: (..., d) int32 at plan.s_in -> int32 clipped to out_bits."""
    shape = q.shape
    d = shape[-1]
    assert d == plan.d, (d, plan.d)
    rows = q.size // d
    x2 = q.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    has_beta = q_beta is not None
    args = [x2, q_gamma] + ([q_beta] if has_beta else [])
    in_specs = [pl.BlockSpec((br, d), lambda i: (i, 0)),
                pl.BlockSpec((d,), lambda i: (0,))]
    if has_beta:
        in_specs.append(pl.BlockSpec((d,), lambda i: (0,)))
    else:
        args = [x2, q_gamma]

    def kernel(*refs):
        if has_beta:
            x_ref, g_ref, b_ref, o_ref = refs
        else:
            (x_ref, g_ref, o_ref), b_ref = refs, None
        _ln_kernel(x_ref, g_ref, b_ref, o_ref, plan=plan, has_beta=has_beta,
                   out_lo=-(1 << (out_bits - 1)),
                   out_hi=(1 << (out_bits - 1)) - 1)

    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.int32),
        interpret=interpret,
    )(*args)
    return out.reshape(shape)
