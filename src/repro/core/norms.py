"""Integer LayerNorm / RMSNorm (SwiftTron §III-I, Fig. 15).

Three phases, matching the ASIC pipeline:
  1. mean      — integer sum, dyadic multiply by 1/d
  2. std       — centred squares (with a design-time pre-shift so the INT32
                 accumulator cannot overflow), dyadic 1/d, iterative i-sqrt
  3. output    — one reciprocal per row (2^k // sigma), per-channel gamma,
                 folded beta, dyadic requant to the int8 output scale

RMSNorm (llama-family extension, DESIGN.md §4) is phase 2+3 only.
All bit budgets are solved at design time in ``make_inorm``.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import intmath
from repro.core.dyadic import (Dyadic, bits_for, clip_to_bits, fit_dyadic,
                               rshift_round)


class INormPlan(NamedTuple):
    d: int                  # normalised dimension
    s_in: float             # input scale (int32 values, |q| <= qmax_in)
    qmax_in: int
    dn_mean: Dyadic         # 1/d on the sum
    dn_var: Dyadic          # 1/d on the squared sum
    pre_shift: int          # s: y >> s before squaring
    recip_bits: int         # k: reciprocal precision (n at scale 2^-k)
    s_gamma: float
    s_out: float
    dn_out: Dyadic          # (2^-k * s_gamma) -> s_out  (applied to n*gamma)
    q_beta_scale: float     # scale at which beta is folded in
    subtract_mean: bool


def make_inorm(d: int, s_in: float, qmax_in: int, s_gamma: float,
               s_out: float, subtract_mean: bool = True) -> INormPlan:
    dn_mean = fit_dyadic(1.0 / d, d * qmax_in)
    # pre-shift so sum((y>>s)^2) fits int32: d * (y_max >> s)^2 < 2^31
    y_max = 2 * qmax_in
    s = 0
    while d * ((y_max >> s) ** 2) > intmath.INT32_MAX:
        s += 1
    dn_var = fit_dyadic(1.0 / d, d * ((y_max >> s) ** 2))
    # reciprocal precision: product y * r must fit int32 with
    # r <= 2^(k + s)  ->  bits(y_max) + k + s <= 31
    k = min(15, 31 - bits_for(y_max) - s)
    if k < 8:
        raise ValueError(f"i-norm reciprocal precision too low (k={k}); "
                         f"reduce qmax_in={qmax_in}")
    # |n| <= sqrt(d) theoretically; size the output requant for that
    nmax = min(math.sqrt(d), 128.0)
    n_q_max = int(nmax * (1 << k))
    dn_out = fit_dyadic((2.0 ** -k) * s_gamma / s_out, n_q_max * 127)
    q_beta_scale = (2.0 ** -k) * s_gamma
    return INormPlan(d, s_in, qmax_in, dn_mean, dn_var, s, k, s_gamma,
                     s_out, dn_out, q_beta_scale, subtract_mean)


def quantize_norm_weights(gamma, beta, plan: INormPlan):
    """Float gamma/beta -> integer-side constants (design time)."""
    q_gamma = jnp.clip(jnp.round(gamma / plan.s_gamma), -127, 127
                       ).astype(jnp.int32)
    if beta is None:
        q_beta = None
    else:
        q_beta = jnp.round(beta / plan.q_beta_scale).astype(jnp.int32)
    return q_gamma, q_beta


def i_norm(q, q_gamma, q_beta, plan: INormPlan, out_bits: int = 8):
    """LayerNorm/RMSNorm over the last axis. q: int32 at plan.s_in.

    Returns int32 clipped to the signed ``out_bits`` range, scale plan.s_out.
    """
    q = q.astype(jnp.int32)
    if plan.subtract_mean:
        mu = plan.dn_mean(jnp.sum(q, axis=-1, keepdims=True))
        y = q - mu
    else:
        y = q
    ys = rshift_round(y, plan.pre_shift)
    var = plan.dn_var(jnp.sum(ys * ys, axis=-1, keepdims=True))
    sigma_s = intmath.i_sqrt(var)               # scale s_in * 2^pre_shift
    # n = y / (sigma_s * 2^pre) at scale 2^-k:
    #   r   = 2^(k+pre) / sigma_s
    #   y*r = n * 2^(k + 2*pre)  ->  shift back by 2*pre
    r = jnp.int32(1 << (plan.recip_bits + plan.pre_shift)) \
        // jnp.maximum(sigma_s, 1)
    n_q = rshift_round(y * r, 2 * plan.pre_shift)
    n_q = jnp.where(sigma_s == 0, 0, n_q)        # all-equal row -> 0
    out = n_q * q_gamma                          # scale 2^-k * s_gamma
    if q_beta is not None:
        out = out + q_beta
    out = plan.dn_out(out)
    return clip_to_bits(out, out_bits)
