"""Paged decode attention + folded wo projection: exact-integer parity.

The contract under test (docs/KERNELS.md "decode kernel contract"):

  * the page-table operand (``pages: int32[B, max_pages]`` riding
    scalar-prefetch next to ``valid_len``) is bit-exact against
    gathering the pages into the contiguous layout first, for every
    backend — natively on ``pallas_fused`` (``paged_decode``), via the
    dispatch layer's gather lowering everywhere else;
  * the folded o-projection (``wo=``/``wo_spec=``) is bit-exact against
    the unfolded attention-then-``int8_matmul`` composition;
  * the engine's paged cache mode produces bit-identical token streams
    to the contiguous mode across admit → evict → re-admit schedules,
    preemption/resume included, and pool exhaustion raises the typed
    :class:`~repro.serving.kvcache.PagePoolExhausted`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import attention as iattn
from repro.kernels import ref as kref
from repro.kernels.int_decode_attention import int_decode_attention_fused
from repro.models import model as M
from repro.models import transformer as tf
from repro.ops import (QuantLinearParams, RequantSpec, get_backend,
                       resolve_ops)
from repro.ops.paged import gather_pages
from repro.quant import convert
from repro.serving import PagePoolExhausted, Request, ServingEngine

FUSED = get_backend("pallas_fused")
REF = get_backend("ref")


def _plan(d):
    return iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)


def _pool(rng, num_pages, ps, hkv, d):
    k = jnp.asarray(rng.integers(-127, 128, (num_pages, ps, hkv, d)),
                    jnp.int8)
    v = jnp.asarray(rng.integers(-127, 128, (num_pages, ps, hkv, d)),
                    jnp.int8)
    return k, v


# ------------------------------------------------- kernel-level parity ----

@pytest.mark.parametrize("sq", [1, 4])
def test_paged_kernel_matches_gathered_oracle_ragged(rng, sq):
    """Arbitrary (permuted, partially-mapped) page tables + ragged
    occupancies: the in-kernel block->page translation must match the
    gather-into-contiguous definition bit-for-bit, empty slots and the
    speculative stepped mask included."""
    b, h, hkv, d, ps, m, num_pages = 4, 4, 2, 32, 16, 4, 11
    plan = _plan(d)
    q8 = jnp.asarray(rng.integers(-127, 128, (b, sq, h, d)), jnp.int8)
    kp, vp = _pool(rng, num_pages, ps, hkv, d)
    pages = jnp.asarray([[0, 0, 0, 0],          # empty slot: all null
                         [7, 3, 0, 0],          # 2 pages, out of order
                         [10, 1, 5, 2],         # full, permuted
                         [4, 6, 8, 9]], jnp.int32)
    vl = jnp.asarray([0, 23, 64, 49], jnp.int32)
    kc, vc = (gather_pages(p, pages, ps) for p in (kp, vp))
    want = np.asarray(kref.ref_int_decode_attention(q8, kc, vc, plan, vl))
    got = np.asarray(int_decode_attention_fused(
        q8, kp, vp, plan, vl, pages=pages, page_size=ps, bkv=16))
    assert np.array_equal(got, want)
    assert not got[0].any()                     # empty slot -> requant(0)
    # sub-page tiling: bkv < page_size walks sub-blocks through the table
    got8 = np.asarray(int_decode_attention_fused(
        q8, kp, vp, plan, vl, pages=pages, page_size=ps, bkv=8))
    assert np.array_equal(got8, want)


def test_paged_dispatch_parity_all_backends(rng):
    """OpSet capability negotiation: pallas_fused consumes the table
    natively, ref/pallas get the exact gather lowering — all three
    return identical integers."""
    b, h, hkv, d, ps, m, num_pages = 3, 2, 1, 16, 16, 3, 7
    plan = _plan(d)
    q8 = jnp.asarray(rng.integers(-127, 128, (b, 1, h, d)), jnp.int8)
    kp, vp = _pool(rng, num_pages, ps, hkv, d)
    pages = jnp.asarray(
        np.stack([rng.permutation(np.arange(1, m + 1)) for _ in range(b)]),
        jnp.int32)
    vl = jnp.asarray([1, 17, 48], jnp.int32)
    outs = {}
    for name in ("ref", "pallas", "pallas_fused"):
        ops = resolve_ops(name)
        outs[name] = np.asarray(ops.int_decode_attention(
            q8, kp, vp, plan, vl, pages=pages, page_size=ps))
    assert np.array_equal(outs["ref"], outs["pallas"])
    assert np.array_equal(outs["ref"], outs["pallas_fused"])
    want = np.asarray(kref.ref_int_paged_decode_attention(
        q8, kp, vp, plan, vl, pages, ps))
    assert np.array_equal(outs["ref"], want)


def test_paged_untileable_page_size_falls_back_exactly(rng):
    """page_size below the kernel's min block: the backend must gather
    + oracle with identical numerics rather than enter the kernel."""
    b, h, d, ps, m, num_pages = 2, 2, 16, 8, 4, 9
    plan = _plan(d)
    q8 = jnp.asarray(rng.integers(-127, 128, (b, 1, h, d)), jnp.int8)
    kp, vp = _pool(rng, num_pages, ps, h, d)
    pages = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    vl = jnp.asarray([5, 32], jnp.int32)
    got = np.asarray(FUSED.int_decode_attention(
        q8, kp, vp, plan, vl, pages=pages, page_size=ps))
    want = np.asarray(kref.ref_int_paged_decode_attention(
        q8, kp, vp, plan, vl, pages, ps))
    assert np.array_equal(got, want)


# ----------------------------------------------------- wo-fold parity -----

@pytest.mark.parametrize("form", ["per_channel", "per_tensor", "raw"])
@pytest.mark.parametrize("paged", [False, True])
def test_wo_fold_matches_unfolded_composition(rng, form, paged):
    """The folded o-projection epilogue — in-kernel on pallas_fused,
    dispatch-composed on ref — is bit-exact against attention followed
    by the per-channel int8 matmul, for every wo RequantSpec form."""
    from repro.core.dyadic import fit_dyadic
    b, h, hkv, d, L = 3, 4, 2, 16, 64
    n_out = h * d
    plan = _plan(d)
    q8 = jnp.asarray(rng.integers(-127, 128, (b, 1, h, d)), jnp.int8)
    if paged:
        ps, num_pages = 16, 13
        kp, vp = _pool(rng, num_pages, ps, hkv, d)
        pages = jnp.asarray(np.stack(
            [rng.permutation(np.arange(1, 5)) for _ in range(b)]),
            jnp.int32)
        kw = dict(pages=pages, page_size=ps)
        kc, vc = (gather_pages(p, pages, ps) for p in (kp, vp))
    else:
        kp = kc = jnp.asarray(rng.integers(-127, 128, (b, L, hkv, d)),
                              jnp.int8)
        vp = vc = jnp.asarray(rng.integers(-127, 128, (b, L, hkv, d)),
                              jnp.int8)
        kw = {}
    vl = jnp.asarray([0, 21, 64], jnp.int32)
    wo_w8 = jnp.asarray(rng.integers(-127, 128, (h * d, n_out)), jnp.int8)
    bias32 = jnp.asarray(rng.integers(-500, 500, (n_out,)), jnp.int32)
    if form == "per_channel":
        spec = RequantSpec.per_channel(c=28, pre=7, out_bits=14)
        wo = QuantLinearParams(wo_w8, jnp.asarray(
            rng.integers(1000, 30000, (n_out,)), jnp.int32), bias32)
    elif form == "per_tensor":
        spec = RequantSpec.per_tensor(fit_dyadic(1 / 64.0, 1 << 24),
                                      out_bits=14)
        wo = QuantLinearParams(wo_w8, None, bias32)
    else:
        spec = RequantSpec.raw()
        wo = QuantLinearParams(wo_w8, None, bias32)
    o8 = kref.ref_int_decode_attention(q8, kc, vc, plan, vl)
    want = np.asarray(kref.ref_apply_wo(o8, wo.w8, wo.bias32, wo.b_mult,
                                        spec))
    for name in ("ref", "pallas_fused"):
        got = np.asarray(resolve_ops(name).int_decode_attention(
            q8, kp, vp, plan, vl, wo=wo, wo_spec=spec, **kw))
        assert np.array_equal(got, want), (name, form, paged)
    assert want.shape == (b, 1, n_out)


def test_wo_fold_rejects_non_int8_attention_epilogue(rng):
    plan = _plan(16)
    q8 = jnp.asarray(rng.integers(-127, 128, (1, 1, 2, 16)), jnp.int8)
    kc = jnp.asarray(rng.integers(-127, 128, (1, 32, 2, 16)), jnp.int8)
    vl = jnp.asarray([4], jnp.int32)
    wo = QuantLinearParams(
        jnp.asarray(rng.integers(-127, 128, (32, 32)), jnp.int8))
    ops = resolve_ops("ref")
    with pytest.raises(ValueError, match="int8 attention epilogue"):
        ops.int_decode_attention(q8, kc, kc, plan, vl,
                                 requant=RequantSpec.raw(), wo=wo,
                                 wo_spec=RequantSpec.raw())
    with pytest.raises(ValueError, match="wo_spec"):
        ops.int_decode_attention(q8, kc, kc, plan, vl, wo=wo)
    # a wide *default* epilogue (out_bits > 8, requant=None) must be
    # rejected too — the int8 lowering would otherwise silently wrap —
    # on the dispatch layer and on the folding backend alike
    with pytest.raises(ValueError, match="int8 attention epilogue"):
        ops.int_decode_attention(q8, kc, kc, plan, vl, out_bits=16,
                                 wo=wo, wo_spec=RequantSpec.raw())
    with pytest.raises(ValueError, match="int8 attention epilogue"):
        FUSED.int_decode_attention(q8, kc, kc, plan, vl, out_bits=16,
                                   wo=wo, wo_spec=RequantSpec.raw())


# ------------------------------------------------------- engine parity ----

@pytest.fixture(scope="module")
def engine_setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          capacity_factor=8.0)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


PROMPTS = [[1, 7, 42], [9, 3], [17, 2, 5, 11], [4], [23, 8, 31]]


def _drive(engine_setup, prompts=PROMPTS, max_new=4, **kw):
    cfg, qp, plans = engine_setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64, **kw)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [r.out_tokens for r in reqs]


def test_engine_paged_token_parity_across_admit_evict_readmit(
        engine_setup):
    """The acceptance schedule: 5 requests through 2 lanes — every lane
    is retired and re-admitted with recycled (never-zeroed) pages at
    least once — must produce bit-identical streams in all four
    (cache_mode × backend) combinations, fused decode running the
    page-table kernel natively."""
    ref_c, toks_c = _drive(engine_setup, ops="ref",
                           cache_mode="contiguous")
    ref_p, toks_p = _drive(engine_setup, ops="ref", cache_mode="paged")
    fus_p, toks_fp = _drive(engine_setup, ops="pallas_fused",
                            cache_mode="paged")
    fus_c, toks_fc = _drive(engine_setup, ops="pallas_fused",
                            cache_mode="contiguous")
    assert toks_p == toks_c
    assert toks_fp == toks_c
    assert toks_fc == toks_c
    assert fus_p.decode_fused and fus_p.decode_paged_native
    assert not ref_p.decode_paged_native       # served via gather lowering
    # after the drain only the prefix index still holds pages (cached
    # prompt prefixes); clearing it returns every page to the allocator
    ref_p.prefix.clear()
    assert ref_p.kv.allocator.used_pages == 0
    ref_p.kv.allocator.check()


def test_engine_fold_wo_token_parity(engine_setup):
    """fold_wo folds each attention sublayer's o-projection requant into
    the decode epilogue — token streams must be bit-identical to the
    unfolded path on both backends."""
    _, base = _drive(engine_setup, ops="ref", fold_wo=False)
    for name in ("ref", "pallas_fused"):
        _, toks = _drive(engine_setup, ops=name, fold_wo=True)
        assert toks == base, name


def test_engine_undersubscribed_pool_serves_all(engine_setup):
    """A pool far smaller than batch x cache_len still serves the whole
    queue (memory O(live tokens)) with unchanged tokens."""
    _, base = _drive(engine_setup, ops="ref", cache_mode="contiguous")
    eng, toks = _drive(engine_setup, ops="ref", cache_mode="paged",
                       page_size=8, num_pages=5)
    assert toks == base
    stats = eng.describe()["cache"]
    assert stats["capacity_tokens"] < 2 * 64   # genuinely undersubscribed


def test_engine_preempt_resume_is_bit_exact(engine_setup):
    cfg, qp, plans = engine_setup
    base = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                         ops="ref")
    r0 = Request(uid=0, prompt=[5, 9, 13], max_new_tokens=8)
    base.submit(r0)
    base.run_until_done()

    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    ra = Request(uid=1, prompt=[5, 9, 13], max_new_tokens=8)
    sa = eng.submit(ra)
    for _ in range(3):
        eng.step()
    mid = list(ra.out_tokens)
    eng.preempt(sa)
    assert sa.state == "preempted" and sa.pages   # lane freed, pages kept
    eng.submit(Request(uid=2, prompt=[100, 3], max_new_tokens=3))
    eng.run_until_done()
    assert ra.out_tokens[:len(mid)] == mid
    assert ra.out_tokens == r0.out_tokens         # resumed bit-exactly


def test_engine_sliding_window_wrap_parity():
    """Sliding-window arch with cache_len > window: decode positions
    wrap (slot = pos % window), so page-table writes revisit earlier
    pages — paged and contiguous streams must still agree bit-for-bit
    well past the wrap point."""
    cfg = M.reduce_config(get_config("h2o-danube-3-4b"), dtype="float32",
                          vocab=128, num_layers=1)
    assert cfg.window == 64
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)

    def drive(**kw):
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=80,
                            **kw)
        reqs = [Request(uid=i, prompt=[1 + i, 7, 3], max_new_tokens=70)
                for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done(max_steps=300)
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    toks_c = drive(ops="ref", cache_mode="contiguous", fold_wo=False)
    toks_p = drive(ops="ref", cache_mode="paged", fold_wo=True)
    assert toks_p == toks_c
    assert len(toks_c[0]) == 70                 # decoded past the wrap


def test_engine_pool_exhaustion_raises_typed(engine_setup):
    cfg, qp, plans = engine_setup
    # a prompt that can never fit the pool fails fast
    eng = ServingEngine(qp, plans, cfg, batch_size=1, cache_len=64,
                        ops="ref", page_size=16, num_pages=2)
    eng.submit(Request(uid=0, prompt=list(range(1, 40)),
                       max_new_tokens=2))
    with pytest.raises(PagePoolExhausted):
        eng.run_until_done()
    # two long decodes over a 2-page pool exhaust it mid-stream
    eng2 = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                         ops="ref", page_size=8, num_pages=3)
    for i in range(2):
        eng2.submit(Request(uid=i, prompt=[1 + i, 2], max_new_tokens=30))
    with pytest.raises(PagePoolExhausted):
        eng2.run_until_done()
    eng2.kv.allocator.check()                    # invariants survive

    with pytest.raises(ValueError, match="empty prompt"):
        eng2.submit(Request(uid=9, prompt=[], max_new_tokens=1))


def test_engine_rejects_prompt_longer_than_cache(engine_setup):
    """A prompt that cannot fit the logical cache fails typed at submit
    (paged and contiguous): prefill would otherwise write past the page
    table / cache slab and silently corrupt positions valid_len still
    marks live."""
    cfg, qp, plans = engine_setup
    for mode in ("paged", "contiguous"):
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=32,
                            ops="ref", cache_mode=mode)
        with pytest.raises(ValueError, match="exceeds the"):
            eng.submit(Request(uid=0, prompt=list(range(1, 40)),
                               max_new_tokens=2))
        # a prompt that exactly fills the cache is still admissible
        eng.submit(Request(uid=1, prompt=list(range(1, 33)),
                           max_new_tokens=1))
        eng.run_until_done()
