"""Serving engine benchmark: decode + prefill throughput, TTFT, prefix
reuse, across cache layouts and prefill modes.

Drives the same request schedule through three `ServingEngine`
configurations — the contiguous per-lane cache (token-streaming
prefill), the paged pool with streaming prefill, and the paged pool
with the **chunked batched prefill** pipeline (+ cross-session prefix
sharing) — asserting bit-identical token streams as a by-product, and
reports:

  * decode throughput (tokens/s) and provisioned KV bytes — derived
    from the stored element width via the engine's ``describe()``, so
    int4-packed pools report half the bytes — plus the quantized
    ``weight_bytes`` and the ``kv_pack`` dtype per config;
  * the **sub-8-bit memory tier**: an msr4-packed-weights config whose
    token streams are asserted bit-identical to the dense int8 baseline
    (the packing is lossless), and an ``kv_dtype="int4"`` paged config
    on the *same* page budget, gated at ≥ 1.8x kv_bytes reduction;
  * **prefill throughput** (prompt tokens/s) and **time-to-first-token**
    measured on a dedicated long-prompt request, after a warmup pass so
    XLA compile time is excluded;
  * the **prefix-hit rate** of the shared-prefix schedule on the
    chunked config (sessions re-using previously prefilled pages);
  * **tensor parallelism**: tp=1 vs tp=4 tokens/s and per-device KV
    bytes, measured in a subprocess forced to 4 host devices (the
    ``--xla_force_host_platform_device_count`` flag must precede jax
    init, so the sharded engine can't run in this process) — token
    parity sharded-vs-unsharded asserted as a by-product;
  * **speculative decoding**: accept-rate and effective tokens/s at
    spec_k in {0, 2, 4} on a decode-heavy prompt-lookup harness, with
    bit-identical streams asserted and an effective-throughput gate
    (>= 1.3x the spec-off decode) enforced.

Besides the usual CSV rows this module writes the machine-readable
``benchmarks/BENCH_serving.json`` (see ``benchmarks/check_bench_json.py``
for the schema, which the bench-smoke CI job enforces) — the artifact CI
uploads, so the serving perf trajectory is tracked per commit.  On CPU
all paths run through XLA/interpret so the ratios mostly document
overhead; on TPU the same harness times compiled kernels.
"""
import json
import os
import time

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "BENCH_serving.json")


def _build(quick: bool, **over):
    import jax
    from repro.configs.registry import get_config
    from repro.models import model as M
    from repro.models import transformer as tf
    from repro.quant import convert

    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1 if quick else 2,
                          **over)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


def _prompts(cfg, quick: bool):
    import numpy as np
    rng = np.random.default_rng(0)
    # 24-token prompts: 2 pages each on the default 16-token pages, so
    # two lanes + copy-on-write headroom fit the undersubscribed pool
    n_req, plen = (4, 24) if quick else (6, 24)
    shared = list(rng.integers(1, cfg.vocab, plen))
    prompts = [shared]
    # half the schedule shares the first prompt's prefix (last token
    # differs), the rest are disjoint — exercises the prefix table and
    # copy-on-write on the chunked config
    for i in range(1, n_req):
        if i % 2:
            prompts.append(shared[:-1] + [int(1 + i)])
        else:
            prompts.append(list(rng.integers(1, cfg.vocab, plen)))
    return prompts


def _engine(cfg, qp, plans, **engine_kw):
    from repro.serving import ServingEngine
    return ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                         ops="ref", **engine_kw)


def _serve(cfg, qp, plans, prompts, max_new: int, **engine_kw):
    from repro.serving import Request

    def run():
        eng = _engine(cfg, qp, plans, **engine_kw)
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_until_done()
        return eng, reqs, time.perf_counter() - t0

    _, reqs_w, _ = run()                    # warmup: compile both steps
    eng, reqs, dt = run()
    toks = [r.out_tokens for r in reqs]
    # every config must be deterministic run-to-run — the only parity
    # reference the lossy int4-KV tier has is itself
    assert toks == [r.out_tokens for r in reqs_w], "non-deterministic run"
    n_tok = sum(len(t) for t in toks)

    # TTFT + prefill throughput on a dedicated long-prompt request
    # (warm executables): step until the first output token lands
    from repro.serving import Request as Rq
    eng2 = _engine(cfg, qp, plans, **engine_kw)
    probe = Rq(uid=99, prompt=list(prompts[0]), max_new_tokens=2)
    eng2.submit(probe)
    t0 = time.perf_counter()
    while not probe.out_tokens:
        eng2.step()
    ttft = time.perf_counter() - t0
    n_pre = len(probe.prompt) - 1

    stats = eng.describe()["cache"]
    prefill = eng.describe()["prefill"]
    px = stats.get("prefix")
    queries = (px["hits"] + px["misses"]) if px else 0
    import jax
    weight_bytes = int(sum(leaf.size * leaf.dtype.itemsize
                           for leaf in jax.tree.leaves(qp)))
    return {
        "tokens": n_tok,
        "tokens_per_s": round(n_tok / dt, 2),
        # both byte counts derive from the stored element widths, so the
        # packed tiers (w_packed nibbles, int4 KV pools) report the real
        # HBM footprint, not a 1-byte/element assumption
        "kv_bytes": stats["kv_bytes"],
        "kv_pack": stats.get("kv_pack", "int8"),
        "weight_bytes": weight_bytes,
        "pages": {k: stats[k] for k in ("page_size", "num_pages")
                  if k in stats},
        "mode": stats["mode"],
        "prefill": {
            "mode": prefill["mode"],
            "chunk": prefill["chunk"],
            "ttft_s": round(ttft, 4),
            "tokens_per_s": round(n_pre / ttft, 2),
        },
        "prefix_hit_rate": round(px["hits"] / queries, 3)
        if queries else None,
    }, toks


def _spec_bench(cfg, qp, plans, quick: bool) -> dict:
    """Speculative decoding: accept-rate and effective tokens/s at
    spec_k in {0, 2, 4} on a decode-heavy prompt-lookup harness.

    The prompt's greedy continuation settles into a short cycle the
    n-gram proposer predicts, so the verify launch commits several
    tokens per step — ``speedup`` is end-to-end wall-clock (prefill
    included), and the committed streams are asserted bit-identical
    across every spec_k as a by-product.
    """
    from repro.serving import Request, ServingEngine

    prompt = [7] * 24
    max_new = 160

    def run(spec_k):
        # best-of-3 after a warmup pass, so one scheduler hiccup on a
        # shared CI box can't fail the speedup gate
        best = None
        for rep in range(4):
            eng = ServingEngine(qp, plans, cfg, batch_size=2,
                                cache_len=256, ops="ref", spec_k=spec_k)
            reqs = [Request(uid=i, prompt=list(prompt),
                            max_new_tokens=max_new) for i in range(2)]
            for r in reqs:
                eng.submit(r)
            t0 = time.perf_counter()
            eng.run_until_done()
            dt = time.perf_counter() - t0
            if rep == 0:
                continue                    # warmup: compile both steps
            n_tok = sum(len(r.out_tokens) for r in reqs)
            if best is None or n_tok / dt > best[0]:
                best = (n_tok / dt, eng.describe()["spec"],
                        [list(r.out_tokens) for r in reqs])
        return best

    out = {}
    toks = {}
    for k in (0, 2, 4):
        tps, stats, toks[k] = run(k)
        out["k%d" % k] = {
            "tokens_per_s": round(tps, 2),
            "accept_rate": stats["accept_rate"],
            "drafted": stats["drafted"],
            "accepted": stats["accepted"],
        }
    out["parity"] = toks[2] == toks[0] and toks[4] == toks[0]
    assert out["parity"], "speculative streams diverged from spec_k=0"
    base = out["k0"]["tokens_per_s"]
    out["speedup"] = round(max(out["k2"]["tokens_per_s"],
                               out["k4"]["tokens_per_s"]) / base, 2)
    assert out["k2"]["accept_rate"] > 0, out["k2"]
    assert out["speedup"] >= 1.3, (
        "speculative decoding effective tokens/s below the 1.3x gate: "
        f"{out}")
    return out


def _latency_bench(cfg, qp, plans, quick: bool) -> dict:
    """Request-latency distribution under open-loop Poisson load.

    Submits the schedule through the async :class:`ServingFrontend`
    with exp-distributed arrival gaps (open loop: arrivals don't wait
    for completions, so queueing delay is real) and reports the
    front end's own metrics surface — p50/p99 TTFT, inter-token gap and
    queue wait, plus terminal-state counts and occupancy.  A warmup
    pass excludes XLA compile time, exactly like the throughput bench.
    """
    import asyncio

    import numpy as np

    from repro.serving import QueueFull, ServingEngine, ServingFrontend

    n_req = 8 if quick else 16
    max_new = 4 if quick else 8
    rate = 20.0                       # requests/s
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab, 8)]
               for _ in range(n_req)]
    gaps = rng.exponential(1.0 / rate, n_req)

    def run_once():
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                            ops="ref", cache_mode="paged", page_size=16,
                            num_pages=7)
        fe = ServingFrontend(eng, max_pending=2 * n_req)

        async def main():
            runner = asyncio.create_task(fe.run())
            handles = []
            for p, g in zip(prompts, gaps):
                await asyncio.sleep(g)
                try:
                    handles.append(fe.submit(p, max_new))
                except QueueFull:
                    handles.append(None)
            await asyncio.gather(*[h.result() for h in handles if h])
            fe.close()
            await runner

        asyncio.run(main())
        return fe

    run_once()                        # warmup: compile both steps
    d = run_once().describe()
    lat = d["latency"]
    out = {
        "arrival_rate_per_s": rate,
        "submitted": d["submitted"],
        "terminal": d["terminal"],
        "ttft_s": lat["ttft_s"],
        "inter_token_s": lat["inter_token_s"],
        "queue_wait_s": lat["queue_wait_s"],
        "occupancy": d["occupancy"],
        "queue_depth": d["queue_depth"],
    }
    # the schema checker re-verifies these; fail at the source first
    assert sum(d["terminal"].values()) == d["submitted"], out
    assert lat["ttft_s"]["p50"] <= lat["ttft_s"]["p99"], out
    return out


# child script for the tensor-parallel measurement: the forced device
# count only takes effect before jax initializes, so it cannot run in
# this (already-1-device) process
_TP_CHILD = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, {src!r})
sys.path.insert(0, {here!r})
import json
import jax
assert jax.device_count() == 4, jax.device_count()
from bench_serving import _build, _engine, _prompts, _serve
quick = {quick!r}
# tp=4 must divide Hkv: lift the reduced config's head counts to 4/4
cfg, qp, plans = _build(quick, n_heads=4, n_kv_heads=4)
prompts = _prompts(cfg, quick)
max_new = 4 if quick else 8
pool = dict(cache_mode="paged", page_size=16, num_pages=7)
out = {{"devices": jax.device_count()}}
toks = {{}}
for tp in (1, 4):
    c, t = _serve(cfg, qp, plans, prompts, max_new, tp=tp, **pool)
    toks[tp] = t
    eng = _engine(cfg, qp, plans, tp=tp, **pool)
    d = eng.describe()["tp"]
    out["tp%d" % tp] = {{
        "tokens_per_s": c["tokens_per_s"],
        "mode": d["mode"],
        "kv_bytes": c["kv_bytes"],
        "per_device_kv_bytes": d["per_device_kv_bytes"],
    }}
out["parity"] = toks[1] == toks[4]
assert out["parity"], "tp=4 token streams diverged from tp=1"
assert out["tp4"]["mode"] == "sharded", out["tp4"]
print("TPJSON " + json.dumps(out))
"""


def _tp_bench(quick: bool) -> dict:
    import subprocess
    import sys

    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.abspath(os.path.join(here, "..", "src"))
    code = _TP_CHILD.format(src=src, here=here, quick=quick)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)      # the child sets its own, pre-import
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("TPJSON ")][-1]
    return json.loads(line[len("TPJSON "):])


def run(quick: bool = False):
    cfg, qp, plans = _build(quick)
    prompts = _prompts(cfg, quick)
    max_new = 4 if quick else 8
    configs = {}
    configs["contiguous"], toks_c = _serve(
        cfg, qp, plans, prompts, max_new, cache_mode="contiguous")
    # undersubscribed pool: far less than batch x cache_len provisioned
    pool = dict(cache_mode="paged", page_size=16, num_pages=7)
    configs["paged_streaming"], toks_s = _serve(
        cfg, qp, plans, prompts, max_new, prefill_chunk=0,
        prefix_cache=False, **pool)
    configs["paged_chunked"], toks_p = _serve(
        cfg, qp, plans, prompts, max_new, **pool)

    # sub-8-bit memory tier: msr4-packed weights are a lossless
    # re-encoding of the int8 plans, so the streams must be identical
    from repro.quant.pack import pack_tree
    qp4 = pack_tree(qp, scheme="msr4", group=64)
    configs["paged_msr4w"], toks_w = _serve(
        cfg, qp4, plans, prompts, max_new, **pool)
    # int4 KV pages on the *same* page budget as paged_chunked: the pool
    # stores nibbles, so kv_bytes halve (auto-fit would instead double
    # the page count at equal memory — 2x sessions).  Page requant is a
    # lossy tier: its stream is self-consistent (asserted run-to-run in
    # _serve), not bit-equal to the int8 pool's.
    configs["paged_kv4"], _ = _serve(
        cfg, qp, plans, prompts, max_new, kv_dtype="int4", **pool)

    parity = toks_p == toks_c and toks_s == toks_c and toks_w == toks_c
    assert parity, "paged/chunked/msr4 tokens diverged from contiguous"
    kv4_reduction = (configs["paged_chunked"]["kv_bytes"]
                     / configs["paged_kv4"]["kv_bytes"])
    assert kv4_reduction >= 1.8, (
        f"int4 KV pages reduce kv_bytes only {kv4_reduction:.2f}x "
        "(gate: >= 1.8x at equal page count)")
    tp = _tp_bench(quick)
    spec = _spec_bench(cfg, qp, plans, quick)
    latency = _latency_bench(cfg, qp, plans, quick)

    with open(JSON_PATH, "w") as f:
        json.dump({"configs": configs, "parity": parity, "tp": tp,
                   "spec": spec, "latency": latency, "arch": cfg.name,
                   "quick": quick},
                  f, indent=2)

    rows = []
    for name, c in configs.items():
        rows.append((f"serving_tokens_per_s[{name}]", c["tokens_per_s"],
                     "parity verified"))
        rows.append((f"serving_kv_bytes[{name}]", c["kv_bytes"],
                     f"mode={c['mode']}"))
        rows.append((f"serving_prefill_tokens_per_s[{name}]",
                     c["prefill"]["tokens_per_s"],
                     f"prefill={c['prefill']['mode']}"))
        rows.append((f"serving_ttft_s[{name}]", c["prefill"]["ttft_s"],
                     "time to first token, warm"))
    saved = 100.0 * (1 - configs["paged_chunked"]["kv_bytes"]
                     / configs["contiguous"]["kv_bytes"])
    rows.append(("serving_kv_bytes_saved_pct", round(saved, 1),
                 f"paged pool undersubscribed; JSON at {JSON_PATH}"))
    rows.append(("serving_kv_bytes_reduction[kv4]",
                 round(kv4_reduction, 2),
                 "int4 KV pages vs int8, equal page count (gate 1.8x)"))
    rows.append(("serving_weight_bytes[paged_chunked]",
                 configs["paged_chunked"]["weight_bytes"],
                 "dense int8 plans"))
    rows.append(("serving_weight_bytes[paged_msr4w]",
                 configs["paged_msr4w"]["weight_bytes"],
                 "msr4 nibbles + outlier lanes, streams bit-identical "
                 "to dense"))
    hit = configs["paged_chunked"]["prefix_hit_rate"]
    if hit is not None:
        rows.append(("serving_prefix_hit_rate", hit,
                     "shared-prefix schedule, chunked config"))
    speedup = (configs["paged_chunked"]["prefill"]["tokens_per_s"]
               / max(configs["paged_streaming"]["prefill"]["tokens_per_s"],
                     1e-9))
    rows.append(("serving_chunked_prefill_speedup", round(speedup, 2),
                 "chunked vs token-streaming prefill tokens/s"))
    for name in ("tp1", "tp4"):
        rows.append((f"serving_tokens_per_s[{name}]",
                     tp[name]["tokens_per_s"],
                     f"mode={tp[name]['mode']}, 4-device child, "
                     "parity verified"))
    rows.append(("serving_per_device_kv_bytes[tp4]",
                 tp["tp4"]["per_device_kv_bytes"],
                 f"of {tp['tp4']['kv_bytes']} global (Hkv/4 heads of "
                 "every page per device)"))
    for k in (0, 2, 4):
        c = spec["k%d" % k]
        note = "spec off (baseline)" if k == 0 else (
            f"accept_rate={c['accept_rate']}, "
            f"{c['accepted']}/{c['drafted']} drafts")
        rows.append((f"serving_spec_tokens_per_s[k{k}]",
                     c["tokens_per_s"], note))
    rows.append(("serving_spec_speedup", spec["speedup"],
                 "best spec_k vs spec off, streams bit-identical"))
    for metric in ("ttft_s", "inter_token_s", "queue_wait_s"):
        p = latency[metric]
        rows.append((f"serving_latency_{metric}[p50]", round(p["p50"], 4),
                     f"open-loop Poisson {latency['arrival_rate_per_s']}"
                     " req/s, async front end"))
        rows.append((f"serving_latency_{metric}[p99]", round(p["p99"], 4),
                     f"n={p['n']}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
