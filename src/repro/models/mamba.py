"""Mamba-2 (SSD, state-space duality) blocks — float (train/QAT) and
integer (serve) paths.

Float path: the chunk-parallel SSD algorithm (intra-chunk quadratic form +
inter-chunk state recurrence) — O(L * Lc) work, scan over chunks.

Integer path (DESIGN.md §6, mamba row): the in/out projections and the
depthwise conv are INT8 matmuls with dyadic requant (that is ~85 % of the
FLOPs); the recurrence itself runs in int32 fixed point with
  * Δt = i_softplus(dt_raw + bias)        (paper-style primitive reuse)
  * decay = i_exp(-Δt * A) as a 2^-15 fraction (multiply + shift update)
  * state h clipped at a design-time qmax (saturating accumulator).
The paper's softmax/GELU/LayerNorm units have no work here — documented as
the partial-inapplicability case in DESIGN.md §6.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard, shard_residual
from repro.models.common import ArchConfig
from repro.models.layers import _init, maybe_fq, fq_weight


def proj_width(cfg: ArchConfig) -> int:
    di = cfg.ssm_d_inner
    return 2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads


def init_mamba(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    d, di = cfg.d_model, cfg.ssm_d_inner
    h = cfg.ssm_heads
    conv_ch = di + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "in_proj": _init(ks[0], (d, proj_width(cfg)), dtype),
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_ch), dtype, scale=3.0),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32)
                    * (math.log(0.1) - math.log(0.001))
                    + math.log(0.001)))),
        "norm_gamma": jnp.ones((di,), dtype),
        "out_proj": _init(ks[3], (di, d), dtype),
    }


def _split_proj(zxbcdt, cfg: ArchConfig):
    di, g, n, h = (cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state,
                   cfg.ssm_heads)
    z, x, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return z, x, B, C, dt


def _conv1d(xbc, w, state=None):
    """Causal depthwise conv, width K. xbc: (B,L,C); w: (K,C).

    With ``state`` (B,K-1,C): decode mode, returns (out, new_state)."""
    k = w.shape[0]
    if state is not None:
        full = jnp.concatenate([state, xbc], axis=1)
        out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
        return out, full[:, -(k - 1):]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(k)), None


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < m <= i} x[..., m]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunk-parallel SSD.  x:(b,l,h,p) dt:(b,l,h) A:(h,) B,C:(b,l,g,n).

    Returns (y, h_last).  h: (b,h,p,n)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0
    nc = l // chunk
    rep = h // g
    xs = x.reshape(b, nc, chunk, h, p)
    dts = dt.reshape(b, nc, chunk, h)
    Bs = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)
    Cs = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)
    dtA = dts * A[None, None, None, :]                  # (b,nc,c,h) <= 0
    ca = jnp.cumsum(dtA, axis=2)

    # intra-chunk (diag) term
    L = jnp.exp(_segsum(dtA.transpose(0, 1, 3, 2)))     # (b,nc,h,c,c)
    scores = jnp.einsum("bzchn,bzdhn->bzhcd", Cs, Bs) * L
    y_diag = jnp.einsum("bzhcd,bzdh,bzdhp->bzchp", scores, dts, xs)

    # chunk states
    decay_to_end = jnp.exp(ca[:, :, -1:, :] - ca)       # (b,nc,c,h)
    S = jnp.einsum("bzchn,bzch,bzch,bzchp->bzhnp",
                   Bs, decay_to_end, dts, xs)           # (b,nc,h,n,p)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(dtA, axis=2))         # (b,nc,h)

    def step(hprev, inp):
        S_c, dec = inp
        return hprev * dec[..., None, None] + S_c, hprev

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), x.dtype)
    h_last, h_prevs = jax.lax.scan(
        step, h0, (S.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (b,nc,h,n,p)

    y_off = jnp.einsum("bzchn,bzch,bzhnp->bzchp",
                       Cs, jnp.exp(ca), h_prevs)
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, h_last


def mamba_fwd(p, u, cfg: ArchConfig, qat=False, chunk: int = 128,
              h0=None, conv_state=None, return_state=False):
    """Float/QAT forward. u: (B,L,D) -> (B,L,D)."""
    b, l, d = u.shape
    di = cfg.ssm_d_inner
    uq = maybe_fq(u, cfg.s_act8, enabled=qat)
    zxbcdt = jnp.einsum("bld,dw->blw", uq, fq_weight(p["in_proj"], 1, qat))
    z, x, B, C, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, B, C], axis=-1)
    xbc, new_conv = _conv1d(xbc, p["conv_w"].astype(u.dtype), conv_state)
    xbc = jax.nn.silu(xbc)
    # QAT: align the float path with the integer grids (x/B/C on the
    # +-16 int8 grid, Δt saturating at 2.0 on the 2^-12 grid)
    xbc = maybe_fq(xbc, 16.0 / 127.0, enabled=qat)
    x, B, C = jnp.split(xbc, [di, di + cfg.ssm_groups * cfg.ssm_state],
                        axis=-1)
    h = cfg.ssm_heads
    x = x.reshape(b, l, h, cfg.ssm_head_dim)
    B = B.reshape(b, l, cfg.ssm_groups, cfg.ssm_state)
    C = C.reshape(b, l, cfg.ssm_groups, cfg.ssm_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    if qat:
        dt = jnp.minimum(dt, 2.0)
    A = -jnp.exp(p["A_log"])
    x = shard(x, "batch", "seq", "heads", None)
    ck = min(chunk, l)
    while l % ck:
        ck -= 1
    y, h_last = ssd_chunked(x.astype(jnp.float32), dt, A,
                            B.astype(jnp.float32), C.astype(jnp.float32),
                            ck, h0=h0)
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, l, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    # RMSNorm before out-projection (mamba2)
    yf = y.astype(jnp.float32)
    y = (yf / jnp.sqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm_gamma"]).astype(u.dtype)
    y = maybe_fq(y, cfg.s_act8, enabled=qat)
    out = jnp.einsum("bld,dw->blw", y, fq_weight(p["out_proj"], 1, qat))
    out = shard_residual(out)
    if return_state:
        return out, (h_last, new_conv)
    return out


def mamba_step(p, u_t, state, cfg: ArchConfig):
    """Float single-token decode step.  u_t: (B,D); state: (h, conv)."""
    h_prev, conv_state = state
    out, (h_new, conv_new) = mamba_fwd(
        p, u_t[:, None, :], cfg, qat=False, chunk=1, h0=h_prev,
        conv_state=conv_state, return_state=True)
    return out[:, 0], (h_new, conv_new)


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    h = jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                  dtype)
    conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype)
    return h, conv
