"""Offline Pallas kernel-contract checking: :func:`check_launch`.

Every kernel wrapper in ``repro.kernels`` guards its launch with
preconditions — tile divisibility, the ``MAX_SKV``/``MAX_SQ`` budgets,
page-size constraints, scalar-prefetch operand shapes.  This module
states those contracts *declaratively and without executing anything*
(pure Python, no jax import), so they can be

  * checked offline — "would this shape take the fused kernel or fall
    back, and why?" (:func:`check_launch` returns a
    :class:`LaunchReport` with the predicted grid, block shapes,
    scalar-prefetch operands and a VMEM footprint estimate);
  * enforced in-kernel — the wrappers call :func:`require_launch`,
    which raises :class:`KernelContractError` (an ``AssertionError``
    subclass, so pre-existing ``assert``-expecting callers and tests
    keep working) with every violated clause named;
  * consulted by the dispatching backends — :func:`can_tile`,
    :func:`can_tile_decode` and :func:`can_tile_prefill` are the
    fused-vs-fallback tiling policy ``ops.backends.pallas_fused``
    delegates to.

The contract clauses mirror the kernel wrappers clause-for-clause; a
report with ``ok=False`` predicts an ``AssertionError`` from the kernel,
``fused=False`` predicts the backend's exact fallback path.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.budgets import MAX_ROWSUM_LEN, MAX_SQ

#: the online (one-pass) kernel's own row budget: its running rescale
#: bounds the accumulator differently, see kernels/int_attention.py
MAX_SKV_ONLINE = 1 << 16

#: backend tiling-policy default (ops.backends.pallas_fused min_block)
MIN_BLOCK = 16


class KernelContractError(AssertionError):
    """A kernel launch precondition is violated.

    Subclasses ``AssertionError``: the kernels historically ``assert``-ed
    these clauses, and callers/tests relying on that contract must keep
    working.  Fields: ``op`` (kernel name), ``reasons`` (every violated
    clause, human-readable, location-bearing).
    """

    def __init__(self, op: str, reasons):
        self.op = op
        self.reasons = tuple(reasons)
        super().__init__(
            f"{op} launch contract violated: " + "; ".join(self.reasons))


@dataclasses.dataclass(frozen=True)
class LaunchReport:
    """What a kernel launch would look like, statically.

    ``ok``     — the kernel's own preconditions hold (False predicts an
                 in-wrapper assertion);
    ``fused``  — the backend tiling policy would take the fused kernel
                 (False predicts the documented exact fallback);
    ``reasons``— every violated / declining clause;
    ``grid``   — the Pallas grid the launch would use;
    ``blocks`` — resolved block shapes (after ``_fit_block`` clamping);
    ``vmem_bytes`` — per-grid-step VMEM estimate (operand blocks +
                 output block + scratch);
    ``scalar_prefetch`` — ``(name, shape)`` for each scalar-prefetch
                 operand the launch consumes.
    """

    op: str
    ok: bool
    fused: bool
    reasons: tuple = ()
    grid: tuple = ()
    blocks: dict = dataclasses.field(default_factory=dict)
    vmem_bytes: int = 0
    scalar_prefetch: tuple = ()


def fit_block(blk: int, dim: int) -> int:
    """Pure twin of ``ops.backends.pallas._fit_block``: the largest
    block <= ``blk`` dividing ``dim``."""
    blk = min(blk, dim)
    while dim % blk:
        blk -= 1
    return blk


# ---------------------------------------------------------------- policy --

def can_tile(sq: int, skv: int, bq: int, bkv: int,
             min_block: int = MIN_BLOCK) -> bool:
    """Fused prefill-attention tiling policy (pallas_fused backend)."""
    if skv > MAX_ROWSUM_LEN:
        return False          # exact row sum leaves the int32 budget
    if sq < min_block or skv < min_block:
        return False          # tiny problem (e.g. decode): oracle wins
    if bq < min_block or bkv < min_block:
        return False          # no usable divisor (e.g. prime Sq)
    return True


def can_tile_decode(sq: int, L: int, d: int, bkv: int,
                    min_block: int = MIN_BLOCK) -> bool:
    """Fused decode tiling policy (pallas_fused backend)."""
    if sq > MAX_SQ:
        return False          # scratch holds at most MAX_SQ query rows
    if L > MAX_ROWSUM_LEN:
        return False          # exact row sum leaves the int32 budget
    if bkv < min_block:
        return False          # no usable cache-block divisor
    if d % 2:
        return False          # odd head dims: lane-hostile, oracle wins
    return True


def can_tile_prefill(L: int, d: int, bq: int, bkv: int,
                     min_block: int = MIN_BLOCK) -> bool:
    """Fused paged-prefill tiling policy (pallas_fused backend)."""
    if L > MAX_ROWSUM_LEN:
        return False          # exact row sum leaves the int32 budget
    if bq < min_block or bkv < min_block:
        return False          # tiny chunk / page: oracle wins
    if d % 2:
        return False          # odd head dims: lane-hostile, oracle wins
    return True


# ----------------------------------------------------------- per-kernel --

def _check_int8_matmul(m, n, k, bm=128, bn=128, bk=512, out_bits=8,
                       has_bias=False, per_channel=False, packed=False):
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    reasons = []
    if m % bm or n % bn or k % bk:
        reasons.append("blocks must divide the problem: "
                       f"(M,N,K)=({m},{n},{k}) %% (bm,bn,bk)="
                       f"({bm},{bn},{bk})")
    if packed and (k % 2 or bk % 2):
        reasons.append("packed weights pair nibbles along K: K and bk "
                       f"must be even (got K={k}, bk={bk})")
    # packed operands halve the weight-block bytes: the w block is
    # (bk // 2, bn) int8 nibbles, unpacked in-register
    vmem = bm * bk + (bk // 2 if packed else bk) * bn \
        + bm * bn * 4                               # x8 + w + acc scratch
    vmem += bm * bn * (1 if out_bits <= 8 else 4)   # output block
    if has_bias:
        vmem += bn * 4
    if per_channel:
        vmem += bn * 4
    return LaunchReport(
        op="int8_matmul_packed" if packed else "int8_matmul",
        ok=not reasons, fused=not reasons,
        reasons=tuple(reasons),
        grid=(m // bm, n // bn, k // bk) if not reasons else (),
        blocks={"bm": bm, "bn": bn, "bk": bk}, vmem_bytes=vmem)


def _check_int8_matmul_packed(m, n, k, bm=128, bn=128, bk=512, out_bits=8,
                              has_bias=False, per_channel=False):
    return _check_int8_matmul(m, n, k, bm=bm, bn=bn, bk=bk,
                              out_bits=out_bits, has_bias=has_bias,
                              per_channel=per_channel, packed=True)


def _attn_common(h, hkv, reasons):
    if h % hkv:
        reasons.append(f"GQA requires Hkv | H: got H={h}, Hkv={hkv}")


def _check_int_attention(b, sq, skv, h, hkv, d, bq=128, bkv=128,
                         out_bits=8, per_channel=False,
                         min_block=MIN_BLOCK, online=False):
    op = "int_attention_online" if online else "int_attention"
    bq, bkv = min(bq, sq), min(bkv, skv)    # the kernels' own clamping
    reasons, policy = [], []
    _attn_common(h, hkv, reasons)
    budget = MAX_SKV_ONLINE if online else MAX_ROWSUM_LEN
    if skv > budget:
        reasons.append(f"row-sum int32 budget: Skv <= {budget} "
                       f"(got {skv})")
    if sq % bq or skv % bkv:
        reasons.append(f"blocks must divide (Sq,Skv)=({sq},{skv}): "
                       f"(bq,bkv)=({bq},{bkv})")
    if not can_tile(sq, skv, bq, bkv, min_block):
        policy.append(f"tiling policy declines: sq={sq}, skv={skv}, "
                      f"bq={bq}, bkv={bkv}, min_block={min_block}")
    out_elem = 1 if (online or out_bits <= 8) else 4
    vmem = (bq * d + 2 * bkv * d                    # q + k + v blocks
            + bq * d * out_elem                     # output block
            + 2 * bq * 4 + bq * d * 4)              # m/s/acc scratch
    if per_channel:
        vmem += d * 4
    if sq % bq or skv % bkv:
        grid = ()
    elif online:
        grid = (b, h, sq // bq, skv // bkv)
    else:
        grid = (b, h, sq // bq, 3, skv // bkv)
    return LaunchReport(
        op=op, ok=not reasons, fused=not (reasons or policy),
        reasons=tuple(reasons + policy), grid=grid,
        blocks={"bq": bq, "bkv": bkv}, vmem_bytes=vmem)


def _check_int_decode_attention(b, sq, h, hkv, d, L=None, bkv=128,
                                max_pages=0, page_size=0, out_bits=8,
                                per_channel=False, fold=False, n_out=0,
                                kv_pack=False, num_pages=0,
                                min_block=MIN_BLOCK):
    paged = page_size > 0
    if paged:
        L = max_pages * page_size
    assert L is not None, "need L (contiguous) or max_pages+page_size"
    reasons, policy = [], []
    _attn_common(h, hkv, reasons)
    if kv_pack:
        if not paged:
            reasons.append("int4 KV pages require the paged layout "
                           "(kv_pack without page_size)")
        if d % 2:
            reasons.append("int4 KV pages pair nibbles along the head "
                           f"dim: d must be even (got {d})")
    if sq > MAX_SQ:
        reasons.append(f"decode kernel holds Sq <= {MAX_SQ} query rows "
                       f"in scratch (got {sq})")
    if L > MAX_ROWSUM_LEN:
        reasons.append("row-sum int32 budget: cache_len <= "
                       f"{MAX_ROWSUM_LEN} (got {L})")
    bkv = min(bkv, page_size if paged else L)
    if paged:
        if page_size % bkv:
            reasons.append("KV block must tile the physical page: "
                           f"page_size={page_size}, bkv={bkv}")
    elif L % bkv:
        reasons.append(f"KV block must tile the cache: L={L}, bkv={bkv}")
    if fold and not n_out:
        reasons.append("folded wo projection needs n_out (= wo_w8 "
                       "output channels)")
    if not can_tile_decode(sq, L, d, bkv, min_block):
        policy.append(f"tiling policy declines: sq={sq}, L={L}, d={d}, "
                      f"bkv={bkv}, min_block={min_block}")
    prefetch = [("valid_len", (b,))]
    if paged:
        prefetch.append(("pages", (b, max_pages)))
    if kv_pack:
        # per-page dequant shifts ride as two more scalar-prefetch
        # operands; K/V blocks hold (bkv, d // 2) nibbles
        prefetch.append(("k_shift", (num_pages,)))
        prefetch.append(("v_shift", (num_pages,)))
    kv_elem = d // 2 if kv_pack else d
    vmem = (sq * d + 2 * bkv * kv_elem              # q + k + v blocks
            + 2 * sq * 4 + sq * d * 4)              # m/s/acc scratch
    if per_channel:
        vmem += d * 4
    if fold:
        vmem += (d * n_out                          # wo weight slab
                 + sq * d                           # int8 attention tile
                 + sq * n_out * 4                   # wo accumulator
                 + sq * n_out)                      # output block
    else:
        vmem += sq * d * (1 if out_bits <= 8 else 4)
    grid = (b, h, 3, L // bkv) if not (L % bkv if not paged
                                       else page_size % bkv) else ()
    return LaunchReport(
        op="int_decode_attention", ok=not reasons,
        fused=not (reasons or policy), reasons=tuple(reasons + policy),
        grid=grid, blocks={"bkv": bkv}, vmem_bytes=vmem,
        scalar_prefetch=tuple(prefetch))


def _check_int_paged_prefill(b, c, h, hkv, d, max_pages, page_size,
                             bq=128, bkv=128, out_bits=8,
                             per_channel=False, fold=False, n_out=0,
                             kv_pack=False, num_pages=0,
                             min_block=MIN_BLOCK):
    L = max_pages * page_size
    reasons, policy = [], []
    _attn_common(h, hkv, reasons)
    if kv_pack and d % 2:
        reasons.append("int4 KV pages pair nibbles along the head dim: "
                       f"d must be even (got {d})")
    if L > MAX_ROWSUM_LEN:
        reasons.append("row-sum int32 budget: logical cache <= "
                       f"{MAX_ROWSUM_LEN} (got {L})")
    bq = min(bq, c)
    bkv = min(bkv, page_size)
    if c % bq:
        reasons.append(f"query block must tile the chunk: c={c}, bq={bq}")
    if page_size % bkv:
        reasons.append("KV block must tile the physical page: "
                       f"page_size={page_size}, bkv={bkv}")
    if fold and not n_out:
        reasons.append("folded wo projection needs n_out (= wo_w8 "
                       "output channels)")
    if not can_tile_prefill(L, d, bq, bkv, min_block):
        policy.append(f"tiling policy declines: L={L}, d={d}, bq={bq}, "
                      f"bkv={bkv}, min_block={min_block}")
    kv_elem = d // 2 if kv_pack else d
    vmem = (bq * d + 2 * bkv * kv_elem
            + 2 * bq * 4 + bq * d * 4)
    if per_channel:
        vmem += d * 4
    if fold:
        vmem += (d * n_out + bq * d + bq * n_out * 4 + bq * n_out)
    else:
        vmem += bq * d * (1 if out_bits <= 8 else 4)
    prefetch = [("pos_end", (b,)), ("pages", (b, max_pages))]
    if kv_pack:
        prefetch.append(("k_shift", (num_pages,)))
        prefetch.append(("v_shift", (num_pages,)))
    grid = (b, c // bq, h, 3, L // bkv) \
        if not (c % bq or page_size % bkv) else ()
    return LaunchReport(
        op="int_paged_prefill", ok=not reasons,
        fused=not (reasons or policy), reasons=tuple(reasons + policy),
        grid=grid, blocks={"bq": bq, "bkv": bkv}, vmem_bytes=vmem,
        scalar_prefetch=tuple(prefetch))


_CHECKS = {
    "int8_matmul": _check_int8_matmul,
    "int8_matmul_packed": _check_int8_matmul_packed,
    "int_attention": _check_int_attention,
    "int_decode_attention": _check_int_decode_attention,
    "int_paged_prefill": _check_int_paged_prefill,
}


def check_launch(op: str, **params) -> LaunchReport:
    """Statically validate a kernel launch.  ``op`` is one of
    ``int8_matmul`` / ``int_attention`` (pass ``online=True`` for the
    one-pass kernel) / ``int_decode_attention`` / ``int_paged_prefill``;
    ``params`` are the launch shapes (see the per-kernel helpers).
    Never executes or imports jax — safe anywhere, including CI."""
    if op not in _CHECKS:
        raise KeyError(f"unknown kernel op {op!r}; known: "
                       f"{sorted(_CHECKS)}")
    return _CHECKS[op](**params)


def check_tp_launch(op: str, tp: int = 1, **params) -> LaunchReport:
    """Statically validate the *per-shard* kernel launch of a
    tensor-parallel serving step: under ``shard_map`` head sharding
    (``distributed.tp_serving``) each device launches the attention
    kernel with ``h/tp`` query heads and ``hkv/tp`` KV heads of the
    global problem — every other shape (batch, chunk, cache geometry,
    head dim) is unchanged.  This is the offline twin of the in-wrapper
    ``require_launch`` call, which under shard_map sees (and validates)
    exactly these local shapes.  Shard-divisibility violations come back
    as a failed report, same as any other contract clause."""
    if op not in ("int_attention", "int_decode_attention",
                  "int_paged_prefill"):
        raise KeyError(f"check_tp_launch covers the attention launches "
                       f"of the tp serving path, not {op!r}")
    reasons = []
    if tp < 1:
        reasons.append(f"tp must be >= 1 (got {tp})")
    h, hkv = params.get("h"), params.get("hkv")
    if h is None or hkv is None:
        reasons.append("per-shard check needs the global h and hkv")
    elif tp >= 1:
        if hkv % tp:
            reasons.append(f"tp={tp} must divide the KV head count "
                           f"(hkv={hkv}): each shard owns hkv/tp heads")
        if h % tp:
            reasons.append(f"tp={tp} must divide the query head count "
                           f"(h={h})")
    if reasons:
        return LaunchReport(op=op, ok=False, fused=False,
                            reasons=tuple(reasons))
    return check_launch(op, **{**params, "h": h // tp, "hkv": hkv // tp})


def require_launch(report: LaunchReport) -> LaunchReport:
    """Raise :class:`KernelContractError` unless the kernel's own
    preconditions hold (``report.ok``).  Policy declines (``fused=False``
    with ``ok=True``) pass — the backend handles those by falling back."""
    if not report.ok:
        raise KernelContractError(report.op, report.reasons)
    return report


# ------------------------------------------------- request feasibility --


class RequestInfeasible(ValueError):
    """A request that can NEVER complete on the engine's cache geometry.

    Admitting it anyway would either corrupt live cache positions
    (prompt longer than the logical cache) or burn pool pages and lane
    time on a stream guaranteed to retire short of ``max_new_tokens``
    (prompt + continuation overrunning ``cache_len``) — and the failure
    would only surface deep inside a step, or never.  Raised at the
    submit / CLI boundary instead.  Fields: ``prompt_len``,
    ``max_new_tokens``, ``cache_len``, ``reasons`` (every violated
    clause)."""

    def __init__(self, prompt_len: int, max_new_tokens: int,
                 cache_len: int, reasons):
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.cache_len = cache_len
        self.reasons = tuple(reasons)
        super().__init__(
            f"infeasible request (prompt_len={prompt_len}, "
            f"max_new_tokens={max_new_tokens}, cache_len={cache_len}): "
            + "; ".join(self.reasons))


def check_request(prompt_len: int, max_new_tokens: int, cache_len: int,
                  window: int = 0, page_size: int = 0,
                  num_pages: int = 0) -> tuple:
    """Statically validate one serving request against a cache geometry;
    returns the tuple of violated clauses (empty = feasible).

    The exact feasibility bound for full-causal archs (``window == 0``):
    prefill writes ``prompt_len - 1`` K/V positions and every decoded
    token writes one more, so the request reaches ``max_new_tokens``
    only if ``prompt_len - 1 + max_new_tokens <= cache_len`` (the engine
    retires lanes at ``pos >= cache_len``).  Sliding-window archs wrap,
    so only the prompt-fits clause applies.  With a paged pool
    (``page_size`` / ``num_pages`` given), a prompt whose block count
    exceeds the allocatable pool can never be admitted either — that
    used to surface as :class:`~repro.serving.kvcache.PagePoolExhausted`
    from deep inside a scheduler step.  Pure Python, no jax — safe at
    any CLI / server boundary."""
    reasons = []
    if prompt_len < 1:
        reasons.append("empty prompt: a request needs at least one token")
    if max_new_tokens < 1:
        reasons.append(f"max_new_tokens must be >= 1 (got "
                       f"{max_new_tokens})")
    L = min(cache_len, window) if window > 0 else cache_len
    if window == 0 and prompt_len > L:
        reasons.append(
            f"prompt of {prompt_len} tokens exceeds the cache_len={L} "
            "logical cache: prefill would write past the page table / "
            "cache slab and silently corrupt live positions")
    elif window == 0 and prompt_len - 1 + max_new_tokens > cache_len:
        reasons.append(
            f"prompt_len + max_new_tokens exceeds the cache: the stream "
            f"needs {prompt_len - 1 + max_new_tokens} K/V positions but "
            f"cache_len={cache_len} — the request would silently retire "
            f"after {cache_len - prompt_len + 1} token(s); shrink "
            "max_new_tokens or raise cache_len")
    if window == 0 and page_size > 0 and num_pages > 0:
        span = min(max(prompt_len - 1, 0), L)
        blocks = -(-span // page_size)
        if blocks > num_pages - 1:
            reasons.append(
                f"prompt prefill needs {blocks} pages but the pool only "
                f"has {num_pages - 1} allocatable (page 0 is the null "
                "page): the admission can never succeed")
    return tuple(reasons)


def require_request(prompt_len: int, max_new_tokens: int, cache_len: int,
                    window: int = 0, page_size: int = 0,
                    num_pages: int = 0) -> None:
    """Raise :class:`RequestInfeasible` if :func:`check_request` finds
    any violated clause."""
    reasons = check_request(prompt_len, max_new_tokens, cache_len,
                            window=window, page_size=page_size,
                            num_pages=num_pages)
    if reasons:
        raise RequestInfeasible(prompt_len, max_new_tokens, cache_len,
                                reasons)


__all__ = [
    "KernelContractError", "LaunchReport", "MAX_SKV_ONLINE", "MIN_BLOCK",
    "RequestInfeasible", "can_tile", "can_tile_decode",
    "can_tile_prefill", "check_launch", "check_request",
    "check_tp_launch", "fit_block", "require_launch", "require_request",
]
