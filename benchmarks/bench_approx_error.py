"""Approximation-error table for every integer nonlinear unit (supports
the paper's §III claims; one row per unit, max & RMS error vs float)."""
import math

import jax.numpy as jnp
import numpy as np

from repro.core import activations as act
from repro.core import intmath
from repro.core import softmax as ism


def _err(got, ref):
    d = np.abs(got - ref)
    return float(d.max()), float(np.sqrt((d ** 2).mean()))


def run():
    rows = []
    s = 2.0 ** -14
    plan = intmath.make_iexp(s)
    x = np.linspace(-20, 0, 20000)
    q = np.round(x / s).astype(np.int32)
    mx, rms = _err(np.asarray(intmath.i_exp(jnp.asarray(q), plan))
                   * plan.s_out, np.exp(q * s))
    rows.append(("approx_iexp_maxerr", round(mx, 6), f"rms={rms:.2e}"))

    s = 16 / 1024
    gp = intmath.make_igelu(s, 1024)
    x = np.linspace(-8, 8, 8001)
    q = np.round(x / s).astype(np.int32)
    erf = np.vectorize(math.erf)
    mx, rms = _err(np.asarray(intmath.i_gelu(jnp.asarray(q), gp))
                   * gp.s_out, 0.5 * (q * s) * (1 + erf(q * s / 2**0.5)))
    rows.append(("approx_igelu_maxerr", round(mx, 5), f"rms={rms:.2e}"))

    sp = ism.make_isoftmax(s_score=0.01, qmax_score=2**21)
    rng = np.random.default_rng(0)
    logits = rng.normal(0, 3, (64, 128)) / 0.01
    qq = jnp.asarray(np.round(logits).astype(np.int32))
    p = np.asarray(ism.i_softmax(qq, sp)) * ism.S_PROB
    xs = logits * 0.01
    ref = np.exp(xs - xs.max(-1, keepdims=True))
    ref /= ref.sum(-1, keepdims=True)
    mx, rms = _err(p, ref)
    rows.append(("approx_isoftmax_maxerr", round(mx, 5), f"rms={rms:.2e}"))

    slp = act.make_isilu(16 / 1024, 1024, s_out=8 / 127)
    x = np.linspace(-8, 8, 4001)
    q = np.round(x / (16 / 1024)).astype(np.int32)
    mx, rms = _err(np.asarray(act.i_silu(jnp.asarray(q), slp)) * (8 / 127),
                   x / (1 + np.exp(-x)))
    rows.append(("approx_isilu_maxerr", round(mx, 5), f"rms={rms:.2e}"))

    n = rng.integers(0, 2**31 - 1, 100000).astype(np.int32)
    got = np.asarray(intmath.i_sqrt(jnp.asarray(n)))
    want = np.array([math.isqrt(int(v)) for v in n])
    rows.append(("approx_isqrt_exact",
                 int(np.array_equal(got, want)), "1=bit-exact"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
