"""Distributed-optimization tricks (DESIGN.md §3).

**INT8 gradient compression with error feedback** — the paper's own insight
(integer arithmetic is ~10x cheaper than float, Fig. 2) applied to the
interconnect: gradients are quantized to int8 with per-tensor dyadic scales
before the data-parallel all-reduce, cutting DP sync wire bytes 2x vs bf16
(4x vs f32).  The residual (quantization error) is carried to the next step
(error feedback), which keeps SGD convergence unbiased in expectation.

Works inside pjit: the quantized tensor is what crosses the ``data`` axis;
XLA reduces int32 partial sums exactly (no float non-determinism across
ring orders — a reproducibility win the integer paper would appreciate).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class CompressionState(NamedTuple):
    error: Pytree          # error-feedback residual, same shapes as grads


def init_compression(grads_like: Pytree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                           grads_like))


def compress_decompress(g, err):
    """Fake-transport int8 quantization of one gradient tensor.

    Returns (g_hat, new_err): g_hat is exactly what the receiving side
    reconstructs; under pjit the int8 tensor is the one all-reduced."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    g_hat = (q * scale).astype(jnp.float32)
    return g_hat.astype(g.dtype), gf - g_hat


def compressed_grads(grads: Pytree, state: CompressionState
                     ) -> Tuple[Pytree, CompressionState]:
    out = jax.tree.map(compress_decompress, grads, state.error)
    g_hat = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, CompressionState(error=err)


def psum_int8(x, axis_name: str):
    """shard_map building block: int8-quantize, all-reduce int32, dequant.

    The wire carries 1-byte payloads + one f32 scale; the int32 sum is
    exact (order-independent)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def psum_int32(x, axis_name: str):
    """Exact all-reduce of int32 partial accumulators across ``axis_name``.

    The quantized collective of the tensor-parallel serving path: each
    device contributes the int32 partial dot over its head slice, and
    the integer sum is exact and order-independent — so a requant
    epilogue applied *after* this psum rounds exactly once, on the same
    accumulator a single device would have produced.  (Contrast
    :func:`psum_int8`, which trades exactness for wire bytes on the
    float training grads; serving partials are already integers, so the
    wire payload is the accumulator itself.)"""
    x = jnp.asarray(x)
    assert x.dtype == jnp.int32, f"psum_int32 takes int32, got {x.dtype}"
    return jax.lax.psum(x, axis_name)
