"""Production mesh construction (multi-pod dry-run step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(axes))


def make_mesh(shape, axes):
    """Arbitrary test meshes (e.g. (2,2) on 4 fake devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(axes))


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get("model", 1)
