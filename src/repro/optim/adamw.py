"""AdamW from scratch (no optax in this environment).

Supports:
  * decoupled weight decay, global-norm gradient clipping
  * bf16 or f32 moments (``moment_dtype``)
  * ZeRO-1 style sharding: with ``zero1=True`` the moment tensors carry a
    sharding constraint that spreads them over the ``data`` axis (flattened
    padding trick), cutting optimizer-state HBM by the DP degree — how the
    235B MoE's train_4k cell fits 16 GB/chip (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Pytree
    v: Pytree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    zero1: bool = False


def _zero1_shard(x):
    """Spread a moment tensor over the data axis when a mesh is in scope."""
    from repro.distributed.sharding import current_axes, get_abstract_mesh
    from jax.sharding import PartitionSpec as P
    axes = current_axes()
    if "data" not in axes:
        return x
    # shard the first dim divisible by the data axis size
    mesh = get_abstract_mesh()
    dsize = dict(zip(mesh.axis_names, mesh.axis_sizes))["data"]
    spec = [None] * x.ndim
    for i, s in enumerate(x.shape):
        if s % dsize == 0 and s >= dsize:
            spec[i] = "data"
            break
    return jax.lax.with_sharding_constraint(x, P(*spec))


def adamw_init(params: Pytree, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)

    def zeros(p):
        z = jnp.zeros(p.shape, dt)
        return _zero1_shard(z) if cfg.zero1 else z

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Pytree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Pytree, state: AdamWState, params: Pytree,
                 cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    dt = jnp.dtype(cfg.moment_dtype)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    step = state.step + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        # NOTE: ZeRO-1 placement is pinned by the jit in/out shardings
        # (launch.dryrun._opt_pspecs) — re-constraining here would fight
        # 2-D-sharded params and force f32 moment resharding.
        return p_new, m_new.astype(dt), v_new.astype(dt)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    p_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return p_new, AdamWState(step, m_new, v_new), {"grad_norm": gnorm}
