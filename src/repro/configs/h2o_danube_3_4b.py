"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention
[arXiv:2401.16818].  SWA => long_500k RUNS with a windowed KV cache."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, d_ff=10240, vocab=32000, head_dim=120,
    window=4096, activation="swiglu", norm="rmsnorm", rope_theta=10000.0,
)
