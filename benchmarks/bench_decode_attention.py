"""Fused-vs-unfused decode attention over ragged KV caches.

The serving hot path: Sq=1 queries against a cache whose slots are
raggedly occupied.  For each (batch, cache_len, occupancy) point, times
the single-launch ``pallas_fused`` decode kernel (valid_len
scalar-prefetch masking, dead blocks skipped — O(valid_len) work)
against the full-matrix oracle (O(cache_len) work), asserts
exact-integer agreement as a by-product, and reports the dead-block
fraction the fusion skips.  On CPU both run through XLA/interpret so
the ratio mostly documents overhead; on TPU the same harness times
compiled kernels and the skipped-block column is what matters.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.core import attention as iattn

SHAPES = [
    # (batch, cache_len, heads, kv_heads, head_dim, mean occupancy, label)
    (4, 512, 4, 2, 64, 0.25, "ragged-25%"),
    (4, 512, 4, 2, 64, 1.00, "full"),
    (8, 256, 4, 4, 64, 0.50, "ragged-50%"),
]

QUICK_SHAPES = SHAPES[:1]


def _time(f, *args, iters=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    ref = ops.resolve_ops("ref")
    fused = ops.resolve_ops("pallas_fused")
    rows = []
    for b, L, h, hkv, d, occ, label in (QUICK_SHAPES if quick else SHAPES):
        plan = iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)
        q8 = jnp.asarray(rng.integers(-127, 128, (b, 1, h, d)), jnp.int8)
        k8 = jnp.asarray(rng.integers(-127, 128, (b, L, hkv, d)), jnp.int8)
        v8 = jnp.asarray(rng.integers(-127, 128, (b, L, hkv, d)), jnp.int8)
        if occ >= 1.0:
            valid = jnp.full((b,), L, jnp.int32)     # every slot full
        else:
            valid = jnp.asarray(
                np.clip(rng.integers(1, max(2, int(2 * occ * L)), b), 1, L),
                jnp.int32)
        f_ref = jax.jit(lambda q, k, v, vl: ref.int_decode_attention(
            q, k, v, plan, vl))
        f_fused = jax.jit(lambda q, k, v, vl: fused.int_decode_attention(
            q, k, v, plan, vl))
        a = np.asarray(f_ref(q8, k8, v8, valid))
        o = np.asarray(f_fused(q8, k8, v8, valid))
        assert np.array_equal(a, o), f"decode fused != oracle on {label}"
        us_ref = _time(f_ref, q8, k8, v8, valid)
        us_fused = _time(f_fused, q8, k8, v8, valid)
        bkv = 128
        n_blocks = b * (L // bkv)
        live = int(np.sum(np.ceil(np.asarray(valid) / bkv)))
        tag = f"{b}x{L}x{h}x{d} {label}"
        rows.append((f"decode_attn_oracle_us[{tag}]", round(us_ref, 1),
                     "exact-match verified"))
        rows.append((f"decode_attn_fused_us[{tag}]", round(us_fused, 1),
                     f"dead KV blocks skipped: {n_blocks - live}/"
                     f"{n_blocks}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
