"""Async serving front end: admission, streaming, lifecycle, metrics.

The :class:`ServingFrontend` turns the batched :class:`~repro.serving.
engine.ServingEngine` — a synchronous step machine — into something
traffic can actually hit:

  * **Admission queue with backpressure** — ``submit()`` feeds the
    engine's three-phase scheduler directly, bounded by ``max_pending``
    in-flight requests; past the bound it raises the typed
    :class:`QueueFull` (the open-loop caller's signal to shed load), and
    impossible requests (prompt + continuation overrunning the cache,
    prompt that can never fit the page pool) are rejected up front with
    :class:`~repro.analysis.contracts.RequestInfeasible` instead of
    failing deep inside a step.
  * **Per-request token streaming** — ``submit()`` returns a
    :class:`StreamHandle`; ``async for tok in handle.stream()`` yields
    tokens as engine steps commit them.  Streams are **bit-exact with
    the synchronous path**: the front end never touches the datapath, it
    only distributes the tokens the engine's (batch-independent, greedy-
    deterministic) steps produce.
  * **Cancellation and deadlines** — ``handle.cancel()`` and a
    per-request ``deadline_s`` both resolve through the engine's own
    ``evict``: the lane frees, every page the session holds returns to
    the allocator at refcount zero (pages the prefix index or a
    prefix-sharing sibling still hold stay cached — refcount-exact under
    sharing/CoW), and the handle's stream ends with terminal state
    ``cancelled`` / ``timeout``.  Lifecycle ops apply only **between**
    a commit and the next dispatch — the engine's
    :class:`~repro.serving.engine.StepInFlight` guard enforces it.
  * **Host/device overlap** — the run loop uses the engine's
    ``dispatch_step()`` / ``commit_step()`` split: step N+1 is
    dispatched (its launch consuming *snapshots* of ``pos`` and the page
    table — the ``jnp.asarray`` zero-copy hazard, lint rule RR002) and
    then the loop yields, so consumer coroutines detokenize/process step
    N's tokens while the device executes N+1; only then does the loop
    block on ``commit_step``.
  * **Request-lifecycle metrics** — per-request TTFT, queue wait and
    inter-token latency; per-step batch occupancy and queue depth;
    terminal-state counts (``completed | cancelled | timeout |
    rejected``).  ``describe()`` reports p50/p99 aggregates; the
    latency section of ``benchmarks/BENCH_serving.json`` is built from
    exactly this surface (schema-checked in CI).

Lifecycle state machine (``StreamHandle.state``)::

    submit() ──rejected──▶ (no handle; QueueFull / RequestInfeasible)
       │
    queued ──▶ prefilling ──▶ active ──▶ completed
       │            │            │
       └────────────┴────────────┴──▶ cancelled | timeout
                 (preempted sessions report their engine state)

Everything runs on one event loop — the engine is not thread-safe, and
the front end never calls it from anywhere else.  A stalled schedule
(``stall_steps`` consecutive steps with no token emitted, no prefill
progress and work still queued) raises the engine's typed
:class:`~repro.serving.engine.EngineStalled` rather than spinning —
the same detection ``run_until_done`` applies to the synchronous path.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.analysis import contracts
from repro.serving.engine import (EngineStalled, PendingStep, Request,
                                  ServingEngine)

#: terminal states a request can reach, in ``describe()["terminal"]``
#: order; ``rejected`` counts submit() attempts that never got a handle
TERMINAL_STATES = ("completed", "cancelled", "timeout", "rejected")

_EOS = object()                    # stream sentinel: handle is terminal


class QueueFull(RuntimeError):
    """Backpressure: the front end already has ``max_pending`` requests
    in flight (queued + prefilling + decoding).  The typed rejection an
    open-loop load source needs — shed the request (or retry later)
    instead of growing an unbounded queue.  Fields: ``max_pending``,
    ``pending``."""

    def __init__(self, max_pending: int, pending: int):
        self.max_pending = max_pending
        self.pending = pending
        super().__init__(
            f"admission queue full: {pending} requests in flight >= "
            f"max_pending={max_pending}; retry later or raise "
            "max_pending")


@dataclasses.dataclass
class RequestMetrics:
    """Per-request lifecycle timestamps (front-end ``clock`` domain —
    ``time.monotonic`` unless the front end was built with a test
    clock).  Durations derive: ``queue_wait_s`` (submit → first lane),
    ``ttft_s`` (submit → first token), ``tbt_s`` (mean gap between
    token commits; a speculative multi-token commit legitimately lands
    several tokens at one timestamp)."""

    submit_t: float
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    last_token_t: Optional[float] = None
    end_t: Optional[float] = None
    n_tokens: int = 0

    @property
    def queue_wait_s(self) -> Optional[float]:
        return None if self.admit_t is None \
            else self.admit_t - self.submit_t

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token_t is None \
            else self.first_token_t - self.submit_t

    @property
    def tbt_s(self) -> Optional[float]:
        if self.first_token_t is None or self.n_tokens < 2:
            return None
        return (self.last_token_t - self.first_token_t) \
            / (self.n_tokens - 1)


class StreamHandle:
    """One submitted request's streaming surface.

    ``async for tok in handle.stream()`` yields tokens as the engine
    commits them and ends when the request reaches a terminal state
    (inspect :attr:`terminal` afterwards — ``completed``, ``cancelled``
    or ``timeout``).  Single consumer.  ``cancel()`` is synchronous and
    idempotent; the run loop applies it at the next commit boundary, so
    already-committed tokens still arrive before the stream ends."""

    def __init__(self, uid: int, request: Request, session,
                 deadline_s: Optional[float], submit_t: float):
        self.uid = uid
        self.request = request
        self.session = session
        self.deadline_s = deadline_s
        self.metrics = RequestMetrics(submit_t=submit_t)
        self.terminal: Optional[str] = None
        self.cancel_requested = False
        self._q: asyncio.Queue = asyncio.Queue()
        self._wake = None          # set by the owning frontend

    @property
    def state(self) -> str:
        """Live engine state, or the terminal state once reached."""
        return self.terminal if self.terminal is not None \
            else self.session.state

    @property
    def tokens(self) -> List[int]:
        """Tokens committed so far (the full output once terminal)."""
        return list(self.request.out_tokens)

    def cancel(self):
        """Request cancellation; applied by the run loop between steps.
        No-op once terminal."""
        if self.terminal is None:
            self.cancel_requested = True
            if self._wake is not None:
                self._wake.set()

    async def stream(self):
        """Async-iterate the token stream until terminal."""
        while True:
            tok = await self._q.get()
            if tok is _EOS:
                return
            yield tok

    async def result(self) -> List[int]:
        """Drain the stream; returns the full token list."""
        async for _ in self.stream():
            pass
        return self.tokens


def _pct(samples: Sequence[float]) -> Optional[dict]:
    if not samples:
        return None
    arr = np.asarray(samples, dtype=np.float64)
    return {
        "n": int(arr.size),
        "mean": float(arr.mean()),
        "p50": float(np.percentile(arr, 50)),
        "p99": float(np.percentile(arr, 99)),
    }


class ServingFrontend:
    """Asyncio front end over one :class:`ServingEngine` (see the module
    docstring for the full contract).

    ``max_pending`` bounds in-flight requests (default ``4 × batch``);
    ``clock`` injects a time source for deterministic deadline tests;
    ``stall_steps`` bounds consecutive no-progress steps before the run
    loop raises :class:`EngineStalled`."""

    def __init__(self, engine: ServingEngine,
                 max_pending: Optional[int] = None,
                 clock=time.monotonic, stall_steps: int = 1000):
        if max_pending is None:
            max_pending = 4 * engine.batch
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got "
                             f"{max_pending}")
        if stall_steps < 1:
            raise ValueError(f"stall_steps must be >= 1, got "
                             f"{stall_steps}")
        self.engine = engine
        self.max_pending = max_pending
        self.clock = clock
        self.stall_steps = stall_steps
        self._live: Dict[int, StreamHandle] = {}
        self._uid = 0
        self._wake = asyncio.Event()
        self._closed = False
        self._running = False
        # aggregates ------------------------------------------------------
        self._counts = {t: 0 for t in TERMINAL_STATES}
        self._submitted = 0
        self._steps = 0
        self._occupancy: List[int] = []
        self._queue_depth: List[int] = []
        self._ttfts: List[float] = []
        self._queue_waits: List[float] = []
        self._itls: List[float] = []
        self._total_tokens = 0
        self._no_progress = 0

    # ------------------------------------------------------- admission --

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               *, temperature: float = 0.0,
               deadline_s: Optional[float] = None) -> StreamHandle:
        """Validate + admit one request; returns its
        :class:`StreamHandle`.

        Typed rejections (also counted in the ``rejected`` terminal
        bucket): :class:`~repro.analysis.contracts.RequestInfeasible`
        for a request that can never complete on this engine's cache
        geometry — including a prompt whose prefill can never fit the
        page pool, which the bare engine only discovers as a
        ``PagePoolExhausted`` deep inside a step — and
        :class:`QueueFull` past the ``max_pending`` bound."""
        self._submitted += 1
        try:
            if deadline_s is not None and deadline_s <= 0:
                raise ValueError(f"deadline_s must be > 0, got "
                                 f"{deadline_s}")
            eng = self.engine
            pool = (dict(page_size=eng.layout.page_size,
                         num_pages=eng.layout.num_pages)
                    if eng.paged else {})
            contracts.require_request(len(prompt), max_new_tokens,
                                      eng.cache_len,
                                      window=eng.cfg.window, **pool)
            if len(self._live) >= self.max_pending:
                raise QueueFull(self.max_pending, len(self._live))
            req = Request(uid=self._uid, prompt=list(prompt),
                          max_new_tokens=max_new_tokens,
                          temperature=temperature)
            session = eng.submit(req)
        except Exception:
            self._counts["rejected"] += 1
            raise
        handle = StreamHandle(self._uid, req, session, deadline_s,
                              self.clock())
        handle._wake = self._wake
        self._live[self._uid] = handle
        self._uid += 1
        self._wake.set()
        return handle

    # ------------------------------------------------------- lifecycle --

    def _finish(self, handle: StreamHandle, terminal: str, now: float):
        """Move a handle to a terminal state: evict its session if it
        still holds engine resources, record metrics, end the stream."""
        sess = handle.session
        if sess.state != "done":
            self.engine.evict(sess)
        handle.terminal = terminal
        handle.metrics.end_t = now
        self._counts[terminal] += 1
        self._live.pop(handle.uid, None)
        handle._q.put_nowait(_EOS)

    def _apply_lifecycle(self, now: float):
        """Cancellations and deadline expiries, applied at the commit
        boundary (never between dispatch and commit — ``StepInFlight``
        would fire)."""
        for handle in list(self._live.values()):
            if handle.cancel_requested:
                self._finish(handle, "cancelled", now)
            elif handle.deadline_s is not None \
                    and now - handle.metrics.submit_t >= handle.deadline_s:
                self._finish(handle, "timeout", now)

    def _collect(self, now: float):
        """After a commit: push newly committed tokens into each
        handle's stream queue, stamp metrics, finish completed
        requests."""
        for handle in list(self._live.values()):
            sess = handle.session
            m = handle.metrics
            if m.admit_t is None and sess.state != "queued":
                m.admit_t = now
                self._queue_waits.append(m.queue_wait_s)
            new = handle.request.out_tokens[m.n_tokens:]
            if new:
                if m.first_token_t is None:
                    m.first_token_t = now
                    self._ttfts.append(now - m.submit_t)
                    gaps = len(new) - 1
                else:
                    gaps = len(new)
                # a multi-token (speculative) commit lands several
                # tokens at one timestamp: the first gap spans from the
                # previous commit, the rest are genuinely ~0
                if gaps:
                    self._itls.append((now - m.last_token_t
                                       if m.last_token_t is not None
                                       else 0.0))
                    self._itls.extend([0.0] * (gaps - 1))
                m.last_token_t = now
                m.n_tokens += len(new)
                self._total_tokens += len(new)
                for tok in new:
                    handle._q.put_nowait(tok)
            if handle.request.done:
                self._finish(handle, "completed", now)

    # -------------------------------------------------------- run loop --

    def _engine_idle(self) -> bool:
        eng = self.engine
        return not eng.queue and all(s is None for s in eng.slots)

    def _progress_stamp(self) -> tuple:
        eng = self.engine
        prefill = sum(s.prefill_pos for s in eng.queue)
        prefill += sum(s.prefill_pos for s in eng.slots if s is not None)
        return (self._total_tokens, prefill,
                sum(s is not None for s in eng.slots), len(eng.queue))

    def _check_stall(self, before: tuple):
        if self._engine_idle() or self._progress_stamp() != before:
            self._no_progress = 0
            return
        self._no_progress += 1
        if self._no_progress >= self.stall_steps:
            eng = self.engine
            slots = [
                None if s is None else {
                    "uid": s.request.uid, "state": s.state,
                    "pos": int(eng.pos[i]), "prefill_pos": s.prefill_pos,
                }
                for i, s in enumerate(eng.slots)
            ]
            raise EngineStalled(self.stall_steps, slots, len(eng.queue))

    def _next_deadline_s(self) -> Optional[float]:
        now = self.clock()
        deltas = [h.metrics.submit_t + h.deadline_s - now
                  for h in self._live.values() if h.deadline_s is not None]
        return max(0.0, min(deltas)) if deltas else None

    async def _sleep_until_work(self):
        """Idle: wait for a submit/cancel/close wake, or the nearest
        deadline (deadline deltas are computed in the injected clock's
        domain — under a test clock, advance it and ``poke()``)."""
        self._wake.clear()
        if self._live and any(h.cancel_requested
                              for h in self._live.values()):
            return                  # raced: apply before sleeping
        try:
            await asyncio.wait_for(self._wake.wait(),
                                   self._next_deadline_s())
        except asyncio.TimeoutError:
            pass

    def poke(self):
        """Wake the run loop (e.g. after advancing an injected test
        clock so a deadline check runs)."""
        self._wake.set()

    def close(self):
        """Ask the run loop to exit once the engine drains; safe to call
        from any coroutine on the loop.  Pending requests keep running
        to completion — cancel them first for a fast shutdown."""
        self._closed = True
        self._wake.set()

    async def step(self) -> int:
        """One front-end scheduling round: apply lifecycle ops, then
        dispatch → (yield to consumers) → commit → distribute.  Returns
        the engine's occupied-lane count.  ``run()`` is this in a loop;
        tests drive it directly for deterministic schedules."""
        now = self.clock()
        self._apply_lifecycle(now)
        if self._engine_idle():
            return 0
        before = self._progress_stamp()
        pending: PendingStep = self.engine.dispatch_step()
        self._steps += 1
        self._occupancy.append(pending.occupied)
        self._queue_depth.append(len(self.engine.queue))
        # overlap window: the launch is on the device; consumers drain
        # the queues the PREVIOUS commit filled while it executes
        await asyncio.sleep(0)
        self.engine.commit_step(pending)
        self._collect(self.clock())
        self._check_stall(before)
        # let consumers react to this commit before the next dispatch
        await asyncio.sleep(0)
        return pending.occupied

    async def run(self):
        """Serve until :meth:`close` (then drain).  Exactly one runner
        at a time; submit/cancel freely from other coroutines on the
        same loop."""
        if self._running:
            raise RuntimeError("ServingFrontend.run() is already active")
        self._running = True
        try:
            while True:
                await self.step()
                if self._engine_idle():
                    # lifecycle ops may still be queued (cancel/timeout
                    # of queued-but-never-admitted handles)
                    self._apply_lifecycle(self.clock())
                    if self._closed and not self._live:
                        return
                    await self._sleep_until_work()
        finally:
            self._running = False

    # ----------------------------------------------------- introspection --

    def describe(self) -> dict:
        """Structured front-end signature + live metrics: admission
        bound and in-flight count, terminal-state counts, per-step
        occupancy / queue-depth aggregates, and the latency section
        (p50/p99 TTFT, inter-token gap, queue wait) the serving bench
        publishes to ``BENCH_serving.json``."""
        occ = np.asarray(self._occupancy or [0])
        qd = np.asarray(self._queue_depth or [0])
        return {
            "max_pending": self.max_pending,
            "pending": len(self._live),
            "submitted": self._submitted,
            "accepted": self._submitted - self._counts["rejected"],
            "terminal": dict(self._counts),
            "steps": self._steps,
            "tokens": self._total_tokens,
            "occupancy": {"mean": float(occ.mean()),
                          "max": int(occ.max())},
            "queue_depth": {"mean": float(qd.mean()),
                            "max": int(qd.max())},
            "latency": {
                "ttft_s": _pct(self._ttfts),
                "inter_token_s": _pct(self._itls),
                "queue_wait_s": _pct(self._queue_waits),
            },
        }
