"""Float-path transformer layers with optional fake-quant (QAT).

This is the *producer* side of the SwiftTron flow (DESIGN.md §3): training
runs in bf16/f32 with straight-through fake quantization on every tensor
the accelerator would see in INT8, so converted checkpoints execute on the
integer path (intlayers.py) with matching numerics.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import fake_quant, per_channel_absmax
from repro.distributed.sharding import (comm_quant_gather, shard,
                                        shard_residual)
from repro.models.common import ArchConfig, apply_rope


# ---------------------------------------------------------------- init ----

def _init(key, shape, dtype, scale=1.0):
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_norm(cfg: ArchConfig, dtype):
    p = {"gamma": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["beta"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_attn(key, cfg: ArchConfig, dtype, cross: bool = False):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    p = {
        "wq": _init(ks[0], (d, cfg.n_heads, hd), dtype),
        "wk": _init(ks[1], (d, cfg.n_kv_heads, hd), dtype),
        "wv": _init(ks[2], (d, cfg.n_kv_heads, hd), dtype),
        "wo": _init(ks[3], (cfg.n_heads, hd, d), dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def init_ffn(key, cfg: ArchConfig, dtype, d_ff: Optional[int] = None):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    p = {"w1": _init(ks[0], (d, f), dtype),
         "w2": _init(ks[1], (f, d), dtype)}
    if cfg.activation == "swiglu":
        p["w3"] = _init(ks[2], (d, f), dtype)
    else:
        p["b1"] = jnp.zeros((f,), dtype)
        p["b2"] = jnp.zeros((d,), dtype)
    return p


def init_moe(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    e = cfg.padded_experts()
    f = cfg.moe_d_ff or cfg.d_ff
    p = {
        "router": _init(ks[0], (d, e), dtype),
        "w1": _init(ks[1], (e, d, f), dtype),
        "w2": _init(ks[2], (e, f, d), dtype),
    }
    if cfg.activation == "swiglu":
        p["w3"] = _init(ks[3], (e, d, f), dtype)
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(ks[4], cfg, dtype,
                               d_ff=f * cfg.n_shared_experts)
    return p


# ------------------------------------------------------------- helpers ----

def maybe_fq(x, scale, bits=8, enabled=False):
    return fake_quant(x, scale, bits) if enabled else x


def fq_weight(w, axis=-1, enabled=False):
    """Per-out-channel fake quant (axis = out-channel dim)."""
    if not enabled:
        return w
    s = jnp.maximum(per_channel_absmax(w, axis), 1e-6) / 127.0
    shape = [1] * w.ndim
    shape[axis] = -1
    return fake_quant(w, s.reshape(shape), 8)


def norm_fwd(p, x, cfg: ArchConfig, eps: float = 1e-6):
    """f32 only for the row statistics; the (B,S,D) tensor stays in the
    input dtype — otherwise XLA fuses the seq-parallel all-gather into the
    f32 upcast and moves 2x the bytes (EXPERIMENTS.md §Perf C8)."""
    stats_in = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(stats_in, -1, keepdims=True)
        var = jnp.var(stats_in, -1, keepdims=True)
        inv = (1.0 / jnp.sqrt(var + eps)).astype(x.dtype)
        out = (x - mu.astype(x.dtype)) * inv * p["gamma"] + p["beta"]
    else:
        rms = jnp.sqrt(jnp.mean(stats_in * stats_in, -1, keepdims=True)
                       + eps)
        out = x * (1.0 / rms).astype(x.dtype) * p["gamma"]
    return out.astype(x.dtype)


# ----------------------------------------------------------- attention ----

def _repeat_kv(k, group: int):
    return jnp.repeat(k, group, axis=2) if group > 1 else k


def attn_fwd(p, x, cfg: ArchConfig, positions=None, causal=True,
             window: int = 0, memory=None, qat=False, q_chunk: int = 1024):
    """Self- or cross-attention. x: (B,S,D); memory: (B,Sm,D) for cross."""
    b, s, d = x.shape
    kv_src = memory if memory is not None else x
    sk = kv_src.shape[1]
    xq = comm_quant_gather(x, cfg.s_act8, enabled=qat) if qat \
        else maybe_fq(x, cfg.s_act8, enabled=qat)
    kq = comm_quant_gather(kv_src, cfg.s_act8, enabled=qat) if qat \
        else maybe_fq(kv_src, cfg.s_act8, enabled=qat)

    q = jnp.einsum("bsd,dhk->bshk", xq, fq_weight(p["wq"], 1, qat))
    k = jnp.einsum("bsd,dhk->bshk", kq, fq_weight(p["wk"], 1, qat))
    v = jnp.einsum("bsd,dhk->bshk", kq, fq_weight(p["wv"], 1, qat))
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.pos == "rope" and memory is None and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    k = _repeat_kv(k, cfg.q_group)
    v = _repeat_kv(v, cfg.q_group)

    scale = 1.0 / math.sqrt(cfg.hd)
    qc = min(q_chunk, s)
    while s % qc:
        qc -= 1
    n_chunks = s // qc

    def one_chunk(qi, q_blk):
        sc = jnp.einsum("bqhk,bthk->bhqt", q_blk, k,
                        preferred_element_type=jnp.float32) * scale
        if causal or window > 0:
            rows = qi * qc + jnp.arange(qc)[:, None]
            cols = jnp.arange(sk)[None, :]
            m = jnp.ones((qc, sk), bool)
            if causal:
                m = m & (cols <= rows)
            if window > 0:
                m = m & (cols > rows - window)
            sc = jnp.where(m[None, None], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        pr = maybe_fq(pr, 1.0 / 127.0, enabled=qat)   # int8 prob grid
        return jnp.einsum("bhqt,bthk->bqhk", pr, v)

    if n_chunks == 1:
        o = one_chunk(0, q)
    else:
        # remat per chunk: the backward recomputes one chunk's scores at a
        # time instead of saving every chunk's (b,h,qc,sk) linearisation
        chunk_fn = jax.remat(lambda args: one_chunk(*args))
        qs = q.reshape(b, n_chunks, qc, cfg.n_heads, cfg.hd) \
              .transpose(1, 0, 2, 3, 4)
        o = jax.lax.map(chunk_fn, (jnp.arange(n_chunks), qs))
        o = o.transpose(1, 0, 2, 3, 4).reshape(b, s, cfg.n_heads, cfg.hd)
    o = maybe_fq(o, cfg.s_act8, enabled=qat)
    out = jnp.einsum("bqhk,hkd->bqd", o, fq_weight(p["wo"], 2, qat))
    return shard_residual(out)


# ----------------------------------------------------------------- ffn ----

def ffn_fwd(p, x, cfg: ArchConfig, qat=False):
    xq = comm_quant_gather(x, cfg.s_act8, enabled=qat) if qat \
        else maybe_fq(x, cfg.s_act8, enabled=qat)
    if cfg.activation == "swiglu":
        h1 = jnp.einsum("bsd,df->bsf", xq, fq_weight(p["w1"], 1, qat))
        h3 = jnp.einsum("bsd,df->bsf", xq, fq_weight(p["w3"], 1, qat))
        h1 = maybe_fq(h1, cfg.s_act10, bits=10, enabled=qat)
        h3 = maybe_fq(h3, cfg.s_act10, bits=10, enabled=qat)
        h = jax.nn.silu(h1) * h3
    else:
        h1 = jnp.einsum("bsd,df->bsf", xq, fq_weight(p["w1"], 1, qat))
        h1 = h1 + p["b1"]
        h1 = maybe_fq(h1, cfg.s_act10, bits=10, enabled=qat)
        h = jax.nn.gelu(h1, approximate=False)
    h = shard(h, "batch", "seq", "ffn")
    h = maybe_fq(h, cfg.s_act8, enabled=qat)
    out = jnp.einsum("bsf,fd->bsd", h, fq_weight(p["w2"], 1, qat))
    if cfg.activation != "swiglu":
        out = out + p["b2"]
    return shard_residual(out)


# ----------------------------------------------------------------- moe ----

def moe_fwd(p, x, cfg: ArchConfig, qat=False, group_size: int = 512):
    """Capacity-based top-k routing with dispatch/combine einsums.

    Tokens are processed in groups (sequence slices) so the dispatch mask
    stays small; experts shard over the ``model`` axis (EP).  Returns
    (out, aux_loss).
    """
    b, s, d = x.shape
    e = cfg.padded_experts()
    k = cfg.top_k
    g = max(1, s // group_size)
    tg = s // g
    cap = max(4, int(cfg.capacity_factor * tg * k / e))
    xg = x.reshape(b * g, tg, d)

    xq = maybe_fq(xg, cfg.s_act8, enabled=qat)
    logits = jnp.einsum("gtd,de->gte", xq,
                        fq_weight(p["router"], 1, qat)).astype(jnp.float32)
    if cfg.padded_experts() != cfg.n_experts:       # mask padding experts
        pad = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (g,t,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch): E * mean(frac_tokens * frac_prob)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        (jax.nn.one_hot(expert_ids[..., 0], e)), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # capacity assignment, slot-by-slot (k is small)
    dispatch = jnp.zeros((b * g, tg, e, cap), x.dtype)
    combine = jnp.zeros((b * g, tg, e, cap), jnp.float32)
    counts = jnp.zeros((b * g, e), jnp.int32)
    for slot in range(k):
        a = jax.nn.one_hot(expert_ids[..., slot], e, dtype=jnp.int32)
        pos = counts[:, None, :] + jnp.cumsum(a, axis=1) - a
        keep = (pos < cap) & (a > 0)
        oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) \
            * keep[..., None].astype(x.dtype)
        dispatch = dispatch + a[..., None].astype(x.dtype) * oh
        combine = combine + (gate_vals[..., slot][..., None, None]
                             * oh.astype(jnp.float32))
        counts = counts + jnp.sum(a, axis=1)

    buf = jnp.einsum("gtd,gtec->gecd", xg, dispatch).astype(x.dtype)
    buf = shard(buf, "batch", "experts", None, "embed")
    bq = maybe_fq(buf, cfg.s_act8, enabled=qat)
    if cfg.activation == "swiglu":
        h1 = jnp.einsum("gecd,edf->gecf", bq, fq_weight(p["w1"], 2, qat))
        h3 = jnp.einsum("gecd,edf->gecf", bq, fq_weight(p["w3"], 2, qat))
        h = jax.nn.silu(maybe_fq(h1, cfg.s_act10, 10, qat)) \
            * maybe_fq(h3, cfg.s_act10, 10, qat)
    else:
        h1 = jnp.einsum("gecd,edf->gecf", bq, fq_weight(p["w1"], 2, qat))
        h = jax.nn.gelu(maybe_fq(h1, cfg.s_act10, 10, qat),
                        approximate=False)
    h = maybe_fq(h, cfg.s_act8, enabled=qat)
    y = jnp.einsum("gecf,efd->gecd", h, fq_weight(p["w2"], 2, qat))
    y = shard(y, "batch", "experts", None, "embed")
    out = jnp.einsum("gecd,gtec->gtd", y.astype(x.dtype),
                     combine.astype(x.dtype))
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + ffn_fwd(p["shared"], x, cfg, qat=qat)
    return shard_residual(out), aux
