"""Property-based schedule sweep for the paged KV-cache allocator.

Hypothesis drives random admit/grow/evict/preempt/resume interleavings
against ``repro.serving.kvcache`` and asserts, after *every* operation:
the allocator's partition invariant (free list and refcounts partition
the allocatable pages, the null page never moves), no page leaked, no
page owned by two live sessions, and every page-table row consistent
with its session's page list.  Deterministic edge cases live in
``test_kvcache.py``; this module needs the optional ``hypothesis`` dev
dependency.
"""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serving.kvcache import (CacheLayout, NULL_PAGE, PagedKVCache,
                                   PagePoolExhausted, Session)


@given(st.lists(st.tuples(st.sampled_from(["admit", "grow", "evict",
                                           "preempt", "resume"]),
                          st.integers(0, 5)),
                max_size=60),
       st.integers(2, 12), st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_random_schedules_preserve_allocator_invariants(schedule,
                                                        num_pages,
                                                        num_slots):
    layout = CacheLayout(num_slots, 64, 16, num_pages)
    kv = PagedKVCache(layout)
    sessions = {}
    lanes = {}

    for op, sid in schedule:
        s = sessions.get(sid)
        try:
            if op == "admit" and (s is None or s.state == "done"):
                free = [ln for ln in range(num_slots) if ln not in lanes]
                if free:
                    s = Session(uid=sid)
                    sessions[sid] = s
                    lanes[free[0]] = sid
                    kv.bind(s, free[0])
            elif op == "grow" and s is not None and s.state == "active":
                kv.ensure(s, min(len(s.pages) * 16, 63))
            elif op == "evict" and s is not None and s.state != "done":
                if s.slot is not None:
                    lanes.pop(s.slot, None)
                kv.release(s)
            elif op == "preempt" and s is not None and s.state == "active":
                lanes.pop(s.slot, None)
                kv.unbind(s)
            elif op == "resume" and s is not None \
                    and s.state == "preempted":
                free = [ln for ln in range(num_slots) if ln not in lanes]
                if free:
                    lanes[free[0]] = sid
                    kv.bind(s, free[0])
        except PagePoolExhausted:
            pass                                 # legal under pressure
        kv.allocator.check()
        # no page owned by two non-done sessions (live lanes never share)
        owned = [p for t in sessions.values() if t.state != "done"
                 for p in t.pages]
        assert len(owned) == len(set(owned))
        # page-table rows only reference pages their session owns
        for lane, sid2 in lanes.items():
            row = kv.page_table.table[lane]
            live = [p for p in row if p != NULL_PAGE]
            assert live == sessions[sid2].pages[:len(live)]
