"""Fault tolerance: checkpoint/restart driver, straggler detection,
elastic re-meshing (DESIGN.md §3).

The design point is 1000+ nodes where *something* is always failing:

  * ``FaultTolerantLoop`` wraps the train step with async checkpointing,
    automatic restore-on-failure (bounded retries), and step-time
    monitoring;
  * ``StragglerDetector`` flags steps slower than ``threshold`` x a robust
    running median — on real pods the hook reports the slow host for
    drain/replace; here it feeds the loop's telemetry and tests;
  * ``ElasticMesh`` re-plans the mesh when devices are lost: it keeps the
    model axis intact (TP degree is fixed by weight shapes) and shrinks
    the data axis to the largest full multiple, so training continues on
    e.g. 15/16 data slices after a host loss, with per-step global batch
    rescaled.  Re-entry of the repaired host happens at the next
    checkpoint boundary.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager

log = logging.getLogger("repro.fault")
Pytree = Any


class StragglerDetector:
    """Robust step-time outlier detection (median-of-window)."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.times = deque(maxlen=window)
        self.threshold = threshold
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 10:
            med = float(np.median(self.times))
            if dt > self.threshold * med:
                self.flagged += 1
                is_straggler = True
                log.warning("straggler step: %.3fs vs median %.3fs",
                            dt, med)
        self.times.append(dt)
        return is_straggler


@dataclasses.dataclass
class ElasticPlan:
    data_size: int
    dropped_hosts: int
    global_batch: int


class ElasticMesh:
    """Re-plan (data, model) after device loss; model axis is inviolable."""

    def __init__(self, data_size: int, model_size: int,
                 global_batch: int):
        self.data_size = data_size
        self.model_size = model_size
        self.global_batch = global_batch

    def replan(self, healthy_devices: int) -> ElasticPlan:
        full_rows = healthy_devices // self.model_size
        if full_rows < 1:
            raise RuntimeError("fewer healthy devices than one model row")
        new_data = full_rows
        per = self.global_batch // self.data_size
        return ElasticPlan(data_size=new_data,
                           dropped_hosts=self.data_size - new_data,
                           global_batch=per * new_data)


class FaultTolerantLoop:
    """Run ``step_fn(state, batch) -> (state, metrics)`` with restart.

    ``state`` is any pytree (params, opt state, ...).  On an exception the
    loop restores the latest checkpoint, rewinds the data iterator, and
    retries (``max_restarts`` total).  Checkpoints every
    ``ckpt_every`` steps, asynchronously.
    """

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 data_iter, ckpt_every: int = 100, max_restarts: int = 3,
                 straggler: Optional[StragglerDetector] = None,
                 fail_injector: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.data = data_iter
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerDetector()
        self.fail_injector = fail_injector
        self.restarts = 0

    def run(self, state: Pytree, n_steps: int, start_step: int = 0):
        step = start_step
        metrics_log = []
        while step < n_steps:
            try:
                batch = next(self.data)
                t0 = time.time()
                if self.fail_injector is not None:
                    self.fail_injector(step)
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(metrics)[0])
                self.straggler.observe(time.time() - t0)
                metrics_log.append(
                    {k: float(v) for k, v in metrics.items()})
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    self.ckpt.save(step, state,
                                   extra={"data": self.data.state_dict()})
            except (FileNotFoundError, KeyboardInterrupt):
                raise
            except Exception as e:     # node failure / preemption path
                self.restarts += 1
                log.error("step %d failed (%s); restart %d/%d", step,
                          type(e).__name__, self.restarts,
                          self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                last = self.ckpt.latest_step()
                if last is None:
                    raise
                state, meta = self.ckpt.restore(state)
                self.data.load_state_dict(meta["extra"]["data"])
                step = meta["step"]
        self.ckpt.wait()
        return state, metrics_log
