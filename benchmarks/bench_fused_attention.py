"""Fused-vs-unfused attention sweep (the tentpole comparison).

For each shape, times the single-launch ``pallas_fused`` kernel (Q·Kᵀ →
Shiftmax → P·V → requant in one kernel, score matrix never in HBM)
against the two-pass reference path, asserts exact-integer agreement as
a by-product, and reports the HBM bytes the fusion avoids (the int32
score matrix the unfused path writes and re-reads).

On CPU both run through XLA/interpret so the ratio mostly documents
kernel overhead; on TPU the same harness times compiled kernels and the
avoided-traffic column is the quantity that matters (SwiftTron §III /
ITA make the same point for the ASIC datapath).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.core import attention as iattn

SHAPES = [
    # (batch, sq, skv, heads, kv_heads, head_dim, causal, label)
    (1, 256, 256, 4, 2, 64, True, "self/GQA"),
    (1, 512, 512, 4, 4, 64, True, "self"),
    (1, 128, 512, 4, 4, 64, False, "cross"),
]


def _time(f, *args, iters=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    ref = ops.resolve_ops("ref")
    fused = ops.resolve_ops("pallas_fused")
    rows = []
    for b, sq, skv, h, hkv, d, causal, label in SHAPES:
        plan = iattn.make_iattention(d, 8 / 127, 8 / 127, 4 / 127, 4 / 127)
        q8 = jnp.asarray(rng.integers(-127, 128, (b, sq, h, d)), jnp.int8)
        k8 = jnp.asarray(rng.integers(-127, 128, (b, skv, hkv, d)),
                         jnp.int8)
        v8 = jnp.asarray(rng.integers(-127, 128, (b, skv, hkv, d)),
                         jnp.int8)
        f_ref = jax.jit(lambda q, k, v: ref.int_attention(
            q, k, v, plan, causal=causal))
        f_fused = jax.jit(lambda q, k, v: fused.int_attention(
            q, k, v, plan, causal=causal))
        a = np.asarray(f_ref(q8, k8, v8))
        bo = np.asarray(f_fused(q8, k8, v8))
        assert np.array_equal(a, bo), f"fused != two-pass on {label}"
        us_ref = _time(f_ref, q8, k8, v8)
        us_fused = _time(f_fused, q8, k8, v8)
        # int32 scores written + re-read by the unfused path, per head
        saved = 2 * b * h * sq * skv * 4
        tag = f"{b}x{sq}x{skv}x{h}x{d} {label}"
        rows.append((f"fused_attn_two_pass_us[{tag}]", round(us_ref, 1),
                     "exact-match verified"))
        rows.append((f"fused_attn_fused_us[{tag}]", round(us_fused, 1),
                     "score-matrix HBM traffic avoided: "
                     f"{saved / 2**20:.1f} MiB"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
