"""Reference backend: pure-jnp oracles from ``repro.kernels.ref``.

What the multi-pod dry-run compiles (XLA-visible FLOPs/bytes for the
roofline) and what every other backend is tested against.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.ops import spec as _spec


class RefBackend:
    name = "ref"
    fused_attention = False   # full-matrix oracle, not an online kernel
    fused_decode = False      # decode runs the full-matrix oracle too
    # no paged/wo-fold decode or chunked-prefill capabilities: OpSet
    # lowers all four operands (gather-into-contiguous / unfolded
    # matmul / chunk scatter+gather) before dispatching here
    paged_decode = False
    decode_wo_fold = False
    paged_prefill = False
    prefill_wo_fold = False
    # the pure-jnp oracles trace cleanly inside a shard_map body, so the
    # serving engine may head-shard its launches across a tp mesh
    tp_serving = True

    def int8_matmul(self, x8, w8, spec, *, bias32=None, b_vec=None, **opts):
        if spec.is_raw:
            acc = jnp.dot(x8, w8, preferred_element_type=jnp.int32)
            if bias32 is not None:
                acc = acc + bias32[None, :]
            return acc
        if spec.kind == _spec.PER_TENSOR:
            return _ref.ref_int8_matmul(x8, w8, bias32, spec.dn,
                                        spec.out_bits)
        if b_vec is None:
            raise ValueError("per-channel RequantSpec needs the b_vec "
                             "multiplier vector (QuantLinearParams.b_mult)")
        return _ref.ref_int8_matmul_perchannel(x8, w8, bias32, b_vec,
                                               spec.c, spec.pre,
                                               spec.out_bits)

    def int_softmax(self, scores, plan, **opts):
        return _ref.ref_int_softmax(scores, plan,
                                    where=opts.get("where"))

    def int_gelu(self, q, plan, dn_out, out_bits: int = 8, **opts):
        return _ref.ref_int_gelu(q, plan, dn_out, out_bits)

    def int_layernorm(self, q, q_gamma, q_beta, plan, out_bits: int = 8,
                      **opts):
        return _ref.ref_int_layernorm(q, q_gamma, q_beta, plan, out_bits)

    def int_attention(self, q8, k8, v8, plan, causal: bool = True,
                      window: int = 0, out_bits: int = 8, requant=None,
                      b_vec=None, **opts):
        return _ref.ref_int_attention(q8, k8, v8, plan, causal, window,
                                      out_bits, requant=requant,
                                      b_vec=b_vec)

    def int_decode_attention(self, q8, k8_cache, v8_cache, plan, valid_len,
                             out_bits: int = 8, requant=None, b_vec=None,
                             **opts):
        return _ref.ref_int_decode_attention(q8, k8_cache, v8_cache, plan,
                                             valid_len, out_bits,
                                             requant=requant, b_vec=b_vec)
