"""Regression tests for the HLO collective parser (EXPERIMENTS.md §Perf C4:
a header-regex bug silently dropped all while-loop trip multipliers)."""
import numpy as np

from benchmarks.roofline import (
    CollectiveOp,
    _shape_bytes,
    parse_hlo_collectives,
    roofline_terms)

HLO = """\
HloModule test

%wide.cond_spmd.clone (arg_tuple.1: (s32[], bf16[16,256]{1,0})) -> pred[] {
  %gte = s32[] get-tuple-element(%arg_tuple.1), index=0
  %constant.9 = s32[] constant(32)
  ROOT %cmp = pred[] compare(%gte, %constant.9), direction=LT
}

%wide.body_spmd.clone (arg_tuple.2: (s32[], bf16[16,256]{1,0})) -> (s32[], bf16[16,256]{1,0}) {
  %gte2 = bf16[16,256]{1,0} get-tuple-element(%arg_tuple.2), index=1
  %ag = bf16[16,4096]{1,0} all-gather(%gte2), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}, use_global_device_ids=true
  ROOT %t = (s32[], bf16[16,256]{1,0}) tuple(%gte2, %gte2)
}

ENTRY %main.1 (p0: bf16[16,256]{1,0}) -> bf16[16,256]{1,0} {
  %p0 = bf16[16,256]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%p0), channel_id=2, replica_groups=[16,16]<=[256], to_apply=%add
  %w = (s32[], bf16[16,256]{1,0}) while(%tuple.1), condition=%wide.cond_spmd.clone, body=%wide.body_spmd.clone
  ROOT %out = bf16[16,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096]{1,0}") == 16 * 4096 * 2
    assert _shape_bytes("(f32[2,3]{1,0}, s8[5]{0})") == 24 + 5
    assert _shape_bytes("s32[]") == 4


def test_while_trip_multiplier_applied():
    colls, mult = parse_hlo_collectives(HLO)
    kinds = {c.kind: c for c in colls}
    assert kinds["all-gather"].multiplier == 32.0     # inside the while
    assert kinds["all-reduce"].multiplier == 1.0      # in ENTRY
    # header regex must survive tuple-typed computation params (C4 bug)
    assert "wide.body_spmd.clone" in mult
    assert mult["wide.body_spmd.clone"] == 32.0


def test_wire_byte_model():
    ag = CollectiveOp("all-gather", 1024.0, 16, "x")
    ar = CollectiveOp("all-reduce", 1024.0, 16, "x")
    assert np.isclose(ag.wire_bytes(), 1024 * 15 / 16)
    assert np.isclose(ar.wire_bytes(), 2 * 1024 * 15 / 16)


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 0.0, 0.0)
    assert t["bottleneck"] == "compute" and abs(t["t_compute_s"] - 1) < 1e-9
    t = roofline_terms(0.0, 0.0, 50e9)
    assert t["bottleneck"] == "collective"
