from repro.serving.engine import (EngineStalled, PendingStep, Request,
                                  ServingEngine, StepInFlight)
from repro.serving.frontend import (QueueFull, RequestMetrics,
                                    ServingFrontend, StreamHandle,
                                    TERMINAL_STATES)
from repro.serving.kvcache import (BlockAllocator, CacheLayout, NULL_PAGE,
                                   PagedKVCache, PagePoolExhausted,
                                   PageTable, PrefixEntry, PrefixIndex,
                                   Session)
from repro.serving.speculate import (NgramProposer, Proposer,
                                     SpeculationError,
                                     SpeculationUnsupported, get_proposer,
                                     validate_spec)

__all__ = ["ServingEngine", "Request", "EngineStalled", "PendingStep",
           "StepInFlight", "ServingFrontend", "StreamHandle", "QueueFull",
           "RequestMetrics", "TERMINAL_STATES", "BlockAllocator",
           "CacheLayout", "NULL_PAGE", "PagedKVCache", "PagePoolExhausted",
           "PageTable", "PrefixEntry", "PrefixIndex", "Session",
           "NgramProposer", "Proposer", "SpeculationError",
           "SpeculationUnsupported", "get_proposer", "validate_spec"]
