"""Production training driver.

Wires every substrate together: config registry -> sharded QAT train step
(SP/TP/ZeRO-1/FSDP rules) -> fault-tolerant loop (async checkpoints,
straggler detection, restart) -> data pipeline.  Runs on whatever devices
exist (1 CPU locally, a v5e pod in production — the mesh shape adapts).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
      --reduced --steps 100 --batch 8 --seq 256 [--ckpt-dir DIR]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import make_train_iterator
from repro.distributed.fault import FaultTolerantLoop, StragglerDetector
from repro.launch import shardings as shd
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh, set_mesh
from repro.models import model as M
from repro.models import transformer as tf
from repro.optim import adamw_init
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine


def choose_mesh():
    n = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default=None,
                    help="token file (memory-mapped); default synthetic")
    ap.add_argument("--int-eval", action="store_true",
                    help="after training, quantize and run one integer "
                         "prefill through the configured op backend")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = M.reduce_config(cfg, dtype="float32", vocab=1024)
    mesh = choose_mesh()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.axis_sizes))}")

    data = make_train_iterator(cfg, args.seq, args.batch, path=args.data,
                               host_id=jax.process_index(),
                               n_hosts=jax.process_count())
    opt_cfg = AdamWConfig(lr=args.lr, zero1=True)
    lr_fn = linear_warmup_cosine(max(args.steps // 10, 1), args.steps)

    with set_mesh(mesh):
        params = tf.init_params(jax.random.key(0), cfg)
        p_sh = shd.param_pspecs(params, mesh,
                                fsdp=cfg.param_count() > 2e10)
        step = steps_mod.make_train_step(cfg, opt_cfg, lr_fn,
                                         param_specs=p_sh)
        opt = adamw_init(params, opt_cfg)
        train_step = jax.jit(step, donate_argnums=(0, 1))

        def step_fn(state, batch):
            params, opt = state
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = train_step(params, opt, batch)
            return (params, opt), metrics

        mgr = CheckpointManager(args.ckpt_dir)
        start = mgr.latest_step() or 0
        state = (params, opt)
        if start:
            print(f"resuming from step {start}")
            state, meta = mgr.restore(state)
            data.load_state_dict(meta["extra"]["data"])
        loop = FaultTolerantLoop(step_fn, mgr, data,
                                 ckpt_every=args.ckpt_every,
                                 straggler=StragglerDetector())
        t0 = time.time()
        state, log = loop.run(state, args.steps, start_step=start)
        dt = time.time() - t0
    tok_s = args.batch * args.seq * (args.steps - start) / max(dt, 1e-9)
    print(f"loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}  "
          f"({tok_s:,.0f} tok/s, restarts={loop.restarts}, "
          f"stragglers={loop.straggler.flagged})")
    if args.int_eval:
        from repro import ops as rops
        from repro.models import inttransformer as it
        from repro.quant import convert
        params = state[0]
        qp, plans = convert.quantize_params(params, cfg)
        ops = rops.resolve_ops(None, cfg)
        batch = next(data)
        logits = it.int_prefill(
            qp, {"tokens": jnp.asarray(batch["tokens"])}, plans, cfg,
            ops=ops)
        print(f"int-eval ({ops.name}): logits {logits.shape} "
              f"max|.|={float(jnp.abs(logits).max()):.2f}")


if __name__ == "__main__":
    main()
