"""Integer-path model assembly (prefill + decode) for every family.

The serving datapath of the framework: everything from embedding lookup to
the last requant is SwiftTron integer arithmetic; only the final logits are
dequantized (host-side sampling boundary).

Caches:
  attention  — int8 KV at s_act8; sliding-window archs keep a rolling
               ``window``-sized buffer (slot = pos % window)
  mamba      — int32 SSD state + int8 conv tail
  cross      — int8 K/V of the encoder/image memory, computed at prefill
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import intlayers as il
from repro.models.common import ArchConfig
from repro.models.transformer import layer_group_spec
from repro.ops import resolve_ops
from repro.quant import plans as qplans

Pytree = Any


def _residual_add(x32, delta32, cfg: ArchConfig):
    return jnp.clip(x32 + delta32, -cfg.qmax_res, cfg.qmax_res)


def _sub_plans(plans: qplans.LayerPlans, kind):
    mix, ff, has_cross = kind
    return plans


def _int_sublayer_fwd(qp, x32, plans: qplans.LayerPlans, cfg: ArchConfig,
                      kind, rope_tab, positions, causal, memory8, ops):
    """Pre-norm integer sublayer.  x32: (B,S,D) int32 at s_res."""
    mix, ff, has_cross = kind
    h8 = il.int_norm(qp["norm1"], x32, plans.norm, ops)
    if mix == "attn":
        a32 = il.int_attn_fwd(qp["attn"], h8, plans.attn, cfg, rope_tab,
                              positions, causal=causal, window=cfg.window,
                              ops=ops)
    elif mix == "cross":
        a32 = il.int_attn_fwd(qp["attn"], h8, plans.cross, cfg, None,
                              positions, causal=False, memory8=memory8,
                              ops=ops)
    else:
        out, _ = il.int_mamba_prefill(qp["ssm"], h8, plans.mamba, cfg,
                                      ops=ops)
        a32 = out
    x32 = _residual_add(x32, a32, cfg)
    if has_cross:
        h8 = il.int_norm(qp["norm_cross"], x32, plans.norm, ops)
        c32 = il.int_attn_fwd(qp["cross"], h8, plans.cross, cfg, None,
                              positions, causal=False, memory8=memory8,
                              ops=ops)
        x32 = _residual_add(x32, c32, cfg)
    if ff is not None:
        h8 = il.int_norm(qp["norm2"], x32, plans.norm, ops)
        if ff == "moe":
            f32 = il.int_moe_fwd(qp["moe"], h8, plans.moe, cfg, ops)
        else:
            f32 = il.int_ffn_fwd(qp["ffn"], h8, plans.ffn, cfg, ops)
        x32 = _residual_add(x32, f32, cfg)
    return x32


def embed_int(qparams, tokens, plans: qplans.LayerPlans, cfg: ArchConfig):
    e8 = jnp.take(qparams["embed_w8"], tokens, axis=0).astype(jnp.int32)
    x32 = plans.embed.dn_res(e8)
    return shard(x32, "batch", "seq", "embed")


def quantize_memory(mem_f, cfg: ArchConfig):
    """Float boundary for stubbed frontends: img/audio embeddings -> int8."""
    q = jnp.round(mem_f.astype(jnp.float32) / cfg.s_act8)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def logits_int(qparams, x32, plans: qplans.LayerPlans, cfg: ArchConfig,
               ops=None):
    ops = resolve_ops(ops, cfg)
    h8 = il.int_norm(qparams["final_norm"], x32, plans.final_norm, ops)
    head_plan = qplans.LinearPlan(cfg.s_act8, 0.0, 32, 0, 0, cfg.d_model)
    acc = il.int_linear(h8, qparams["head"], head_plan, ops)
    # host-side dequant boundary: float per-channel scales
    return acc.astype(jnp.float32) * qparams["head_scale"][None] \
        * cfg.s_act8


def int_prefill(qparams, batch, plans: qplans.LayerPlans, cfg: ArchConfig,
                ops=None, return_cache=False, cache_len: int = 0,
                rope_tab=None):
    """Full-sequence integer forward; returns last-position float logits
    (+ decode caches when ``return_cache``).

    ``ops``: an ``repro.ops.OpSet`` (or backend name) resolved once here
    and handed down — per-call backend strings are gone.
    ``rope_tab``: int32 (cos, sin) design tables passed as *arguments* so
    they are inputs, not multi-MB HLO constants."""
    ops = resolve_ops(ops, cfg)
    gl, ng, kinds = layer_group_spec(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    memory8 = None
    if cfg.family == "encdec":
        memory8 = _int_encoder(qparams, batch["src_embeds"], plans, cfg,
                               ops)
    elif cfg.family == "vlm":
        memory8 = quantize_memory(batch["img_embeds"], cfg)
    if rope_tab is None and cfg.pos == "rope":
        rope_tab = il.build_rope_table(max(s, cache_len) + 1, cfg.hd,
                                       cfg.rope_theta)
    positions = jnp.arange(s)
    x32 = embed_int(qparams, tokens, plans, cfg)

    def body(x32, qp_group):
        for j, kind in enumerate(kinds):
            x32 = _int_sublayer_fwd(qp_group[j], x32, plans, cfg, kind,
                                    rope_tab, positions, cfg.is_causal,
                                    memory8, ops)
        return x32, None

    x32, _ = jax.lax.scan(body, x32, tuple(qparams["layers"]))
    last = x32[:, -1:, :]
    logits = logits_int(qparams, last, plans, cfg, ops)[:, 0]
    if not return_cache:
        return logits
    cache = build_cache_from_prefill(qparams, batch, plans, cfg, ops,
                                     cache_len or s)
    return logits, cache


def _int_encoder(qparams, src_embeds, plans, cfg: ArchConfig, ops):
    mem8 = quantize_memory(src_embeds, cfg)
    # boundary embeddings are on the s_act8 grid -> bring to the residual bus
    dn = qplans.fit_dyadic(cfg.s_act8 / cfg.s_res, 127)
    x32 = dn(mem8.astype(jnp.int32))
    positions = jnp.arange(mem8.shape[1])

    def body(x32, qp):
        x32 = _int_sublayer_fwd(qp, x32, plans, cfg,
                                ("attn", "ffn", False), None, positions,
                                False, None, ops)
        return x32, None

    enc = qparams["enc_layers"]
    x32, _ = jax.lax.scan(body, x32, enc[0] if isinstance(enc, list)
                          else enc)
    return il.int_norm(qparams["enc_final_norm"], x32, plans.norm, ops)


# ============================================================ decode =======

def init_decode_cache(cfg: ArchConfig, batch: int, cache_len: int,
                      memory8=None, qparams=None, plans=None,
                      ops=None, layout=None):
    """Per-sublayer-position stacked caches (scan-compatible).

    ``layout``: an optional ``repro.serving.kvcache.CacheLayout`` — the
    attention K/V become physical *page pools* ``(ng, num_pages,
    page_size, Hkv, hd)`` addressed through a page table instead of
    per-lane contiguous buffers; every other cache kind (Mamba state,
    cross-attention memory) stays lane-indexed.  Pool memory is
    ``num_pages × page_size`` tokens per sublayer — O(provisioned
    pages), not O(batch × cache_len).  With ``layout.kv_dtype ==
    "int4"`` the pools pack two head-dim nibbles per byte (last dim
    ``hd // 2``) and carry per-page requant shift arrays ``k_shift`` /
    ``v_shift`` ``(ng, num_pages)`` int32 (``repro.ops.packed.KV_SHIFT``
    everywhere — the static shift the write-side quantizer uses)."""
    ops = resolve_ops(ops, cfg)
    gl, ng, kinds = layer_group_spec(cfg)
    L = min(cache_len, cfg.window) if cfg.window > 0 else cache_len
    kv_packed = layout is not None and layout.kv_dtype == "int4"
    if kv_packed and cfg.hd % 2:
        raise ValueError("int4 KV pages pair head-dim nibbles: hd must "
                         f"be even, got {cfg.hd}")
    caches = []
    for j, (mix, ff, has_cross) in enumerate(kinds):
        c: Dict[str, Any] = {}
        if mix == "attn":
            if layout is None:
                kv_shape = (ng, batch, L, cfg.n_kv_heads, cfg.hd)
            else:
                hd = cfg.hd // 2 if kv_packed else cfg.hd
                kv_shape = (ng, layout.num_pages, layout.page_size,
                            cfg.n_kv_heads, hd)
            c["k8"] = jnp.zeros(kv_shape, jnp.int8)
            c["v8"] = jnp.zeros_like(c["k8"])
            if kv_packed:
                from repro.ops.packed import KV_SHIFT
                c["k_shift"] = jnp.full((ng, layout.num_pages),
                                        KV_SHIFT, jnp.int32)
                c["v_shift"] = jnp.full_like(c["k_shift"], KV_SHIFT)
        elif mix == "ssm":
            st = il.init_int_mamba_state(cfg, batch)
            c["h"] = jnp.broadcast_to(st.h, (ng,) + st.h.shape)
            c["conv"] = jnp.broadcast_to(st.conv, (ng,) + st.conv.shape)
        if (mix == "cross" or has_cross) and memory8 is not None:
            # precompute cross K/V once per sublayer position
            kv = []
            for g in range(ng):
                qp = jax.tree.map(lambda t: t[g], qparams["layers"][j])
                src = qp["cross"] if has_cross else qp["attn"]
                sk = memory8.shape[1]
                k8 = il.int_linear(memory8, src["wk"],
                                   plans.cross.qkv, ops)
                v8 = il.int_linear(memory8, src["wv"],
                                   plans.cross.qkv, ops)
                kv.append((k8.reshape(batch, sk, cfg.n_kv_heads, cfg.hd),
                           v8.reshape(batch, sk, cfg.n_kv_heads, cfg.hd)))
            c["ck8"] = jnp.stack([a for a, _ in kv])
            c["cv8"] = jnp.stack([b for _, b in kv])
        caches.append(c)
    return caches


def _int_sublayer_decode(qp, cache, x32, plans, cfg: ArchConfig, kind,
                         rope_tab, pos, ops, pages=None,
                         page_size: int = 0, max_len: int = 0,
                         fold_wo: bool = False, tp_axis=None):
    mix, ff, has_cross = kind
    new_cache = dict(cache)
    h8 = il.int_norm(qp["norm1"], x32, plans.norm, ops)
    if mix == "attn":
        a32, kv = il.int_attn_decode(qp["attn"], h8, cache, pos,
                                     plans.attn, cfg, rope_tab,
                                     window=cfg.window, ops=ops,
                                     pages=pages, page_size=page_size,
                                     max_len=max_len, fold_wo=fold_wo,
                                     tp_axis=tp_axis)
        new_cache.update(kv)
    elif mix == "cross":
        a32 = _cross_decode(qp["attn"], h8, cache, plans, cfg, pos, ops)
    else:
        st = il.IntMambaState(cache["h"], cache["conv"])
        a32_t, st = il.int_mamba_step(qp["ssm"], h8[:, 0], st, plans.mamba,
                                      cfg, ops)
        a32 = a32_t[:, None]
        new_cache.update({"h": st.h, "conv": st.conv})
    x32 = _residual_add(x32, a32, cfg)
    if has_cross:
        h8 = il.int_norm(qp["norm_cross"], x32, plans.norm, ops)
        c32 = _cross_decode(qp["cross"], h8, cache, plans, cfg, pos,
                            ops)
        x32 = _residual_add(x32, c32, cfg)
    if ff is not None:
        h8 = il.int_norm(qp["norm2"], x32, plans.norm, ops)
        if ff == "moe":
            f32 = il.int_moe_fwd(qp["moe"], h8, plans.moe, cfg, ops,
                                 group_size=1)
        else:
            f32 = il.int_ffn_fwd(qp["ffn"], h8, plans.ffn, cfg, ops)
        x32 = _residual_add(x32, f32, cfg)
    return x32, new_cache


def _cross_decode(qp, h8, cache, plans, cfg, pos, ops):
    # cross memory is fully valid at decode time: decode attention with
    # valid_len pinned to the full memory length — through the configured
    # backend's fused decode path (one kernel launch on pallas_fused;
    # GQA head-repeat is the backend's job).  Bit-identical to plain
    # non-causal attention over the same K/V.
    b = h8.shape[0]
    sk = cache["ck8"].shape[1]
    q8 = il.int_linear(h8, qp["wq"], plans.cross.qkv, ops) \
        .reshape(b, 1, cfg.n_heads, cfg.hd)
    valid = jnp.full((b,), sk, jnp.int32)
    o8 = ops.int_decode_attention(q8, cache["ck8"], cache["cv8"],
                                  plans.cross.attn, valid)
    return il.int_linear(o8.astype(jnp.int8).reshape(b, 1, -1), qp["wo"],
                         plans.cross.out, ops)


def int_decode_step(qparams, caches, tokens, pos, plans, cfg: ArchConfig,
                    rope_tab=None, ops=None, pages=None,
                    page_size: int = 0, max_len: int = 0,
                    fold_wo: bool = False, tp_axis=None):
    """tokens: (B,) int32; pos: (B,) int32.  Returns (logits, caches).

    One scan over layer groups; inside the body the ``gl`` sublayers run in
    architectural order (same traversal as prefill).

    ``pages``/``page_size``/``max_len``: the paged KV-cache operands
    (page table int32 (B, max_pages); see ``init_decode_cache(layout=)``
    and repro.serving.kvcache).  ``fold_wo`` folds each attention
    sublayer's o-projection requant into the decode epilogue
    (bit-exact either way).  ``tp_axis``: tensor-parallel tracing under
    shard_map — ``qparams``/``caches`` are head-sharded, ``cfg`` carries
    the local head counts, and each attention o-projection all-reduces
    its int32 partials before requanting once (see
    ``repro.distributed.tp_serving``)."""
    ops = resolve_ops(ops, cfg)
    gl, ng, kinds = layer_group_spec(cfg)
    x32 = embed_int(qparams, tokens[:, None], plans, cfg)

    def body(x32, xs):
        qp_group, cache_group = xs
        new_group = []
        for j, kind in enumerate(kinds):
            x32, nc = _int_sublayer_decode(qp_group[j], cache_group[j],
                                           x32, plans, cfg, kind, rope_tab,
                                           pos, ops, pages=pages,
                                           page_size=page_size,
                                           max_len=max_len,
                                           fold_wo=fold_wo,
                                           tp_axis=tp_axis)
            new_group.append(nc)
        return x32, tuple(new_group)

    x32, new_caches = jax.lax.scan(
        body, x32, (tuple(qparams["layers"]), tuple(caches)))
    logits = logits_int(qparams, x32, plans, cfg, ops)[:, 0]
    return logits, list(new_caches)


def speculative_decode_supported(cfg: ArchConfig) -> bool:
    """Whether :func:`int_verify_step` serves this arch: full
    (non-windowed) causal attention, no lane-indexed sublayer state.
    Sliding windows interleave rolling-buffer writes and reads token by
    token (a batched multi-position write would clobber slots earlier
    verify rows still need), and SSM / cross-attention state advances
    destructively per token — a rejected draft could not roll it back.
    Dense FFN *and* MoE sublayers are fine: decode routes MoE with
    ``group_size=1`` (one token per routing group), so each verify row
    routes independently, bit-exact against sequential decode."""
    _, _, kinds = layer_group_spec(cfg)
    return cfg.window == 0 and all(mix == "attn" and not has_cross
                                   for (mix, ff, has_cross) in kinds)


def int_verify_step(qparams, caches, tokens, pos, n_new, plans,
                    cfg: ArchConfig, rope_tab=None, ops=None, pages=None,
                    page_size: int = 0, max_len: int = 0,
                    fold_wo: bool = False, tp_axis=None):
    """One speculative verify step: score S = spec_k + 1 candidate
    positions per lane in a single stepped-mask decode launch.

    ``tokens``: (B, S) int32, each lane's real tokens (last committed
    token + its drafts) **right-aligned**; ``pos``: (B,) the lane's
    current position (the first real row writes there); ``n_new``: (B,)
    count of real rows, ``1 <= n_new <= S`` with ``pos + n_new <= L``
    (idle lanes pass ``n_new = 1`` with token 0 — the same discarded
    garbage row the plain decode step gives them).  Returns
    ``(logits (B, S, V), caches)`` — the caller reads rows
    ``S - n_new ..`` and commits the longest argmax-matching draft
    prefix plus the bonus token.

    Row ``i`` of lane ``b`` covers logical position ``pos[b] +
    n_new[b] - S + i`` and the ``valid_len = pos + n_new`` stepped mask
    (``ops.int_decode_attention``; built for exactly this in PR 3)
    limits it to positions ``<= pos + n_new - S + i`` — the visibility
    a sequential decode of the same tokens would have.  Embedding,
    norms, FFN/MoE(``group_size=1``) and the residual stream are
    position-independent, and the attention rows are masked
    identically, so each real row's logits are **bit-exact** against
    feeding its token through :func:`int_decode_step` — greedy
    acceptance therefore reproduces the non-speculative stream token
    for token.  Supported archs: :func:`speculative_decode_supported`.
    """
    ops = resolve_ops(ops, cfg)
    if not speculative_decode_supported(cfg):
        raise ValueError("speculative verify unsupported for arch "
                         f"{cfg.name!r} (needs window == 0 and "
                         "attention+ffn/moe sublayers only)")
    gl, ng, kinds = layer_group_spec(cfg)
    x32 = embed_int(qparams, tokens, plans, cfg)

    def body(x32, xs):
        qp_group, cache_group = xs
        new_group = []
        for j, kind in enumerate(kinds):
            qp, cache = qp_group[j], cache_group[j]
            new_cache = dict(cache)
            h8 = il.int_norm(qp["norm1"], x32, plans.norm, ops)
            a32, kv = il.int_attn_decode(qp["attn"], h8, cache, pos,
                                         plans.attn, cfg, rope_tab,
                                         window=0, ops=ops, pages=pages,
                                         page_size=page_size,
                                         max_len=max_len, fold_wo=fold_wo,
                                         tp_axis=tp_axis, n_new=n_new)
            new_cache.update(kv)
            x32 = _residual_add(x32, a32, cfg)
            _, ff, _ = kind
            if ff is not None:
                h8 = il.int_norm(qp["norm2"], x32, plans.norm, ops)
                if ff == "moe":
                    f32 = il.int_moe_fwd(qp["moe"], h8, plans.moe, cfg,
                                         ops, group_size=1)
                else:
                    f32 = il.int_ffn_fwd(qp["ffn"], h8, plans.ffn, cfg,
                                         ops)
                x32 = _residual_add(x32, f32, cfg)
            new_group.append(new_cache)
        return x32, tuple(new_group)

    x32, new_caches = jax.lax.scan(
        body, x32, (tuple(qparams["layers"]), tuple(caches)))
    logits = logits_int(qparams, x32, plans, cfg, ops)
    return logits, list(new_caches)


def chunked_prefill_supported(cfg: ArchConfig) -> bool:
    """Whether :func:`int_prefill_chunk_step` serves this arch: full
    (non-windowed) causal attention + dense FFN sublayers only.  Sliding
    windows interleave rolling-buffer writes and reads token-by-token
    (a batched chunk write would clobber positions earlier chunk rows
    still need), SSM state updates are inherently sequential per lane,
    MoE capacity-based routing drops tokens per *group* (so chunked
    grouping would diverge from token streaming), and cross-attention
    archs carry lane-indexed memory — all of those keep the engine's
    token-streaming prefill."""
    _, _, kinds = layer_group_spec(cfg)
    return cfg.window == 0 and all(kind == ("attn", "ffn", False)
                                   for kind in kinds)


def int_prefill_chunk_step(qparams, caches, tokens, base_pos, plans,
                           cfg: ArchConfig, rope_tab=None, ops=None,
                           pages=None, page_size: int = 0,
                           fold_wo: bool = False, tp_axis=None):
    """One chunked-prefill step: advance every prefilling lane by one
    C-token prompt chunk, writing K/V straight into the paged pools.

    ``tokens``: (B, C) int32 chunk tokens (pad lanes/positions with 0 —
    their writes land on pages the table routes to the reserved null
    page, or on positions a later decode step overwrites before
    ``valid_len`` ever marks them live); ``base_pos``: (B,) int32 first
    logical position of each lane's chunk; ``pages``: the *prefill view*
    of the page table — rows of lanes not being prefilled must be
    nulled, so their (discarded) chunk writes cannot touch live pages.

    Returns the new caches only — chunked prefill fills the cache, it
    does not sample (the engine feeds the prompt's last token through
    the decode step, exactly as the token-streaming path).  Bit-exact
    against streaming the same tokens through :func:`int_decode_step`
    one at a time (same ops, same epilogues, row-independent integer
    math).  Supported archs: :func:`chunked_prefill_supported`.
    """
    ops = resolve_ops(ops, cfg)
    if not chunked_prefill_supported(cfg):
        raise ValueError("chunked prefill unsupported for arch "
                         f"{cfg.name!r} (needs window == 0 and "
                         "attention+ffn sublayers only)")
    gl, ng, kinds = layer_group_spec(cfg)
    x32 = embed_int(qparams, tokens, plans, cfg)

    def body(x32, xs):
        qp_group, cache_group = xs
        new_group = []
        for j in range(len(kinds)):
            qp, cache = qp_group[j], cache_group[j]
            new_cache = dict(cache)
            h8 = il.int_norm(qp["norm1"], x32, plans.norm, ops)
            a32, kv = il.int_attn_prefill_chunk(
                qp["attn"], h8, cache, base_pos, plans.attn, cfg,
                rope_tab, ops=ops, pages=pages, page_size=page_size,
                fold_wo=fold_wo, tp_axis=tp_axis)
            new_cache.update(kv)
            x32 = _residual_add(x32, a32, cfg)
            h8 = il.int_norm(qp["norm2"], x32, plans.norm, ops)
            f32 = il.int_ffn_fwd(qp["ffn"], h8, plans.ffn, cfg, ops)
            x32 = _residual_add(x32, f32, cfg)
            new_group.append(new_cache)
        return x32, tuple(new_group)

    _, new_caches = jax.lax.scan(
        body, x32, (tuple(qparams["layers"]), tuple(caches)))
    return list(new_caches)


def build_cache_from_prefill(qparams, batch, plans, cfg, ops,
                             cache_len):
    """Serving-engine helper: run prefill token-by-token into the decode
    cache (kept simple; the engine uses it for short prompts)."""
    ops = resolve_ops(ops, cfg)
    gl, ng, kinds = layer_group_spec(cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    memory8 = None
    if cfg.family == "vlm":
        memory8 = quantize_memory(batch["img_embeds"], cfg)
    elif cfg.family == "encdec":
        memory8 = _int_encoder(qparams, batch["src_embeds"], plans, cfg,
                               ops)
    caches = init_decode_cache(cfg, b, cache_len, memory8, qparams, plans,
                               ops)
    rope_tab = il.build_rope_table(cache_len + 1, cfg.hd, cfg.rope_theta) \
        if cfg.pos == "rope" else None

    def step(carry, t):
        caches = carry
        tok = jax.lax.dynamic_index_in_dim(tokens, t, 1, keepdims=False)
        pos = jnp.full((b,), t, jnp.int32)
        logits, caches = int_decode_step(qparams, caches, tok, pos, plans,
                                         cfg, rope_tab, ops)
        return caches, logits

    caches, _ = jax.lax.scan(step, caches, jnp.arange(s))
    return caches
