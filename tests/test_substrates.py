"""Data pipeline, optimizer, checkpointing, fault tolerance, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.data.pipeline import SyntheticLMDataset
from repro.distributed.collectives import (compressed_grads,
                                           init_compression)
from repro.distributed.fault import (ElasticMesh, FaultTolerantLoop,
                                     StragglerDetector)
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine


# ------------------------------------------------------------- data -------

def test_data_deterministic_and_resumable():
    d1 = SyntheticLMDataset(1024, 32, 4, seed=7)
    a = next(d1)
    b = next(d1)
    st = d1.state_dict()
    c = next(d1)
    d2 = SyntheticLMDataset(1024, 32, 4, seed=7)
    d2.load_state_dict(st)
    c2 = next(d2)
    assert np.array_equal(c["tokens"], c2["tokens"])
    assert not np.array_equal(a["tokens"], b["tokens"])
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()


def test_data_host_sharding():
    h0 = next(SyntheticLMDataset(1024, 32, 4, seed=7, host_id=0))
    h1 = next(SyntheticLMDataset(1024, 32, 4, seed=7, host_id=1))
    assert not np.array_equal(h0["tokens"], h1["tokens"])


# ------------------------------------------------------------ optim -------

def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(jnp.abs(params["w"] - target).max()) < 1e-2


def test_lr_schedule():
    fn = linear_warmup_cosine(10, 100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(fn(jnp.asarray(100))) < 0.2


# ------------------------------------------------------- checkpoint -------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 4))},
            "layers": [{"w": jnp.zeros(2)}, {"w": jnp.ones(2)}]}
    save_checkpoint(str(tmp_path), 5, tree, extra={"k": 1})
    out, meta = load_checkpoint(str(tmp_path), tree)
    assert meta["step"] == 5 and meta["extra"]["k"] == 1
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones(4)}
    for s in (1, 2, 3):
        mgr.save(s, tree)
        mgr.wait()
    assert mgr.latest_step() == 3
    dirs = sorted(os.listdir(tmp_path))
    assert len([d for d in dirs if d.startswith("step_")]) == 2


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones(4)})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"w": jnp.ones(5)})


# ------------------------------------------------------------ fault -------

def test_fault_tolerant_loop_recovers(tmp_path):
    data = SyntheticLMDataset(64, 8, 2, seed=0)
    mgr = CheckpointManager(str(tmp_path))
    calls = {"n": 0}

    def injector(step):
        calls["n"] += 1
        if calls["n"] == 7:                 # one simulated node failure
            raise RuntimeError("simulated preemption")

    def step_fn(state, batch):
        return {"w": state["w"] + 1}, {"loss": jnp.asarray(1.0)}

    loop = FaultTolerantLoop(step_fn, mgr, data, ckpt_every=2,
                             fail_injector=injector)
    state, log = loop.run({"w": jnp.zeros(())}, n_steps=10)
    assert loop.restarts == 1
    assert float(state["w"]) == 10.0        # replayed steps land correctly
    assert len(log) >= 10


def test_straggler_detector():
    det = StragglerDetector(window=20, threshold=2.0)
    for _ in range(15):
        det.observe(0.1)
    assert det.observe(0.5) is True
    assert det.observe(0.1) is False
    assert det.flagged == 1


def test_elastic_replan():
    em = ElasticMesh(data_size=16, model_size=16, global_batch=256)
    plan = em.replan(healthy_devices=255)       # lost one chip
    assert plan.data_size == 15
    assert plan.global_batch == 240
    with pytest.raises(RuntimeError):
        em.replan(healthy_devices=15)


# ---------------------------------------------------- compression ---------

def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 0.1, (64,)).astype(np.float32))
    state = init_compression({"g": g_true})
    acc = jnp.zeros_like(g_true)
    # over many steps the error-feedback mean converges to the true grad
    for _ in range(64):
        g_hat, state = compressed_grads({"g": g_true}, state)
        acc = acc + g_hat["g"]
    mean = acc / 64
    assert float(jnp.abs(mean - g_true).max()) < 2e-3
    # single-shot error bounded by one int8 ulp
    g_hat, _ = compressed_grads({"g": g_true}, init_compression(
        {"g": g_true}))
    ulp = float(jnp.max(jnp.abs(g_true))) / 127
    assert float(jnp.abs(g_hat["g"] - g_true).max()) <= ulp
