"""Property-based schedule sweep for speculative decoding, plus the
sharded spec-parity check.

Hypothesis drives random submit/step/preempt/evict schedules against a
spec-enabled paged engine and asserts, **after every schedule op**:

  * per-page refcounts equal the page's live holders exactly (sessions
    + prefix entries) — draft rollback (``PagedKVCache.truncate``) must
    never leak or double-free a page, under CoW and prefix sharing;
  * every committed stream is a prefix of (or equal to) the memoized
    solo spec-off reference — speculation plus arbitrary scheduling
    never changes *which* tokens a request gets.

The mesh test replays a spec workload at tp in {1, 2} inside a
forced-2-device subprocess (``mesh_runner``) and asserts the sharded
spec streams match the unsharded spec-off streams bit-exactly — the
verify launch's ``Sq`` axis is replicated under the mesh.

Needs the optional ``hypothesis`` dev dependency (skip without it).
"""
import collections

import jax
import numpy as np
import pytest

from mesh_runner import run_with_devices

from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import PagePoolExhausted, Request, ServingEngine

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1, n_heads=4,
                          n_kv_heads=4)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans


rng = np.random.default_rng(3)
stem = list(map(int, rng.integers(1, 100, 20)))
PROMPTS = [stem, stem[:-1] + [101], stem[:9],
           [7, 8, 9, 7, 8, 9, 7, 8], [5, 9], [42]]


def check_refcounts(eng, sessions):
    eng.kv.allocator.check()
    held = collections.Counter()
    for sess in sessions:
        held.update(sess.pages)
    if eng.prefix is not None:
        for entry in eng.prefix.entries.values():
            held.update(entry.pages)
    for page in range(1, eng.layout.num_pages):
        assert eng.kv.allocator.refcount[page] == held.get(page, 0), (
            page, eng.kv.allocator.refcount[page], held.get(page, 0))


def test_spec_random_schedules_keep_refcounts_and_streams(setup):
    pytest.importorskip("hypothesis",
                        reason="property tests need hypothesis "
                               "(pip install -r requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st
    cfg, qp, plans = setup
    solo = {}

    def expected(prompt):
        key = tuple(prompt)
        if key not in solo:
            eng = ServingEngine(qp, plans, cfg, batch_size=2,
                                cache_len=64, ops="ref",
                                cache_mode="contiguous")
            req = Request(uid=0, prompt=list(prompt),
                          max_new_tokens=MAX_NEW)
            eng.submit(req)
            eng.run_until_done()
            solo[key] = list(req.out_tokens)
        return solo[key]

    def run_schedule(spec_k, schedule, num_pages, prefix):
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                            ops="ref", page_size=8, num_pages=num_pages,
                            prefix_cache=prefix, spec_k=spec_k)
        requests, sessions = [], []
        uid = 0

        def relieve():
            live = [s for s in sessions
                    if s.state in ("prefilling", "active", "preempted")]
            if live:
                eng.evict(live[0])

        for op, arg in schedule:
            try:
                if op == "submit":
                    req = Request(uid=uid, prompt=list(PROMPTS[arg]),
                                  max_new_tokens=MAX_NEW)
                    uid += 1
                    requests.append(req)
                    sessions.append(eng.submit(req))
                elif op == "step":
                    eng.step()
                elif op == "preempt":
                    live = [s for s in sessions
                            if s.state in ("active", "prefilling")]
                    if live:
                        eng.preempt(live[arg % len(live)])
                elif op == "evict":
                    live = [s for s in sessions
                            if s.state not in ("done",)]
                    live = [s for s in live if s.pages or s in eng.queue
                            or s.slot is not None]
                    if live:
                        eng.evict(live[arg % len(live)])
            except PagePoolExhausted:
                relieve()               # legal under pool pressure
            check_refcounts(eng, sessions)
        for _ in range(400):            # drain, relieving pressure
            if not eng.queue and all(s is None for s in eng.slots):
                break
            try:
                eng.step()
            except PagePoolExhausted:
                relieve()
            check_refcounts(eng, sessions)
        return [(list(r.prompt), list(r.out_tokens), r.done)
                for r in requests]

    @given(
        schedule=st.lists(
            st.tuples(st.sampled_from(["submit", "step", "preempt",
                                       "evict"]),
                      st.integers(0, 5)),
            max_size=14),
        num_pages=st.sampled_from([6, 9]),
        prefix=st.booleans(),
        spec_k=st.sampled_from([2, 3]),
    )
    @settings(max_examples=6, deadline=None)
    def prop(schedule, num_pages, prefix, spec_k):
        outs = run_schedule(spec_k, schedule, num_pages, prefix)
        outs0 = run_schedule(0, schedule, num_pages, prefix)
        # spec + arbitrary scheduling never changes *which* tokens:
        # every stream is a prefix of the solo spec-off reference ...
        for prompt, toks, done in outs + outs0:
            want = expected(prompt)
            assert toks == (want if done else want[:len(toks)]), prompt
        # ... and per request the spec-on and spec-off runs of the SAME
        # schedule agree token-for-token as far as both got (a lane
        # committing k+1 tokens per step reaches an evict op deeper
        # into its stream, so lengths — never tokens — may differ)
        assert len(outs) == len(outs0)
        for (p, t_on, _), (p0, t_off, _) in zip(outs, outs0):
            assert p == p0
            n = min(len(t_on), len(t_off))
            assert t_on[:n] == t_off[:n], p

    prop()


SHARDED_BODY = """
from repro.configs.registry import get_config
from repro.models import model as M, transformer as tf
from repro.quant import convert
from repro.serving import Request, ServingEngine

cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                      vocab=128, num_layers=1, n_heads=4, n_kv_heads=4)
params = tf.init_params(jax.random.key(0), cfg)
qp, plans = convert.quantize_params(params, cfg)
PROMPTS = [[3, 5, 7, 3, 5, 7, 3, 5], [11, 2, 11, 2, 11]]

def run(tp, spec_k):
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", tp=tp, spec_k=spec_k)
    mode = eng.describe()["tp"]["mode"]
    assert mode == ("sharded" if tp > 1 else "off"), mode
    reqs = [Request(uid=i, prompt=list(p), max_new_tokens=16)
            for i, p in enumerate(PROMPTS)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    stats = eng.describe()["spec"]
    return [list(r.out_tokens) for r in reqs], stats

base, _ = run(1, 0)
for tp in (1, 2):
    for spec_k in (2, 4):
        out, stats = run(tp, spec_k)
        assert out == base, (tp, spec_k, out, base)
        assert stats["drafted"] > 0, (tp, spec_k)
# the host-side proposer/acceptance logic is replicated, so sharded
# and unsharded runs must also agree on the accounting
_, s1 = run(1, 2)
_, s2 = run(2, 2)
assert s1 == s2, (s1, s2)
"""


def test_sharded_spec_streams_match_unsharded_spec_off(tmp_path):
    run_with_devices(SHARDED_BODY, 2, tmp_path)
