"""mamba2-130m [ssm]: SSD (state-space duality) [arXiv:2405.21060].
Attention-free: the paper's softmax/attention units are N/A (DESIGN.md
§6); the quantization scheme applies to the projections and the SSD
recurrence runs int32 fixed-point.  vocab 50280 padded to 50288."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280, ssm_state=128,
    ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_groups=1,
    tie_embeddings=True, norm="rmsnorm", pos="none",
)
