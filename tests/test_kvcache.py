"""Paged KV-cache layer: layout, allocator and page-table invariants.

Deterministic edge cases for ``repro.serving.kvcache`` — typed pool
exhaustion, ref-counted release/reuse, zero-length sessions, the null
page 0 reservation — plus a hypothesis property sweep over random
admit/evict/preempt schedules asserting the allocator's partition
invariant after every operation (importorskip'd like the decode props
suite).  Engine-level paged-decode parity lives in
``test_paged_decode.py``.
"""
import pytest

from repro.serving.kvcache import (BlockAllocator, CacheLayout, NULL_PAGE,
                                   PagedKVCache, PagePoolExhausted,
                                   PageTable, Session)


# ----------------------------------------------------------- layout ------

def test_layout_geometry():
    lo = CacheLayout(num_slots=2, max_len=60, page_size=16, num_pages=9)
    assert lo.max_pages == 4                    # ceil(60 / 16)
    assert lo.logical_len == 64                 # kernel-visible length
    assert lo.capacity_tokens == 8 * 16         # null page excluded


def test_layout_fit_full_provisioning():
    lo = CacheLayout.fit(num_slots=4, max_len=64, page_size=16)
    # every lane can reach max_len simultaneously, +1 for the null page
    assert lo.num_pages == 4 * 4 + 1
    assert CacheLayout.fit(4, 64, 16, num_pages=6).num_pages == 6


def test_layout_validation():
    with pytest.raises(ValueError, match="num_pages"):
        CacheLayout(1, 16, 16, 1)               # no room for the null page
    with pytest.raises(ValueError, match="page_size"):
        CacheLayout(1, 16, 0, 4)


# -------------------------------------------------------- allocator ------

def test_alloc_never_hands_out_null_page():
    a = BlockAllocator(num_pages=5)
    got = {a.alloc() for _ in range(4)}
    assert NULL_PAGE not in got and got == {1, 2, 3, 4}


def test_exhaustion_raises_typed_error():
    a = BlockAllocator(num_pages=3)
    a.alloc(), a.alloc()
    with pytest.raises(PagePoolExhausted, match="exhausted"):
        a.alloc()
    # PagePoolExhausted is a RuntimeError so generic handlers still work
    assert issubclass(PagePoolExhausted, RuntimeError)


def test_release_returns_page_and_lifo_reuse():
    """Evict -> re-admit reuses the just-freed page (LIFO free list):
    the smallest possible physical page set is touched, and the engine's
    bit-exact-reuse property is exercised on every recycle."""
    a = BlockAllocator(num_pages=4)
    p1, p2, p3 = a.alloc(), a.alloc(), a.alloc()
    a.release(p2)
    assert a.alloc() == p2
    a.check()


def test_refcount_shared_page():
    a = BlockAllocator(num_pages=3)
    p = a.alloc()
    a.retain(p)                                 # second holder
    a.release(p)
    assert a.free_pages == 1                    # still held once
    a.release(p)
    assert a.free_pages == 2
    a.check()


def test_refcount_misuse_raises():
    a = BlockAllocator(num_pages=3)
    with pytest.raises(ValueError):
        a.release(1)                            # never allocated
    with pytest.raises(ValueError):
        a.retain(NULL_PAGE)
    p = a.alloc()
    a.release(p)
    with pytest.raises(ValueError):
        a.release(p)                            # double free


# ------------------------------------------------------- page table ------

def test_page_table_rows_default_to_null_page():
    lo = CacheLayout(2, 64, 16, 9)
    t = PageTable(lo)
    assert (t.table == NULL_PAGE).all()
    t.set_row(1, [3, 7])
    assert t.table[1].tolist() == [3, 7, 0, 0]
    t.clear_row(1)
    assert (t.table == NULL_PAGE).all()
    with pytest.raises(ValueError, match="max_pages"):
        t.set_row(0, [1, 2, 3, 4, 5])


def test_page_table_snapshot_is_a_copy():
    """The decode step must see a snapshot: jnp.asarray may zero-copy a
    numpy buffer while dispatch is still async (same aliasing hazard as
    the engine's pos array)."""
    t = PageTable(CacheLayout(1, 32, 16, 5))
    snap = t.snapshot()
    t.set_row(0, [2])
    assert snap[0, 0] == NULL_PAGE and t.table[0, 0] == 2


# ---------------------------------------------- controller / sessions ----

def test_zero_length_session_holds_no_pages():
    kv = PagedKVCache(CacheLayout(2, 64, 16, 9))
    s = Session(uid=0)
    kv.bind(s, 0)
    assert s.pages == [] and s.live_tokens == 0
    kv.release(s)                               # releasing nothing is fine
    assert kv.allocator.free_pages == 8
    kv.allocator.check()


def test_ensure_is_append_only_and_reuse_is_bitwise():
    kv = PagedKVCache(CacheLayout(1, 64, 16, 9))
    s = Session(uid=0)
    kv.bind(s, 0)
    kv.ensure(s, 0)
    kv.ensure(s, 17)                            # needs block 1 -> 2 pages
    assert len(s.pages) == 2
    assert kv.page_table.table[0, :2].tolist() == s.pages
    first_pages = list(s.pages)
    kv.release(s)
    # re-admitted session gets the same (LIFO) physical pages back —
    # nothing was zeroed in between, so reuse is bit-exact by definition
    s2 = Session(uid=1)
    kv.bind(s2, 0)
    kv.ensure(s2, 17)
    assert sorted(s2.pages) == sorted(first_pages)
    kv.allocator.check()


def test_ensure_past_max_len_raises():
    kv = PagedKVCache(CacheLayout(1, 32, 16, 9))
    s = Session(uid=0)
    kv.bind(s, 0)
    with pytest.raises(ValueError, match="max_len"):
        kv.ensure(s, 32)


def test_preempted_session_keeps_pages_without_a_lane():
    kv = PagedKVCache(CacheLayout(2, 64, 16, 9))
    s = Session(uid=0)
    kv.bind(s, 1)
    kv.ensure(s, 20)
    held = list(s.pages)
    kv.unbind(s)
    assert s.state == "preempted" and s.slot is None
    assert (kv.page_table.table[1] == NULL_PAGE).all()
    assert kv.allocator.free_pages == 8 - len(held)   # still owned
    kv.bind(s, 0)                                     # resume on a new lane
    assert kv.page_table.table[0, :len(held)].tolist() == held
    kv.allocator.check()


# The hypothesis property sweep over random admit/evict/preempt
# schedules lives in test_kvcache_props.py (importorskip'd, like the
# decode props suite) so these deterministic cases run without the
# optional dependency.
