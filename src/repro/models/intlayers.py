"""Integer-path transformer layers — the SwiftTron datapath in JAX.

Every function here consumes int8/int32 tensors and the design-time plans
from ``repro.quant.plans``; no float enters the computation (RoPE tables,
polynomial constants and dyadic multipliers are integer design constants).

Residual stream: int32 at ``cfg.s_res`` clipped to ``cfg.qmax_res``
(14-bit) — the ASIC's inter-block INT32 bus.  Matmul operands: int8.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import activations as iact
from repro.core import attention as iattn
from repro.core import intmath, norms
from repro.core import softmax as ism
from repro.core.dyadic import apply_dyadic_perchannel, clip_to_bits, \
    rshift_round
from repro.distributed.collectives import psum_int32
from repro.distributed.sharding import shard
from repro.models.common import ArchConfig
from repro.ops import QuantLinearParams, RequantSpec
from repro.ops import get_backend, resolve_ops
from repro.ops.packed import pack_kv
from repro.quant import plans as qplans


# ------------------------------------------------------------- linear -----

def int_linear(x8, qw, plan: qplans.LinearPlan, ops=None):
    """x8: (..., K) int8; qw: QuantLinearParams (or legacy dict).

    Returns (..., N): int8 when plan.s_out > 0 (requantized) else int32
    accumulator.
    """
    ops = resolve_ops(ops)
    qw = QuantLinearParams.of(qw)
    lead = x8.shape[:-1]
    k = x8.shape[-1]
    n = qw.n_dim
    x2 = x8.reshape(-1, k)
    spec = RequantSpec.for_linear(plan)
    if qw.is_packed:
        out = ops.int8_matmul_packed(x2, qw, spec)
    else:
        out = ops.int8_matmul(x2, qw.w8, spec, bias32=qw.bias32,
                              b_vec=qw.b_mult)
    out = out.reshape(*lead, n)
    if not spec.is_raw and plan.out_bits <= 8:
        out = out.astype(jnp.int8)
    return out


def _tp_wo_project(o8, qw, plan: qplans.LinearPlan, tp_axis: str,
                   ops=None):
    """Head-sharded o-projection (tensor-parallel serving).

    ``o8``: (..., H_local·hd) int8 — this device's slice of the
    attention output; ``qw.w8``: the matching *row* slice of wo.  Each
    device computes the raw int32 partial product over its head slice,
    :func:`~repro.distributed.collectives.psum_int32` combines the
    partial slabs exactly, and only then do bias and the per-channel
    requant epilogue apply — once, on the full-sum accumulator — so the
    requant rounds exactly as it would on a single device (mirroring
    ``kernels.ref.ref_apply_wo``).
    """
    ops = resolve_ops(ops)
    qw = QuantLinearParams.of(qw)
    lead = o8.shape[:-1]
    n = qw.n_dim
    x2 = o8.reshape(-1, o8.shape[-1])
    if qw.is_packed:
        # raw partial product only — bias must be added once, after the
        # psum, so strip it from the packed epilogue operands
        acc = ops.int8_matmul_packed(
            x2, qw._replace(bias32=None, b_mult=None), RequantSpec.raw())
    else:
        acc = ops.int8_matmul(x2, qw.w8, RequantSpec.raw())
    acc = psum_int32(acc, tp_axis)
    if qw.bias32 is not None:
        acc = acc + qw.bias32[None, :]
    spec = RequantSpec.for_linear(plan)
    if spec.is_raw:
        out = acc
    else:
        out = apply_dyadic_perchannel(acc, qw.b_mult, spec.c, spec.pre,
                                      axis=-1)
        out = clip_to_bits(out, spec.out_bits)
        if spec.out_bits <= 8:
            out = out.astype(jnp.int8)
    return out.reshape(*lead, n)


# ------------------------------------------------------------- norms ------

def int_expert_linear(x8, qw, plan: qplans.LinearPlan):
    """Batched-per-expert linear: x8 (G,E,C,K) x w8 (E,K,N) -> (G,E,C,N).

    Per-channel requant with b_mult (E,N); shared static (c, pre)."""
    qw = QuantLinearParams.of(qw)
    acc = jnp.einsum("geck,ekn->gecn", x8, qw.w8,
                     preferred_element_type=jnp.int32)
    if qw.bias32 is not None:
        acc = acc + qw.bias32[None, :, None, :]
    b = qw.b_mult[None, :, None, :].astype(jnp.int32)
    out = rshift_round(rshift_round(acc, plan.pre) * b, plan.c - plan.pre)
    out = clip_to_bits(out, plan.out_bits)
    return out.astype(jnp.int8) if plan.out_bits <= 8 else out


def int_norm(qnorm, q32, plan: norms.INormPlan, ops=None):
    """q32 (..., D) int32 at s_res -> int8 at s_act8."""
    ops = resolve_ops(ops)
    out = ops.int_layernorm(q32, qnorm["gamma_q"], qnorm.get("beta_q"),
                            plan, out_bits=8)
    return out.astype(jnp.int8)


# ------------------------------------------------------------- rope -------

ROPE_FRAC = 14


def build_rope_table(max_seq: int, hd: int, theta: float):
    """Design-time int16 cos/sin tables at 2^-14 (integer RoPE)."""
    pos = np.arange(max_seq, dtype=np.float64)[:, None]
    freqs = 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))
    ang = pos * freqs[None, :]
    cos = np.round(np.cos(ang) * (1 << ROPE_FRAC)).astype(np.int32)
    sin = np.round(np.sin(ang) * (1 << ROPE_FRAC)).astype(np.int32)
    return jnp.asarray(cos), jnp.asarray(sin)


def apply_int_rope(q8, positions, rope_tab):
    """q8: (B,S,H,hd) int8; positions: (B,S) or (S,) int32."""
    cos_t, sin_t = rope_tab
    cos = jnp.take(cos_t, positions, axis=0)     # (B,S,hd/2) or (S,hd/2)
    sin = jnp.take(sin_t, positions, axis=0)
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    q = q8.astype(jnp.int32)
    q1, q2 = jnp.split(q, 2, axis=-1)
    r1 = rshift_round(q1 * cos - q2 * sin, ROPE_FRAC)
    r2 = rshift_round(q1 * sin + q2 * cos, ROPE_FRAC)
    out = jnp.concatenate([r1, r2], axis=-1)
    return jnp.clip(out, -127, 127).astype(jnp.int8)


# --------------------------------------------------------- attention ------

def int_attn_fwd(qp, x8, plans: qplans.AttnPlan, cfg: ArchConfig,
                 rope_tab=None, positions=None, causal=True, window: int = 0,
                 memory8=None, ops=None, fuse_attention=True):
    """Self/cross attention.  x8: (B,S,D) int8 -> (B,S,D) int32 at s_res."""
    ops = resolve_ops(ops, cfg)
    b, s, d = x8.shape
    kv_src = memory8 if memory8 is not None else x8
    sk = kv_src.shape[1]
    q8 = int_linear(x8, qp["wq"], plans.qkv, ops) \
        .reshape(b, s, cfg.n_heads, cfg.hd)
    k8 = int_linear(kv_src, qp["wk"], plans.qkv, ops) \
        .reshape(b, sk, cfg.n_kv_heads, cfg.hd)
    v8 = int_linear(kv_src, qp["wv"], plans.qkv, ops) \
        .reshape(b, sk, cfg.n_kv_heads, cfg.hd)
    if rope_tab is not None and memory8 is None:
        pos = positions if positions is not None else jnp.arange(s)
        q8 = apply_int_rope(q8, pos, rope_tab)
        k8 = apply_int_rope(k8, pos, rope_tab)
    q8 = shard(q8, "batch", "seq", "heads", None)
    k8 = shard(k8, "batch", "seq", "kv_heads", None)
    v8 = shard(v8, "batch", "seq", "kv_heads", None)

    # the configured backend handles attention in every branch (the old
    # code hardcoded the pallas/ref choice here); backends without a
    # fused kernel fall back to chunked streaming on long sequences, and
    # fused backends fall back internally on shapes their kernel can't
    # tile (see ops.backends.pallas_fused).  The epilogue travels as a
    # typed RequantSpec, same as the matmul call sites.
    attn_backend = ops.backend_for("int_attention")
    if fuse_attention and attn_backend.fused_attention:
        o8 = ops.int_attention(q8, k8, v8, plans.attn,
                               causal=causal and memory8 is None,
                               window=window,
                               requant=RequantSpec.per_tensor(
                                   plans.attn.dn_out))
    elif s * sk > (4096 * 4096) // 4 and memory8 is None:
        # memory-bounded two-pass streaming path
        rep = cfg.q_group
        k8r = jnp.repeat(k8, rep, 2) if rep > 1 else k8
        v8r = jnp.repeat(v8, rep, 2) if rep > 1 else v8
        o8 = iattn.i_attention_chunked(q8, k8r, v8r, plans.attn,
                                       chunk=min(1024, sk), causal=causal,
                                       window=window)
        o8 = o8.astype(jnp.int8)
    else:
        # fuse_attention=False asks for the exact two-pass numerics, so
        # a fused backend must not be re-entered here — use the oracle
        be = (get_backend("ref") if attn_backend.fused_attention
              else attn_backend)
        o8 = be.int_attention(q8, k8, v8, plans.attn,
                              causal=causal and memory8 is None,
                              window=window)
    o8 = shard(o8, "batch", "seq", "heads", None)
    out32 = int_linear(o8.reshape(b, s, cfg.n_heads * cfg.hd), qp["wo"],
                       plans.out, ops)
    return shard(out32, "batch", "seq", "embed")


def int_attn_decode(qp, x8, cache, pos, plans: qplans.AttnPlan,
                    cfg: ArchConfig, rope_tab=None, window: int = 0,
                    ops=None, pages=None, page_size: int = 0,
                    max_len: int = 0, fold_wo: bool = False,
                    tp_axis: Optional[str] = None, n_new=None):
    """One-token decode.  x8: (B,1,D); cache: {"k8","v8"}.

    ``pos``: (B,) current position (tokens written at logical slot
    ``pos``, or ``pos % window`` for sliding-window caches).  Returns
    (out32, new_cache).

    Cache layouts: contiguous ``(B, L, Hkv, hd)`` by default; with
    ``pages`` (int32 ``(B, max_pages)`` page table) the cache is a
    physical page pool ``(num_pages, page_size, Hkv, hd)`` and the
    logical slot resolves to ``(pages[b, slot // page_size],
    slot % page_size)`` — unmapped lanes write into the reserved null
    page 0, whose contents are never valid (repro.serving.kvcache).
    ``max_len`` bounds the logical occupancy under paging (defaults to
    the page-table span).

    The ragged-cache attention dispatches through the configured
    backend's ``int_decode_attention`` (per-slot ``valid_len`` masking;
    ``pallas_fused`` runs it as one kernel launch skipping dead cache
    blocks, translating paged blocks through the scalar-prefetched
    table) — the backend owns GQA head-repeat, so the KV cache is
    handed over in its compact Hkv form.  With ``fold_wo`` the output
    projection's per-channel requant rides in the decode epilogue
    (``wo=``/``wo_spec=`` operands; bit-exact vs the unfolded path).

    ``tp_axis``: when tracing under a tensor-parallel shard_map (see
    ``repro.distributed.tp_serving``), ``cfg`` carries the *local* head
    counts and the o-projection runs as partial-matmul → exact int32
    psum across ``tp_axis`` → requant-once epilogue
    (:func:`_tp_wo_project`).  Incompatible with ``fold_wo`` — the fold
    would requant each device's partial slab before the all-reduce,
    rounding more than once.

    ``n_new``: the speculative-verify generalization.  When given,
    ``x8`` is (B, S, D) with each lane's real tokens **right-aligned**
    in the S rows — row ``i`` is real iff ``i >= S - n_new[b]`` and
    covers logical position ``pos[b] + n_new[b] - S + i`` (the last row
    always lands on ``pos + n_new - 1``; full causal only, so the
    engine gates speculation to ``window == 0``).  Pad rows write
    nothing (paged: routed to the null page; contiguous: out-of-bounds
    scatter explicitly dropped) and their garbage outputs are discarded
    by the caller.  ``valid_len = pos + n_new`` then gives every row
    ``i`` the stepped-mask visibility ``positions <= pos + n_new - S +
    i`` — exactly the positions a sequential one-token decode of the
    same tokens would see, which is why the verify launch is bit-exact
    against ``n_new`` single-token steps.  Precondition (engine-
    enforced): ``pos + n_new <= L``, so every real write lands in
    bounds and ``valid_len`` never clips a real row's mask limit.
    """
    ops = resolve_ops(ops, cfg)
    if tp_axis is not None and fold_wo:
        raise ValueError("fold_wo cannot cross the tensor-parallel "
                         "all-reduce: the wo requant must round once, "
                         "after psum (pass fold_wo=False under tp)")
    b, s, d = x8.shape
    paged = pages is not None
    packed_kv = "k_shift" in cache
    if packed_kv and not paged:
        raise ValueError("int4 KV pages (k_shift/v_shift in the cache) "
                         "need the paged layout")
    if paged:
        L = max_len or pages.shape[1] * page_size
    else:
        L = cache["k8"].shape[1]
    q8 = int_linear(x8, qp["wq"], plans.qkv, ops) \
        .reshape(b, s, cfg.n_heads, cfg.hd)
    k8 = int_linear(x8, qp["wk"], plans.qkv, ops) \
        .reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v8 = int_linear(x8, qp["wv"], plans.qkv, ops) \
        .reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if n_new is None:
        if rope_tab is not None:
            q8 = apply_int_rope(q8, pos[:, None], rope_tab)
            k8 = apply_int_rope(k8, pos[:, None], rope_tab)
        if window > 0:
            slot = pos % window
        else:
            slot = pos
        if paged:
            pages = jnp.asarray(pages, jnp.int32)
            bidx = jnp.arange(b)
            page = pages[bidx, slot // page_size]
            off = slot % page_size
            k_w, v_w = k8[:, 0], v8[:, 0]
            if packed_kv:
                # quantize + nibble-pack before the write: pool bytes
                # always hold the packed representation (one
                # quantization policy — repro.ops.packed.pack_kv)
                k_w, v_w = pack_kv(k_w), pack_kv(v_w)
            k_cache = cache["k8"].at[page, off].set(k_w)
            v_cache = cache["v8"].at[page, off].set(v_w)
        else:
            bidx = jnp.arange(b)
            k_cache = cache["k8"].at[bidx, slot].set(k8[:, 0])
            v_cache = cache["v8"].at[bidx, slot].set(v8[:, 0])
        valid = jnp.minimum(pos + 1, L) if (window > 0 or paged) \
            else pos + 1
    else:
        assert window == 0, \
            "speculative verify needs full causal attention"
        n_new = jnp.asarray(n_new, jnp.int32)
        rows = jnp.arange(s, dtype=jnp.int32)[None, :]       # (1, S)
        rpos = pos[:, None] + n_new[:, None] - s + rows      # (B, S)
        write_ok = rows >= s - n_new[:, None]
        # pad-row positions clamp to 0 (their rope rotation and writes
        # are masked/discarded; a negative gather index would clamp to
        # the table's LAST entry and silently alias a live position)
        rpos_c = jnp.maximum(rpos, 0)
        if rope_tab is not None:
            q8 = apply_int_rope(q8, rpos_c, rope_tab)
            k8 = apply_int_rope(k8, rpos_c, rope_tab)
        if paged:
            pages = jnp.asarray(pages, jnp.int32)
            bidx = jnp.arange(b)[:, None]
            page = pages[bidx, rpos_c // page_size]          # (B, S)
            # pad rows write into the reserved null page 0, whose
            # contents are never valid (repro.serving.kvcache)
            page = jnp.where(write_ok, page, 0)
            off = rpos_c % page_size
            k_w, v_w = k8, v8
            if packed_kv:
                k_w, v_w = pack_kv(k_w), pack_kv(v_w)
            k_cache = cache["k8"].at[page, off].set(k_w)
            v_cache = cache["v8"].at[page, off].set(v_w)
        else:
            bidx = jnp.arange(b)[:, None]
            # pad rows scatter out of bounds and are explicitly
            # dropped (scatter OOB is unspecified without a mode)
            slot_w = jnp.where(write_ok, rpos_c, L)
            k_cache = cache["k8"].at[bidx, slot_w].set(k8, mode="drop")
            v_cache = cache["v8"].at[bidx, slot_w].set(v8, mode="drop")
        valid = pos + n_new
    kw = {}
    if paged:
        kw.update(pages=pages, page_size=page_size)
    if packed_kv:
        kw.update(kv_shifts=(cache["k_shift"], cache["v_shift"]))
    if fold_wo:
        out32 = ops.int_decode_attention(
            q8, k_cache, v_cache, plans.attn, valid,
            requant=RequantSpec.per_tensor(plans.attn.dn_out),
            wo=QuantLinearParams.of(qp["wo"]),
            wo_spec=RequantSpec.for_linear(plans.out), **kw)
    else:
        o8 = ops.int_decode_attention(
            q8, k_cache, v_cache, plans.attn, valid,
            requant=RequantSpec.per_tensor(plans.attn.dn_out), **kw)
        o8 = o8.astype(jnp.int8).reshape(b, s, cfg.n_heads * cfg.hd)
        if tp_axis is not None:
            out32 = _tp_wo_project(o8, qp["wo"], plans.out, tp_axis, ops)
        else:
            out32 = int_linear(o8, qp["wo"], plans.out, ops)
    return out32, {"k8": k_cache, "v8": v_cache}


def int_attn_prefill_chunk(qp, x8, cache, base_pos, plans: qplans.AttnPlan,
                           cfg: ArchConfig, rope_tab=None, ops=None,
                           pages=None, page_size: int = 0,
                           fold_wo: bool = False,
                           tp_axis: Optional[str] = None):
    """Chunked prefill attention over a *paged* KV cache.

    x8: (B, C, D) — one prompt chunk per lane, covering that lane's
    logical positions ``[base_pos[b], base_pos[b] + C)``; cache:
    ``{"k8", "v8"}`` physical page pools ``(num_pages, page_size, Hkv,
    hd)``; ``pages``: int32 (B, max_pages) page table.  The op writes
    the chunk's K/V through the table and runs causal attention over
    history + chunk (``ops.int_paged_prefill`` — one fused kernel launch
    on ``pallas_fused``, exact scatter/gather lowering elsewhere).
    Returns (out32 (B, C, D) at s_res, new_cache).

    Full (non-windowed) causal attention only — the rolling
    sliding-window buffer interleaves writes and reads token-by-token,
    which a batched chunk write cannot reproduce (the serving engine
    keeps token streaming for ``cfg.window > 0``).  Bit-exact against
    streaming the same tokens through :func:`int_attn_decode` one at a
    time.  With ``fold_wo`` the o-projection's per-channel requant rides
    in the prefill launch's epilogue (``prefill_wo_fold``).

    ``tp_axis``: tensor-parallel tracing, exactly as in
    :func:`int_attn_decode` (local-head ``cfg``, partial o-projection,
    exact psum, requant-once; ``fold_wo`` must be off).
    """
    assert cfg.window == 0, "chunked prefill needs full causal attention"
    ops = resolve_ops(ops, cfg)
    if tp_axis is not None and fold_wo:
        raise ValueError("fold_wo cannot cross the tensor-parallel "
                         "all-reduce: the wo requant must round once, "
                         "after psum (pass fold_wo=False under tp)")
    b, c, d = x8.shape
    q8 = int_linear(x8, qp["wq"], plans.qkv, ops) \
        .reshape(b, c, cfg.n_heads, cfg.hd)
    k8 = int_linear(x8, qp["wk"], plans.qkv, ops) \
        .reshape(b, c, cfg.n_kv_heads, cfg.hd)
    v8 = int_linear(x8, qp["wv"], plans.qkv, ops) \
        .reshape(b, c, cfg.n_kv_heads, cfg.hd)
    if rope_tab is not None:
        positions = base_pos[:, None] + jnp.arange(c, dtype=jnp.int32)
        q8 = apply_int_rope(q8, positions, rope_tab)
        k8 = apply_int_rope(k8, positions, rope_tab)
    requant = RequantSpec.per_tensor(plans.attn.dn_out)
    kw = {}
    if "k_shift" in cache:
        # int4 KV pools: the dispatch layer quantizes + packs the
        # chunk's K/V before the scatter (one policy for every backend)
        kw.update(kv_shifts=(cache["k_shift"], cache["v_shift"]))
    if fold_wo:
        out32, k_pool, v_pool = ops.int_paged_prefill(
            q8, k8, v8, cache["k8"], cache["v8"], plans.attn, base_pos,
            pages, page_size, requant=requant,
            wo=QuantLinearParams.of(qp["wo"]),
            wo_spec=RequantSpec.for_linear(plans.out), **kw)
    else:
        o8, k_pool, v_pool = ops.int_paged_prefill(
            q8, k8, v8, cache["k8"], cache["v8"], plans.attn, base_pos,
            pages, page_size, requant=requant, **kw)
        o8 = o8.astype(jnp.int8).reshape(b, c, cfg.n_heads * cfg.hd)
        if tp_axis is not None:
            out32 = _tp_wo_project(o8, qp["wo"], plans.out, tp_axis, ops)
        else:
            out32 = int_linear(o8, qp["wo"], plans.out, ops)
    return out32, {"k8": k_pool, "v8": v_pool}


# --------------------------------------------------------------- ffn ------

def int_ffn_fwd(qp, x8, plans: qplans.FfnPlan, cfg: ArchConfig,
                ops=None):
    """x8 (B,S,D) int8 -> int32 at s_res."""
    ops = resolve_ops(ops, cfg)
    h1 = int_linear(x8, qp["w1"], plans.up, ops)            # 10-bit int32
    if cfg.activation == "swiglu":
        h3 = int_linear(x8, qp["w3"], plans.up, ops)
        a8 = iact.i_silu(h1, plans.act_silu, out_bits=8)
        prod = a8 * h3                                      # s8 * s10
        h = clip_to_bits(plans.dn_gate(prod), 8).astype(jnp.int8)
    else:
        a = ops.int_gelu(h1, plans.act_gelu.gelu, plans.act_gelu.dn_out,
                         out_bits=8)
        h = a.astype(jnp.int8)
    h = shard(h, "batch", "seq", "ffn")
    return shard(int_linear(h, qp["w2"], plans.down, ops),
                 "batch", "seq", "embed")


# --------------------------------------------------------------- moe ------

def int_moe_fwd(qp, x8, plans: qplans.MoePlan, cfg: ArchConfig,
                ops=None, group_size: int = 512):
    """Integer MoE: int32 router logits, integer top-k gates (i-softmax
    over the selected k logits), int8 expert FFNs, integer combine."""
    ops = resolve_ops(ops, cfg)
    b, s, d = x8.shape
    e = cfg.padded_experts()
    k = cfg.top_k
    g = max(1, s // group_size)
    tg = s // g
    cap = max(4, int(cfg.capacity_factor * tg * k / e))
    xg = x8.reshape(b * g, tg, d)

    logits = int_linear(xg, qp["router"], plans.router, ops)      # int32
    if e != cfg.n_experts:
        padmask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(padmask[None, None], jnp.int32(-(2 ** 30)),
                           logits)
    top_logits, expert_ids = jax.lax.top_k(logits, k)       # (g,t,k)
    gates8 = ism.i_softmax(top_logits, plans.gate_sm, axis=-1)  # 2^-7 int8

    dispatch = jnp.zeros((b * g, tg, e, cap), jnp.int8)
    counts = jnp.zeros((b * g, e), jnp.int32)
    slot_oh = []
    for slot in range(k):
        a = jax.nn.one_hot(expert_ids[..., slot], e, dtype=jnp.int32)
        pos = counts[:, None, :] + jnp.cumsum(a, axis=1) - a
        keep = (pos < cap) & (a > 0)
        oh = (jax.nn.one_hot(pos, cap, dtype=jnp.int32)
              * keep[..., None]).astype(jnp.int8)           # (g,t,e,cap)
        slot_oh.append(oh)
        dispatch = dispatch + oh
        counts = counts + jnp.sum(a, axis=1)

    buf = jnp.einsum("gtd,gtec->gecd", xg, dispatch,
                     preferred_element_type=jnp.int32).astype(jnp.int8)
    buf = shard(buf, "batch", "experts", None, "embed")
    h1 = int_expert_linear(buf, qp["w1"], plans.expert.up)
    if cfg.activation == "swiglu":
        h3 = int_expert_linear(buf, qp["w3"], plans.expert.up)
        a8 = iact.i_silu(h1, plans.expert.act_silu, out_bits=8)
        h = clip_to_bits(plans.expert.dn_gate(a8 * h3), 8).astype(jnp.int8)
    else:
        h = ops.int_gelu(h1, plans.expert.act_gelu.gelu,
                         plans.expert.act_gelu.dn_out,
                         out_bits=8).astype(jnp.int8)
    y8 = int_expert_linear(h, qp["w2"], plans.expert.down)   # s_res int32
    y8 = shard(y8, "batch", "experts", None, "embed")

    out32 = jnp.zeros((b * g, tg, d), jnp.int32)
    for slot in range(k):
        y_slot = jnp.einsum("gecd,gtec->gtd", y8, slot_oh[slot],
                            preferred_element_type=jnp.int32)
        gate = gates8[..., slot].astype(jnp.int32)[..., None]
        out32 = out32 + rshift_round(y_slot * gate, ism.PROB_SHIFT)
    out32 = out32.reshape(b, s, d)
    if plans.shared is not None:
        out32 = out32 + int_ffn_fwd(qp["shared"], x8, plans.shared, cfg,
                                    ops)
    return shard(out32, "batch", "seq", "embed")


# -------------------------------------------------------------- mamba -----

class IntMambaState(NamedTuple):
    h: jnp.ndarray        # (B, H, N, P) int32 at s_h
    conv: jnp.ndarray     # (B, K-1, C) int8


def init_int_mamba_state(cfg: ArchConfig, batch: int) -> IntMambaState:
    h = jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                  jnp.int32)
    conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.int8)
    return IntMambaState(h, conv)


def _int_conv_step(xbc8_t, conv_state, qconv_w8, mp: qplans.MambaPlan):
    """Depthwise causal conv, one step.  xbc8_t: (B,C) int8."""
    window = jnp.concatenate([conv_state, xbc8_t[:, None, :]], axis=1)
    acc = jnp.sum(window.astype(jnp.int32)
                  * qconv_w8.astype(jnp.int32)[None], axis=1)
    new_state = window[:, 1:]
    h10 = clip_to_bits(mp.dn_conv(acc), 11)
    out8 = iact.i_silu(h10, mp.silu_conv, out_bits=8).astype(jnp.int8)
    return out8, new_state


def int_mamba_step(qp, u8_t, state: IntMambaState, mp: qplans.MambaPlan,
                   cfg: ArchConfig, ops=None):
    """One token.  u8_t: (B, D) int8 -> (out32 (B,D) at s_res, new state)."""
    ops = resolve_ops(ops, cfg)
    b = u8_t.shape[0]
    di, gq, n, hh, p = (cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state,
                        cfg.ssm_heads, cfg.ssm_head_dim)
    zxbc8 = int_linear(u8_t, qp["in_proj"], mp.in_proj, ops)
    dt_acc = int_linear(u8_t, qp["dt_proj"], _INT32_PLAN(mp), ops)
    z8, xbc8 = zxbc8[:, :di], zxbc8[:, di:]
    xbc8, conv_new = _int_conv_step(xbc8, state.conv, qp["conv_w8"], mp)
    x8 = xbc8[:, :di].reshape(b, hh, p)
    B8 = xbc8[:, di:di + gq * n].reshape(b, gq, n)
    C8 = xbc8[:, di + gq * n:].reshape(b, gq, n)

    dt_in = clip_to_bits(mp.dn_dt_in(dt_acc + qp["dt_bias_q"][None]), 11)
    dt = iact.i_softplus(dt_in, mp.softplus, out_bits=13)    # s_dt, (B,H)
    dtA = mp.dn_dtA(dt * qp["A_q"][None])                    # -> 2^-14
    decay16 = mp.dn_decay16(intmath.i_exp(-dtA, mp.iexp_decay))
    decay16 = jnp.clip(decay16, 0, 1 << 15)                  # (B,H)

    rep = hh // gq
    B8h = jnp.repeat(B8, rep, axis=1)                        # (B,H,N)
    # contribution: dt * B * x  (s_dt*s8*s8) -> s_h
    contrib = (dt[:, :, None, None] *
               (B8h[:, :, :, None].astype(jnp.int32)
                * x8[:, :, None, :].astype(jnp.int32)))
    contrib = mp.dn_h(contrib)
    h = state.h
    h = ism.rescale_sum(h, decay16[:, :, None, None]) + contrib
    h = jnp.clip(h, -mp.qmax_h, mp.qmax_h)

    # dynamic block-floating-point h -> int8 (one exponent per batch row,
    # shared across heads so the downstream RMSNorm shift cancels exactly)
    h_max = jnp.max(jnp.abs(h), axis=(1, 2, 3), keepdims=True)
    sd = jnp.maximum(intmath.int_bit_length(h_max) - 7, 0)    # (B,1,1,1)
    half_h = jnp.where(sd > 0, jnp.left_shift(
        jnp.int32(1), jnp.maximum(sd - 1, 0)), 0)
    h8 = jnp.clip(jax.lax.shift_right_arithmetic(h + half_h, sd),
                  -127, 127)                                   # (B,H,N,P)
    C8h = jnp.repeat(C8, rep, axis=1)                          # (B,H,N)
    y_acc = jnp.einsum("bhn,bhnp->bhp", C8h.astype(jnp.int32),
                       h8.astype(jnp.int32))
    # D*x on the same (shifted) h grid: D_q at 2^-16, >> sd
    d_term = jax.lax.shift_right_arithmetic(
        qp["D_q"][None, :, None] * x8.astype(jnp.int32), sd[:, :, 0])
    y_acc = y_acc + d_term
    y32 = y_acc.reshape(b, di)                # unnormalised, wide range

    z10 = mp.dn_z10(z8.astype(jnp.int32))
    sig16 = _silu16(z10, mp.silu_z)
    gated = ism.rescale_sum(y32, sig16)       # y * sigmoid(z), int32
    # per-row dynamic block-floating-point shift into the RMSNorm: the
    # norm is scale-invariant so the shift cancels exactly, and the
    # 12-bit mantissa satisfies the i-norm bit budget.
    row_max = jnp.max(jnp.abs(gated), axis=-1, keepdims=True)
    s_dyn = jnp.maximum(intmath.int_bit_length(row_max) - 11, 0)
    half = jnp.where(s_dyn > 0,
                     jnp.left_shift(jnp.int32(1),
                                    jnp.maximum(s_dyn - 1, 0)), 0)
    y12 = jax.lax.shift_right_arithmetic(gated + half, s_dyn)
    y8 = int_norm({"gamma_q": qp["norm_gamma_q"]}, y12, mp.norm,
                  ops).astype(jnp.int8)
    out32 = int_linear(y8, qp["out_proj"], mp.out_proj, ops)
    return out32, IntMambaState(h, conv_new)


def _silu16(zq, plan: iact.ISiluPlan):
    """sigmoid(z) as a 2^-15 fraction (int32), z int32 at plan.s_in."""
    q = zq.astype(jnp.int32)
    e = intmath.i_exp(-jnp.abs(q), plan.iexp)
    e16 = jnp.clip(plan.dn_e16(e), 0, 1 << 15)
    one16 = jnp.int32(1 << 15)
    den = one16 + e16
    r = jnp.int32(1 << 30) // den
    num = jnp.where(q >= 0, one16, e16)
    return (num * r) >> 15


def int_mamba_prefill(qp, u8, mp: qplans.MambaPlan, cfg: ArchConfig,
                      state: Optional[IntMambaState] = None, ops=None):
    """Integer prefill with the token-parallel stages hoisted out of the
    recurrence: projections / conv / Δt / decays / contributions batch over
    the whole sequence (MXU-shaped, HLO-countable); only the O(L) h-state
    update and the per-token read-out stay in the scan (cheap elementwise).
    """
    ops = resolve_ops(ops, cfg)
    b, l, d = u8.shape
    di, gq, n, hh, p = (cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state,
                        cfg.ssm_heads, cfg.ssm_head_dim)
    if state is None:
        state = init_int_mamba_state(cfg, b)

    # --- token-parallel stages -------------------------------------------
    zxbc8 = int_linear(u8, qp["in_proj"], mp.in_proj, ops)       # (B,L,*)
    dt_acc = int_linear(u8, qp["dt_proj"], _INT32_PLAN(mp), ops)
    z8, xbc8 = zxbc8[..., :di], zxbc8[..., di:]
    # causal depthwise conv over the sequence, seeded by the carried tail
    km1 = state.conv.shape[1]
    full = jnp.concatenate([state.conv, xbc8], axis=1)
    w = qp["conv_w8"].astype(jnp.int32)
    acc = sum(full[:, i:i + l].astype(jnp.int32) * w[i]
              for i in range(km1 + 1))
    conv_tail = full[:, -km1:]
    h10 = clip_to_bits(mp.dn_conv(acc), 11)
    xbc8a = iact.i_silu(h10, mp.silu_conv, out_bits=8).astype(jnp.int8)
    x8 = xbc8a[..., :di].reshape(b, l, hh, p)
    B8 = xbc8a[..., di:di + gq * n].reshape(b, l, gq, n)
    C8 = xbc8a[..., di + gq * n:].reshape(b, l, gq, n)

    dt_in = clip_to_bits(mp.dn_dt_in(dt_acc + qp["dt_bias_q"][None, None]),
                         11)
    dt = iact.i_softplus(dt_in, mp.softplus, out_bits=13)        # (B,L,H)
    dtA = mp.dn_dtA(dt * qp["A_q"][None, None])
    decay16 = jnp.clip(mp.dn_decay16(intmath.i_exp(-dtA, mp.iexp_decay)),
                       0, 1 << 15)                               # (B,L,H)
    rep = hh // gq
    B8h = jnp.repeat(B8, rep, axis=2)                            # (B,L,H,N)
    contrib = mp.dn_h(dt[..., None, None] *
                      (B8h[..., :, None].astype(jnp.int32)
                       * x8[..., None, :].astype(jnp.int32)))    # (B,L,H,N,P)
    C8h = jnp.repeat(C8, rep, axis=2)

    # --- sequential state recurrence + read-out --------------------------
    def step(h, xs):
        dec_t, con_t, c_t, x_t = xs
        h = ism.rescale_sum(h, dec_t[:, :, None, None]) + con_t
        h = jnp.clip(h, -mp.qmax_h, mp.qmax_h)
        h_max = jnp.max(jnp.abs(h), axis=(1, 2, 3), keepdims=True)
        sd = jnp.maximum(intmath.int_bit_length(h_max) - 7, 0)
        half = jnp.where(sd > 0, jnp.left_shift(
            jnp.int32(1), jnp.maximum(sd - 1, 0)), 0)
        h8 = jnp.clip(jax.lax.shift_right_arithmetic(h + half, sd),
                      -127, 127)
        y = jnp.einsum("bhn,bhnp->bhp", c_t.astype(jnp.int32),
                       h8.astype(jnp.int32))
        y = y + jax.lax.shift_right_arithmetic(
            qp["D_q"][None, :, None] * x_t.astype(jnp.int32), sd[:, :, 0])
        return h, y

    xs = (decay16.transpose(1, 0, 2), contrib.transpose(1, 0, 2, 3, 4),
          C8h.transpose(1, 0, 2, 3), x8.transpose(1, 0, 2, 3))
    h, ys = jax.lax.scan(step, state.h, xs)
    y32 = ys.transpose(1, 0, 2, 3).reshape(b, l, di)

    # --- gate + BFP norm + out-projection (token-parallel) ---------------
    z10 = mp.dn_z10(z8.astype(jnp.int32))
    sig16 = _silu16(z10, mp.silu_z)
    gated = ism.rescale_sum(y32, sig16)
    row_max = jnp.max(jnp.abs(gated), axis=-1, keepdims=True)
    s_dyn = jnp.maximum(intmath.int_bit_length(row_max) - 11, 0)
    half = jnp.where(s_dyn > 0, jnp.left_shift(
        jnp.int32(1), jnp.maximum(s_dyn - 1, 0)), 0)
    y12 = jax.lax.shift_right_arithmetic(gated + half, s_dyn)
    y8 = int_norm({"gamma_q": qp["norm_gamma_q"]}, y12, mp.norm,
                  ops).astype(jnp.int8)
    out32 = int_linear(y8, qp["out_proj"], mp.out_proj, ops)
    return out32, IntMambaState(h, conv_tail)


class _INT32_PLAN:
    """dt projection keeps the raw int32 accumulator (requant happens after
    the dt_bias add)."""
    def __new__(cls, mp):
        return qplans.LinearPlan(mp.in_proj.s_in, 0.0, 32, 0, 0,
                                 mp.in_proj.k_dim)
