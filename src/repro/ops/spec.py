"""Typed operator-API datatypes: the requant epilogue and linear params.

SwiftTron freezes every scale ratio at design time; at the API boundary
that means each integer op carries exactly one of three epilogue forms:

  * **per-tensor**  — a single :class:`~repro.core.dyadic.Dyadic` pair
    ``(b, c, pre)`` applied to the whole accumulator;
  * **per-channel** — an int32 multiplier *vector* (a runtime array,
    ``QuantLinearParams.b_mult``) with plan-level shared shifts
    ``(c, pre)`` (the paper's per-channel weight scales folded into the
    requant unit);
  * **raw**         — no requant: the int32 accumulator is returned
    untouched (router logits, lm-head, Δt projection).

:class:`RequantSpec` is the frozen, validated union of the three; it
replaces the ``dn= / b_vec= / c= / pre= / out_bits=`` keyword spaghetti
the kernels used to take.  :class:`QuantLinearParams` replaces the
untyped ``{"w8", "b_mult", "bias32"}`` dicts in the quantized parameter
pytree (NamedTuples are jax pytrees, so scan / tree_map / checkpointing
all keep working).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.dyadic import Dyadic

PER_TENSOR = "per_tensor"
PER_CHANNEL = "per_channel"
RAW = "raw"

_KINDS = (PER_TENSOR, PER_CHANNEL, RAW)


@dataclasses.dataclass(frozen=True)
class RequantSpec:
    """Frozen description of an op's requantization epilogue.

    Use the constructors — ``per_tensor`` / ``per_channel`` / ``raw`` /
    ``for_linear`` — rather than the raw dataclass fields.
    """

    kind: str
    out_bits: int = 8
    dn: Optional[Dyadic] = None   # per-tensor dyadic pair
    c: int = 0                    # per-channel shared total shift
    pre: int = 0                  # per-channel shared pre-shift

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"RequantSpec kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if not 2 <= self.out_bits <= 32:
            raise ValueError("out_bits must be in [2, 32], got "
                             f"{self.out_bits}")
        if self.kind == PER_TENSOR:
            if not isinstance(self.dn, Dyadic):
                raise ValueError("per-tensor RequantSpec needs a Dyadic "
                                 f"(got dn={self.dn!r})")
        elif self.kind == PER_CHANNEL:
            if self.dn is not None:
                raise ValueError("per-channel RequantSpec takes (c, pre), "
                                 "not a Dyadic")
            if not 0 <= self.pre <= self.c:
                raise ValueError(f"need 0 <= pre <= c, got c={self.c} "
                                 f"pre={self.pre}")
        else:  # RAW
            if self.dn is not None or self.c or self.pre:
                raise ValueError("raw RequantSpec carries no requant "
                                 "constants")
            if self.out_bits != 32:
                raise ValueError("raw accumulators are int32 "
                                 f"(out_bits=32), got {self.out_bits}")

    # ------------------------------------------------------ constructors --

    @classmethod
    def per_tensor(cls, dn: Dyadic, out_bits: int = 8) -> "RequantSpec":
        """Whole-tensor dyadic requant (``q_out = (q_in * b) >> c``)."""
        return cls(PER_TENSOR, out_bits, dn=dn)

    @classmethod
    def per_channel(cls, c: int, pre: int, out_bits: int = 8
                    ) -> "RequantSpec":
        """Per-out-channel multipliers with shared static shifts.

        The multiplier vector itself is a runtime array and travels with
        the weights (``QuantLinearParams.b_mult``); only the shifts are
        frozen here.
        """
        return cls(PER_CHANNEL, out_bits, c=c, pre=pre)

    @classmethod
    def raw(cls) -> "RequantSpec":
        """Keep the int32 accumulator (requant happens downstream)."""
        return cls(RAW, 32)

    @classmethod
    def for_linear(cls, plan) -> "RequantSpec":
        """The epilogue a ``quant.plans.LinearPlan`` describes."""
        if plan.s_out == 0.0:
            return cls.raw()
        return cls.per_channel(plan.c, plan.pre, plan.out_bits)

    # -------------------------------------------------------- properties --

    @property
    def is_raw(self) -> bool:
        return self.kind == RAW

    @property
    def out_dtype(self):
        """Narrowest container for the clipped output."""
        return jnp.int8 if self.out_bits <= 8 else jnp.int32


PACK_SCHEMES = ("int4", "msr4")


@dataclasses.dataclass(frozen=True)
class PackMeta:
    """Static description of a packed weight tensor (compression tier).

    ``scheme``     — ``"int4"`` (plain two-nibbles-per-byte, weights must
                     already fit [-7, 7]) or ``"msr4"`` (4-bit
                     most-significant-run nibbles plus per-group
                     outlier-compensation lanes; lossless for all int8);
    ``group``      — K-group size of the msr4 outlier lanes (divides k);
    ``n_outliers`` — static outlier-lane count per (group, out-channel)
                     column (0 for plain int4);
    ``k``          — the unpacked contraction length (``w_packed`` stores
                     ``k // 2`` bytes along that axis).

    Registered as an aux-data-only pytree node: it rides the treedef, so
    it stays *static* under ``jit`` / ``lax.scan`` and contributes no
    array leaves.
    """

    scheme: str
    group: int
    n_outliers: int
    k: int

    def __post_init__(self):
        if self.scheme not in PACK_SCHEMES:
            raise ValueError(f"pack scheme must be one of {PACK_SCHEMES}, "
                             f"got {self.scheme!r}")
        if self.k % 2:
            raise ValueError(f"packed k must be even, got {self.k}")
        if self.scheme == "msr4":
            if self.group <= 0 or self.k % self.group:
                raise ValueError(f"msr4 group {self.group} must divide "
                                 f"k={self.k}")
            if self.n_outliers < 0:
                raise ValueError("n_outliers must be >= 0")
        elif self.n_outliers:
            raise ValueError("plain int4 packing carries no outlier lanes")


jax.tree_util.register_pytree_node(
    PackMeta, lambda m: ((), m), lambda m, _: m)


class QuantLinearParams(NamedTuple):
    """Quantized linear-layer parameters (a jax pytree).

    Dense (int8) storage:

    ``w8``     — int8 weights ``(..., K, N)``;
    ``b_mult`` — optional int32 per-out-channel requant multipliers
                 ``(..., N)`` (present iff the layer's plan requantizes);
    ``bias32`` — optional int32 bias at the accumulator scale ``(..., N)``.

    Packed (sub-8-bit) storage — produced by ``quant.pack.pack_linear``;
    ``w8`` is ``None`` and the weight bytes live in:

    ``w_packed``  — int8 nibble pairs ``(..., K // 2, N)`` (value ``2i``
                    in the low nibble, ``2i + 1`` in the high nibble);
    ``pack_meta`` — the static :class:`PackMeta`;
    ``out_idx``   — msr4 only: int16 within-group row indices of the
                    outlier lanes, ``(..., K // group, n_outliers, N)``;
    ``out_val``   — msr4 only: int8 outlier deltas (same shape), with
                    ``w8 == unpack(nibbles) + scatter(out_val @ out_idx)``
                    exactly.

    Consumers never unpack outside ``kernels/`` / ``ops/`` (lint RR004):
    dispatch goes through ``ops.int8_matmul_packed``.
    """

    w8: Any
    b_mult: Optional[Any] = None
    bias32: Optional[Any] = None
    w_packed: Optional[Any] = None
    pack_meta: Optional[PackMeta] = None
    out_idx: Optional[Any] = None
    out_val: Optional[Any] = None

    @classmethod
    def of(cls, obj) -> "QuantLinearParams":
        """Normalize a legacy ``{"w8", ...}`` dict or pass through."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls(w8=obj.get("w8"), b_mult=obj.get("b_mult"),
                       bias32=obj.get("bias32"),
                       w_packed=obj.get("w_packed"),
                       pack_meta=obj.get("pack_meta"),
                       out_idx=obj.get("out_idx"),
                       out_val=obj.get("out_val"))
        raise TypeError(f"cannot interpret {type(obj).__name__} as "
                        "QuantLinearParams")

    # -------------------------------------------------------- properties --

    @property
    def is_packed(self) -> bool:
        return self.w_packed is not None

    @property
    def k_dim(self) -> int:
        """Unpacked contraction length K."""
        if self.is_packed:
            return self.pack_meta.k
        return self.w8.shape[-2]

    @property
    def n_dim(self) -> int:
        """Output width N (valid for dense and packed storage)."""
        w = self.w_packed if self.is_packed else self.w8
        return w.shape[-1]
