"""Async serving front end: streaming bit-exactness, lifecycle
(cancel / deadline / backpressure), typed admission errors, the
dispatch/commit step split, and the metrics surface.

The load-bearing claim is that the front end never touches the
datapath: a request streamed through ``ServingFrontend`` — under
concurrency, cancellation of its batch neighbours, speculative
decoding, sharding — must produce the byte-identical token stream of a
solo synchronous ``run_until_done`` of the same prompt.  The matrix
test pins that across {ref, pallas_fused} x {paged, contiguous} x
spec_k in {0, 2} with 16 concurrent streams; the lifecycle tests pin
refcount-exact page reclaim on cancel/timeout (mid-prefill and
mid-decode) against the allocator's own accounting.

Random arrival/cancel/timeout schedules live in
``test_frontend_props.py``; both modules run in the multi-device CI
matrix, so lifecycle ops are exercised under tp > 1 as well.
"""
import asyncio
import collections

import jax
import numpy as np
import pytest

from repro.analysis.contracts import RequestInfeasible
from repro.configs.registry import get_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.quant import convert
from repro.serving import (EngineStalled, QueueFull, Request,
                           ServingEngine, ServingFrontend, StepInFlight)

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=128, num_layers=1)
    params = tf.init_params(jax.random.key(0), cfg)
    qp, plans = convert.quantize_params(params, cfg)
    return cfg, qp, plans, {}               # {} = expected-stream cache


def _prompts(n=16):
    rng = np.random.default_rng(7)
    stem = [int(t) for t in rng.integers(1, 100, 12)]
    out = []
    for i in range(n):
        if i % 3 == 0:
            out.append(stem[: 4 + (i % 8)] + [101 + i])  # shared prefix
        else:
            out.append([int(t)
                        for t in rng.integers(1, 100, 3 + (i % 9))])
    return out


def _expected(setup, prompt, max_new=MAX_NEW):
    """Solo synchronous greedy reference (contiguous, ref ops) —
    memoized across tests."""
    cfg, qp, plans, cache = setup
    key = (tuple(prompt), max_new)
    if key not in cache:
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                            ops="ref", cache_mode="contiguous")
        req = Request(uid=0, prompt=list(prompt), max_new_tokens=max_new)
        eng.submit(req)
        eng.run_until_done()
        cache[key] = list(req.out_tokens)
    return cache[key]


def _check_refcounts(eng, sessions):
    eng.kv.allocator.check()
    held = collections.Counter()
    for sess in sessions:
        held.update(sess.pages)
    if eng.prefix is not None:
        for entry in eng.prefix.entries.values():
            held.update(entry.pages)
    for page in range(1, eng.layout.num_pages):
        assert eng.kv.allocator.refcount[page] == held.get(page, 0), \
            f"page {page}: refcount {eng.kv.allocator.refcount[page]} " \
            f"vs holders {held.get(page, 0)}"


def _frontend(setup, batch_size=4, cache_len=64, **kw):
    cfg, qp, plans, _ = setup
    fe_kw = {k: kw.pop(k) for k in ("max_pending", "clock", "stall_steps")
             if k in kw}
    eng = ServingEngine(qp, plans, cfg, batch_size=batch_size,
                        cache_len=cache_len, ops=kw.pop("ops", "ref"),
                        **kw)
    return ServingFrontend(eng, **fe_kw)


# ---------------------------------------------------------------------------
# streaming bit-exactness


@pytest.mark.parametrize("ops,engine_kw", [
    ("ref", dict(cache_mode="paged")),
    ("ref", dict(cache_mode="contiguous")),
    ("pallas_fused", dict(cache_mode="paged")),
    ("pallas_fused", dict(cache_mode="contiguous")),
])
@pytest.mark.parametrize("spec_k", [0, 2])
def test_16_concurrent_streams_bit_exact(setup, ops, engine_kw, spec_k):
    """16 requests streamed concurrently through the async front end
    must each reproduce the solo synchronous reference stream — across
    backend x cache mode x speculation."""
    prompts = _prompts(16)

    async def main():
        fe = _frontend(setup, ops=ops, spec_k=spec_k,
                       max_pending=32, **engine_kw)
        runner = asyncio.create_task(fe.run())
        handles = [fe.submit(p, MAX_NEW) for p in prompts]
        streams = await asyncio.gather(
            *[h.result() for h in handles])
        fe.close()
        await runner
        return fe, handles, streams

    fe, handles, streams = asyncio.run(main())
    for h, toks, prompt in zip(handles, streams, prompts):
        assert h.terminal == "completed"
        assert toks == _expected(setup, prompt), prompt
    d = fe.describe()
    assert d["terminal"]["completed"] == 16
    assert d["pending"] == 0 and d["submitted"] == 16
    if fe.engine.paged:
        _check_refcounts(fe.engine, [h.session for h in handles])


def test_streaming_is_incremental(setup):
    """Tokens arrive per engine step, not in one burst at completion:
    a consumer sees the first token while its request is still live."""

    async def main():
        fe = _frontend(setup, batch_size=2)
        h = fe.submit([3, 1, 4], max_new_tokens=6)
        runner = asyncio.create_task(fe.run())
        states = []
        async for _ in h.stream():
            states.append(h.state)
        fe.close()
        await runner
        return states

    states = asyncio.run(main())
    assert len(states) == 6
    assert states[0] == "active"            # mid-generation, not done


def test_frontend_tp2_streams_match_solo(setup):
    """Lifecycle ops compose with the sharded engine: tp=2 frontend
    streams (sharded under the 4-device CI matrix, exact gathered
    fallback on one device) match the unsharded solo reference."""
    prompts = _prompts(6)

    async def main():
        fe = _frontend(setup, tp=2, max_pending=8)
        runner = asyncio.create_task(fe.run())
        handles = [fe.submit(p, MAX_NEW) for p in prompts]
        streams = await asyncio.gather(*[h.result() for h in handles])
        fe.close()
        await runner
        return streams

    for toks, prompt in zip(asyncio.run(main()), prompts):
        assert toks == _expected(setup, prompt), prompt


# ---------------------------------------------------------------------------
# lifecycle: cancel / deadline / backpressure


def test_cancel_mid_decode_releases_pages_exactly(setup):
    """Cancel a decoding request: its stream ends with terminal
    'cancelled', its pages return to the allocator, and the surviving
    neighbour's stream is untouched."""

    async def main():
        fe = _frontend(setup, batch_size=2, page_size=8)
        victim = fe.submit([9, 9, 2], max_new_tokens=32)
        keeper = fe.submit([3, 1, 4], max_new_tokens=6)
        while victim.metrics.n_tokens < 2:
            await fe.step()
        assert victim.state == "active"
        victim.cancel()
        await fe.step()                     # applied at the boundary
        assert victim.terminal == "cancelled"
        while await fe.step():
            pass
        keep = await keeper.result()        # queue already drained: EOS
        return fe, victim, keeper, keep

    fe, victim, keeper, keep = asyncio.run(main())
    assert 2 <= len(victim.tokens) < 32
    assert victim.tokens == _expected(setup, [9, 9, 2], 32)[
        : len(victim.tokens)]               # a prefix of the reference
    assert keep == _expected(setup, [3, 1, 4], 6)
    assert keeper.terminal == "completed"
    _check_refcounts(fe.engine, [victim.session, keeper.session])


def test_cancel_mid_prefill_releases_pages_exactly(setup):
    """Cancel while the prompt is still prefilling (prefill_budget
    stretches it over many steps): the half-prefilled pages must all
    come back."""
    prompt = [int(t) for t in
              np.random.default_rng(11).integers(1, 100, 40)]

    async def main():
        fe = _frontend(setup, batch_size=2, page_size=8,
                       prefill_budget=4, prefix_cache=False)
        h = fe.submit(prompt, max_new_tokens=4)
        await fe.step()
        assert h.state == "prefilling"
        h.cancel()
        await fe.step()
        return fe, h

    fe, h = asyncio.run(main())
    assert h.terminal == "cancelled" and h.tokens == []
    assert fe.engine.kv.allocator.used_pages == 0   # all pages came back
    _check_refcounts(fe.engine, [h.session])


def test_cancel_queued_request_never_admitted(setup):
    """A request cancelled while still queued (no lane, no pages) ends
    'cancelled' without the engine ever touching it."""

    async def main():
        fe = _frontend(setup, batch_size=2)
        hogs = [fe.submit([7 + i, 5], max_new_tokens=8)
                for i in range(2)]
        queued = fe.submit([1, 2, 3], max_new_tokens=4)
        await fe.step()
        assert queued.state == "queued"
        queued.cancel()
        await fe.step()
        assert queued.terminal == "cancelled"
        while await fe.step():
            pass
        return fe, hogs, queued

    fe, hogs, queued = asyncio.run(main())
    assert queued.tokens == []
    assert all(h.terminal == "completed" for h in hogs)
    _check_refcounts(fe.engine,
                     [h.session for h in hogs] + [queued.session])


def test_deadline_expiry_times_out(setup):
    """An expired deadline_s evicts the request with terminal 'timeout'
    — driven by an injected fake clock, so no real waiting."""
    t = [0.0]

    async def main():
        fe = _frontend(setup, batch_size=2, clock=lambda: t[0])
        slow = fe.submit([9, 9, 2], max_new_tokens=48, deadline_s=5.0)
        fast = fe.submit([3, 1, 4], max_new_tokens=6)
        while slow.metrics.n_tokens < 1:
            await fe.step()
        t[0] = 4.9
        await fe.step()
        assert slow.terminal is None        # not yet expired
        t[0] = 5.0
        await fe.step()
        assert slow.terminal == "timeout"
        while await fe.step():
            pass
        return fe, slow, fast

    fe, slow, fast = asyncio.run(main())
    assert 1 <= len(slow.tokens) < 48       # partial stream kept
    assert fast.terminal == "completed"
    assert fast.tokens == _expected(setup, [3, 1, 4], 6)
    _check_refcounts(fe.engine, [slow.session, fast.session])
    assert fe.describe()["terminal"]["timeout"] == 1


def test_queue_full_backpressure(setup):
    """Past max_pending, submit() raises typed QueueFull and counts the
    rejection; capacity frees once requests finish."""

    async def main():
        fe = _frontend(setup, batch_size=2, max_pending=3)
        handles = [fe.submit([5 + i, 9], max_new_tokens=2)
                   for i in range(3)]
        with pytest.raises(QueueFull) as exc:
            fe.submit([1, 2], max_new_tokens=2)
        assert exc.value.max_pending == 3 and exc.value.pending == 3
        while await fe.step():
            pass
        late = fe.submit([1, 2], max_new_tokens=2)   # capacity is back
        while await fe.step():
            pass
        return fe, handles, late

    fe, handles, late = asyncio.run(main())
    assert all(h.terminal == "completed" for h in handles + [late])
    d = fe.describe()
    assert d["terminal"]["rejected"] == 1
    assert d["submitted"] == 5
    assert sum(d["terminal"].values()) == d["submitted"]


# ---------------------------------------------------------------------------
# typed admission errors


def test_infeasible_request_rejected_at_submit(setup):
    """prompt + max_new_tokens overrunning cache_len is a typed error
    at submit() — frontend and bare engine alike — not a failure deep
    inside a step."""
    cfg, qp, plans, _ = setup
    fe = _frontend(setup, batch_size=2, cache_len=32)
    with pytest.raises(RequestInfeasible, match="exceeds the"):
        fe.submit([1] * 8, max_new_tokens=64)       # 8-1+64 > 32
    assert fe.describe()["terminal"]["rejected"] == 1
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=32)
    with pytest.raises(RequestInfeasible):
        eng.submit(Request(uid=0, prompt=[1] * 8, max_new_tokens=64))
    # the boundary case is admissible: prompt fills the cache, prefill
    # writes len-1 positions, the last decode lands exactly at the end
    h = fe.submit([1] * 8, max_new_tokens=32 - 8 + 1)
    assert h.state == "queued"
    with pytest.raises(RequestInfeasible):
        fe.submit([1] * 8, max_new_tokens=32 - 8 + 2)
    with pytest.raises(RequestInfeasible, match="empty prompt"):
        fe.submit([], max_new_tokens=4)


def test_never_fits_pool_rejected_at_frontend_submit(setup):
    """A prompt needing more pages than the pool can ever provide is
    RequestInfeasible at the *frontend* boundary; the bare engine keeps
    its legacy contract (admit, then typed PagePoolExhausted from the
    step), so the frontend check is strictly earlier."""
    fe = _frontend(setup, batch_size=2, cache_len=64, page_size=8,
                   num_pages=4)            # 3 usable pages = 24 tokens
    with pytest.raises(RequestInfeasible, match="pages but the pool"):
        fe.submit([1] * 30, max_new_tokens=2)
    h = fe.submit([1] * 20, max_new_tokens=2)       # 3 pages: fits
    assert h.state == "queued"


# ---------------------------------------------------------------------------
# engine step split (dispatch / commit)


@pytest.mark.parametrize("spec_k", [0, 2])
def test_dispatch_commit_split_matches_step(setup, spec_k):
    """step() == commit_step(dispatch_step()) by construction; driving
    the halves explicitly produces the same streams."""
    cfg, qp, plans, _ = setup
    prompts = _prompts(4)

    def run(split):
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                            ops="ref", spec_k=spec_k)
        reqs = [Request(uid=i, prompt=list(p), max_new_tokens=MAX_NEW)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        for _ in range(400):
            if not eng.queue and all(s is None for s in eng.slots):
                break
            if split:
                eng.commit_step(eng.dispatch_step())
            else:
                eng.step()
        return [r.out_tokens for r in reqs]

    assert run(split=True) == run(split=False)


def test_step_in_flight_guards_lifecycle_ops(setup):
    """evict/preempt between dispatch and commit is a typed error —
    the launch captured the session state; mutating it mid-flight
    would commit against stale snapshots."""
    cfg, qp, plans, _ = setup
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref")
    sess = eng.submit(Request(uid=0, prompt=[3, 1, 4],
                              max_new_tokens=8))
    eng.step()                              # prefill; decoding now
    pending = eng.dispatch_step()
    with pytest.raises(StepInFlight):
        eng.evict(sess)
    with pytest.raises(StepInFlight):
        eng.dispatch_step()
    eng.commit_step(pending)
    eng.evict(sess)                         # legal again after commit
    with pytest.raises(StepInFlight):       # stale pending is typed too
        eng.commit_step(pending)


def test_frontend_stall_detection_raises_typed(setup):
    """The front end carries run_until_done's EngineStalled contract:
    consecutive no-progress steps with work still queued raise instead
    of spinning forever."""
    fe = _frontend(setup, batch_size=2, stall_steps=2)
    fe.submit([3, 1, 4], max_new_tokens=2)
    stamp = fe._progress_stamp()
    fe._check_stall(stamp)                  # 1st no-progress step: armed
    with pytest.raises(EngineStalled):
        fe._check_stall(stamp)


# ---------------------------------------------------------------------------
# metrics surface


def test_describe_metrics_surface(setup):
    """describe() exposes the full lifecycle-metrics contract: latency
    percentiles (p50 <= p99), occupancy/queue-depth aggregates, and
    terminal accounting summing to submitted."""

    async def main():
        fe = _frontend(setup, batch_size=2, max_pending=4)
        handles = [fe.submit(p, MAX_NEW) for p in _prompts(4)]
        runner = asyncio.create_task(fe.run())
        await asyncio.gather(*[h.result() for h in handles])
        fe.close()
        await runner
        return fe, handles

    fe, handles = asyncio.run(main())
    d = fe.describe()
    for metric in ("ttft_s", "inter_token_s", "queue_wait_s"):
        p = d["latency"][metric]
        assert p["n"] > 0 and p["p50"] <= p["p99"] and p["mean"] >= 0
    assert d["occupancy"]["max"] <= fe.engine.batch
    assert d["queue_depth"]["max"] >= 2     # 4 requests through 2 lanes
    assert sum(d["terminal"].values()) + d["pending"] == d["submitted"]
    for h in handles:
        m = h.metrics
        assert m.ttft_s is not None and m.ttft_s >= 0
        assert m.queue_wait_s is not None and m.queue_wait_s <= m.ttft_s
        assert m.tbt_s is not None and m.n_tokens == MAX_NEW
