"""Pallas TPU kernel: integer-only GELU (SwiftTron §III-H, Fig. 14).

Pure VPU elementwise tile: i-erf second-order polynomial with sign
handling, the x*(erf+1) product, and the output dyadic requant — all int32
adds/multiplies/shifts, constants baked at design time (q5..q8 in the
paper's Fig. 14).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dyadic import Dyadic
from repro.core.intmath import IGeluPlan


def _rshift_round(x, s: int):
    if s == 0:
        return x
    return (x + (1 << (s - 1))) >> s


def _gelu_kernel(x_ref, o_ref, *, plan: IGeluPlan, dn_out: Dyadic,
                 out_lo: int, out_hi: int):
    q = x_ref[...].astype(jnp.int32)
    erf = plan.erf
    sgn = jnp.sign(q).astype(jnp.int32)
    q_abs = jnp.minimum(jnp.abs(q), jnp.int32(erf.q_clip))
    t = q_abs + jnp.int32(erf.q_bneg)
    bracket = t * t + jnp.int32(erf.q_c)
    q_erf = sgn * (-bracket)
    out = q * (q_erf + jnp.int32(plan.q_one))
    out = _rshift_round(_rshift_round(out, dn_out.pre) * jnp.int32(dn_out.b),
                        dn_out.c - dn_out.pre)
    o_ref[...] = jnp.clip(out, out_lo, out_hi).astype(o_ref.dtype)


def int_gelu_pallas(q, plan: IGeluPlan, dn_out: Dyadic, out_bits: int = 8,
                    block: int = 4096, interpret: bool = True):
    """q: int32 (...,) any shape; returns int32 clipped to out_bits."""
    shape = q.shape
    n = q.size
    blk = min(block, n)
    while n % blk:
        blk -= 1
    x2 = q.reshape(n // blk, blk)
    kernel = functools.partial(
        _gelu_kernel, plan=plan, dn_out=dn_out,
        out_lo=-(1 << (out_bits - 1)), out_hi=(1 << (out_bits - 1)) - 1)
    out = pl.pallas_call(
        kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // blk, blk), jnp.int32),
        interpret=interpret,
    )(x2)
    return out.reshape(shape)
