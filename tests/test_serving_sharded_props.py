"""Property-based schedule sweep for tensor-parallel serving.

The sharded-engine counterpart of ``test_chunked_prefill_props``: inside
one forced-4-device subprocess (``mesh_runner``), hypothesis drives
random submit/step/preempt/evict schedules and replays each schedule on
tp=1 / tp=2 / tp=4 engines.  Asserted after every schedule:

  * identical greedy token streams at every tp degree, each matching the
    memoized solo (contiguous, streaming, unsharded) reference;
  * identical per-page refcount accounting — the allocator, page table
    and prefix index are replicated host-side, so every tp degree must
    make byte-for-byte the same paging decisions — and exact agreement
    between each page's refcount and its live holders (sessions + prefix
    entries), allocator partition invariant included.

Needs the optional ``hypothesis`` dev dependency (skip without it).
"""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from mesh_runner import run_with_devices

BODY = """
import collections

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models import model as M, transformer as tf
from repro.quant import convert
from repro.serving import PagePoolExhausted, Request, ServingEngine

MAX_NEW = 3
cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                      vocab=128, num_layers=1, n_heads=4, n_kv_heads=4)
params = tf.init_params(jax.random.key(0), cfg)
qp, plans = convert.quantize_params(params, cfg)

rng = np.random.default_rng(3)
stem = list(map(int, rng.integers(1, 100, 20)))
PROMPTS = [stem, stem[:-1] + [101], stem[:9],
           list(map(int, rng.integers(1, 100, 13))), [5, 9], [42]]

SOLO = {}

def expected(prompt):
    key = tuple(prompt)
    if key not in SOLO:
        eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                            ops="ref", cache_mode="contiguous")
        req = Request(uid=0, prompt=list(prompt), max_new_tokens=MAX_NEW)
        eng.submit(req)
        eng.run_until_done()
        SOLO[key] = list(req.out_tokens)
    return SOLO[key]

def check_refcounts(eng, sessions):
    eng.kv.allocator.check()
    held = collections.Counter()
    for sess in sessions:
        held.update(sess.pages)
    if eng.prefix is not None:
        for entry in eng.prefix.entries.values():
            held.update(entry.pages)
    for page in range(1, eng.layout.num_pages):
        assert eng.kv.allocator.refcount[page] == held.get(page, 0), (
            page, eng.kv.allocator.refcount[page], held.get(page, 0))

def run_schedule(tp, schedule, num_pages, chunk, prefix):
    eng = ServingEngine(qp, plans, cfg, batch_size=2, cache_len=64,
                        ops="ref", page_size=8, num_pages=num_pages,
                        prefill_chunk=chunk, prefix_cache=prefix, tp=tp)
    assert eng.describe()["tp"]["mode"] == ("sharded" if tp > 1
                                            else "off")
    requests, sessions = [], []
    uid = 0

    def relieve():
        live = [s for s in sessions
                if s.state in ("prefilling", "active", "preempted")]
        if live:
            eng.evict(live[0])

    for op, arg in schedule:
        try:
            if op == "submit":
                req = Request(uid=uid, prompt=list(PROMPTS[arg]),
                              max_new_tokens=MAX_NEW)
                uid += 1
                requests.append(req)
                sessions.append(eng.submit(req))
            elif op == "step":
                eng.step()
            elif op == "preempt":
                live = [s for s in sessions
                        if s.state in ("active", "prefilling")]
                if live:
                    eng.preempt(live[arg % len(live)])
            elif op == "evict":
                live = [s for s in sessions if s.state not in ("done",)]
                live = [s for s in live if s.pages or s in eng.queue
                        or s.slot is not None]
                if live:
                    eng.evict(live[arg % len(live)])
        except PagePoolExhausted:
            relieve()                       # legal under pool pressure
        check_refcounts(eng, sessions)
    for _ in range(400):                    # drain, relieving pressure
        if not eng.queue and all(s is None for s in eng.slots):
            break
        try:
            eng.step()
        except PagePoolExhausted:
            relieve()
    check_refcounts(eng, sessions)
    return ([(list(r.prompt), list(r.out_tokens), r.done)
             for r in requests],
            list(map(int, eng.kv.allocator.refcount)))

@given(
    schedule=st.lists(
        st.tuples(st.sampled_from(["submit", "step", "preempt",
                                   "evict"]),
                  st.integers(0, 5)),
        max_size=16),
    num_pages=st.sampled_from([6, 9]),
    chunk=st.sampled_from([0, 16]),
    prefix=st.booleans(),
)
@settings(max_examples=4, deadline=None)
def prop(schedule, num_pages, chunk, prefix):
    outs1, counts1 = run_schedule(1, schedule, num_pages, chunk, prefix)
    for prompt, toks, done in outs1:
        want = expected(prompt)
        assert toks == (want if done else want[:len(toks)]), prompt
    for tp in (2, 4):
        outs, counts = run_schedule(tp, schedule, num_pages, chunk,
                                    prefix)
        # identical streams AND identical per-page refcount accounting:
        # the replicated host-side scheduler made the same decisions
        assert outs == outs1, tp
        assert counts == counts1, tp

prop()
"""


def test_sharded_random_schedules_match_solo_reference(tmp_path):
    run_with_devices(BODY, 4, tmp_path)
