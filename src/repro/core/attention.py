"""Integer attention (SwiftTron §III-D/E, Figs. 8-10).

The ASIC streams Q*K^T -> Scale -> Softmax -> Requant -> P*V through
dedicated blocks.  Here the same integer flow is expressed over MXU-shaped
einsums:

  * scores  = int8 Q x int8 K -> int32 (MXU, accumulate int32)
  * scale   = 1/sqrt(head_dim) folded into the softmax input dyadic
              (the paper folds its /d scale into a shift when d = 2^k —
              same idea, one constant, §III-E)
  * softmax = integer-only (core.softmax), emits int8 probs at 2^-7
  * out     = int8 P x int8 V -> int32, requantized to the output scale

Variants:
  * ``i_attention_full``     — materialises the score matrix (tests, decode)
  * ``i_attention_chunked``  — two-pass streaming over KV chunks with
    integer-exact running max/sum corrections; O(chunk) memory, used for
    32k prefill.  Probabilities are normalised by the *global* sum before
    the P*V matmul, so the int32 accumulator is bounded by 127*2^7
    regardless of sequence length (no overflow even at 512k rows).
  * ``i_attention_decode``   — one query row against an int8 KV cache.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import softmax as ism
from repro.core.dyadic import Dyadic, clip_to_bits, fit_dyadic
from repro.core.softmax import (
    ISoftmaxPlan,
    combine_correction,
    i_softmax,
    i_softmax_stats,
    make_isoftmax,
    rescale_sum)


class IAttnPlan(NamedTuple):
    head_dim: int
    sm: ISoftmaxPlan
    dn_out: Dyadic          # (2^-7 * s_v) -> s_out
    s_q: float
    s_k: float
    s_v: float
    s_out: float


def make_iattention(head_dim: int, s_q: float, s_k: float, s_v: float,
                    s_out: float) -> IAttnPlan:
    s_score = s_q * s_k / math.sqrt(head_dim)
    qmax_score = head_dim * 127 * 127
    sm = make_isoftmax(s_score, qmax_score)
    # P*V accumulator: sum_t p8 * v8, p8 normalised -> |acc| <= 127 * 2^7
    dn_out = fit_dyadic(ism.S_PROB * s_v / s_out, 127 * (1 << 7) * 2)
    return IAttnPlan(head_dim, sm, dn_out, s_q, s_k, s_v, s_out)


def _scores(q8, k8):
    """int8 (B,Sq,H,D) x int8 (B,Sk,H,D) -> int32 (B,H,Sq,Sk)."""
    return jnp.einsum("bqhd,bkhd->bhqk", q8, k8,
                      preferred_element_type=jnp.int32)


def i_attention_full(q8, k8, v8, plan: IAttnPlan, mask=None,
                     out_bits: int = 8):
    """mask: bool (B,H,Sq,Sk) or broadcastable; True = attend."""
    out = i_attention_acc(q8, k8, v8, plan, mask=mask)
    return clip_to_bits(plan.dn_out(out), out_bits)


def i_attention_acc(q8, k8, v8, plan: IAttnPlan, mask=None):
    """Full-matrix attention stopping at the int32 P·V accumulator
    (scale ``2^-7 * s_v``) — the input of the requant epilogue; what a
    ``RequantSpec.raw()`` attention returns."""
    scores = _scores(q8, k8)
    p8 = i_softmax(scores, plan.sm, axis=-1, where=mask)
    return jnp.einsum("bhqk,bkhd->bqhd", p8, v8,
                      preferred_element_type=jnp.int32)


def causal_mask(sq: int, sk: int, q_offset: int = 0, window: int = 0):
    """(Sq, Sk) bool; ``window`` > 0 adds sliding-window banding."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window > 0:
        m = m & (ki > qi - window)
    return m


def i_attention_chunked(q8, k8, v8, plan: IAttnPlan, chunk: int,
                        causal: bool = True, window: int = 0,
                        out_bits: int = 8):
    """Two-pass streaming attention over KV chunks (int8 in/out).

    Pass 1 scans KV chunks keeping a running (max, rescaled sum) per row —
    the rescale is an i-exp multiply on the row *scalars* only.  Pass 2
    recomputes each chunk's e16 against the global max, normalises by the
    global sum, and accumulates int8 probs x int8 V on the MXU.
    """
    b, sq, h, d = q8.shape
    sk = k8.shape[1]
    assert sk % chunk == 0, (sk, chunk)
    n_chunks = sk // chunk
    k8c = k8.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    v8c = v8.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    neg_inf = jnp.int32(-(2 ** 30))

    def pass1(carry, xs):
        m_run, s_run = carry
        ci, kc = xs
        scores = _scores(q8, kc)
        mask = chunk_mask_dyn(ci)
        e16, m_c, s_c = i_softmax_stats(scores, plan.sm, where=mask)
        m_new = jnp.maximum(m_run, m_c)
        s_run = rescale_sum(s_run, combine_correction(m_run, m_new, plan.sm))
        s_c = rescale_sum(s_c, combine_correction(m_c, m_new, plan.sm))
        return (m_new, s_run + s_c), None

    def chunk_mask_dyn(ci):
        if not causal and window <= 0:
            return None
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(chunk)[None, :] + ci * chunk
        m = ki <= qi
        if window > 0:
            m = m & (ki > qi - window)
        return m[None, None]

    m0 = jnp.full((b, h, sq, 1), neg_inf, jnp.int32)
    s0 = jnp.zeros((b, h, sq, 1), jnp.int32)
    (g_max, g_sum), _ = jax.lax.scan(
        pass1, (m0, s0), (jnp.arange(n_chunks), k8c))
    r = jnp.int32(1 << ism.RECIP_BITS) // jnp.maximum(g_sum, 1)

    def pass2(acc, xs):
        ci, kc, vc = xs
        scores = _scores(q8, kc)
        mask = chunk_mask_dyn(ci)
        q = scores if mask is None else jnp.where(mask, scores, neg_inf)
        e16 = ism._exp16(q - g_max, plan.sm)
        if mask is not None:
            e16 = jnp.where(mask, e16, 0)
        p8 = jnp.clip(
            ism.rshift_round(e16 * r, ism.RECIP_BITS - ism.PROB_SHIFT),
            0, 127).astype(jnp.int8)
        acc = acc + jnp.einsum("bhqk,bkhd->bqhd", p8, vc,
                               preferred_element_type=jnp.int32)
        return acc, None

    acc0 = jnp.zeros((b, sq, h, d), jnp.int32)
    acc, _ = jax.lax.scan(pass2, acc0,
                          (jnp.arange(n_chunks), k8c, v8c))
    return clip_to_bits(plan.dn_out(acc), out_bits)


def i_attention_decode(q8, k8_cache, v8_cache, plan: IAttnPlan,
                       valid_len, out_bits: int = 8):
    """One new token per sequence against an int8 KV cache.

    q8: (B, 1, H, D); caches: (B, L, Hkv, D) already head-repeated or
    grouped by the caller; valid_len: (B,) int32 number of live positions.
    """
    scores = _scores(q8, k8_cache)                       # (B,H,1,L)
    pos = jnp.arange(k8_cache.shape[1])[None, None, None, :]
    mask = pos < valid_len[:, None, None, None]
    p8 = i_softmax(scores, plan.sm, axis=-1, where=mask)
    out = jnp.einsum("bhqk,bkhd->bqhd", p8, v8_cache,
                     preferred_element_type=jnp.int32)
    return clip_to_bits(plan.dn_out(out), out_bits)
