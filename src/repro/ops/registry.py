"""Backend protocol, registry, and the OpSet dispatch handle.

Every integer operator (INT8 matmul, attention, decode attention,
softmax, GELU, LayerNorm) is implemented by a *backend* — an object with
the six methods of :class:`Backend`.  Backends register under a name
(``register_backend``) and models receive a resolved :class:`OpSet`
handle once at construction instead of threading ``backend="ref"``
strings through every call.

Resolution order for ``resolve_ops(spec, cfg)``:

  1. an explicit ``spec`` argument (OpSet / Backend / name);
  2. the innermost active :func:`use_backend` context;
  3. the ``REPRO_BACKEND`` environment variable;
  4. ``cfg.kernel_backend`` when an ArchConfig is supplied;
  5. the ``"ref"`` default.
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Any, Callable, Dict, Optional, Protocol, Union, \
    runtime_checkable

ENV_VAR = "REPRO_BACKEND"
DEFAULT_BACKEND = "ref"

# the six methods every backend MUST implement
REQUIRED_OPS = ("int8_matmul", "int_softmax", "int_gelu", "int_layernorm",
                "int_attention", "int_decode_attention")
# ... plus ops that are pure capabilities: a backend advertising the
# matching flag implements them natively, everyone else is served by an
# exact lowering in OpSet (so OP_NAMES is what dispatch/overrides/
# describe() route on, REQUIRED_OPS is what the protocol demands)
OP_NAMES = REQUIRED_OPS + ("int_paged_prefill", "int8_matmul_packed")


@runtime_checkable
class Backend(Protocol):
    """The six integer ops every backend implements.

    ``fused_attention`` advertises a single-kernel attention path (the
    model layer falls back to the streaming/chunked formulation when the
    backend only offers the full-matrix oracle).

    ``int_attention`` and ``int_decode_attention`` additionally accept
    ``requant=`` (a :class:`~repro.ops.spec.RequantSpec` epilogue;
    default: the plan's per-tensor ``dn_out``) and ``b_vec=`` (the
    per-channel multiplier vector) via ``**opts`` — see docs/KERNELS.md
    for the exact contract.  ``int_decode_attention`` serves the ragged
    KV-cache hot path: ``valid_len`` (B,) int32 is the per-slot cache
    occupancy (see the "Decode kernel contract" section there); an
    optional ``fused_decode`` flag (default False) advertises a
    single-launch kernel for it — the numerics are identical either way.

    Two further *optional* decode capabilities, negotiated by
    :meth:`OpSet.int_decode_attention` so plain backends never see the
    operands:

      * ``paged_decode`` — the backend consumes the paged KV layout
        directly (``pages: int32[B, max_pages]`` page table +
        ``page_size``, K/V as physical ``(num_pages, page_size, Hkv,
        D)`` pools).  Without the flag the dispatch layer gathers the
        pages into the contiguous layout first (bit-identical).
      * ``decode_wo_fold`` — the backend folds the output projection
        (``wo=`` a QuantLinearParams, ``wo_spec=`` its RequantSpec)
        into the decode launch, returning ``(B, Sq, N)``.  Without the
        flag the dispatch layer composes the backend's decode attention
        with its ``int8_matmul`` (bit-identical).

    A third pair of optional capabilities serves the *chunked prefill*
    path (:meth:`OpSet.int_paged_prefill` — scatter a prompt chunk's
    K/V through the page table, then attend causally over history +
    chunk):

      * ``paged_prefill`` — the backend implements
        ``int_paged_prefill`` natively (the fused prefill attention
        kernel reading K/V through the page-table scalar-prefetch
        operand).  Without the flag the dispatch layer lowers exactly:
        ``scatter_chunk`` + ``gather_pages`` + the backend's own
        ``int_decode_attention`` with ``valid_len = base_pos + C``
        (whose stepped mask *is* the chunked causal mask).
      * ``prefill_wo_fold`` — the backend folds the o-projection into
        the prefill launch's epilogue, mirroring ``decode_wo_fold``.
        Without it, decode-then-``int8_matmul`` (bit-identical).
    The sub-8-bit storage tier adds two more negotiated capabilities:

      * ``packed_matmul`` — the backend implements
        ``int8_matmul_packed`` natively (nibbles unpacked *inside* the
        matmul launch, msr4 outlier lanes applied as an exact sparse
        correction).  Without the flag the dispatch layer unpacks to
        dense int8 first (``repro.ops.packed.unpack_weights`` — the
        declared reference) and calls the backend's ``int8_matmul``:
        bit-identical either way.
      * ``packed_kv`` — the backend's paged decode/prefill launches
        consume int4-packed KV page pools directly (``kv_shifts=`` a
        pair of per-page int32 shift arrays; the kernel dequantizes
        ``q4 << shift`` in-register).  Without the flag the dispatch
        layer dequantizes the pools to int8
        (``repro.ops.packed.unpack_kv_pool``) and proceeds on the
        plain paged path — the declared reference numerics.

      * ``tp_serving`` — the backend's ops trace inside a ``shard_map``
        body, so the serving engine may head-shard its decode/prefill
        launches tensor-parallel over a device mesh
        (``distributed.tp_serving``; each shard launches with ``H/tp``
        query and ``Hkv/tp`` KV heads).  Without the flag — on ANY
        backend in the OpSet — a ``tp > 1`` engine takes the exact
        single-device gather lowering instead: same API, bit-identical
        tokens, no mesh.
    """

    name: str
    fused_attention: bool

    def int8_matmul(self, x8, w8, spec, *, bias32=None, b_vec=None,
                    **opts): ...

    def int_softmax(self, scores, plan, **opts): ...

    def int_gelu(self, q, plan, dn_out, out_bits: int = 8, **opts): ...

    def int_layernorm(self, q, q_gamma, q_beta, plan, out_bits: int = 8,
                      **opts): ...

    def int_attention(self, q8, k8, v8, plan, causal: bool = True,
                      window: int = 0, out_bits: int = 8, **opts): ...

    def int_decode_attention(self, q8, k8_cache, v8_cache, plan, valid_len,
                             out_bits: int = 8, **opts): ...


def _is_backend(obj) -> bool:
    """A backend *instance*: the six required ops plus
    name/fused_attention (capability ops like ``int_paged_prefill`` are
    optional — OpSet lowers them for backends without the flag).

    Classes are excluded — a registered class is a factory, and calling
    its unbound methods would misbind ``self``.
    """
    if isinstance(obj, type):
        return False
    return (all(callable(getattr(obj, op, None)) for op in REQUIRED_OPS)
            and isinstance(getattr(obj, "name", None), str)
            and hasattr(obj, "fused_attention"))


_REGISTRY: Dict[str, Union[Backend, Callable[[], Backend]]] = {}
_LOCK = threading.Lock()


def register_backend(name: str, backend, *, overwrite: bool = False):
    """Register a backend instance or zero-arg factory under ``name``."""
    if not (_is_backend(backend) or callable(backend)):
        raise TypeError(f"{backend!r} implements neither the Backend "
                        "protocol nor a factory for one")
    with _LOCK:
        if name in _REGISTRY and not overwrite:
            raise ValueError(f"backend {name!r} already registered "
                             "(pass overwrite=True to replace)")
        _REGISTRY[name] = backend


def unregister_backend(name: str):
    with _LOCK:
        _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    """Look up a registered backend, instantiating lazy factories once."""
    with _LOCK:
        entry = _REGISTRY.get(name)
    if entry is None:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{available_backends()}")
    if not _is_backend(entry):
        entry = entry()
        if not _is_backend(entry):
            raise TypeError(f"factory for {name!r} returned a "
                            "non-Backend")
        with _LOCK:
            _REGISTRY[name] = entry
    return entry


def available_backends():
    with _LOCK:
        return sorted(_REGISTRY)


def _as_backend(spec) -> Backend:
    if isinstance(spec, str):
        return get_backend(spec)
    if _is_backend(spec):
        return spec
    raise TypeError(f"cannot interpret {spec!r} as a backend")


class OpSet:
    """A resolved operator bundle: one default backend + per-op overrides.

    Models hold exactly one of these; every integer op dispatches through
    it, so swapping backends (or overriding a single op, e.g. fused
    attention on Pallas with everything else on ref) never touches model
    code.
    """

    __slots__ = ("default", "overrides")

    def __init__(self, default, overrides: Optional[Dict[str, Any]] = None):
        self.default = _as_backend(default)
        ov = {}
        for op, b in (overrides or {}).items():
            if op not in OP_NAMES:
                raise KeyError(f"unknown op {op!r}; valid ops: {OP_NAMES}")
            ov[op] = _as_backend(b)
        self.overrides = ov

    # ------------------------------------------------------------ admin --

    @property
    def name(self) -> str:
        if not self.overrides:
            return self.default.name
        ov = ",".join(f"{op}={b.name}"
                      for op, b in sorted(self.overrides.items()))
        return f"{self.default.name}[{ov}]"

    def backend_for(self, op: str) -> Backend:
        if op not in OP_NAMES:
            raise KeyError(f"unknown op {op!r}; valid ops: {OP_NAMES}")
        return self.overrides.get(op, self.default)

    def with_overrides(self, **per_op) -> "OpSet":
        merged = dict(self.overrides)
        merged.update(per_op)
        return OpSet(self.default, merged)

    def __repr__(self):
        return f"OpSet({self.name})"

    # --------------------------------------------------------- dispatch --

    def int8_matmul(self, x8, w8, spec, *, bias32=None, b_vec=None, **opts):
        return self.backend_for("int8_matmul").int8_matmul(
            x8, w8, spec, bias32=bias32, b_vec=b_vec, **opts)

    def int_softmax(self, scores, plan, **opts):
        return self.backend_for("int_softmax").int_softmax(
            scores, plan, **opts)

    def int_gelu(self, q, plan, dn_out, out_bits: int = 8, **opts):
        return self.backend_for("int_gelu").int_gelu(
            q, plan, dn_out, out_bits=out_bits, **opts)

    def int_layernorm(self, q, q_gamma, q_beta, plan, out_bits: int = 8,
                      **opts):
        return self.backend_for("int_layernorm").int_layernorm(
            q, q_gamma, q_beta, plan, out_bits=out_bits, **opts)

    def int_attention(self, q8, k8, v8, plan, causal: bool = True,
                      window: int = 0, out_bits: int = 8, **opts):
        return self.backend_for("int_attention").int_attention(
            q8, k8, v8, plan, causal=causal, window=window,
            out_bits=out_bits, **opts)

    def int8_matmul_packed(self, x8, qw, spec, **opts):
        """Matmul against packed (int4/msr4) weights, with negotiation.

        ``qw`` is a packed :class:`~repro.ops.spec.QuantLinearParams`
        (``w_packed`` nibbles + optional msr4 outlier lanes); its
        ``bias32``/``b_mult`` feed the epilogue exactly as on the dense
        path.  Backends advertising ``packed_matmul`` unpack inside the
        launch; for the rest this method lowers exactly — dense
        reconstruction via ``repro.ops.packed.unpack_weights`` (the
        declared reference) followed by the backend's own
        ``int8_matmul`` — so callers get identical integers from every
        backend.  A dense ``qw`` falls through to plain ``int8_matmul``.
        """
        from repro.ops.spec import QuantLinearParams
        qw = QuantLinearParams.of(qw)
        if not qw.is_packed:
            return self.int8_matmul(x8, qw.w8, spec, bias32=qw.bias32,
                                    b_vec=qw.b_mult, **opts)
        be = self.backend_for("int8_matmul_packed")
        if getattr(be, "packed_matmul", False):
            return be.int8_matmul_packed(x8, qw, spec, **opts)
        from repro.ops.packed import unpack_weights
        return be.int8_matmul(x8, unpack_weights(qw), spec,
                              bias32=qw.bias32, b_vec=qw.b_mult, **opts)

    def _compose_wo(self, be, o8, wo, wo_spec):
        """Exact unfolded wo composition: decode output → o-projection.

        Packed wo never folds into an attention launch — it routes
        through :meth:`int8_matmul_packed` (same negotiated numerics).
        """
        import jax.numpy as jnp
        b, sq = o8.shape[0], o8.shape[1]
        x8 = o8.astype(jnp.int8).reshape(b * sq, -1)
        if wo.is_packed:
            acc = self.int8_matmul_packed(x8, wo, wo_spec)
        else:
            acc = be.int8_matmul(x8, wo.w8, wo_spec, bias32=wo.bias32,
                                 b_vec=wo.b_mult)
        if not wo_spec.is_raw and wo_spec.out_bits <= 8:
            acc = acc.astype(jnp.int8)     # match the folded kernel's dtype
        return acc.reshape(b, sq, -1)

    def int_decode_attention(self, q8, k8_cache, v8_cache, plan, valid_len,
                             out_bits: int = 8, pages=None,
                             page_size: int = 0, wo=None, wo_spec=None,
                             kv_shifts=None, **opts):
        """Decode attention with capability negotiation.

        ``pages``/``page_size`` select the paged KV layout (k8/v8 are
        physical page pools); ``wo``/``wo_spec`` ask for the folded
        output projection; ``kv_shifts`` marks the pools as int4-packed
        (nibbles along the head dim + per-page requant shifts — the
        ``kv_dtype="int4"`` cache tier).  Backends advertising
        ``paged_decode`` / ``decode_wo_fold`` / ``packed_kv`` get the
        operands verbatim; for the rest this method lowers them exactly
        — gather-into-contiguous for pages, decode-then-``int8_matmul``
        for the fold, pool dequantization for packed KV — so callers
        get identical integers from every backend.
        """
        be = self.backend_for("int_decode_attention")
        kw = {}
        if kv_shifts is not None and pages is None:
            raise ValueError("int4 KV (kv_shifts=) requires the paged "
                             "layout")
        if pages is not None:
            paged_native = getattr(be, "paged_decode", False)
            if kv_shifts is not None:
                if paged_native and getattr(be, "packed_kv", False):
                    kw.update(kv_shifts=kv_shifts)
                else:
                    from repro.ops.packed import unpack_kv_pool
                    k8_cache = unpack_kv_pool(k8_cache, kv_shifts[0])
                    v8_cache = unpack_kv_pool(v8_cache, kv_shifts[1])
            if paged_native:
                kw.update(pages=pages, page_size=page_size)
            else:
                from repro.ops.paged import gather_pages
                k8_cache = gather_pages(k8_cache, pages, page_size)
                v8_cache = gather_pages(v8_cache, pages, page_size)
        if wo is None:
            return be.int_decode_attention(q8, k8_cache, v8_cache, plan,
                                           valid_len, out_bits=out_bits,
                                           **kw, **opts)
        wo = _validate_wo(wo, wo_spec, opts.get("requant"), out_bits)
        if getattr(be, "decode_wo_fold", False) and not wo.is_packed:
            return be.int_decode_attention(q8, k8_cache, v8_cache, plan,
                                           valid_len, out_bits=out_bits,
                                           wo=wo, wo_spec=wo_spec,
                                           **kw, **opts)
        # exact unfolded composition through the backend's own matmul
        o8 = be.int_decode_attention(q8, k8_cache, v8_cache, plan,
                                     valid_len, out_bits=out_bits,
                                     **kw, **opts)
        return self._compose_wo(be, o8, wo, wo_spec)

    def int_paged_prefill(self, q8, k8_new, v8_new, k_pool, v_pool, plan,
                          base_pos, pages, page_size: int,
                          out_bits: int = 8, wo=None, wo_spec=None,
                          kv_shifts=None, **opts):
        """Chunked paged prefill with capability negotiation.

        Scatter the chunk's new K/V (``k8_new``/``v8_new``: ``(B, C,
        Hkv, D)`` int8, RoPE applied) into the physical pools through
        the page table, then run the chunk queries ``q8 (B, C, H, D)``
        against history + chunk under the causal-over-history mask —
        chunk row ``i`` of slot ``b`` sees positions ``≤ base_pos[b] +
        i``.  Returns ``(o, k_pool, v_pool)``.

        Backends advertising ``paged_prefill`` get the operands verbatim
        (the fused prefill kernel reads K/V through the scalar-prefetched
        table; ``prefill_wo_fold`` additionally folds ``wo=``/``wo_spec=``
        into the launch).  For the rest this method lowers exactly —
        ``scatter_chunk`` + ``gather_pages`` + the stepped-mask
        :meth:`int_decode_attention` with ``valid_len = base_pos + C``
        (which also negotiates the wo fold) — so callers get identical
        integers from every backend.  Oracle:
        ``kernels.ref.ref_int_paged_prefill``.

        ``kv_shifts`` marks the pools as int4-packed (kv_dtype="int4"):
        the chunk's K/V are quantized + nibble-packed before the
        scatter (``repro.ops.packed.pack_kv`` — one quantization policy
        for every path, so pool bytes are backend-independent), and a
        backend without ``packed_kv`` is served by dequantizing the
        updated pools and running the plain lowering.
        """
        be = self.backend_for("int_paged_prefill")
        if wo is not None:
            wo = _validate_wo(wo, wo_spec, opts.get("requant"), out_bits)
        packed_kv_native = (kv_shifts is not None
                            and getattr(be, "packed_kv", False))
        if getattr(be, "paged_prefill", False) \
                and (kv_shifts is None or packed_kv_native):
            kw = {}
            if kv_shifts is not None:
                kw.update(kv_shifts=kv_shifts)
            if wo is not None and getattr(be, "prefill_wo_fold", False) \
                    and not wo.is_packed:
                kw.update(wo=wo, wo_spec=wo_spec)
                wo = None
            o, k_pool, v_pool = be.int_paged_prefill(
                q8, k8_new, v8_new, k_pool, v_pool, plan, base_pos,
                pages, page_size, out_bits=out_bits, **kw, **opts)
            if wo is None:
                return o, k_pool, v_pool
            # fold requested but the backend only does paged prefill:
            # exact unfolded composition through its own matmul
            return self._compose_wo(be, o, wo, wo_spec), k_pool, v_pool
        from repro.ops.paged import gather_pages, scatter_chunk
        import jax.numpy as jnp
        c = q8.shape[1]
        if kv_shifts is not None:
            from repro.ops.packed import pack_kv, unpack_kv_pool
            k_pool = scatter_chunk(k_pool, pack_kv(k8_new), base_pos,
                                   pages, page_size)
            v_pool = scatter_chunk(v_pool, pack_kv(v8_new), base_pos,
                                   pages, page_size)
            kc = gather_pages(unpack_kv_pool(k_pool, kv_shifts[0]),
                              pages, page_size)
            vc = gather_pages(unpack_kv_pool(v_pool, kv_shifts[1]),
                              pages, page_size)
        else:
            k_pool = scatter_chunk(k_pool, k8_new, base_pos, pages,
                                   page_size)
            v_pool = scatter_chunk(v_pool, v8_new, base_pos, pages,
                                   page_size)
            kc = gather_pages(k_pool, pages, page_size)
            vc = gather_pages(v_pool, pages, page_size)
        vl = jnp.asarray(base_pos, jnp.int32) + c
        o = self.int_decode_attention(q8, kc, vc, plan, vl,
                                      out_bits=out_bits, wo=wo,
                                      wo_spec=wo_spec, **opts)
        return o, k_pool, v_pool


def _validate_wo(wo, wo_spec, requant, out_bits: int):
    """Shared wo-fold operand validation (decode and paged prefill):
    normalizes ``wo`` to QuantLinearParams and rejects epilogues the
    int8 fold/lowering would silently wrap on."""
    from repro.ops.spec import QuantLinearParams
    wo = QuantLinearParams.of(wo)
    if wo_spec is None:
        raise ValueError("folded wo projection needs wo_spec (the "
                         "o-projection's RequantSpec)")
    # the effective attention epilogue must clip to int8 — it feeds
    # the int8 wo contraction (a wider epilogue would silently wrap
    # in the lowering's astype)
    if requant is not None and (requant.is_raw or requant.out_bits > 8):
        raise ValueError("wo folding needs an int8 attention "
                         f"epilogue, got {requant}")
    if requant is None and out_bits > 8:
        raise ValueError("wo folding needs an int8 attention "
                         f"epilogue, got out_bits={out_bits}")
    return wo


# ------------------------------------------------------------ resolution --

_TLS = threading.local()


def _stack():
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


def current_opset() -> Optional[OpSet]:
    """The innermost active ``use_backend`` OpSet, if any."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_backend(spec, **per_op):
    """Scope a backend choice: ``with use_backend("pallas"): ...``.

    ``per_op`` overrides route individual ops elsewhere, e.g.
    ``use_backend("ref", int_attention="pallas")``.
    """
    ops = OpSet(_as_backend(spec),
                per_op or None) if not isinstance(spec, OpSet) \
        else (spec.with_overrides(**per_op) if per_op else spec)
    stack = _stack()
    stack.append(ops)
    try:
        yield ops
    finally:
        stack.pop()


def resolve_ops(spec=None, cfg=None) -> OpSet:
    """Resolve ``spec`` (OpSet / Backend / name / None) to an OpSet."""
    if isinstance(spec, OpSet):
        return spec
    if spec is not None:
        return OpSet(_as_backend(spec))
    active = current_opset()
    if active is not None:
        return active
    env = os.environ.get(ENV_VAR)
    if env:
        return OpSet(get_backend(env))
    if cfg is not None and getattr(cfg, "kernel_backend", None):
        return OpSet(get_backend(cfg.kernel_backend))
    return OpSet(get_backend(DEFAULT_BACKEND))
