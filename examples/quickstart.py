"""Quickstart: the complete SwiftTron flow on a small model (paper Fig. 17).

  float init -> QAT fine-tune (few steps) -> convert to integer-only
  parameters -> integer prefill + greedy decode -> compare to float path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLMDataset
from repro.models import inttransformer as it
from repro.models import model as M
from repro.models import transformer as tf
from repro import ops as rops
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import AdamWConfig
from repro.quant import convert, qat


def main():
    cfg = M.reduce_config(get_config("llama3-8b"), dtype="float32",
                          vocab=256, num_layers=2)
    print(f"arch={cfg.name} (reduced) d={cfg.d_model} L={cfg.num_layers}")
    data = SyntheticLMDataset(cfg.vocab, 32, 8, seed=0)
    params = tf.init_params(jax.random.key(0), cfg)

    opt_cfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(qat.loss_fn, has_aux=True)(
            params, batch, cfg, qat=True)
        params, opt, _ = adamw_update(g, opt, params, opt_cfg)
        return params, opt, loss

    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, loss = step(params, opt, batch)
        if i % 10 == 0:
            print(f"  QAT step {i:3d}  loss {float(loss):.3f}")

    print("converting to integer-only parameters ...")
    qp, plans = convert.quantize_params(params, cfg)
    n_int8 = sum(l.size for l in jax.tree.leaves(qp)
                 if hasattr(l, "dtype") and l.dtype == jnp.int8)
    print(f"  int8 weights: {n_int8 / 1e6:.2f} M params")

    batch = next(data)
    toks = jnp.asarray(batch["tokens"])
    # integer ops dispatch through the repro.ops backend registry; the
    # use_backend context (or REPRO_BACKEND=...) swaps implementations —
    # "ref" / "pallas" / "pallas_tuned" / "pallas_fused", docs/OPS_API.md
    with rops.use_backend("ref"):
        logits_int = it.int_prefill(qp, {"tokens": toks}, plans, cfg)
    logits_f, _ = tf.forward_float(params, {"tokens": toks,
                                            "labels": toks}, cfg)
    corr = np.corrcoef(np.asarray(logits_int).ravel(),
                       np.asarray(logits_f[:, -1], np.float32).ravel())[0, 1]
    agree = float((np.argmax(np.asarray(logits_int), -1)
                   == np.argmax(np.asarray(logits_f[:, -1]), -1)).mean())
    print(f"integer vs float logits: corr={corr:.4f} "
          f"argmax agreement={agree:.2%}")
    assert corr > 0.9
    print("OK")


if __name__ == "__main__":
    main()
