"""Certification CLI: sweep every registry config, emit CERTIFY.json.

``python -m repro.analysis.certify`` runs
:func:`repro.analysis.interpret.certify_config` over all registry
architectures at a given ``(seq_len, cache_len)`` and writes the
machine-readable report to ``benchmarks/CERTIFY.json`` (schema-checked
by ``benchmarks/check_bench_json.py``).  Exit status is non-zero if any
config fails — the CI ``static-analysis`` job gates on it, so an unsafe
plan constant cannot merge.

Per config the report carries: certification status, worst-case bits and
minimum int32 headroom across all ops, per-op worst-case magnitude /
bits / predicted kernel path, the number of plan-tree dyadics whose
staging invariant was re-proved, and the list of assumptions (what is
taken on contract rather than proven — see docs/ANALYSIS.md).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.budgets import (INT32_MAX, MAX_ROWSUM_LEN, MAX_SQ,
                                    BitBudgetError)
from repro.analysis.interpret import certify_config

SCHEMA = "repro/certify-v1"

DEFAULT_JSON = os.path.join("benchmarks", "CERTIFY.json")


def _op_entry(o):
    return {
        "op": o.op,
        "layer": o.layer,
        "worst": o.worst,
        "bits": o.bits,
        "headroom_bits": o.headroom_bits,
        "path": o.path,
        "note": o.note,
    }


def certify_all(seq_len: int, cache_len: int, names=None):
    """Certify the selected (default: all) registry configs.  Returns
    ``(report_dict, n_failed)`` — never raises on certification failure,
    so one bad config still reports every other."""
    from repro.configs.registry import ARCHS
    names = list(names) if names else sorted(ARCHS)
    configs = {}
    n_failed = 0
    for name in names:
        cfg = ARCHS[name]            # KeyError on unknown names: intended
        try:
            r = certify_config(cfg, seq_len=seq_len, cache_len=cache_len)
        except BitBudgetError as e:
            n_failed += 1
            configs[name] = {
                "ok": False,
                "error": {
                    "what": e.what,
                    "value": e.value,
                    "budget": e.budget,
                    "op": e.op or "",
                    "layer": e.layer or "",
                    "message": str(e),
                },
            }
            continue
        configs[name] = {
            "ok": True,
            "worst_bits": r.worst_bits,
            "min_headroom_bits": r.min_headroom_bits,
            "n_ops": len(r.ops),
            "n_dyadics": r.n_dyadics,
            "ops": [_op_entry(o) for o in r.ops],
            "assumptions": list(r.assumptions),
        }
    report = {
        "schema": SCHEMA,
        "seq_len": seq_len,
        "cache_len": cache_len,
        "budgets": {
            "INT32_MAX": INT32_MAX,
            "MAX_ROWSUM_LEN": MAX_ROWSUM_LEN,
            "MAX_SQ": MAX_SQ,
        },
        "n_configs": len(configs),
        "n_failed": n_failed,
        "configs": configs,
    }
    return report, n_failed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.certify",
        description="Statically certify every registry config "
                    "overflow-free (docs/ANALYSIS.md).")
    ap.add_argument("--seq-len", type=int, default=4096,
                    help="prefill sequence length to certify at")
    ap.add_argument("--cache-len", type=int, default=32768,
                    help="decode/paged-prefill cache length to certify at")
    ap.add_argument("--arch", action="append", default=None,
                    help="certify only this config (repeatable)")
    ap.add_argument("--json", default=DEFAULT_JSON, metavar="PATH",
                    help="report path ('-' to skip writing)")
    args = ap.parse_args(argv)

    report, n_failed = certify_all(args.seq_len, args.cache_len, args.arch)
    for name, entry in report["configs"].items():
        if entry["ok"]:
            print(f"  ok    {name}: {entry['n_ops']} ops, worst "
                  f"{entry['worst_bits']} bits (headroom "
                  f"{entry['min_headroom_bits']}), "
                  f"{entry['n_dyadics']} dyadics audited")
        else:
            print(f"  FAIL  {name}: {entry['error']['message']}")
    if args.json != "-":
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")
    if n_failed:
        print(f"{n_failed} config(s) failed certification",
              file=sys.stderr)
        return 1
    print(f"all {report['n_configs']} configs certified overflow-free "
          f"at seq_len={args.seq_len}, cache_len={args.cache_len}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
