"""Integer activation functions.

i-GELU is the paper's §III-H unit (see ``intmath.i_gelu``); this module adds
the activation plans and the two extensions required by the assigned
architecture pool (DESIGN.md §4): **i-SiLU** for SwiGLU FFNs and
**i-softplus** for Mamba's Δt — built from the same primitives the paper
uses (i-exp, one integer division, dyadic requants).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import intmath
from repro.core.dyadic import Dyadic, clip_to_bits, fit_dyadic

SIG_FRAC = 15                     # sigmoid as a 16-bit fraction
RECIP_BITS = 30


class IGeluActPlan(NamedTuple):
    gelu: intmath.IGeluPlan
    dn_out: Dyadic
    s_in: float
    s_out: float


def make_igelu_act(s_in: float, qmax_in: int, s_out: float) -> IGeluActPlan:
    g = intmath.make_igelu(s_in, qmax_in)
    dn_out = fit_dyadic(g.s_out / s_out, qmax_in * (2 * g.q_one))
    return IGeluActPlan(g, dn_out, s_in, s_out)


def i_gelu_act(q, plan: IGeluActPlan, out_bits: int = 8):
    out = intmath.i_gelu(q.astype(jnp.int32), plan.gelu)
    return clip_to_bits(plan.dn_out(out), out_bits)


class ISiluPlan(NamedTuple):
    iexp: intmath.IExpPlan
    dn_e16: Dyadic            # iexp out -> 2^-15 fraction
    s_in: float
    s_out: float              # = s_in * 2^-SIG_FRAC before dn_out
    dn_out: Dyadic
    qmax_in: int


def make_isilu(s_in: float, qmax_in: int, s_out: float) -> ISiluPlan:
    """sigma(x) = e/(1+e), e = i_exp(-|x|); SiLU = x * sigma(x).

    Bit budget: e16, one16 <= 2^15; den <= 2^16; r = 2^30//den <= 2^15;
    num*r <= den*r <= 2^30; q * sig16 needs bits(qmax_in) + 16 <= 31.
    """
    if intmath.bits_for(qmax_in) + SIG_FRAC + 1 > 31:
        raise ValueError(f"i-silu qmax_in too large: {qmax_in}")
    iexp = intmath.make_iexp(s_in)
    dn_e16 = fit_dyadic(iexp.s_out / 2.0 ** -SIG_FRAC, iexp.q_one + 1)
    s_mid = s_in * 2.0 ** -SIG_FRAC
    dn_out = fit_dyadic(s_mid / s_out, qmax_in << SIG_FRAC)
    return ISiluPlan(iexp, dn_e16, s_in, s_mid, dn_out, qmax_in)


def i_silu(q, plan: ISiluPlan, out_bits: int = 8):
    q = q.astype(jnp.int32)
    e = intmath.i_exp(-jnp.abs(q), plan.iexp)
    e16 = jnp.clip(plan.dn_e16(e), 0, 1 << SIG_FRAC)
    one16 = jnp.int32(1 << SIG_FRAC)
    den = one16 + e16
    r = jnp.int32(1 << RECIP_BITS) // den
    num = jnp.where(q >= 0, one16, e16)
    sig16 = (num * r) >> (RECIP_BITS - SIG_FRAC)      # sigmoid * 2^15
    out = q * sig16                                    # scale s_in * 2^-15
    return clip_to_bits(plan.dn_out(out), out_bits)


class ISoftplusPlan(NamedTuple):
    iexp: intmath.IExpPlan
    dn_e16: Dyadic
    ln1p: intmath.ILn1pPlan    # emits directly at s_out (fine grid)
    s_in: float
    dn_relu: Dyadic            # s_in -> s_out for the max(x,0) branch
    s_out: float


def make_isoftplus(s_in: float, qmax_in: int, s_out: float) -> ISoftplusPlan:
    """softplus(x) = max(x, 0) + ln1p(exp(-|x|)), emitted at ``s_out``.

    Both branches are computed directly on the (typically much finer)
    output grid — Mamba Δt values live in [1e-3, 1], far below the input
    grid's resolution, so computing ln1p at s_in would zero them out."""
    iexp = intmath.make_iexp(s_in)
    dn_e16 = fit_dyadic(iexp.s_out / 2.0 ** -SIG_FRAC, iexp.q_one + 1)
    ln1p = intmath.make_iln1p(2.0 ** -SIG_FRAC, s_out, 1 << SIG_FRAC)
    dn_relu = fit_dyadic(s_in / s_out, qmax_in)
    return ISoftplusPlan(iexp, dn_e16, ln1p, s_in, dn_relu, s_out)


def i_softplus(q, plan: ISoftplusPlan, out_bits: int = 16):
    q = q.astype(jnp.int32)
    e = intmath.i_exp(-jnp.abs(q), plan.iexp)
    e16 = jnp.clip(plan.dn_e16(e), 0, 1 << SIG_FRAC)
    lq = intmath.i_ln1p(e16, plan.ln1p)                # scale s_out
    out = plan.dn_relu(jnp.maximum(q, 0)) + lq
    return clip_to_bits(out, out_bits)
