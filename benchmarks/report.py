"""Roofline report generator: experiments/dryrun/*.json -> the §Roofline
table (per-cell three terms, bottleneck, MODEL_FLOPS ratio)."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from benchmarks.roofline import model_flops, roofline_terms
from repro.configs.registry import ASSIGNED, get_config
from repro.models.common import SHAPES
from repro.models.transformer import layer_group_spec

N_CHIPS = 256     # roofline table is single-pod


def _load(dirpath: str, arch: str, shape: str, mesh: str = "16x16",
          tag: str = "") -> Optional[dict]:
    name = f"{arch}_{shape}_{mesh}" + (f"_{tag}" if tag else "")
    p = os.path.join(dirpath, name + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def corrected_cost(rec: dict, cfg) -> Dict[str, float]:
    """Scan-undercount correction: unrolled 1/2-group probes give the exact
    per-group delta; totals extrapolate linearly in groups and rescale
    linearly in batch (flops/bytes are batch-linear)."""
    flops = rec["cost"]["flops"]
    bts = rec["cost"]["bytes"]
    gl, ng, _ = layer_group_spec(cfg)
    probe = rec.get("probe")
    if probe and "ng1" in probe and "ng2" in probe:
        bs = probe.get("batch_scale", 1.0)
        b0 = probe.get("b_probe", 16)

        def total(key1, key2):
            d = probe[key2]["flops"] - probe[key1]["flops"]
            db = probe[key2]["bytes"] - probe[key1]["bytes"]
            return (probe[key1]["flops"] + (ng - 1) * max(d, 0.0),
                    probe[key1]["bytes"] + (ng - 1) * max(db, 0.0))

        f16, b16 = total("ng1", "ng2")
        if "ng1b32" in probe and "ng2b32" in probe and b0 == 16:
            # affine in batch: weights are batch-constant, activations
            # batch-linear — two batch points separate the components
            f32_, b32_ = total("ng1b32", "ng2b32")
            B = bs * b0
            flops = f16 + (f32_ - f16) * (B - 16) / 16.0
            bts = b16 + (b32_ - b16) * (B - 16) / 16.0
        else:
            flops = f16 * bs
            bts = b16 * bs
        if flops <= 0:
            flops = rec["cost"]["flops"]
        if bts <= 0:
            bts = rec["cost"]["bytes"]
    return {"flops": flops, "bytes": bts}


def cell_row(dirpath: str, arch: str, shape_name: str) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = _load(dirpath, arch, shape_name)
    if rec is None:
        return {"arch": arch, "shape": shape_name, "missing": True}
    if "skipped" in rec:
        return {"arch": arch, "shape": shape_name,
                "skipped": rec["skipped"]}
    if "error" in rec:
        return {"arch": arch, "shape": shape_name,
                "error": rec["error"][:120]}
    cost = corrected_cost(rec, cfg)
    coll = rec.get("collective_bytes_dev", 0.0)
    mf = model_flops(cfg, shape, per_device=True, n_chips=N_CHIPS)
    # inner lax.scans (two-pass attention chunks, SSD recurrence) are
    # cost-counted once; when the analytic MODEL_FLOPS exceeds the
    # (layer-corrected) HLO count, the compute term uses the analytic
    # value and the row is flagged.
    flops_eff = max(cost["flops"], mf)
    terms = roofline_terms(flops_eff, cost["bytes"], coll,
                           int8_compute=shape.is_serve)
    row = {
        "arch": arch, "shape": shape_name,
        "flops_dev": cost["flops"], "bytes_dev": cost["bytes"],
        "coll_dev": coll,
        "peak_gib": rec["memory"]["peak_gib"],
        "model_flops_dev": mf,
        "flops_src": "hlo" if cost["flops"] >= mf else "analytic",
        "useful_ratio": mf / cost["flops"] if cost["flops"] else 0.0,
        **terms,
    }
    return row


def full_table(dirpath: str = "experiments/dryrun") -> List[Dict]:
    return [cell_row(dirpath, a, s) for a in ASSIGNED for s in SHAPES]


def render_markdown(rows: List[Dict]) -> str:
    out = ["| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | bound | "
           "HBM GiB | MODEL/HLO | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| — | SKIP: {r['skipped'][:60]} |")
            continue
        if r.get("error") or r.get("missing"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| — | {r.get('error', 'missing')} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['bottleneck']} | {r['peak_gib']:.1f} | "
            f"{r['useful_ratio']:.2f} | |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render_markdown(full_table()))
