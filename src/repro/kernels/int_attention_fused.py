"""Pallas TPU kernel: fused integer attention + requant, bit-exact.

One kernel launch computes the whole SwiftTron attention datapath
(§III-D/E, Figs. 8-10): int8 Q·Kᵀ → dyadic-scaled integer softmax (the
``core.softmax`` Shiftmax numerics) → int8 P·V → requant epilogue —
streaming over KV blocks with int32 accumulators, so the O(Sq·Skv) score
matrix never exists in HBM.

Relation to ``int_attention.py`` (the ``pallas`` backend's kernel): that
kernel keeps a one-pass *online* softmax whose running rescales round
(±LSB vs the oracle).  This kernel instead makes **three streaming
sweeps** over the KV blocks per query block and is *bit-exact* against
the two-pass reference (``kernels.ref.ref_int_attention``):

  sweep 0  row max        m = max_k(scores)          (int32 compare — exact)
  sweep 1  row sum        s = Σ_k e16(scores - m)    (int32 add — exact)
  sweep 2  normalise+AV   p8 = ⌊e16·(2³⁰//s) + h⌋»23; acc += p8·v8 (MXU)

Each sweep recomputes the int8 Q·Kᵀ block product instead of storing it —
the FlashAttention recompute-over-store trade, paid twice more here to
buy exactness (integer maxima and sums are associative; the online
rescale of ``int_attention.py`` is not).

Epilogue: the int32 accumulator (scale ``2⁻⁷·s_v``) takes any of the
three :class:`repro.ops.RequantSpec` forms —

  * per-tensor  — ``clip(rshift_round(rshift_round(acc, pre)·b, c-pre))``
  * per-channel — same staging with an int32 multiplier vector over the
    flattened (head, head_dim) output channels
  * raw         — the int32 accumulator is written untouched

Bit budgets (mirroring ``core.softmax``): row sums need Skv ≤ 2¹⁵ so
``Σ e16 ≤ 2³⁰`` stays int32-exact; the P·V accumulator is bounded by
``(2⁷ + Skv/2)·127`` (normalised probabilities + rounding), int32-safe at
every supported length.  The wrapper asserts the sum budget; backends
fall back to the two-pass path beyond it (see
``ops.backends.pallas_fused``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.budgets import MAX_ROWSUM_LEN
from repro.analysis.contracts import check_launch, require_launch
from repro.core.attention import IAttnPlan
from repro.core.softmax import PROB_SHIFT, RECIP_BITS
from repro.kernels.int_softmax import _exp16_tile, _rshift_round
from repro.ops.spec import PER_CHANNEL, PER_TENSOR, RequantSpec

NEG = -(2 ** 30)

# the row-sum budget is owned by repro.analysis.budgets (one source of
# truth shared with the decode kernel and the tiling policy)
MAX_SKV = MAX_ROWSUM_LEN


def _streaming_attn_body(phase, kv_step, n_kv, q8, k8, v8, live, blk_live,
                         o_ref, m_ref, s_ref, acc_ref, b_ref, *,
                         plan: IAttnPlan, requant: RequantSpec):
    """The shared three-sweep streaming datapath + requant epilogue.

    Everything downstream of mask construction is identical between the
    prefill kernel and the decode kernel (``int_decode_attention.py``)
    — only ``live`` (element mask) and ``blk_live`` (whole-block skip
    predicate) differ, so both kernels delegate here and a numerics
    change lands in exactly one place.
    """
    @pl.when((phase == 0) & (kv_step == 0))
    def _init_max():
        m_ref[...] = jnp.full_like(m_ref, NEG)

    @pl.when((phase == 1) & (kv_step == 0))
    def _init_sum():
        s_ref[...] = jnp.zeros_like(s_ref)

    @pl.when((phase == 2) & (kv_step == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _scores():
        s = jax.lax.dot_general(q8, k8, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.int32)
        return jnp.where(live, s, jnp.int32(NEG))

    def _e16():
        e16 = _exp16_tile(_scores() - m_ref[...], plan.sm)
        return jnp.where(live, e16, 0)

    @pl.when((phase == 0) & blk_live)
    def _sweep_max():
        m_ref[...] = jnp.maximum(m_ref[...],
                                 jnp.max(_scores(), axis=-1, keepdims=True))

    @pl.when((phase == 1) & blk_live)
    def _sweep_sum():
        s_ref[...] = s_ref[...] + jnp.sum(_e16(), axis=-1, keepdims=True)

    @pl.when((phase == 2) & blk_live)
    def _sweep_av():
        r = jnp.int32(1 << RECIP_BITS) // jnp.maximum(s_ref[...], 1)
        p = _rshift_round(_e16() * r, RECIP_BITS - PROB_SHIFT)
        p8 = jnp.clip(p, 0, 127).astype(jnp.int8)
        acc_ref[...] = acc_ref[...] + jax.lax.dot_general(
            p8, v8, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when((phase == 2) & (kv_step == n_kv - 1))
    def _epilogue():
        acc = acc_ref[...]                      # int32 at 2^-7 * s_v
        if requant.is_raw:
            o_ref[0, :, 0, :] = acc
            return
        b_row = None if b_ref is None \
            else b_ref[0, :].astype(jnp.int32)[None, :]
        o_ref[0, :, 0, :] = _requant_tile(acc, requant,
                                          b_row).astype(o_ref.dtype)


def _requant_tile(acc, requant: RequantSpec, b_row=None):
    """The in-kernel requant epilogue on an int32 tile: the exact
    two-stage rounding of docs/KERNELS.md for the per-tensor and
    per-channel forms (``b_row``: int32 ``(1, N)`` multipliers, required
    iff per-channel).  Shared by the prefill/decode epilogues and the
    decode kernel's folded wo projection, so the rounding exists once."""
    if requant.is_raw:
        return acc
    lo = -(1 << (requant.out_bits - 1))
    hi = (1 << (requant.out_bits - 1)) - 1
    if requant.kind == PER_TENSOR:
        dn = requant.dn
        out = _rshift_round(_rshift_round(acc, dn.pre) * jnp.int32(dn.b),
                            dn.c - dn.pre)
    else:                                       # per-channel over N
        out = _rshift_round(_rshift_round(acc, requant.pre) * b_row,
                            requant.c - requant.pre)
    return jnp.clip(out, lo, hi)


def _unpack_kv_tile(p8, shift):
    """In-register int4 KV expansion of a ``(rows, d // 2)`` packed tile
    to ``(rows, d)`` int8: low nibble = even head-dim lane, high = odd,
    then a per-page requant left-shift.  All arithmetic in int32 with
    explicit sign extension — bit-exact twin of
    ``repro.ops.packed.unpack_kv_pool`` on the gathered layout.  The
    shifted magnitudes stay ≤ 7·2⁴ = 112, int8-safe by construction."""
    rows, half = p8.shape
    p32 = p8.astype(jnp.int32)
    lo = ((p32 & 15) ^ 8) - 8
    hi = (((p32 >> 4) & 15) ^ 8) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(rows, 2 * half)
    return (q << shift).astype(jnp.int8)


def _epilogue_setup(requant, plan: IAttnPlan, out_bits: int, b_vec,
                    h: int, d: int):
    """Shared wrapper-side epilogue policy (prefill and decode kernels):
    default requant, per-channel b_vec validation + (h, d) reshape, and
    the output container rule.  Returns (requant, has_bvec, b2,
    out_dtype)."""
    if requant is None:
        requant = RequantSpec.per_tensor(plan.dn_out, out_bits)
    has_bvec = requant.kind == PER_CHANNEL
    b2 = None
    if has_bvec:
        if b_vec is None:
            raise ValueError("per-channel RequantSpec needs the b_vec "
                             "multiplier vector")
        b2 = jnp.asarray(b_vec, jnp.int32).reshape(h, d)
    out_dtype = jnp.int8 if (not requant.is_raw
                             and requant.out_bits <= 8) else jnp.int32
    return requant, has_bvec, b2, out_dtype


def _fused_kernel(q_ref, k_ref, v_ref, *rest, plan: IAttnPlan,
                  requant: RequantSpec, has_bvec: bool, n_kv: int,
                  bq: int, bkv: int, causal: bool, window: int):
    if has_bvec:
        b_ref, o_ref, m_ref, s_ref, acc_ref = rest
    else:
        b_ref = None
        o_ref, m_ref, s_ref, acc_ref = rest
    q_blk = pl.program_id(2)
    phase = pl.program_id(3)
    kv_step = pl.program_id(4)

    q8 = q_ref[0, :, 0, :]                      # (bq, d) int8
    k8 = k_ref[0, :, 0, :]                      # (bkv, d) int8
    v8 = v_ref[0, :, 0, :]

    qi = q_blk * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    ki = kv_step * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    live = jnp.ones((bq, bkv), jnp.bool_)
    if causal or window > 0:
        # mirror core.attention.causal_mask: a window implies causality
        live = live & (ki <= qi)
    if window > 0:
        live = live & (ki > qi - window)

    # upper-triangle blocks contribute NEG to the max and 0 to the sum
    # and the accumulator — skip them entirely under a causal mask
    if causal or window > 0:
        blk_live = kv_step * bkv <= q_blk * bq + bq - 1
    else:
        blk_live = True

    _streaming_attn_body(phase, kv_step, n_kv, q8, k8, v8, live, blk_live,
                         o_ref, m_ref, s_ref, acc_ref, b_ref,
                         plan=plan, requant=requant)


def int_attention_fused(q8, k8, v8, plan: IAttnPlan, requant=None,
                        b_vec=None, causal: bool = True, window: int = 0,
                        bq: int = 128, bkv: int = 128, out_bits: int = 8,
                        interpret: bool = True):
    """q8: (B, Sq, H, D) int8; k8/v8: (B, Skv, Hkv, D) int8 (GQA: Hkv | H).

    ``requant``: a :class:`RequantSpec` for the epilogue (default: the
    plan's per-tensor ``dn_out``); ``b_vec``: int32 per-channel
    multipliers, shape (H*D,) or (H, D), required iff per-channel.

    Returns (B, Sq, H, D): int8 when the epilogue clips to ≤ 8 bits,
    int32 otherwise (raw / wide output).  Bit-exact against
    ``kernels.ref.ref_int_attention`` for the same arguments.

    Under tensor-parallel serving (``distributed.tp_serving``) the
    wrapper runs inside a shard_map body on head-sliced operands, so
    ``require_launch`` validates the local (H/tp, Hkv/tp) launch;
    ``analysis.contracts.check_tp_launch`` is its offline twin.
    """
    b, sq, h, d = q8.shape
    _, skv, hkv, _ = k8.shape
    require_launch(check_launch(
        "int_attention", b=b, sq=sq, skv=skv, h=h, hkv=hkv, d=d,
        bq=bq, bkv=bkv, out_bits=out_bits))
    group = h // hkv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    n_kv = skv // bkv

    requant, has_bvec, b2, out_dtype = _epilogue_setup(
        requant, plan, out_bits, b_vec, h, d)

    kernel = functools.partial(
        _fused_kernel, plan=plan, requant=requant, has_bvec=has_bvec,
        n_kv=n_kv, bq=bq, bkv=bkv, causal=causal, window=window)

    in_specs = [
        pl.BlockSpec((1, bq, 1, d),
                     lambda bi, hi, qi, ph, ki: (bi, qi, hi, 0)),
        pl.BlockSpec((1, bkv, 1, d),
                     lambda bi, hi, qi, ph, ki: (bi, ki, hi // group, 0)),
        pl.BlockSpec((1, bkv, 1, d),
                     lambda bi, hi, qi, ph, ki: (bi, ki, hi // group, 0)),
    ]
    args = [q8, k8, v8]
    if has_bvec:
        in_specs.append(
            pl.BlockSpec((1, d), lambda bi, hi, qi, ph, ki: (hi, 0)))
        args.append(b2)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq, 3, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda bi, hi, qi, ph, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, 1), jnp.int32),
                        pltpu.VMEM((bq, d), jnp.int32)],
        interpret=interpret,
    )(*args)


# ===================================================== paged prefill =======
#
# The chunked-prefill variant of the kernel above: C chunk queries per
# slot (the serving engine's prompt chunk) against a *paged* KV cache —
# history plus the chunk itself, already scattered into the physical
# pools through the page table (``repro.ops.paged.scatter_chunk``).
# Two scalar-prefetch operands steer the launch, exactly as in the
# decode kernel (``int_decode_attention.py``):
#
#   pos_end : int32 (B,)          = base_pos + C, the logical occupancy
#                                   after the chunk (the decode kernel's
#                                   ``valid_len``);
#   pages   : int32 (B, max_pages) logical block -> physical page.
#
# Masking is the decode kernel's stepped occupancy mask with Sq = C:
# chunk row ``i`` (global position ``pos_end - C + i``) sees cache
# positions ``< pos_end - C + i + 1`` — which *is* causal attention over
# history + chunk.  Unlike the decode kernel (Sq <= 8 rows in scratch for
# the whole launch) the chunk is tiled over query blocks like prefill,
# so C is bounded by VMEM tiling only, not by MAX_SQ.
#
# The folded wo projection (``wo_w8=``) mirrors the decode kernel's:
# query blocks sit *outside* the head grid dimension so the per-q-block
# ``(bq, N)`` VMEM accumulator sums that block's o-projection across the
# heads before the last head applies bias + the wo RequantSpec.


def _paged_prefill_kernel(*refs, plan: IAttnPlan, requant: RequantSpec,
                          has_bvec: bool, n_kv: int, c: int, bq: int,
                          bkv: int, fold: bool, wo_spec,
                          wo_has_bias: bool, wo_has_bvec: bool,
                          n_heads: int, packed_kv: bool = False,
                          sub: int = 1):
    refs = list(refs)
    vl_ref = refs.pop(0)
    # page table: read by index maps only — except under packed KV,
    # where the body re-derives the physical page for the shift lookup
    pt_ref = refs.pop(0)
    ks_ref = vs_ref = None
    if packed_kv:
        ks_ref, vs_ref = refs.pop(0), refs.pop(0)
    q_ref, k_ref, v_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    b_ref = refs.pop(0) if has_bvec else None
    wo_ref = wob_ref = wobv_ref = None
    if fold:
        wo_ref = refs.pop(0)
        if wo_has_bias:
            wob_ref = refs.pop(0)
        if wo_has_bvec:
            wobv_ref = refs.pop(0)
    o_ref = refs.pop(0)
    m_ref, s_ref, acc_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    attn_out = refs.pop(0) if fold else o_ref
    wacc_ref = refs.pop(0) if fold else None

    bi = pl.program_id(0)
    q_blk = pl.program_id(1)
    head = pl.program_id(2)
    phase = pl.program_id(3)
    kv_step = pl.program_id(4)
    vl = vl_ref[bi]
    base = vl - c                       # chunk's first global position

    q8 = q_ref[0, :, 0, :]              # (bq, d) int8
    if packed_kv:
        # re-derive the physical page exactly as the KV index map did
        # (same dead-block clamp) and dequantize the nibble tile with
        # that page's requant shift, in-register
        last = jnp.maximum(pl.cdiv(vl, bkv) - 1, 0)
        kc = jnp.minimum(kv_step, last)
        page = pt_ref[bi, kc // sub]
        k8 = _unpack_kv_tile(k_ref[0, :, 0, :], ks_ref[page])
        v8 = _unpack_kv_tile(v_ref[0, :, 0, :], vs_ref[page])
    else:
        k8 = k_ref[0, :, 0, :]          # (bkv, d) int8
        v8 = v_ref[0, :, 0, :]

    # causal-over-history mask: chunk row i at global position base +
    # q_blk*bq + i sees logical cache positions <= its own.  ki is the
    # *logical* position — the index map already translated the block
    # through the page table, the mask math is unchanged.
    qpos = base + q_blk * bq \
        + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    ki = kv_step * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    live = ki <= qpos

    # a KV block whose first position is past this query block's last
    # row is entirely dead (upper triangle / beyond occupancy: qpos is
    # always <= vl - 1, so the causal bound subsumes the vl bound)
    blk_live = kv_step * bkv <= base + q_blk * bq + bq - 1

    _streaming_attn_body(phase, kv_step, n_kv, q8, k8, v8, live, blk_live,
                         attn_out, m_ref, s_ref, acc_ref, b_ref,
                         plan=plan, requant=requant)

    if fold:
        @pl.when((phase == 2) & (kv_step == n_kv - 1))
        def _wo_accumulate():
            o8 = attn_out[0, :, 0, :]
            part = jax.lax.dot_general(o8, wo_ref[...],
                                       (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.int32)
            prev = jnp.where(head == 0, jnp.zeros_like(part),
                             wacc_ref[...])
            wacc_ref[...] = prev + part

        @pl.when((phase == 2) & (kv_step == n_kv - 1)
                 & (head == n_heads - 1))
        def _wo_epilogue():
            acc = wacc_ref[...]
            if wo_has_bias:
                acc = acc + wob_ref[0, :][None, :]
            b_row = None if wobv_ref is None \
                else wobv_ref[0, :].astype(jnp.int32)[None, :]
            o_ref[0, :, :] = _requant_tile(acc, wo_spec,
                                           b_row).astype(o_ref.dtype)


def int_paged_prefill_fused(q8, k_pool, v_pool, plan: IAttnPlan, pos_end,
                            pages, page_size: int, requant=None,
                            b_vec=None, bq: int = 128, bkv: int = 128,
                            out_bits: int = 8, interpret: bool = True,
                            wo_w8=None, wo_bias32=None, wo_b_vec=None,
                            wo_spec=None, kv_shifts=None):
    """q8: (B, C, H, D) int8 chunk queries; k_pool/v_pool: physical
    ``(num_pages, page_size, Hkv, D)`` int8 pools *already containing
    the chunk's K/V* (``repro.ops.paged.scatter_chunk``); ``pos_end``:
    (B,) int32 logical occupancy after the chunk (``base_pos + C``);
    ``pages``: int32 (B, max_pages) page table.

    ``kv_shifts``: a ``(k_shift, v_shift)`` pair of int32
    ``(num_pages,)`` per-page requant shifts switches the pools to the
    **packed int4** layout ``(num_pages, page_size, Hkv, D // 2)`` —
    two head-dim nibbles per byte, expanded and left-shifted in-register
    (``_unpack_kv_tile``); packed pages never materialize as int8 in
    HBM.  The shifts ride as two extra scalar-prefetch operands.

    ``requant``/``b_vec``: the attention epilogue, exactly as
    :func:`int_attention_fused`.  ``wo_w8`` (+ ``wo_bias32`` /
    ``wo_b_vec`` / ``wo_spec``): fold the o-projection into the launch,
    exactly as the decode kernel — the attention epilogue must clip to
    ≤ 8 bits, and the return becomes ``(B, C, N)``.

    Returns (B, C, H, D) — or (B, C, N) folded.  Bit-exact against
    ``kernels.ref.ref_int_paged_prefill``'s attention output for the
    same (post-scatter) pools.

    Under tensor-parallel serving the pools arrive head-sliced (each
    device owns Hkv/tp heads of every page — page *ids* are global), so
    ``require_launch`` validates the local launch;
    ``analysis.contracts.check_tp_launch`` is its offline twin.
    """
    b, c, h, d = q8.shape
    ps, hkv = k_pool.shape[1], k_pool.shape[2]
    assert page_size == ps, (page_size, ps)
    pages = jnp.asarray(pages, jnp.int32)
    assert pages.ndim == 2 and pages.shape[0] == b, pages.shape
    L = pages.shape[1] * ps
    packed_kv = kv_shifts is not None
    num_pages = k_pool.shape[0]
    if packed_kv:
        assert k_pool.shape[3] == d // 2, (k_pool.shape, d)
        k_shift = jnp.asarray(kv_shifts[0], jnp.int32)
        v_shift = jnp.asarray(kv_shifts[1], jnp.int32)
        assert k_shift.shape == v_shift.shape == (num_pages,), \
            (k_shift.shape, v_shift.shape, num_pages)
    require_launch(check_launch(
        "int_paged_prefill", b=b, c=c, h=h, hkv=hkv, d=d,
        max_pages=pages.shape[1], page_size=ps, bq=bq, bkv=bkv,
        out_bits=out_bits, kv_pack=packed_kv, num_pages=num_pages))
    group = h // hkv
    bq = min(bq, c)
    bkv = min(bkv, ps)
    sub = ps // bkv                     # KV sub-blocks per physical page
    n_kv = L // bkv
    pos_end = jnp.asarray(pos_end, jnp.int32)

    requant, has_bvec, b2, out_dtype = _epilogue_setup(
        requant, plan, out_bits, b_vec, h, d)

    fold = wo_w8 is not None
    wo_has_bias = wo_has_bvec = False
    if fold:
        assert wo_spec is not None, "folded wo projection needs wo_spec"
        assert not requant.is_raw and requant.out_bits <= 8, \
            "wo folding needs an int8 attention epilogue"
        wo_w8 = jnp.asarray(wo_w8)
        n_out = wo_w8.shape[-1]
        assert wo_w8.shape == (h * d, n_out), (wo_w8.shape, h, d)
        wo_has_bias = wo_bias32 is not None
        wo_has_bvec = wo_spec.kind == PER_CHANNEL
        if wo_has_bvec and wo_b_vec is None:
            raise ValueError("per-channel wo_spec needs the wo_b_vec "
                             "multiplier vector")
        out_dtype = jnp.int8 if (not wo_spec.is_raw
                                 and wo_spec.out_bits <= 8) else jnp.int32

    kernel = functools.partial(
        _paged_prefill_kernel, plan=plan, requant=requant,
        has_bvec=has_bvec, n_kv=n_kv, c=c, bq=bq, bkv=bkv,
        fold=fold, wo_spec=wo_spec, wo_has_bias=wo_has_bias,
        wo_has_bvec=wo_has_bvec, n_heads=h, packed_kv=packed_kv,
        sub=sub)

    def _kv_block(ki, vl):
        # clamp dead blocks to the slot's last live one before table
        # translation, exactly as the decode kernel (unmapped entries
        # hold the resident null page anyway; the clamp keeps the DMA
        # on this lane's own pages)
        last = jnp.maximum(pl.cdiv(vl, bkv) - 1, 0)
        return jnp.minimum(ki, last)

    # index maps: grid is (b, q_blk, head, phase, kv) — query blocks sit
    # OUTSIDE the head dim so the folded-wo accumulator for one query
    # block sweeps all heads consecutively (decode kernel: Sq <= 8 in
    # scratch needs no q dim at all); scalar-prefetch refs (pos_end,
    # pages[, k_shift, v_shift]) arrive as trailing args (``*_`` absorbs
    # the shift refs under the packed layout).
    def q_map(bi, qi, hi, ph, ki, vl, pt, *_):
        return (bi, qi, hi, 0)

    def kv_map(bi, qi, hi, ph, ki, vl, pt, *_):
        kc = _kv_block(ki, vl[bi])
        return (pt[bi, kc // sub], kc % sub, hi // group, 0)

    def head_row_map(bi, qi, hi, ph, ki, vl, pt, *_):
        return (hi, 0)

    def one_row_map(bi, qi, hi, ph, ki, vl, pt, *_):
        return (0, 0)

    def out_map(bi, qi, hi, ph, ki, vl, pt, *_):
        return (bi, qi, 0) if fold else (bi, qi, hi, 0)

    kv_blk = (1, bkv, 1, d // 2 if packed_kv else d)
    in_specs = [
        pl.BlockSpec((1, bq, 1, d), q_map),
        pl.BlockSpec(kv_blk, kv_map),
        pl.BlockSpec(kv_blk, kv_map),
    ]
    args = [q8, k_pool, v_pool]
    if has_bvec:
        in_specs.append(pl.BlockSpec((1, d), head_row_map))
        args.append(b2)
    if fold:
        in_specs.append(pl.BlockSpec((d, n_out), head_row_map))
        args.append(wo_w8)
        if wo_has_bias:
            in_specs.append(pl.BlockSpec((1, n_out), one_row_map))
            args.append(jnp.asarray(wo_bias32, jnp.int32).reshape(1, n_out))
        if wo_has_bvec:
            in_specs.append(pl.BlockSpec((1, n_out), one_row_map))
            args.append(jnp.asarray(wo_b_vec, jnp.int32).reshape(1, n_out))

    from jax.experimental.pallas import tpu as pltpu
    scratch = [pltpu.VMEM((bq, 1), jnp.int32),
               pltpu.VMEM((bq, 1), jnp.int32),
               pltpu.VMEM((bq, d), jnp.int32)]
    if fold:
        # per-head attention tile (int8: asserted above) + the (bq, N)
        # o-projection accumulator carried across the head grid dim
        scratch += [pltpu.VMEM((1, bq, 1, d), jnp.int8),
                    pltpu.VMEM((bq, n_out), jnp.int32)]
        out_specs = pl.BlockSpec((1, bq, n_out), out_map)
        out_shape = jax.ShapeDtypeStruct((b, c, n_out), out_dtype)
    else:
        out_specs = pl.BlockSpec((1, bq, 1, d), out_map)
        out_shape = jax.ShapeDtypeStruct((b, c, h, d), out_dtype)

    scalar_args = (pos_end, pages, k_shift, v_shift) if packed_kv \
        else (pos_end, pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(b, c // bq, h, 3, n_kv),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*scalar_args, *args)
