"""Built-in backend implementations (registered by ``repro.ops``)."""
from repro.ops.backends.ref import RefBackend
from repro.ops.backends.pallas import PallasBackend
from repro.ops.backends.pallas_fused import PallasFusedBackend

__all__ = ["RefBackend", "PallasBackend", "PallasFusedBackend"]
