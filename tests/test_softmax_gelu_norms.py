"""Integer softmax / activations / norms vs float oracles."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import activations as act
from repro.core import norms
from repro.core import softmax as ism


def test_isoftmax_close_to_float(rng):
    sp = ism.make_isoftmax(s_score=0.01, qmax_score=2**21)
    logits = rng.normal(0, 3, (16, 64)) / 0.01
    q = jnp.asarray(np.round(logits).astype(np.int32))
    p = np.asarray(ism.i_softmax(q, sp)) * ism.S_PROB
    x = logits * 0.01
    ref = np.exp(x - x.max(-1, keepdims=True))
    ref /= ref.sum(-1, keepdims=True)
    assert np.abs(p - ref).max() < 8e-3            # int8 prob granularity
    # int8 prob rows under-sum by the truncated tail mass (paper-faithful)
    assert abs(p.sum(-1).mean() - 1.0) < 0.05


def test_isoftmax_masking(rng):
    sp = ism.make_isoftmax(s_score=0.01, qmax_score=2**21)
    q = jnp.asarray(rng.integers(-1000, 1000, (4, 32)), jnp.int32)
    mask = jnp.asarray(rng.random((4, 32)) > 0.5)
    p = np.asarray(ism.i_softmax(q, sp, where=mask))
    assert (p[~np.asarray(mask)] == 0).all()


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=2, max_value=256))
def test_isoftmax_rowsum_bounded(rowlen):
    """Rows up to the 256-element int8 representability limit sum to ~1
    (beyond that see test_isoftmax_uniform_row_limitation)."""
    rng = np.random.default_rng(rowlen)
    sp = ism.make_isoftmax(s_score=3.5e-4, qmax_score=128 * 127 * 127)
    q = jnp.asarray(rng.integers(-60000, 60000, (2, rowlen)), jnp.int32)
    p = np.asarray(ism.i_softmax(q, sp)).astype(np.int64)
    s = p.sum(-1) * ism.S_PROB
    assert (np.abs(s - 1.0) < 0.07).all()


def test_isoftmax_uniform_row_limitation():
    """Documented int8 limitation (paper-faithful INT8 probs): a near-
    uniform row longer than ~256 cannot be represented — every probability
    rounds to zero.  Real attention rows are peaked; the e16-domain sums
    used inside the fused attention kernel keep normalisation correct."""
    sp = ism.make_isoftmax(s_score=3.5e-4, qmax_score=128 * 127 * 127)
    q = jnp.zeros((1, 512), jnp.int32)
    p = np.asarray(ism.i_softmax(q, sp))
    assert p.max() == 0


def test_inorm_layernorm(rng):
    d, s_in = 768, 8 / 1024
    plan = norms.make_inorm(d, s_in, 1024, 2 / 127, 8 / 127)
    gamma = rng.normal(1, 0.2, d).astype(np.float32)
    beta = rng.normal(0, 0.2, d).astype(np.float32)
    qg, qb = norms.quantize_norm_weights(jnp.asarray(gamma),
                                         jnp.asarray(beta), plan)
    x = rng.normal(0, 2, (16, d)).astype(np.float32)
    q = np.clip(np.round(x / s_in), -1024, 1024).astype(np.int32)
    xc = q * s_in
    got = np.asarray(norms.i_norm(jnp.asarray(q), qg, qb, plan)) \
        * plan.s_out
    mu = xc.mean(-1, keepdims=True)
    sd = xc.std(-1, keepdims=True)
    ref = (xc - mu) / sd * gamma + beta
    assert np.abs(got - ref).max() < 0.1


def test_inorm_rmsnorm(rng):
    d, s_in = 512, 8 / 1024
    plan = norms.make_inorm(d, s_in, 1024, 2 / 127, 8 / 127,
                            subtract_mean=False)
    gamma = rng.normal(1, 0.2, d).astype(np.float32)
    qg, _ = norms.quantize_norm_weights(jnp.asarray(gamma), None, plan)
    x = rng.normal(0, 2, (8, d)).astype(np.float32)
    q = np.clip(np.round(x / s_in), -1024, 1024).astype(np.int32)
    xc = q * s_in
    got = np.asarray(norms.i_norm(jnp.asarray(q), qg, None, plan)) \
        * plan.s_out
    ref = xc / np.sqrt((xc ** 2).mean(-1, keepdims=True)) * gamma
    assert np.abs(got - ref).max() < 0.1


def test_inorm_constant_row():
    d, s_in = 64, 8 / 1024
    plan = norms.make_inorm(d, s_in, 1024, 2 / 127, 8 / 127)
    qg, qb = norms.quantize_norm_weights(jnp.ones(d), jnp.zeros(d), plan)
    q = jnp.full((2, d), 37, jnp.int32)
    got = np.asarray(norms.i_norm(q, qg, qb, plan))
    assert np.abs(got).max() == 0                   # zero variance -> 0


def test_isilu(rng):
    s = 16 / 1024
    plan = act.make_isilu(s, 1024, s_out=8 / 127)
    x = np.linspace(-8, 8, 2001)
    q = np.round(x / s).astype(np.int32)
    got = np.asarray(act.i_silu(jnp.asarray(q), plan)) * (8 / 127)
    ref = x / (1 + np.exp(-x))
    assert np.abs(got - ref).max() < 6e-2


def test_isoftplus(rng):
    s = 16 / 1024
    plan = act.make_isoftplus(s, 1024, s_out=16 / 2**13)
    x = np.linspace(-10, 10, 2001)
    q = np.round(x / s).astype(np.int32)
    got = np.asarray(act.i_softplus(jnp.asarray(q), plan)) * plan.s_out
    ref = np.log1p(np.exp(x))
    assert np.abs(got - ref).max() < 4e-2


def test_igelu_act(rng):
    s = 16 / 1024
    plan = act.make_igelu_act(s, 1024, s_out=8 / 127)
    import math
    x = np.linspace(-8, 8, 2001)
    q = np.round(x / s).astype(np.int32)
    got = np.asarray(act.i_gelu_act(jnp.asarray(q), plan)) * (8 / 127)
    erf = np.vectorize(math.erf)
    ref = 0.5 * x * (1 + erf(x / np.sqrt(2)))
    assert np.abs(got - ref).max() < 7e-2
