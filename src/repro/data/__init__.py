from repro.data.pipeline import (SyntheticLMDataset, TokenFileDataset,
                                 make_train_iterator)

__all__ = ["SyntheticLMDataset", "TokenFileDataset", "make_train_iterator"]
