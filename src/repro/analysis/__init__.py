"""Static analysis for the quantized datapath (the design-time proof layer).

Three tools, one goal — *prove* properties before anything runs:

  * :mod:`repro.analysis.budgets`    — the single home of the repo's bit
    budgets (``INT32_MAX``, ``MAX_ROWSUM_LEN``, ``MAX_SQ``) and the typed
    :class:`BitBudgetError`;
  * :mod:`repro.analysis.ranges`     — the :class:`IntRange` abstract
    domain + sound transfer functions for the integer primitives
    (dyadic requant, matmul accumulation, Shiftmax, i-GELU, i-norm);
  * :mod:`repro.analysis.interpret`  — per-op certification walking a
    whole model config layer-by-layer (the seven ``repro.ops`` ops);
  * :mod:`repro.analysis.contracts`  — :func:`check_launch`, the
    offline Pallas kernel-contract checker (tile divisibility, budget,
    scalar-prefetch shapes, VMEM footprint) and the fused-vs-fallback
    tiling policy the backends consult;
  * :mod:`repro.analysis.lint`       — the AST repo-rule linter
    (``python -m repro.analysis.lint``);
  * :mod:`repro.analysis.certify`    — the CLI sweeping every registry
    config into ``benchmarks/CERTIFY.json``
    (``python -m repro.analysis.certify``).

See docs/ANALYSIS.md for the abstract-domain contract.
"""
from repro.analysis.budgets import (BitBudgetError, INT32_MAX,
                                    MAX_ROWSUM_LEN, MAX_SQ, static_check)
from repro.analysis.contracts import (KernelContractError, LaunchReport,
                                      can_tile, can_tile_decode,
                                      can_tile_prefill, check_launch,
                                      require_launch)
from repro.analysis.ranges import IntRange
